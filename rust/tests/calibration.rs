//! Accelerator-calibration regression against the paper's reported scores.
//!
//! The paper's scalability table reports 56.1 Tera-OPS for the 32-T4
//! system and 194.53 Peta-OPS for the 4096-Ascend-910 system. The named
//! device models ([`aiperf::cluster::GpuModel::t4`] / `ascend910`) are
//! calibrated so the *simulated* benchmark reproduces those numbers; this
//! suite pins each preset's stable-window score inside a ±20 % band so a
//! drive-by change to the throughput model, the timing composition, or
//! the search loop cannot silently drift the headline metric.
//!
//! The score is a rate (analytical ops / wall time) that stabilizes once
//! the first trials are underway (Ascend epochs are ~80 modelled seconds,
//! so its nodes are into round 3 within the first two modelled hours), so
//! the Ascend run is shortened from the preset's 12 modelled hours to 2
//! to keep the 512-shard simulation affordable in CI's debug-built test
//! step; the T4 preset (4 nodes, ~20 long epochs) is cheap enough to run
//! at full length.

use aiperf::coordinator::run_benchmark;
use aiperf::scenarios;

fn assert_in_band(score: f64, paper: f64, label: &str) {
    let (lo, hi) = (0.8 * paper, 1.2 * paper);
    assert!(
        (lo..=hi).contains(&score),
        "{label}: simulated score {score:.4e} outside ±20% of paper {paper:.4e} \
         (band [{lo:.4e}, {hi:.4e}])"
    );
}

#[test]
fn t4_32_score_within_band_of_56_1_tera_ops() {
    let p = scenarios::get("t4-32").expect("t4-32 preset");
    let r = run_benchmark(&p.config);
    assert_in_band(r.score_flops, 56.1e12, "t4-32");
    // The whole cluster is one T4 group; its attributed rate must carry
    // essentially the entire score.
    assert_eq!(r.groups.len(), 1);
    assert!(r.groups[0].ops > 0.0);
}

#[test]
fn ascend_4096_score_within_band_of_194_53_peta_ops() {
    let mut cfg = scenarios::get("ascend-4096").expect("ascend preset").config;
    cfg.duration_s = 2.0 * 3600.0;
    let r = run_benchmark(&cfg);
    assert_in_band(r.score_flops, 194.53e15, "ascend-4096");
    assert_eq!(r.nodes, 512);
    assert_eq!(r.total_gpus, 4096);
}

#[test]
fn t4_32_band_holds_with_subshards_and_stealing() {
    // The sub-shard refactor must not drift the calibrated headline
    // score: two half-width lanes per node train the same images/s in
    // aggregate (each lane runs the full dataset per epoch over half the
    // devices), and the steal scheduler only re-times work the classic
    // layout would have wasted at the deadline.
    let mut cfg = scenarios::get("t4-32").expect("t4-32 preset").config;
    cfg.subshards_per_node = 2;
    cfg.work_stealing = true;
    cfg.validate().expect("subshards divide gpus_per_node");
    let r = run_benchmark(&cfg);
    assert_in_band(r.score_flops, 56.1e12, "t4-32 subshards");
    assert_eq!(r.groups.len(), 1);
    assert!(
        r.groups[0].barrier_slack_s >= 0.0,
        "slack metric must be reported"
    );
}

#[test]
fn per_device_throughput_ordering_matches_paper() {
    // Paper Table 1 ordering at the per-device level:
    // T4 (~1.75 T/device) < V100 (~14 T/device) < Ascend (~47.5 T/device).
    let t4 = run_benchmark(&{
        let mut c = scenarios::get("t4-32").unwrap().config;
        c.duration_s = 2.0 * 3600.0;
        c
    });
    let v100 = run_benchmark(&{
        let mut c = scenarios::get("v100-128").unwrap().config;
        c.duration_s = 2.0 * 3600.0;
        c
    });
    let per_device = |r: &aiperf::metrics::BenchmarkReport| r.score_flops / r.total_gpus as f64;
    assert!(per_device(&t4) < per_device(&v100));
    // The Ascend leg of the ordering is pinned without re-running the
    // 512-shard simulation: the ±20 % band test above forces the Ascend
    // per-device score to at least 0.8 × 194.53 P / 4096 ≈ 38 T/device,
    // so V100 staying below that floor closes the V100 < Ascend gap.
    assert!(per_device(&v100) < 0.8 * 194.53e15 / 4096.0);
}
