//! Cross-module integration tests over the simulated benchmark.

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::metrics::score::Validity;
use aiperf::scenarios;
use aiperf::util::json::Json;

fn cfg(nodes: u64, hours: f64, seed: u64) -> BenchmarkConfig {
    let mut cfg = BenchmarkConfig::homogeneous(nodes);
    cfg.duration_s = hours * 3600.0;
    cfg.seed = seed;
    cfg
}

#[test]
fn twelve_hour_run_produces_full_series() {
    let r = run_benchmark(&cfg(2, 12.0, 0));
    assert_eq!(r.score_series.len(), 12, "hourly samples over 12 h");
    // Telemetry every 18 min over 12 h = 40 samples.
    assert_eq!(r.telemetry.len(), 40);
    assert_eq!(r.validity, Validity::Valid);
}

#[test]
fn bit_reproducible_under_fixed_seed() {
    let a = run_benchmark(&cfg(3, 6.0, 11));
    let b = run_benchmark(&cfg(3, 6.0, 11));
    assert_eq!(a.score_flops.to_bits(), b.score_flops.to_bits());
    assert_eq!(a.final_error.to_bits(), b.final_error.to_bits());
    assert_eq!(a.regulated_score.to_bits(), b.regulated_score.to_bits());
    assert_eq!(a.architectures_evaluated, b.architectures_evaluated);
    for (x, y) in a.score_series.iter().zip(&b.score_series) {
        assert_eq!(x.flops.to_bits(), y.flops.to_bits());
    }
}

#[test]
fn different_seeds_differ() {
    let a = run_benchmark(&cfg(2, 6.0, 0));
    let b = run_benchmark(&cfg(2, 6.0, 1));
    assert_ne!(a.score_flops.to_bits(), b.score_flops.to_bits());
}

#[test]
fn scaling_2_to_16_nodes_linear() {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for nodes in [2u64, 4, 8, 16] {
        let r = run_benchmark(&cfg(nodes, 12.0, 0));
        xs.push(nodes as f64);
        ys.push(r.score_flops);
    }
    let r2 = aiperf::util::stats::r_squared(&xs, &ys);
    assert!(r2 > 0.99, "R²={r2}");
    // Per-GPU score roughly constant across scales (±15 %).
    let per_gpu: Vec<f64> = ys.iter().zip(&xs).map(|(y, x)| y / (x * 8.0)).collect();
    let max = per_gpu.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_gpu.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min < 1.15, "per-GPU spread {max}/{min}");
}

#[test]
fn longer_runs_do_not_reduce_quality() {
    let short = run_benchmark(&cfg(2, 6.0, 3));
    let long = run_benchmark(&cfg(2, 12.0, 3));
    assert!(long.final_error <= short.final_error + 0.02);
    assert!(long.architectures_evaluated >= short.architectures_evaluated);
}

#[test]
fn gpus_per_node_scaling() {
    // Scale-up (more GPUs per node) must raise the score too.
    let mut c4 = cfg(2, 6.0, 0);
    c4.topology.groups[0].gpus_per_node = 4;
    let mut c8 = cfg(2, 6.0, 0);
    c8.topology.groups[0].gpus_per_node = 8;
    let r4 = run_benchmark(&c4);
    let r8 = run_benchmark(&c8);
    assert!(r8.score_flops > 1.5 * r4.score_flops);
}

#[test]
fn report_json_roundtrips() {
    let r = run_benchmark(&cfg(2, 6.0, 5));
    let text = r.to_json().to_string();
    let parsed = Json::parse(&text).expect("report JSON parses");
    assert_eq!(parsed.get("nodes").unwrap().as_u64(), Some(2));
    assert_eq!(parsed.get("total_gpus").unwrap().as_u64(), Some(16));
    assert_eq!(
        parsed.get("groups").unwrap().as_arr().unwrap().len(),
        r.groups.len()
    );
    assert_eq!(
        parsed.get("score_series").unwrap().as_arr().unwrap().len(),
        r.score_series.len()
    );
    let flops = parsed.get("score_flops").unwrap().as_f64().unwrap();
    assert!((flops - r.score_flops).abs() / r.score_flops < 1e-9);
}

#[test]
fn config_file_flow() {
    let text = "nodes = 3\nseed = 9\nduration_hours = 6\nbatch_per_gpu = 256\n";
    let cfg = BenchmarkConfig::from_text(text).unwrap();
    assert_eq!(cfg.total_nodes(), 3);
    assert_eq!(cfg.batch_per_gpu, 256);
    let r = run_benchmark(&cfg);
    assert!(r.score_flops > 0.0);
}

#[test]
fn heterogeneous_config_file_flow() {
    let text = "seed = 3\nduration_hours = 2\nbatch_per_gpu = 256\n\
                [group.t4]\ncount = 1\ngpus_per_node = 8\ngpu = t4\n\
                [group.v100]\ncount = 1\ngpus_per_node = 8\ngpu = v100\n";
    let cfg = BenchmarkConfig::from_text(text).unwrap();
    assert_eq!(cfg.total_nodes(), 2);
    assert_eq!(cfg.topology.groups.len(), 2);
    let r = run_benchmark(&cfg);
    assert_eq!(r.groups.len(), 2);
    assert!(r.groups.iter().all(|g| g.ops > 0.0));
    // The V100 group sustains more analytical ops than the T4 group.
    assert!(r.groups[1].ops > r.groups[0].ops);
}

#[test]
fn warmup_records_are_predicted_then_measured() {
    let r = run_benchmark(&cfg(2, 12.0, 7));
    // Architectures were evaluated and the error satisfies validity.
    assert!(r.architectures_evaluated >= 6);
    assert!(r.final_error < 0.35);
    // Error at hour 1 must be worse than the final error (learning curve).
    let early = r.score_series.first().unwrap().best_error;
    assert!(early > r.final_error);
}

#[test]
fn tiny_cluster_and_short_run_still_work() {
    let mut c = cfg(1, 1.0, 0);
    c.topology.groups[0].gpus_per_node = 1;
    let r = run_benchmark(&c);
    // One GPU for one hour: little progress, but a well-formed report.
    assert!(r.score_flops > 0.0);
    assert!(!r.score_series.is_empty());
}

#[test]
fn nfs_traffic_scales_with_trials() {
    let small = run_benchmark(&cfg(2, 6.0, 0));
    let big = run_benchmark(&cfg(8, 6.0, 0));
    assert!(big.nfs_bytes_read > small.nfs_bytes_read);
}

#[test]
fn every_scenario_preset_validates() {
    let presets = scenarios::all();
    assert!(
        presets.len() >= 5,
        "expected the paper's systems + smoke + mixed"
    );
    for p in &presets {
        p.config
            .validate()
            .unwrap_or_else(|e| panic!("preset {}: {e}", p.name));
        // A preset must round-trip through the configuration text format
        // (what `aiperf config` emits and `--config` reads back) exactly —
        // topology (incl. the heterogeneous preset's two accelerator
        // models) and all.
        let text = p.config.to_text();
        let parsed = BenchmarkConfig::from_text(&text)
            .unwrap_or_else(|e| panic!("preset {} text: {e}", p.name));
        assert_eq!(parsed, p.config, "preset {} round trip", p.name);
    }
}

#[test]
fn smoke_scenario_runs_within_wall_clock_budget() {
    let p = scenarios::get("smoke").expect("smoke preset exists");
    let start = std::time::Instant::now();
    let r = run_benchmark(&p.config);
    let elapsed = start.elapsed().as_secs_f64();
    assert!(
        elapsed < p.wall_clock_budget_s,
        "smoke took {elapsed:.1}s, budget {}s",
        p.wall_clock_budget_s
    );
    // And it produced a meaningful report: dense sampling over 2 h.
    assert_eq!(r.score_series.len(), 8, "2 h at 15-min score interval");
    assert_eq!(r.telemetry.len(), 12, "2 h at 10-min telemetry interval");
    assert!(r.score_flops > 0.0);
    assert!(r.architectures_evaluated > 0);
}
