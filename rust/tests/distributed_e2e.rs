//! Distributed master–slave runtime over real TCP (localhost).
//!
//! Spins a master server and N slave-worker threads, runs a bounded
//! AutoML benchmark over the wire protocol, and checks the aggregated
//! report: exactly-once trial accounting, history-driven search progress,
//! and score consistency.

use aiperf::distributed::{DistributedReport, MasterServer, SlaveWorker};

fn run_cluster(slaves: u64, max_trials: u64, seed: u64) -> DistributedReport {
    let master = MasterServer::bind(slaves, max_trials, 30.0).unwrap();
    let addr = master.addr().unwrap();
    let mut handles = Vec::new();
    for node in 0..slaves {
        let worker = SlaveWorker::new(node, seed);
        handles.push(std::thread::spawn(move || worker.run(addr).unwrap()));
    }
    let report = master.serve().unwrap();
    let completed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(
        completed,
        report.trials.len() as u64,
        "slave and master trial counts disagree"
    );
    report
}

#[test]
fn cluster_completes_requested_trials() {
    let r = run_cluster(4, 24, 0);
    assert_eq!(r.trials.len(), 24);
    assert_eq!(r.slaves, 4);
    // Exactly-once: all trial ids distinct.
    let mut ids: Vec<u64> = r.trials.iter().map(|t| t.trial).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 24);
    // Every slave did work.
    for node in 0..4 {
        assert!(
            r.trials.iter().any(|t| t.node == node),
            "node {node} starved"
        );
    }
}

#[test]
fn search_improves_over_trials() {
    let r = run_cluster(2, 30, 1);
    // Best error among the first third vs the whole run: history-driven
    // morphism must find better architectures as the history grows.
    let third = r.trials.len() / 3;
    let early_best = r.trials[..third]
        .iter()
        .map(|t| t.error)
        .fold(1.0f64, f64::min);
    let overall_best = r.best_error;
    assert!(
        overall_best <= early_best,
        "no search progress: early {early_best} vs overall {overall_best}"
    );
    assert!(overall_best < 0.6, "search stuck: best={overall_best}");
}

#[test]
fn report_scores_consistent() {
    let r = run_cluster(2, 10, 2);
    let sum_ops: f64 = r.trials.iter().map(|t| t.ops).sum();
    assert!((sum_ops - r.total_ops).abs() / r.total_ops < 1e-9);
    assert!(r.score_flops > 0.0);
    assert!(r.regulated_score > 0.0);
    assert!(r.duration_s > 0.0);
}

#[test]
fn single_slave_cluster_works() {
    let r = run_cluster(1, 6, 3);
    assert_eq!(r.trials.len(), 6);
    // Rounds advance → warm-up schedule grows epoch budgets.
    let max_epochs = r.trials.iter().map(|t| t.epochs).max().unwrap();
    assert!(max_epochs > 10, "warm-up schedule did not advance");
}
