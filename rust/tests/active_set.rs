//! Active-set window scheduling vs. the historic full sweep.
//!
//! ISSUE 9's tentpole makes per-window work proportional to *active*
//! shards: a shard whose next event lies beyond the window end is never
//! handed to a worker. `AIPERF_FORCE_FULL_SWEEP=1` is the debugging
//! escape hatch that restores the visit-every-shard sweep; because a
//! dormant shard executes zero events either way, the two modes must be
//! byte-identical on every output surface — buffered JSON report and
//! NDJSON stream alike, counters included (both modes report the
//! *eligible* set, by design, so even `shards_skipped` matches).
//!
//! These tests live in their own binary because the escape hatch is a
//! process-global environment variable: everything here serializes on
//! one lock so a force-full run can never bleed into a filtered one.

use std::sync::{Mutex, MutexGuard};

use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::{run_benchmark_streaming, run_benchmark_with};

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Run `f` with the full-sweep escape hatch set, then clear it. Callers
/// must hold [`lock`] — the variable is process-global.
fn force_full<R>(f: impl FnOnce() -> R) -> R {
    std::env::set_var("AIPERF_FORCE_FULL_SWEEP", "1");
    let out = f();
    std::env::remove_var("AIPERF_FORCE_FULL_SWEEP");
    out
}

fn elastic_cfg(seed: u64) -> BenchmarkConfig {
    let mut cfg = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    cfg.seed = seed;
    cfg
}

/// The exascale preset truncated to three barrier windows — the same
/// seed `engine_parity` pins across engines.
fn exa_cfg() -> BenchmarkConfig {
    let mut cfg = aiperf::scenarios::get("exa-100k").expect("exa preset").config;
    cfg.duration_s = 5400.0;
    cfg.seed = 42;
    cfg
}

#[test]
fn elastic_mixed_skips_most_window_visits() {
    let _g = lock();
    // The migration showcase is idle-heavy by construction: barriers
    // every 120 s but telemetry only every 600 s, epochs thousands of
    // modelled seconds long, and the whole T4 group parked from
    // t ≈ 9100 s — so most (window, shard) visits must be skipped.
    let report = run_benchmark_with(&elastic_cfg(5), Engine::Sequential);
    let total = report.shards_touched + report.shards_skipped;
    assert!(total > 0, "counters must be populated");
    assert!(
        report.shards_skipped > 0,
        "elastic-mixed must skip dormant shards"
    );
    assert!(
        2 * report.shards_skipped > total,
        "expected >50% of window-shard visits skipped, got {} of {}",
        report.shards_skipped,
        total
    );
}

#[test]
fn force_full_sweep_is_byte_identical_on_elastic_mixed() {
    let _g = lock();
    for seed in [0u64, 5] {
        let cfg = elastic_cfg(seed);
        for engine in [Engine::Sequential, Engine::Parallel] {
            let filtered = run_benchmark_with(&cfg, engine);
            let full = force_full(|| run_benchmark_with(&cfg, engine));
            assert_eq!(
                filtered.to_json().to_string(),
                full.to_json().to_string(),
                "elastic-mixed seed {seed} {engine:?}: full sweep diverged"
            );
            assert!(
                filtered.shards_skipped > 0,
                "elastic-mixed seed {seed} {engine:?}: filter never engaged"
            );
        }
    }
}

#[test]
fn force_full_sweep_streams_identical_bytes() {
    let _g = lock();
    let cfg = elastic_cfg(0);
    let mut filtered = Vec::new();
    run_benchmark_streaming(&cfg, Engine::Sequential, &mut filtered);
    let mut full = Vec::new();
    force_full(|| run_benchmark_streaming(&cfg, Engine::Sequential, &mut full));
    assert_eq!(
        filtered, full,
        "NDJSON stream bytes diverged under the full sweep"
    );
}

#[test]
fn force_full_sweep_is_byte_identical_on_exa_100k_truncated() {
    let _g = lock();
    let cfg = exa_cfg();
    let filtered = run_benchmark_with(&cfg, Engine::Parallel);
    let full = force_full(|| run_benchmark_with(&cfg, Engine::Parallel));
    assert_eq!(
        filtered.to_json().to_string(),
        full.to_json().to_string(),
        "exa-100k truncated: full sweep diverged"
    );
    // Window 1 is sparse by construction: the SLURM setup stagger leaves
    // more than half the 12,800 shards with no event before the first
    // 1800 s barrier.
    assert!(
        filtered.shards_skipped > 0,
        "truncated exa run must skip dormant shards"
    );
}
