//! End-to-end tests of the streaming NDJSON report pipeline
//! (`--stream-report` / `metrics::stream`).
//!
//! The hard contract (ISSUE 7): with streaming off, nothing changes
//! byte for byte; with it on, the summary reconstructed from the
//! stream equals the buffered report exactly, the stream itself is a
//! pure function of the seed (double-run and cross-engine
//! byte-identical), and truncated streams are detected, not crashed on.

use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::{run_benchmark_streaming, run_benchmark_with};
use aiperf::metrics::stream::{reconstruct_summary, StreamError};
use aiperf::util::tmp::TempDir;

fn small_cfg() -> BenchmarkConfig {
    let mut cfg = BenchmarkConfig::homogeneous(2);
    cfg.duration_s = 4.0 * 3600.0;
    cfg.subshards_per_node = 2;
    cfg.seed = 11;
    cfg
}

fn stream_to_vec(cfg: &BenchmarkConfig, engine: Engine) -> (Vec<u8>, aiperf::metrics::BenchmarkReport) {
    let mut buf = Vec::new();
    let report = run_benchmark_streaming(cfg, engine, &mut buf);
    (buf, report)
}

#[test]
fn reconstructed_summary_equals_buffered_report() {
    let cfg = small_cfg();
    let buffered = run_benchmark_with(&cfg, Engine::Sequential);
    let (bytes, streamed) = stream_to_vec(&cfg, Engine::Sequential);

    // The streamed run's returned report: identical scalars, empty
    // series (they live in the stream).
    assert_eq!(streamed.score_flops.to_bits(), buffered.score_flops.to_bits());
    assert_eq!(streamed.final_error.to_bits(), buffered.final_error.to_bits());
    assert_eq!(
        streamed.regulated_score.to_bits(),
        buffered.regulated_score.to_bits()
    );
    assert_eq!(
        streamed.architectures_evaluated,
        buffered.architectures_evaluated
    );
    assert_eq!(streamed.validity, buffered.validity);
    assert_eq!(streamed.nfs_bytes_read, buffered.nfs_bytes_read);
    assert_eq!(streamed.nfs_bytes_written, buffered.nfs_bytes_written);
    assert_eq!(streamed.shards_touched, buffered.shards_touched);
    assert_eq!(streamed.shards_skipped, buffered.shards_skipped);
    assert!(streamed.score_series.is_empty());
    assert!(streamed.telemetry.is_empty());
    assert!(streamed.lane_util.is_empty());
    for (sg, bg) in streamed.groups.iter().zip(&buffered.groups) {
        assert_eq!(sg, bg);
    }

    // The summary reconstructed from the stream: equal to the buffered
    // report bit for bit, with the full series accounted for.
    let text = String::from_utf8(bytes).unwrap();
    let summary = reconstruct_summary(&text).expect("stream reconstructs");
    assert_eq!(summary.nodes, buffered.nodes);
    assert_eq!(summary.total_gpus, buffered.total_gpus);
    assert_eq!(summary.duration_s.to_bits(), buffered.duration_s.to_bits());
    assert_eq!(summary.score_flops.to_bits(), buffered.score_flops.to_bits());
    assert_eq!(summary.final_error.to_bits(), buffered.final_error.to_bits());
    assert_eq!(
        summary.regulated_score.to_bits(),
        buffered.regulated_score.to_bits()
    );
    assert_eq!(
        summary.architectures_evaluated,
        buffered.architectures_evaluated
    );
    assert_eq!(summary.validity, format!("{:?}", buffered.validity));
    assert_eq!(summary.shards_touched, buffered.shards_touched);
    assert_eq!(summary.shards_skipped, buffered.shards_skipped);
    assert_eq!(summary.score_samples as usize, buffered.score_series.len());
    assert_eq!(summary.telemetry_ticks as usize, buffered.telemetry.len());
    assert_eq!(summary.lanes as usize, buffered.lane_util.len());
    assert!(summary.trials > 0);
    assert!(summary.windows > 0);
}

#[test]
fn stream_is_a_pure_function_of_the_seed() {
    let cfg = small_cfg();
    let (a, _) = stream_to_vec(&cfg, Engine::Sequential);
    let (b, _) = stream_to_vec(&cfg, Engine::Sequential);
    assert_eq!(a, b, "double-run stream bytes diverged");
    // The parallel engine must produce the identical stream: records
    // are emitted at the single-threaded barrier merges, in node order.
    let (par, _) = stream_to_vec(&cfg, Engine::Parallel);
    assert_eq!(a, par, "sequential vs parallel stream bytes diverged");
    // A different seed must not collapse onto the same stream.
    let mut other = small_cfg();
    other.seed = 12;
    let (c, _) = stream_to_vec(&other, Engine::Sequential);
    assert_ne!(a, c, "seed is not reaching the stream");
}

#[test]
fn stream_report_config_key_writes_the_file() {
    let dir = TempDir::new("stream").unwrap();
    let path = dir.path().join("run.ndjson");
    let mut cfg = small_cfg();
    cfg.stream_report = Some(path.to_str().unwrap().to_string());
    let via_file = run_benchmark_with(&cfg, Engine::Sequential);
    let text = std::fs::read_to_string(&path).unwrap();
    let summary = reconstruct_summary(&text).expect("file stream reconstructs");
    assert_eq!(summary.score_flops.to_bits(), via_file.score_flops.to_bits());
    // And the file path goes through the same writer as the in-memory
    // stream: identical bytes for the same config.
    cfg.stream_report = None;
    let (mem, _) = stream_to_vec(&cfg, Engine::Sequential);
    assert_eq!(text.as_bytes(), &mem[..]);
}

#[test]
fn truncated_streams_error_cleanly_at_any_cut() {
    let cfg = small_cfg();
    let (bytes, _) = stream_to_vec(&cfg, Engine::Sequential);
    let text = String::from_utf8(bytes).unwrap();
    assert!(reconstruct_summary(&text).is_ok());
    // Cut the stream at a spread of byte offsets (snapped to char
    // boundaries): every strict prefix must produce an error — Parse
    // for mid-record cuts, Truncated for clean line-boundary cuts —
    // and never a panic or a silently wrong Ok. (A cut at n-1 would
    // only drop the final newline, which is legitimately complete, so
    // the range stops short of it.)
    let n = text.len();
    for cut in (0..n - 1).step_by((n / 97).max(1)) {
        let mut cut = cut;
        while !text.is_char_boundary(cut) {
            cut -= 1;
        }
        let prefix = &text[..cut];
        match reconstruct_summary(prefix) {
            Err(StreamError::Parse { .. })
            | Err(StreamError::Truncated { .. })
            | Err(StreamError::Malformed { .. }) => {}
            Ok(_) => panic!("prefix of {cut}/{n} bytes reconstructed as complete"),
        }
    }
    // Dropping just the final newline still reconstructs (the trailer
    // line is complete).
    assert!(reconstruct_summary(text.trim_end()).is_ok());
}
