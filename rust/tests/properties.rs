//! Property-based tests over coordinator invariants.
//!
//! proptest is not vendored in this offline environment, so the driver is
//! hand-rolled: each property generates many random operation sequences
//! from a seeded in-tree RNG and asserts the invariant after every step.
//! On failure the seed and step index identify the reproducer exactly.

use aiperf::cluster::{ClusterTopology, GpuModel, NodeGroup};
use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::buffer::{ArchBuffer, Candidate};
use aiperf::coordinator::dispatcher::Dispatcher;
use aiperf::coordinator::trial::{ActiveTrial, TrialStatus};
use aiperf::flops::{graph_ops_per_image, OpWeights};
use aiperf::hpo::{aiperf_space, build, Backend, Optimizer};
use aiperf::nas::graph::Architecture;
use aiperf::nas::morphism::{morph, random_legal_morph, random_morph, MorphLimits};
use aiperf::sim::accuracy::HpPoint;
use aiperf::sim::engine::EventQueue;
use aiperf::util::rng::derive;

const CASES: u64 = 64;

/// Routing invariant: every trial is assigned to exactly one node and
/// completed at most once; assigned = completed + in-flight at all times.
#[test]
fn prop_dispatcher_exactly_once_routing() {
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-dispatch", 0);
        let nodes = rng.gen_range_usize(1, 9);
        let mut d = Dispatcher::new();
        let mut in_flight: Vec<Option<u64>> = vec![None; nodes];
        for step in 0..200 {
            let node = rng.gen_range_usize(0, nodes);
            match in_flight[node] {
                None => {
                    let id = d.assign(node).unwrap_or_else(|e| {
                        panic!("seed {seed} step {step}: assign failed: {e}")
                    });
                    // Double-assign to a busy node must fail.
                    assert!(d.assign(node).is_err());
                    in_flight[node] = Some(id);
                }
                Some(id) => {
                    // Completing on the wrong node must fail.
                    let wrong = (node + 1) % nodes;
                    if wrong != node {
                        assert!(d.complete(id, wrong).is_err());
                    }
                    d.complete(id, node).unwrap();
                    // Double-complete must fail.
                    assert!(d.complete(id, node).is_err());
                    in_flight[node] = None;
                }
            }
            d.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
    }
}

/// Buffer invariant: len ≤ capacity always; FIFO order preserved;
/// accepted − popped = len.
#[test]
fn prop_buffer_bounded_fifo() {
    let arch = Architecture::initial(32, 3, 10);
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-buffer", 0);
        let cap = rng.gen_range_usize(1, 9);
        let mut b = ArchBuffer::new(cap);
        let mut model: std::collections::VecDeque<usize> = Default::default();
        let mut next = 0usize;
        for step in 0..300 {
            if rng.gen_bool(0.55) {
                let c = Candidate {
                    arch: arch.clone(),
                    proposed_by: next,
                    proposed_at: step as f64,
                };
                let ok = b.push(c).is_ok();
                assert_eq!(ok, model.len() < cap, "seed {seed} step {step}");
                if ok {
                    model.push_back(next);
                }
                next += 1;
            } else {
                let got = b.pop().map(|c| c.proposed_by);
                assert_eq!(got, model.pop_front(), "seed {seed} step {step}");
            }
            assert!(b.len() <= cap);
            assert_eq!(b.len(), model.len());
            // Conservation: every push attempt was either accepted or
            // rejected, never both.
            assert_eq!((b.accepted + b.rejected) as usize, next);
        }
    }
}

/// Morphism invariant: any sequence of legal morphs yields a structurally
/// valid architecture within limits, and illegal morphs never mutate.
#[test]
fn prop_morphism_preserves_validity() {
    let limits = MorphLimits::default();
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-morph", 0);
        let mut arch = if rng.gen_bool(0.5) {
            Architecture::initial(32, 3, 10)
        } else {
            Architecture::initial_imagenet()
        };
        for step in 0..60 {
            let proposal = random_morph(&arch, &mut rng);
            match morph(&arch, proposal, &limits) {
                Ok(child) => {
                    child
                        .validate()
                        .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
                    assert!(child.params() <= limits.max_params);
                    assert!(child.depth() <= limits.max_depth);
                    arch = child;
                }
                Err(_) => {
                    // Parent must be untouched (morph clones).
                    arch.validate().unwrap();
                }
            }
        }
    }
}

/// Capacity semantics per morph kind: Deepen grows depth by one; Widen
/// strictly grows ops and params; Skip never reduces ops. (Deepen may
/// legitimately REDUCE ops: a small-kernel block inserted before a
/// large-kernel transition conv shrinks that conv's input channels — so
/// the depth claim, not an ops claim, is the Deepen invariant.)
#[test]
fn prop_morph_capacity_semantics() {
    let w = OpWeights::default();
    let limits = MorphLimits::default();
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-flops", 0);
        let mut arch = Architecture::initial(32, 3, 10);
        for _ in 0..40 {
            let prev = graph_ops_per_image(&arch.lower(), &w);
            let prev_depth = arch.depth();
            let (child, applied) = random_legal_morph(&arch, &limits, &mut rng, 16);
            let cur = graph_ops_per_image(&child.lower(), &w);
            if let Some(m) = applied {
                use aiperf::nas::morphism::Morph;
                match m {
                    Morph::Deepen { .. } => {
                        assert_eq!(child.depth(), prev_depth + 1, "seed {seed}: {m:?}");
                        assert!(cur.params > 0);
                    }
                    Morph::Widen { .. } => {
                        assert!(cur.fp > prev.fp, "seed {seed}: {m:?} did not grow ops");
                        assert!(cur.params > prev.params, "seed {seed}: {m:?}");
                    }
                    Morph::Skip { .. } => {
                        assert!(cur.fp >= prev.fp, "seed {seed}: {m:?} reduced ops");
                        assert_eq!(child.depth(), prev_depth);
                    }
                    Morph::Kernel { .. } => {
                        assert_eq!(child.depth(), prev_depth);
                    }
                }
            }
            arch = child;
        }
    }
}

/// Event-queue invariant: pops are globally time-ordered and FIFO within
/// a timestamp, for any interleaving of schedules and pops.
#[test]
fn prop_event_queue_ordering() {
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-queue", 0);
        let mut q = EventQueue::new();
        let mut popped: Vec<(f64, u64)> = Vec::new();
        let mut scheduled = 0u64;
        for _ in 0..400 {
            if rng.gen_bool(0.6) {
                let t = q.now() + rng.gen_range_f64(0.0, 10.0);
                q.schedule(t, scheduled);
                scheduled += 1;
            } else if let Some((t, e)) = q.pop() {
                popped.push((t, e));
            }
        }
        while let Some((t, e)) = q.pop() {
            popped.push((t, e));
        }
        assert_eq!(popped.len() as u64, scheduled, "seed {seed}: lost events");
        for w in popped.windows(2) {
            assert!(
                w[0].0 <= w[1].0,
                "seed {seed}: time order violated: {w:?}"
            );
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "seed {seed}: FIFO violated: {w:?}");
            }
        }
    }
}

/// Event-queue tie-breaking: on a coarse integer time grid (forcing many
/// equal timestamps) and under random schedule/pop interleavings, pops
/// must match a reference model that always yields the pending event with
/// the smallest (time, insertion order) — i.e. time-ordered with FIFO
/// tie-breaking.
#[test]
fn prop_event_queue_fifo_tie_breaking() {
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-queue-ties", 0);
        let mut q = EventQueue::new();
        // Reference model: pending (time, insertion-order id) pairs.
        let mut pending: Vec<(f64, u64)> = Vec::new();
        let mut scheduled = 0u64;
        let mut last: Option<(f64, u64)> = None;
        for step in 0..500 {
            if rng.gen_bool(0.6) {
                // Integer offsets 0..4 from `now` make timestamp
                // collisions the common case, not the exception.
                let t = q.now() + rng.gen_range_u64(0, 4) as f64;
                q.schedule(t, scheduled);
                pending.push((t, scheduled));
                scheduled += 1;
            } else {
                let got = q.pop();
                let want = pending
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        a.0.partial_cmp(&b.0)
                            .unwrap()
                            .then_with(|| a.1.cmp(&b.1))
                    })
                    .map(|(i, _)| i);
                match (got, want) {
                    (None, None) => {}
                    (Some((t, e)), Some(i)) => {
                        let (wt, we) = pending.remove(i);
                        assert_eq!(
                            (t, e),
                            (wt, we),
                            "seed {seed} step {step}: wrong pop order"
                        );
                        if let Some((lt, le)) = last {
                            assert!(
                                lt < t || (lt == t && le < e),
                                "seed {seed} step {step}: (time, seq) not increasing"
                            );
                        }
                        last = Some((t, e));
                    }
                    (got, want) => {
                        panic!("seed {seed} step {step}: pop {got:?} vs model {want:?}")
                    }
                }
            }
        }
        assert_eq!(q.len(), pending.len(), "seed {seed}: queue/model diverged");
    }
}

/// HPO invariant: every optimizer only ever suggests points inside the
/// search space, for arbitrary observation feedback. Built through the
/// one public factory ([`build`]) — the same path the engine uses.
#[test]
fn prop_optimizers_respect_domain() {
    let space = aiperf_space();
    for seed in 0..16 {
        let opts: Vec<Box<dyn Optimizer>> = vec![
            build(Backend::Tpe, space.clone(), seed),
            build(Backend::Random, space.clone(), seed),
            build(Backend::Grid, space.clone(), seed),
            build(Backend::Evolutionary, space.clone(), seed),
        ];
        for (k, mut opt) in opts.into_iter().enumerate() {
            let mut rng = derive(seed, "prop-hpo", k as u64);
            for step in 0..60 {
                let c = opt.suggest(&mut rng);
                assert!(
                    space.contains(&c),
                    "seed {seed} opt {k} step {step}: {c:?} outside space"
                );
                let loss = rng.gen_range_f64(0.0, 1.0);
                opt.observe(c, loss);
            }
        }
    }
}

/// Early-stopping invariant: a trial never trains past its budget, never
/// stops before `patience` stale epochs, and `best_accuracy` equals the
/// max of the recorded curve.
#[test]
fn prop_trial_early_stopping() {
    let arch = Architecture::initial(32, 3, 10);
    let ops = graph_ops_per_image(&arch.lower(), &OpWeights::default());
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-trial", 0);
        let budget = rng.gen_range_u64(1, 60);
        let patience = rng.gen_range_u64(1, 8);
        let mut trial = ActiveTrial::new(
            0,
            arch.clone(),
            1,
            HpPoint::default(),
            ops,
            64,
            1,
            budget,
        );
        let mut max_acc = 0.0f64;
        let mut stale = 0u64;
        loop {
            let acc = rng.gen_range_f64(0.0, 1.0);
            let status = trial.record_epoch(acc, patience, 1e-3);
            if acc > max_acc + 1e-3 {
                max_acc = acc.max(max_acc);
                stale = 0;
            } else {
                stale += 1;
            }
            max_acc = max_acc.max(acc.min(max_acc + 1e-3));
            match status {
                TrialStatus::Continue => {
                    assert!(trial.epoch < budget, "seed {seed}: ran past budget");
                    assert!(stale < patience, "seed {seed}: missed early stop");
                }
                TrialStatus::BudgetExhausted => {
                    assert_eq!(trial.epoch, budget);
                    break;
                }
                TrialStatus::EarlyStopped => {
                    assert!(stale >= patience, "seed {seed}: stopped too early");
                    assert!(trial.epoch < budget);
                    break;
                }
            }
        }
        let curve_max = trial
            .accs
            .iter()
            .cloned()
            .fold(f64::MIN, f64::max);
        assert!((trial.best_accuracy() - curve_max).abs() < 1e-2 + 1e-3);
    }
}

/// Configuration round-trip invariant: `from_text(to_text(cfg))` is the
/// identity for arbitrary multi-group (heterogeneous) topologies and
/// arbitrary knob values — every field survives, bit for bit (f64 Display
/// prints the shortest exactly-round-tripping decimal).
#[test]
fn prop_config_text_roundtrip_identity() {
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-config", 0);
        let n_groups = rng.gen_range_usize(1, 5);
        let topology = ClusterTopology {
            groups: (0..n_groups)
                .map(|i| {
                    let base = match rng.gen_range_u64(0, 3) {
                        0 => GpuModel::t4(),
                        1 => GpuModel::v100(),
                        _ => GpuModel::ascend910(),
                    };
                    let mut g = NodeGroup::new(
                        &format!("g{i}"),
                        rng.gen_range_u64(1, 40),
                        rng.gen_range_u64(1, 17),
                        base,
                    );
                    // Arbitrary per-field overrides, including awkward f64s.
                    g.gpu.sustained_flops = rng.gen_range_f64(1e11, 9e13);
                    g.gpu.memory_bytes = rng.gen_range_u64(1 << 30, 1 << 36);
                    g.gpu.util_half_batch = rng.gen_range_f64(1.0, 200.0);
                    g.gpu.util_max = rng.gen_range_f64(0.5, 0.999);
                    g.gpu.step_overhead_s = rng.gen_range_f64(1e-4, 1e-2);
                    // Optional per-group scheduling overrides: absent and
                    // present values must both survive the round trip.
                    if rng.gen_bool(0.5) {
                        g.batch_per_gpu = Some(rng.gen_range_u64(8, 513));
                    }
                    if rng.gen_bool(0.5) {
                        g.subshards_per_node = Some(rng.gen_range_u64(1, 9));
                    }
                    g.accepts_migrants = rng.gen_bool(0.5);
                    if rng.gen_bool(0.5) {
                        g.hpo = Some(match rng.gen_range_u64(0, 4) {
                            0 => Backend::Tpe,
                            1 => Backend::Evolutionary,
                            2 => Backend::Random,
                            _ => Backend::Grid,
                        });
                    }
                    g
                })
                .collect(),
        };
        let host = aiperf::cluster::HostModel {
            cpu_cores: rng.gen_range_u64(1, 129),
            search_seconds: rng.gen_range_f64(0.1, 10.0),
            ..aiperf::cluster::HostModel::default()
        };
        let cfg = BenchmarkConfig {
            topology,
            host,
            batch_per_gpu: rng.gen_range_u64(8, 512),
            learning_rate: rng.gen_range_f64(1e-4, 1.0),
            duration_s: rng.gen_range_f64(600.0, 100_000.0),
            seed: rng.gen_range_u64(0, u64::MAX),
            sync_interval_s: rng.gen_range_f64(10.0, 5000.0),
            engine: if rng.gen_bool(0.5) {
                Engine::Sequential
            } else {
                Engine::Parallel
            },
            subshards_per_node: rng.gen_range_u64(1, 5),
            work_stealing: rng.gen_bool(0.5),
            migration: rng.gen_bool(0.5),
            migration_nfs_bytes_per_param: rng.gen_range_u64(1, 64),
            feedback_routing: rng.gen_bool(0.5),
            hpo: match rng.gen_range_u64(0, 4) {
                0 => Backend::Tpe,
                1 => Backend::Evolutionary,
                2 => Backend::Random,
                _ => Backend::Grid,
            },
            early_stop: rng.gen_bool(0.5),
            early_stop_min_epochs: rng.gen_range_u64(1, 20),
            early_stop_margin: rng.gen_range_f64(0.0, 0.2),
            ..BenchmarkConfig::default()
        };
        let text = cfg.to_text();
        let parsed = BenchmarkConfig::from_text(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: reparse failed: {e}\n{text}"));
        assert_eq!(parsed, cfg, "seed {seed}: round trip not identity");
    }
}

/// Legacy flat cluster keys must still parse to an equivalent one-group
/// topology (backward compatibility with pre-topology config files).
#[test]
fn prop_config_legacy_flat_keys_one_group() {
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-config-flat", 0);
        let nodes = rng.gen_range_u64(1, 100);
        let gpus = rng.gen_range_u64(1, 17);
        let flops = rng.gen_range_f64(1e11, 9e13);
        let text = format!(
            "nodes = {nodes}\ngpus_per_node = {gpus}\ngpu_sustained_flops = {flops}\n"
        );
        let cfg = BenchmarkConfig::from_text(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(cfg.topology.groups.len(), 1, "seed {seed}");
        let g = &cfg.topology.groups[0];
        assert_eq!(g.count, nodes);
        assert_eq!(g.gpus_per_node, gpus);
        assert_eq!(g.gpu.sustained_flops, flops);
        assert_eq!(cfg.total_gpus(), nodes * gpus);
        // And the reparse of its canonical form is still the identity.
        assert_eq!(BenchmarkConfig::from_text(&cfg.to_text()).unwrap(), cfg);
    }
}

/// Steal-schedule invariant: with sub-shards and work stealing enabled
/// on a heterogeneous topology, the whole run — steal counts, barrier
/// slack, and the full machine-readable report — is a pure function of
/// the seed (the victim scan order is seed-derived, not time- or
/// thread-dependent).
#[test]
fn prop_steal_schedule_deterministic_per_seed() {
    use aiperf::coordinator::run_benchmark;
    let mut jsons = Vec::new();
    for seed in 0..4u64 {
        let mut t4 = NodeGroup::new("t4", 1, 8, GpuModel::t4());
        t4.batch_per_gpu = Some(256);
        let mut cfg = BenchmarkConfig {
            topology: ClusterTopology {
                groups: vec![t4, NodeGroup::new("v100", 1, 8, GpuModel::v100())],
            },
            subshards_per_node: 2,
            work_stealing: true,
            ..BenchmarkConfig::default()
        };
        cfg.duration_s = 2.5 * 3600.0;
        cfg.seed = seed;
        cfg.validate().unwrap();
        let a = run_benchmark(&cfg);
        let b = run_benchmark(&cfg);
        let (ja, jb) = (a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(ja, jb, "seed {seed}: report not a pure function of seed");
        assert_eq!(
            a.groups.iter().map(|g| g.steals).collect::<Vec<_>>(),
            b.groups.iter().map(|g| g.steals).collect::<Vec<_>>(),
            "seed {seed}: steal schedule diverged"
        );
        for g in &a.groups {
            assert!(g.barrier_slack_s >= 0.0, "seed {seed}: negative slack");
        }
        jsons.push(ja);
    }
    // Different seeds must not all collapse onto one trajectory.
    jsons.dedup();
    assert!(jsons.len() > 1, "all seeds produced identical runs");
}

/// Migration-schedule invariant: with sub-shards, work stealing, AND
/// cross-group migration enabled on the heterogeneous preset, the whole
/// run — migration counters, overhead seconds, per-lane busy fractions,
/// and the full machine-readable report — is a pure function of the seed
/// (staging happens inside each shard's own event loop; placement
/// happens single-threaded at the barriers in deterministic lane order).
#[test]
fn prop_migration_schedule_deterministic_per_seed() {
    use aiperf::coordinator::run_benchmark;
    let mut jsons = Vec::new();
    for seed in 0..4u64 {
        let mut cfg = aiperf::scenarios::get("t4v100-mixed")
            .expect("mixed preset")
            .config;
        assert!(cfg.work_stealing && cfg.migration, "preset enables both");
        cfg.duration_s = 2.5 * 3600.0;
        cfg.seed = seed;
        cfg.validate().unwrap();
        let a = run_benchmark(&cfg);
        let b = run_benchmark(&cfg);
        let (ja, jb) = (a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(ja, jb, "seed {seed}: report not a pure function of seed");
        assert_eq!(
            a.groups
                .iter()
                .map(|g| (g.migrations_in, g.migrations_out))
                .collect::<Vec<_>>(),
            b.groups
                .iter()
                .map(|g| (g.migrations_in, g.migrations_out))
                .collect::<Vec<_>>(),
            "seed {seed}: migration schedule diverged"
        );
        // Conservation: every adopted trial was dispatched by someone.
        let inn: u64 = a.groups.iter().map(|g| g.migrations_in).sum();
        let out: u64 = a.groups.iter().map(|g| g.migrations_out).sum();
        assert_eq!(inn, out, "seed {seed}: migrations in/out must balance");
        for g in &a.groups {
            assert!(g.migration_overhead_s >= 0.0, "seed {seed}: negative overhead");
        }
        // Per-lane telemetry is present and well-formed: one entry per
        // sub-shard lane, fractions in [0, 1].
        assert_eq!(a.lane_util.len() as u64, cfg.total_subshards());
        assert!(a
            .lane_util
            .iter()
            .all(|l| (0.0..=1.0).contains(&l.busy_fraction)));
        jsons.push(ja);
    }
    // Different seeds must not all collapse onto one trajectory.
    jsons.dedup();
    assert!(jsons.len() > 1, "all seeds produced identical runs");
}

/// K-way merge invariant (the barrier window merge): heap-merging
/// per-lane time-sorted deltas must equal the historic full re-sort of
/// the lane-order concatenation — ties older lane first, FIFO within a
/// lane — across random heterogeneous lane layouts (each node its own
/// `subshards_per_node`-style lane count) and a collision-heavy time
/// grid.
#[test]
fn prop_kway_merge_equals_stable_resort() {
    use aiperf::coordinator::merge_by_time;
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-kway", 0);
        // Heterogeneous lane layout: 1..=6 nodes, each with its own
        // 1..=8 lane count (mirroring per-group subshards_per_node
        // overrides), lanes of uneven length including empty ones.
        let nodes = rng.gen_range_usize(1, 7);
        let mut lanes: Vec<Vec<(f64, usize, usize)>> = Vec::new();
        for _ in 0..nodes {
            let k = rng.gen_range_usize(1, 9);
            for _ in 0..k {
                let lane_idx = lanes.len();
                let len = rng.gen_range_usize(0, 30);
                let mut t = 0.0;
                let delta: Vec<(f64, usize, usize)> = (0..len)
                    .map(|pos| {
                        // Coarse integer steps (including zero) make
                        // cross-lane timestamp collisions the common
                        // case, so the older-lane-first tie rule is
                        // really exercised, not just time ordering.
                        t += rng.gen_range_u64(0, 3) as f64;
                        (t, lane_idx, pos)
                    })
                    .collect();
                lanes.push(delta);
            }
        }
        // Historic path: concatenate in lane order, stable-sort by time
        // (ties keep lane order, FIFO within a lane).
        let mut expect: Vec<(f64, usize, usize)> =
            lanes.iter().flatten().copied().collect();
        expect.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let got = merge_by_time(lanes, |x| x.0);
        assert_eq!(got, expect, "seed {seed}: merge order diverged");
    }
}

/// Score invariants: regulated score is monotone decreasing in error and
/// strictly linear in FLOPS, over random inputs.
#[test]
fn prop_regulated_score_shape() {
    use aiperf::metrics::score::regulated_score;
    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-score", 0);
        let f = rng.gen_range_f64(1e9, 1e18);
        let e1 = rng.gen_range_f64(0.01, 0.98);
        let e2 = e1 + rng.gen_range_f64(0.001, 1.0 - e1 - 0.01);
        assert!(regulated_score(e1, f) > regulated_score(e2, f));
        let k = rng.gen_range_f64(1.1, 10.0);
        let a = regulated_score(e1, f);
        let b = regulated_score(e1, f * k);
        assert!((b / a - k).abs() < 1e-9);
    }
}

/// The incremental best-error state (running min + prefix-min series)
/// must answer exactly like a naive scan over the records — on the
/// coordinator's time-ordered push path *and* after an out-of-order
/// push demotes the list to the scanning fallback.
#[test]
fn prop_incremental_best_error_matches_naive_scan() {
    use aiperf::coordinator::{HistoryList, ModelRecord};
    use std::sync::Arc;

    for seed in 0..CASES {
        let mut rng = derive(seed, "prop-best-error", 0);
        let n = rng.gen_range_usize(1, 61);
        let mut recs: Vec<(f64, f64, bool)> = (0..n)
            .map(|_| {
                let t = rng.gen_range_f64(0.0, 1000.0);
                let acc = rng.gen_range_f64(0.0, 1.0);
                let penalty = rng.gen_range_f64(0.0, 1.0) < 0.2;
                (t, acc, penalty)
            })
            .collect();
        // Even seeds exercise the fast path (nondecreasing completion
        // times, as the coordinator pushes); odd seeds keep the random
        // order, which almost surely trips the out-of-order fallback.
        if seed % 2 == 0 {
            recs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }

        let arch = Arc::new(Architecture::initial(32, 3, 10));
        let mut h = HistoryList::new();
        for (i, &(t, acc, penalty)) in recs.iter().enumerate() {
            h.push(ModelRecord {
                id: i as u64,
                arch: Arc::clone(&arch),
                signature: format!("m{i}"),
                params: 1000,
                accuracy: acc,
                measured_accuracy: if penalty { 0.0 } else { acc },
                predicted: false,
                penalty,
                node: 0,
                group: 0,
                round: 1,
                epochs_trained: 1,
                ops: 1.0,
                dropout: 0.0,
                kernel: 3.0,
                completed_at: t,
            });
        }

        let naive_best = recs
            .iter()
            .filter(|r| !r.2)
            .map(|r| 1.0 - r.1)
            .min_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(
            h.best_measured_error(),
            naive_best,
            "seed {seed}: overall best diverged"
        );

        for _ in 0..40 {
            let t = rng.gen_range_f64(-10.0, 1100.0);
            let naive = recs
                .iter()
                .filter(|r| !r.2 && r.0 <= t)
                .map(|r| 1.0 - r.1)
                .min_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(
                h.best_measured_error_at(t),
                naive,
                "seed {seed}: best-at({t}) diverged"
            );
        }
    }
}
