//! Inter-group trial migration: end-to-end behavior of the elastic
//! scheduler (`coordinator::sched`).
//!
//! Three contracts under test:
//! 1. with migration *off*, the elastic scheduler reproduces the pure
//!    steal schedules exactly — every migration knob is inert, bit for
//!    bit (the PR 3 regression guarantee);
//! 2. on the `elastic-mixed` preset's imbalanced deadline, migrations
//!    actually occur and recover tail ops the same run forfeits with
//!    migration disabled;
//! 3. the steal-aware search: OOM-skipped candidates feed penalty
//!    entries into the ranked history instead of only advancing the
//!    proposal RNG (the parent-selection side is unit-tested in
//!    `nas::search`: penalized entries never seed new morphs while real
//!    records exist, so repeated unfittable proposals stop recurring).

use aiperf::cluster::{ClusterTopology, GpuModel, NodeGroup};
use aiperf::config::{BenchmarkConfig, WarmupSchedule};
use aiperf::coordinator::run_benchmark;
use aiperf::coordinator::shard::{HistorySnapshot, SimContext, SlaveShard};
use aiperf::flops::OpWeights;
use aiperf::metrics::report::BenchmarkReport;
use aiperf::nas::graph::Architecture;

fn migrations_in(r: &BenchmarkReport) -> u64 {
    r.groups.iter().map(|g| g.migrations_in).sum()
}

fn migrations_out(r: &BenchmarkReport) -> u64 {
    r.groups.iter().map(|g| g.migrations_out).sum()
}

#[test]
fn migration_off_keeps_the_pure_steal_schedule() {
    // The PR 3 regression: the scheduler extraction plus the whole
    // migration surface (bytes-per-param, accepts_migrants, outboxes,
    // barrier passes) must be invisible when `migration = false` — two
    // configs differing in every inert knob produce byte-identical
    // machine-readable reports.
    let mut base = aiperf::scenarios::get("t4v100-mixed")
        .expect("mixed preset")
        .config;
    base.duration_s = 2.5 * 3600.0;
    base.seed = 3;
    base.migration = false;
    let a = run_benchmark(&base);

    let mut alt = base.clone();
    alt.migration_nfs_bytes_per_param = 4096;
    // Feedback routing only acts through migrated trials (and through
    // penalties, which the preset cannot produce), so with migration off
    // flipping it must be invisible too.
    alt.feedback_routing = false;
    for g in alt.topology.groups.iter_mut() {
        g.accepts_migrants = false;
    }
    let b = run_benchmark(&alt);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "migration knobs must be inert with migration off"
    );
    assert_eq!(migrations_in(&a), 0);
    assert_eq!(migrations_out(&a), 0);
    assert!(a.groups.iter().all(|g| g.migration_overhead_s == 0.0));
    // The steal pass still runs (the preset keeps stealing on) and the
    // per-lane telemetry is populated either way.
    assert_eq!(a.lane_util.len() as u64, base.total_subshards());
}

#[test]
fn elastic_mixed_migrates_and_recovers_tail_ops() {
    // The acceptance contract of the `elastic-mixed` preset: its
    // deliberately imbalanced deadline strands the T4 group's tail, and
    // cross-group migration both fires (nonzero in/out) and beats the
    // same run with `--migration off` on total trained ops. Trial
    // trajectories vary per seed, so — like the work-stealing endgame
    // test — the claim is over a seed scan, with per-seed invariants
    // checked unconditionally.
    let mut any_migration = false;
    let mut any_gain = false;
    for seed in 0..6u64 {
        let mut on = aiperf::scenarios::get("elastic-mixed")
            .expect("elastic preset")
            .config;
        on.seed = seed;
        let mut off = on.clone();
        off.migration = false;
        let r_on = run_benchmark(&on);
        let r_off = run_benchmark(&off);

        // Conservation: every adopted trial was dispatched by somebody.
        assert_eq!(
            migrations_in(&r_on),
            migrations_out(&r_on),
            "seed {seed}: migrations must balance"
        );
        assert_eq!(migrations_in(&r_off), 0, "seed {seed}: off-run migrated");
        if migrations_in(&r_on) > 0 {
            any_migration = true;
            // Adoption is never free: staging + IB-sync overhead was
            // charged somewhere.
            let overhead: f64 = r_on.groups.iter().map(|g| g.migration_overhead_s).sum();
            assert!(overhead > 0.0, "seed {seed}: migration without overhead");
        }
        if r_on.total_ops() > r_off.total_ops() {
            any_gain = true;
        }
    }
    assert!(
        any_migration,
        "cross-group migration never fired on elastic-mixed across seeds"
    );
    assert!(
        any_gain,
        "migration never recovered tail ops over the steal-only run across seeds"
    );
}

#[test]
fn migration_without_destination_groups_is_inert() {
    // Migration needs somewhere to go: on a homogeneous topology (or
    // when every other group refuses migrants) a lane must not stage
    // checkpoints and park itself — it keeps the classic steal-only
    // behavior, bit for bit, and pays no overhead.
    let mut on = BenchmarkConfig::homogeneous(2);
    on.duration_s = 2.0 * 3600.0;
    on.seed = 5;
    on.subshards_per_node = 2;
    on.work_stealing = true;
    on.migration = true;
    let mut off = on.clone();
    off.migration = false;
    let r_on = run_benchmark(&on);
    assert_eq!(
        r_on.to_json().to_string(),
        run_benchmark(&off).to_json().to_string(),
        "single-group migration must be a no-op"
    );
    assert!(r_on.groups.iter().all(|g| g.migration_overhead_s == 0.0));

    // Same when the only other group opts out of adopting migrants.
    let mut refused = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    refused.seed = 2;
    for g in refused.topology.groups.iter_mut() {
        g.accepts_migrants = false;
    }
    let r = run_benchmark(&refused);
    assert_eq!(migrations_in(&r), 0);
    assert_eq!(migrations_out(&r), 0);
    assert!(r.groups.iter().all(|g| g.migration_overhead_s == 0.0));
}

#[test]
fn migration_schedule_is_deterministic_per_seed() {
    let mut cfg = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    cfg.seed = 1;
    let a = run_benchmark(&cfg);
    let b = run_benchmark(&cfg);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}

#[test]
fn parked_tails_show_in_per_lane_utilization() {
    // The imbalanced deadline parks T4 lanes for the last stretch of the
    // run: at least one lane's busy fraction must sit visibly below a
    // fully-loaded lane's, and the JSON report must expose the per-lane
    // view (Figs 9–12 aggregate nodes; this is the lane-level
    // complement).
    let mut cfg = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    cfg.seed = 0;
    let r = run_benchmark(&cfg);
    assert_eq!(r.lane_util.len() as u64, cfg.total_subshards());
    assert!(r
        .lane_util
        .iter()
        .all(|l| (0.0..=1.0).contains(&l.busy_fraction)));
    let max = r.lane_util.iter().map(|l| l.busy_fraction).fold(0.0, f64::max);
    let min = r.lane_util.iter().map(|l| l.busy_fraction).fold(1.0, f64::min);
    assert!(max > 0.5, "no lane ever got busy: max={max}");
    assert!(
        min < max,
        "per-lane view must resolve the utilization spread the node aggregate hides"
    );
    let json = r.to_json().to_string();
    assert!(json.contains("\"lanes\""), "JSON report must list lanes");
    assert!(json.contains("\"busy_fraction\""));
}

/// A single-node configuration whose accelerator fits the initial
/// architecture at batch 4 with only ~1 MB to spare: any morph that
/// grows capacity materially is absolutely unfittable (no batch works),
/// so the memory boundary is exercised hard. The dataset is shrunk so
/// single-epoch trials turn over quickly enough to generate many
/// proposals inside the budget.
fn memory_cliff_cfg(seed: u64) -> BenchmarkConfig {
    let stats = Architecture::initial_imagenet().stats(&OpWeights::default());
    // Mirror GpuModel::memory_demand: states (12 B/param) + framework
    // overhead + batch-4 activations, plus a ~1 MB margin.
    let fixed = stats.params * 12 + 3 * (1 << 29);
    let gpu = GpuModel {
        memory_bytes: fixed + stats.activation_elems * 2 * 4 + (1 << 20),
        ..GpuModel::v100()
    };
    let mut cfg = BenchmarkConfig {
        topology: ClusterTopology::single(NodeGroup::new("cliff", 1, 8, gpu)),
        batch_per_gpu: 4,
        warmup: WarmupSchedule {
            first_epochs: 1,
            step_epochs: 1,
            max_epochs: 2,
            hpo_start_round: 5,
        },
        duration_s: 4.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    // The architecture shape stays ImageNet (it sizes the memory cliff);
    // fewer images per epoch just speeds the trial cadence up.
    cfg.dataset.train_images = 100_000;
    cfg.dataset.val_images = 10_000;
    cfg.seed = seed;
    cfg
}

#[test]
fn oom_skips_feed_penalties_into_the_ranked_history() {
    // Steal-aware search, shard level: on the memory cliff the run must
    // hit the boundary (oom_skips > 0 for some seed), record penalty
    // entries in its window output, and still train the candidates that
    // do fit — penalties never count as evaluated architectures.
    let mut any_skip = false;
    for seed in 0..4u64 {
        let cfg = memory_cliff_cfg(seed);
        cfg.validate().unwrap();
        let ctx = SimContext::new(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut shard = SlaveShard::new(0, 0, &cfg);
        shard.run_until(cfg.duration_s, &snapshot, &ctx);

        let penalties = shard.completed.iter().filter(|r| r.penalty).count() as u64;
        assert_eq!(
            penalties, shard.oom_skips,
            "seed {seed}: every OOM skip must leave exactly one penalty record"
        );
        let trained = shard.completed.iter().filter(|r| !r.penalty).count() as u64;
        assert_eq!(
            trained,
            shard.total_completed(),
            "seed {seed}: penalties must not count as completed trials"
        );
        assert!(trained >= 1, "seed {seed}: the initial architecture fits");
        for r in shard.completed.iter().filter(|r| r.penalty) {
            assert_eq!(r.epochs_trained, 0, "penalty records are untrained");
            assert_eq!(r.ops, 0.0, "penalty records carry no ops");
            assert_eq!(r.accuracy, 0.0, "penalty records rank at the bottom");
            assert!(r.id >> 63 == 1, "penalty ids live in the top-bit range");
        }
        if shard.oom_skips > 0 {
            any_skip = true;
        }
    }
    assert!(
        any_skip,
        "the memory cliff never produced an OOM skip across seeds"
    );
}

fn feedback_routed(r: &BenchmarkReport) -> u64 {
    r.groups.iter().map(|g| g.feedback_routed).sum()
}

fn ring_joins(r: &BenchmarkReport) -> u64 {
    r.groups.iter().map(|g| g.migrant_ring_joins).sum()
}

#[test]
fn feedback_routing_off_reproduces_the_pre_feedback_schedule() {
    // The PR 4 regression: with `feedback_routing = false` the router,
    // the group-scoped penalty filter, and steal-into-migrant are all
    // inert — the elastic scheduler produces the pre-feedback schedules
    // exactly. Checked two ways: the counters read zero on the migration
    // showcase, and on a run where migration never fires the knob's two
    // settings are byte-identical (the loop only ever acts through
    // migrated trials and OOM penalties).
    let mut off = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    off.seed = 3;
    off.feedback_routing = false;
    let r = run_benchmark(&off);
    assert_eq!(feedback_routed(&r), 0, "router must be inert with the knob off");
    assert_eq!(ring_joins(&r), 0, "steal-into-migrant must be off with the knob off");
    assert_eq!(
        r.to_json().to_string(),
        run_benchmark(&off).to_json().to_string(),
        "the pre-feedback schedule stays a pure function of the seed"
    );

    // Homogeneous topology: migration can never fire, so the knob must
    // be invisible bit for bit.
    let mut on = BenchmarkConfig::homogeneous(2);
    on.duration_s = 2.0 * 3600.0;
    on.seed = 7;
    on.subshards_per_node = 2;
    on.work_stealing = true;
    on.migration = true;
    on.feedback_routing = true;
    let mut knob_off = on.clone();
    knob_off.feedback_routing = false;
    assert_eq!(
        run_benchmark(&on).to_json().to_string(),
        run_benchmark(&knob_off).to_json().to_string(),
        "feedback routing must be a no-op when nothing ever migrates"
    );
}

#[test]
fn elastic_mixed_routes_feedback_and_joins_migrant_rings() {
    // The closed-loop acceptance contract on the migration showcase:
    // across a seed scan, migrated trials' observations land back in
    // their source lanes' optimizers (nonzero feedback_routed), at least
    // one stranded sibling joins an adopted migrant's IB ring (nonzero
    // migrant_ring_joins), and closing the loop actually changes the
    // schedule relative to the same run with the knob off.
    let mut any_feedback = false;
    let mut any_ring_join = false;
    let mut any_schedule_change = false;
    for seed in 0..8u64 {
        let mut on = aiperf::scenarios::get("elastic-mixed")
            .expect("elastic preset")
            .config;
        on.seed = seed;
        assert!(on.feedback_routing, "preset closes the loop by default");
        let mut off = on.clone();
        off.feedback_routing = false;
        let r_on = run_benchmark(&on);
        let r_off = run_benchmark(&off);

        // Per-seed invariants: conservation still holds with the loop
        // closed; an observation can only come from an adopted trial; a
        // ring join is a steal; the off-run routes nothing.
        assert_eq!(
            migrations_in(&r_on),
            migrations_out(&r_on),
            "seed {seed}: migrations must balance with feedback on"
        );
        assert!(
            feedback_routed(&r_on) <= migrations_in(&r_on),
            "seed {seed}: at most one routed observation per adoption"
        );
        let steals: u64 = r_on.groups.iter().map(|g| g.steals).sum();
        assert!(
            ring_joins(&r_on) <= steals,
            "seed {seed}: ring joins are a subset of steals"
        );
        assert_eq!(feedback_routed(&r_off), 0, "seed {seed}: off-run routed");
        assert_eq!(ring_joins(&r_off), 0, "seed {seed}: off-run joined a ring");

        if feedback_routed(&r_on) > 0 {
            any_feedback = true;
        }
        if ring_joins(&r_on) > 0 {
            any_ring_join = true;
        }
        if r_on.to_json().to_string() != r_off.to_json().to_string() {
            any_schedule_change = true;
        }
    }
    assert!(
        any_feedback,
        "no migrated-trial observation ever routed back across seeds"
    );
    assert!(
        any_ring_join,
        "no stranded lane ever joined an adopted migrant's ring across seeds"
    );
    assert!(
        any_schedule_change,
        "closing the feedback loop never changed the schedule across seeds"
    );
}

/// Heterogeneous memory cliff: the `cliff` group's accelerator fits the
/// initial architecture with ~1 MB to spare, while the V100 group has
/// room for every morph the limits allow — so OOM penalties are recorded
/// on (and only on) the cliff group, and with the loop closed they stop
/// disqualifying parenthood for the V100 group's proposals.
fn heterogeneous_cliff_cfg(seed: u64) -> BenchmarkConfig {
    let stats = Architecture::initial_imagenet().stats(&OpWeights::default());
    let fixed = stats.params * 12 + 3 * (1 << 29);
    let cliff_gpu = GpuModel {
        memory_bytes: fixed + stats.activation_elems * 2 * 4 + (1 << 20),
        ..GpuModel::v100()
    };
    let mut cfg = BenchmarkConfig {
        topology: ClusterTopology {
            groups: vec![
                NodeGroup::new("cliff", 1, 8, cliff_gpu),
                NodeGroup::new("big", 1, 8, GpuModel::v100()),
            ],
        },
        batch_per_gpu: 4,
        warmup: WarmupSchedule {
            first_epochs: 1,
            step_epochs: 1,
            max_epochs: 2,
            hpo_start_round: 5,
        },
        duration_s: 4.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    cfg.dataset.train_images = 100_000;
    cfg.dataset.val_images = 10_000;
    cfg.seed = seed;
    cfg
}

#[test]
fn oom_penalties_carry_their_group_and_stay_on_it() {
    // Shard-level provenance: every penalty record a cliff-group shard
    // emits carries the cliff group, and the V100 group's shard — same
    // run, same shared snapshot mechanics — never skips at all.
    let mut any_skip = false;
    for seed in 0..4u64 {
        let cfg = heterogeneous_cliff_cfg(seed);
        cfg.validate().unwrap();
        let ctx = SimContext::new(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut cliff = SlaveShard::new(0, 0, &cfg);
        let mut big = SlaveShard::new(1, 1, &cfg);
        cliff.run_until(cfg.duration_s, &snapshot, &ctx);
        big.run_until(cfg.duration_s, &snapshot, &ctx);
        for r in cliff.completed.iter().filter(|r| r.penalty) {
            assert_eq!(r.group, 0, "seed {seed}: penalty must carry the cliff group");
            assert_eq!(r.node, 0, "seed {seed}: penalty must carry the cliff node");
        }
        assert_eq!(big.oom_skips, 0, "seed {seed}: the V100 shard fits everything");
        assert!(big.completed.iter().all(|r| !r.penalty), "seed {seed}");
        if cliff.oom_skips > 0 {
            any_skip = true;
        }
    }
    assert!(any_skip, "the cliff group never hit its memory boundary");
}

#[test]
fn group_scoped_penalties_change_the_heterogeneous_search() {
    // End to end: with the loop closed, a candidate OOM-skipped on the
    // cliff group remains a legal morph parent for the V100 group, so
    // the scoped and global filters must diverge on some seed — while
    // each stays deterministic, completes, and scores.
    let mut any_divergence = false;
    for seed in 0..4u64 {
        let scoped_cfg = heterogeneous_cliff_cfg(seed);
        assert!(scoped_cfg.feedback_routing, "scoping rides the default-on knob");
        let mut global_cfg = scoped_cfg.clone();
        global_cfg.feedback_routing = false;
        let scoped = run_benchmark(&scoped_cfg);
        let global = run_benchmark(&global_cfg);
        for r in [&scoped, &global] {
            assert!(r.score_flops > 0.0, "seed {seed}");
            assert!(r.architectures_evaluated >= 1, "seed {seed}");
        }
        assert_eq!(
            scoped.to_json().to_string(),
            run_benchmark(&scoped_cfg).to_json().to_string(),
            "seed {seed}: scoped run must be a pure function of the seed"
        );
        if scoped.to_json().to_string() != global.to_json().to_string() {
            any_divergence = true;
        }
    }
    assert!(
        any_divergence,
        "per-group penalty scoping never changed a heterogeneous schedule"
    );
}

#[test]
fn memory_cliff_benchmark_is_deterministic_and_scores() {
    // The full pipeline stays healthy with penalties merging into the
    // shared history at every barrier: the run completes, scores, and is
    // a pure function of the seed.
    let cfg = memory_cliff_cfg(2);
    let a = run_benchmark(&cfg);
    let b = run_benchmark(&cfg);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert!(a.score_flops > 0.0);
    assert!(a.architectures_evaluated >= 1);
}
