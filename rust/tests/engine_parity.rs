//! Sequential vs. parallel engine parity.
//!
//! The sharded coordinator must produce *bit-identical* reports from
//! `Engine::Sequential` and `Engine::Parallel` for the same seed: the
//! parallel path only changes which thread executes a shard, never what
//! the shard computes or the order window outputs are merged. Every f64
//! is compared through `to_bits` — "close enough" is a bug here.

use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::run_benchmark_with;
use aiperf::hpo::Backend;
use aiperf::metrics::report::BenchmarkReport;

fn assert_bit_identical(a: &BenchmarkReport, b: &BenchmarkReport, label: &str) {
    assert_eq!(a.nodes, b.nodes, "{label}: nodes");
    assert_eq!(a.total_gpus, b.total_gpus, "{label}: total_gpus");
    assert_eq!(
        a.groups.len(),
        b.groups.len(),
        "{label}: group breakdown length"
    );
    for (i, (x, y)) in a.groups.iter().zip(&b.groups).enumerate() {
        assert_eq!(x.label, y.label, "{label}: group {i} label");
        assert_eq!(x.nodes, y.nodes, "{label}: group {i} nodes");
        assert_eq!(
            x.ops.to_bits(),
            y.ops.to_bits(),
            "{label}: group {i} ops {} vs {}",
            x.ops,
            y.ops
        );
        assert_eq!(
            x.ops_per_second.to_bits(),
            y.ops_per_second.to_bits(),
            "{label}: group {i} ops/s"
        );
        assert_eq!(x.steals, y.steals, "{label}: group {i} steal count");
        assert_eq!(x.oom_skips, y.oom_skips, "{label}: group {i} oom skips");
        assert_eq!(
            x.migrations_in, y.migrations_in,
            "{label}: group {i} migrations in"
        );
        assert_eq!(
            x.migrations_out, y.migrations_out,
            "{label}: group {i} migrations out"
        );
        assert_eq!(
            x.migration_overhead_s.to_bits(),
            y.migration_overhead_s.to_bits(),
            "{label}: group {i} migration overhead"
        );
        assert_eq!(
            x.feedback_routed, y.feedback_routed,
            "{label}: group {i} feedback routed"
        );
        assert_eq!(
            x.migrant_ring_joins, y.migrant_ring_joins,
            "{label}: group {i} migrant ring joins"
        );
        assert_eq!(
            x.barrier_slack_s.to_bits(),
            y.barrier_slack_s.to_bits(),
            "{label}: group {i} barrier slack"
        );
        assert_eq!(x.early_stops, y.early_stops, "{label}: group {i} early stops");
        assert_eq!(
            x.epochs_saved, y.epochs_saved,
            "{label}: group {i} epochs saved"
        );
    }
    assert_eq!(
        a.lane_util.len(),
        b.lane_util.len(),
        "{label}: lane utilization length"
    );
    for (i, (x, y)) in a.lane_util.iter().zip(&b.lane_util).enumerate() {
        assert_eq!(x.group, y.group, "{label}: lane {i} group");
        assert_eq!(x.node, y.node, "{label}: lane {i} node");
        assert_eq!(x.lane, y.lane, "{label}: lane {i} index");
        assert_eq!(
            x.busy_fraction.to_bits(),
            y.busy_fraction.to_bits(),
            "{label}: lane {i} busy fraction"
        );
    }
    assert_eq!(
        a.score_flops.to_bits(),
        b.score_flops.to_bits(),
        "{label}: score {} vs {}",
        a.score_flops,
        b.score_flops
    );
    assert_eq!(
        a.final_error.to_bits(),
        b.final_error.to_bits(),
        "{label}: final_error {} vs {}",
        a.final_error,
        b.final_error
    );
    assert_eq!(
        a.regulated_score.to_bits(),
        b.regulated_score.to_bits(),
        "{label}: regulated score"
    );
    assert_eq!(
        a.architectures_evaluated, b.architectures_evaluated,
        "{label}: architectures evaluated"
    );
    assert_eq!(a.validity, b.validity, "{label}: validity");
    assert_eq!(a.nfs_bytes_read, b.nfs_bytes_read, "{label}: NFS reads");
    assert_eq!(
        a.nfs_bytes_written, b.nfs_bytes_written,
        "{label}: NFS writes"
    );
    // The active-set filter is engine-independent: both engines must
    // see the identical eligible set every window.
    assert_eq!(
        a.shards_touched, b.shards_touched,
        "{label}: shards touched"
    );
    assert_eq!(
        a.shards_skipped, b.shards_skipped,
        "{label}: shards skipped"
    );

    assert_eq!(
        a.score_series.len(),
        b.score_series.len(),
        "{label}: score series length"
    );
    for (i, (x, y)) in a.score_series.iter().zip(&b.score_series).enumerate() {
        assert_eq!(x.t.to_bits(), y.t.to_bits(), "{label}: sample {i} t");
        assert_eq!(
            x.cumulative_ops.to_bits(),
            y.cumulative_ops.to_bits(),
            "{label}: sample {i} cumulative ops"
        );
        assert_eq!(x.flops.to_bits(), y.flops.to_bits(), "{label}: sample {i} flops");
        assert_eq!(
            x.best_error.to_bits(),
            y.best_error.to_bits(),
            "{label}: sample {i} best error"
        );
        assert_eq!(
            x.regulated.to_bits(),
            y.regulated.to_bits(),
            "{label}: sample {i} regulated"
        );
    }

    assert_eq!(
        a.telemetry.len(),
        b.telemetry.len(),
        "{label}: telemetry length"
    );
    for (i, (x, y)) in a.telemetry.iter().zip(&b.telemetry).enumerate() {
        for (what, u, v) in [
            ("t", x.t, y.t),
            ("gpu_util_mean", x.gpu_util_mean, y.gpu_util_mean),
            ("gpu_util_std", x.gpu_util_std, y.gpu_util_std),
            ("gpu_mem_mean", x.gpu_mem_mean, y.gpu_mem_mean),
            ("gpu_mem_std", x.gpu_mem_std, y.gpu_mem_std),
            ("cpu_util_mean", x.cpu_util_mean, y.cpu_util_mean),
            ("cpu_util_std", x.cpu_util_std, y.cpu_util_std),
            ("host_mem_mean", x.host_mem_mean, y.host_mem_mean),
            ("host_mem_std", x.host_mem_std, y.host_mem_std),
        ] {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{label}: telemetry sample {i} field {what}"
            );
        }
    }

    // Belt and braces: the machine-readable report must serialize
    // identically byte for byte.
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "{label}: JSON report"
    );
}

#[test]
fn smoke_scenario_parity_seeds_0_to_2() {
    for seed in 0..3u64 {
        let mut cfg = aiperf::scenarios::get("smoke").expect("smoke preset").config;
        cfg.seed = seed;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_bit_identical(&seq, &par, &format!("smoke seed {seed}"));
    }
}

#[test]
fn parity_with_odd_shard_count_and_uneven_windows() {
    // 5 shards never divide evenly across a pool, and a sync interval
    // that does not divide the duration (6300 / 800 = 7.875) exercises
    // the truncated final window.
    let mut cfg = BenchmarkConfig::homogeneous(5);
    cfg.duration_s = 1.75 * 3600.0;
    cfg.seed = 13;
    cfg.sync_interval_s = 800.0;
    let seq = run_benchmark_with(&cfg, Engine::Sequential);
    let par = run_benchmark_with(&cfg, Engine::Parallel);
    assert_bit_identical(&seq, &par, "odd shards");
}

#[test]
fn parity_on_heterogeneous_mixed_gpu_topology() {
    // Non-uniform shards: T4 and V100 groups evolve at different speeds,
    // so the parallel pool sees unbalanced work — merge order and per-
    // group ops attribution must still be bit-identical to sequential.
    for seed in [0u64, 7] {
        let mut cfg = aiperf::scenarios::get("t4v100-mixed")
            .expect("mixed preset")
            .config;
        cfg.duration_s = 2.0 * 3600.0;
        cfg.seed = seed;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_bit_identical(&seq, &par, &format!("t4v100-mixed seed {seed}"));
        assert_eq!(seq.groups.len(), 2, "expected two-group breakdown");
        assert!(
            seq.groups.iter().all(|g| g.ops > 0.0),
            "both groups must contribute ops"
        );
    }
}

#[test]
fn parity_with_subshards_and_work_stealing_on_mixed_topology() {
    // The elastic path: sub-shard lanes (2 per node), per-group batch
    // overrides, the steal scheduler, and cross-group migration all
    // enabled on a heterogeneous topology. Stealing resolves inside each
    // node's own event loop in a seed-derived scan order and migration
    // resolves single-threaded at the barriers, so both must be
    // invisible to the engine choice — fresh seeds beyond the classic
    // mixed-parity test.
    for seed in [3u64, 11] {
        let mut cfg = aiperf::scenarios::get("t4v100-mixed")
            .expect("mixed preset")
            .config;
        assert!(cfg.work_stealing, "preset enables stealing");
        assert!(cfg.migration, "preset enables migration");
        assert_eq!(cfg.subshards_per_node, 2, "preset enables sub-shards");
        cfg.duration_s = 3.0 * 3600.0;
        cfg.seed = seed;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_bit_identical(&seq, &par, &format!("subshard steal seed {seed}"));
        assert!(
            seq.groups.iter().all(|g| g.ops > 0.0),
            "both groups must contribute ops"
        );
    }
}

#[test]
fn parity_on_elastic_mixed_migration_preset() {
    // The migration showcase at its full crafted duration: staged
    // candidates, barrier placements, adopted trials re-timed over IB,
    // and the closed feedback loop (observation routing, group-scoped
    // penalties, steal-into-migrant — all on by default) — all of it
    // must be a pure function of (seed, config), independent of the
    // engine. A fresh seed set beyond the other mixed tests.
    for seed in [0u64, 5, 9] {
        let mut cfg = aiperf::scenarios::get("elastic-mixed")
            .expect("elastic preset")
            .config;
        assert!(cfg.feedback_routing, "preset closes the feedback loop");
        cfg.seed = seed;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_bit_identical(&seq, &par, &format!("elastic-mixed seed {seed}"));
    }
}

#[test]
fn parity_on_elastic_mixed_with_feedback_routing_off() {
    // The pre-feedback schedule (PR 4's) must also stay engine-parity
    // clean: with the knob off the router, penalty scoping, and
    // steal-into-migrant are all inert, and the counters read zero.
    let mut cfg = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    cfg.feedback_routing = false;
    cfg.seed = 1;
    let seq = run_benchmark_with(&cfg, Engine::Sequential);
    let par = run_benchmark_with(&cfg, Engine::Parallel);
    assert_bit_identical(&seq, &par, "elastic-mixed feedback off");
    assert!(
        seq.groups
            .iter()
            .all(|g| g.feedback_routed == 0 && g.migrant_ring_joins == 0),
        "feedback counters must be zero with routing off"
    );
}

#[test]
fn parity_on_t4_preset_shortened() {
    let mut cfg = aiperf::scenarios::get("t4-32").expect("t4 preset").config;
    cfg.duration_s = 2.0 * 3600.0;
    cfg.seed = 1;
    let seq = run_benchmark_with(&cfg, Engine::Sequential);
    let par = run_benchmark_with(&cfg, Engine::Parallel);
    assert_bit_identical(&seq, &par, "t4-32 short");
}

#[test]
fn parity_on_exa_100k_truncated() {
    // The aspirational exascale preset, truncated to three barrier
    // windows (5400 s at the preset's 1800 s sync interval). 102,400
    // trial lanes: the first window seeds every lane, the ~10^4-record
    // merge lands before the final window, so window-3 proposals select
    // against a big penalty-free snapshot — the closed-form rank path —
    // while this test pins it bit-identical across engines.
    let mut cfg = aiperf::scenarios::get("exa-100k").expect("exa preset").config;
    assert_eq!(cfg.total_subshards(), 102_400, "preset lane count");
    cfg.duration_s = 5400.0;
    cfg.seed = 42;
    let seq = run_benchmark_with(&cfg, Engine::Sequential);
    let par = run_benchmark_with(&cfg, Engine::Parallel);
    assert_bit_identical(&seq, &par, "exa-100k truncated");
    assert!(
        seq.architectures_evaluated > 0,
        "truncated exa run must complete trials"
    );
}

#[test]
fn hpo_and_early_stop_knobs_off_are_byte_inert() {
    // The redesigned search API must be invisible until asked for:
    // spelling out the defaults (`hpo = tpe`, `early_stop` off — even
    // with per-group overrides naming tpe explicitly and the inert
    // early-stop tuning knobs perturbed) reproduces the pre-knob
    // schedule byte for byte on the full machine-readable report.
    let baseline = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    let mut spelled = baseline.clone();
    spelled.hpo = Backend::Tpe;
    spelled.early_stop = false;
    // Tuning knobs of a disabled feature must not leak into the run.
    spelled.early_stop_min_epochs = 7;
    spelled.early_stop_margin = 0.5;
    for g in &mut spelled.topology.groups {
        g.hpo = Some(Backend::Tpe);
    }
    let a = run_benchmark_with(&baseline, Engine::Sequential);
    let b = run_benchmark_with(&spelled, Engine::Sequential);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "explicit default knobs must reproduce the implicit-default run"
    );
    assert!(
        a.groups.iter().all(|g| g.early_stops == 0 && g.epochs_saved == 0),
        "early-stop counters must read zero with the knob off"
    );
}

#[test]
fn parity_holds_for_every_hpo_backend() {
    // Each pluggable backend draws its suggestions from the lane RNG (or
    // a deterministic cursor) inside the shard's own event loop, so the
    // engine choice must stay invisible no matter which optimizer runs.
    for backend in [
        Backend::Tpe,
        Backend::Evolutionary,
        Backend::Random,
        Backend::Grid,
    ] {
        let mut cfg = aiperf::scenarios::get("t4v100-mixed")
            .expect("mixed preset")
            .config;
        cfg.duration_s = 3.0 * 3600.0;
        cfg.seed = 2;
        cfg.hpo = backend;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_bit_identical(&seq, &par, &format!("hpo backend {}", backend.as_str()));
    }
}

#[test]
fn early_stop_terminates_trials_and_frees_lanes() {
    // With the LogFit predictor armed on the elastic preset, some seed in
    // a small scan must actually terminate doomed trials — and the freed
    // lanes must show up as scheduler opportunities (steals and adopted
    // migrants stay nonzero alongside them). Parity is pinned on the
    // first seed so the EarlyStopped event's re-timing rules get engine
    // coverage too.
    let mut any_early = false;
    let mut any_steals = false;
    let mut any_migrations = false;
    for seed in 0..8u64 {
        let mut cfg = aiperf::scenarios::get("elastic-mixed")
            .expect("elastic preset")
            .config;
        cfg.seed = seed;
        cfg.early_stop = true;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        if seed == 0 {
            let par = run_benchmark_with(&cfg, Engine::Parallel);
            assert_bit_identical(&seq, &par, "elastic-mixed early-stop seed 0");
        }
        for g in &seq.groups {
            if g.early_stops > 0 {
                any_early = true;
                assert!(
                    g.epochs_saved > 0,
                    "seed {seed}: an early stop must save at least one epoch"
                );
            }
            any_steals |= g.steals > 0;
            any_migrations |= g.migrations_in > 0;
        }
        if any_early && any_steals && any_migrations {
            break;
        }
    }
    assert!(any_early, "no seed in the scan early-stopped a trial");
    assert!(any_steals, "freed lanes never joined a sibling trial");
    assert!(any_migrations, "freed lanes never adopted a migrant");
}
