//! Cross-language parity: the rust synthetic dataset must produce
//! bit-identical batches to python/compile/dataset.py (the ABI that lets
//! both sides materialize the same corpus without shipping arrays).
//!
//! Shells out to the build-time python; skips when python/jax is absent
//! (the runtime never needs python — this is a build-path check).

use aiperf::data::SyntheticDataset;

fn python_batch(seed: u64, start: u64, batch: usize, image: usize, channels: usize,
                classes: usize) -> Option<(Vec<f32>, Vec<i32>)> {
    let code = format!(
        "import sys; sys.path.insert(0, 'python')\n\
         from compile.dataset import make_batch\n\
         xs, ys = make_batch({seed}, {start}, {batch}, {image}, {channels}, {classes})\n\
         print(' '.join(repr(float(v)) for v in xs.reshape(-1)))\n\
         print(' '.join(str(int(v)) for v in ys))"
    );
    // The python/ tree lives at the workspace root, one level above the
    // aiperf crate's manifest directory (rust/).
    let out = std::process::Command::new("python3")
        .arg("-c")
        .arg(&code)
        .current_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
        .output()
        .ok()?;
    if !out.status.success() {
        eprintln!(
            "SKIP python parity: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        return None;
    }
    let text = String::from_utf8(out.stdout).ok()?;
    let mut lines = text.lines();
    let xs: Vec<f32> = lines
        .next()?
        .split_whitespace()
        .map(|v| v.parse().unwrap())
        .collect();
    let ys: Vec<i32> = lines
        .next()?
        .split_whitespace()
        .map(|v| v.parse().unwrap())
        .collect();
    Some((xs, ys))
}

#[test]
fn labels_match_python() {
    let Some((_, py_ys)) = python_batch(3, 100, 16, 4, 1, 4) else {
        return;
    };
    let d = SyntheticDataset::new(3, 4, 1, 4);
    let (_, rs_ys) = d.batch(100, 16);
    assert_eq!(rs_ys, py_ys, "label streams diverge");
}

#[test]
fn pixels_match_python_within_f32_rounding() {
    let Some((py_xs, _)) = python_batch(7, 0, 4, 8, 3, 10) else {
        return;
    };
    let d = SyntheticDataset::new(7, 8, 3, 10);
    let (rs_xs, _) = d.batch(0, 4);
    assert_eq!(rs_xs.len(), py_xs.len());
    let mut max_err = 0f32;
    for (a, b) in rs_xs.iter().zip(&py_xs) {
        max_err = max_err.max((a - b).abs());
    }
    // python computes templates in float64 then casts; rust accumulates in
    // f32 — identical counter hashes, so only rounding separates them.
    assert!(max_err < 1e-5, "pixel divergence {max_err}");
}
