//! Fuzz-style robustness harness for the text-facing parsers:
//! `config::from_text`, the NDJSON reader, `Json::parse`, and the
//! stream-summary reconstructor.
//!
//! proptest/cargo-fuzz are not vendored offline, so this is a seeded
//! in-tree fuzzer: valid corpus inputs are battered with random byte
//! flips, insertions, deletions, truncations, slice duplications, and
//! line-level shuffles, and every parser must return `Ok`/`Err` —
//! never panic, never hang. Truncated stream files specifically must
//! be *detected* (an `Err`), not crashed on.
//!
//! Iteration count: `AIPERF_FUZZ_ITERS` (default 256; CI smoke runs
//! more).

use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::run_benchmark_streaming;
use aiperf::metrics::stream::reconstruct_summary;
use aiperf::util::json::Json;
use aiperf::util::ndjson::NdjsonReader;
use aiperf::util::rng::{derive, Rng};

fn iters() -> u64 {
    std::env::var("AIPERF_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Apply 1–7 random byte-level edits to a copy of `input`.
fn mutate_bytes(input: &[u8], rng: &mut Rng) -> Vec<u8> {
    let mut out = input.to_vec();
    for _ in 0..rng.gen_range_usize(1, 8) {
        if out.is_empty() {
            out.push(rng.gen_range_u64(0, 256) as u8);
            continue;
        }
        match rng.gen_range_u64(0, 5) {
            // Flip one byte.
            0 => {
                let i = rng.gen_range_usize(0, out.len());
                out[i] = rng.gen_range_u64(0, 256) as u8;
            }
            // Insert a random byte.
            1 => {
                let i = rng.gen_range_usize(0, out.len() + 1);
                out.insert(i, rng.gen_range_u64(0, 256) as u8);
            }
            // Delete one byte.
            2 => {
                let i = rng.gen_range_usize(0, out.len());
                out.remove(i);
            }
            // Truncate (the mid-write crash shape).
            3 => {
                let i = rng.gen_range_usize(0, out.len());
                out.truncate(i);
            }
            // Duplicate a short slice somewhere else.
            _ => {
                let a = rng.gen_range_usize(0, out.len());
                let b = (a + rng.gen_range_usize(1, 64)).min(out.len());
                let slice: Vec<u8> = out[a..b].to_vec();
                let i = rng.gen_range_usize(0, out.len() + 1);
                for (k, byte) in slice.into_iter().enumerate() {
                    out.insert(i + k, byte);
                }
            }
        }
    }
    out
}

/// Line-level mutation: drop, duplicate, or swap whole lines — the
/// shapes a hand-edited or concatenated stream file takes.
fn mutate_lines(input: &str, rng: &mut Rng) -> String {
    let mut lines: Vec<&str> = input.lines().collect();
    if lines.is_empty() {
        return String::new();
    }
    match rng.gen_range_u64(0, 3) {
        0 => {
            let i = rng.gen_range_usize(0, lines.len());
            lines.remove(i);
        }
        1 => {
            let i = rng.gen_range_usize(0, lines.len());
            lines.insert(i, lines[i]);
        }
        _ => {
            let i = rng.gen_range_usize(0, lines.len());
            let j = rng.gen_range_usize(0, lines.len());
            lines.swap(i, j);
        }
    }
    let mut out = lines.join("\n");
    out.push('\n');
    out
}

/// Valid config corpus: the default, a heterogeneous preset, and a
/// config exercising the search-API knobs (non-default `hpo` backend,
/// early stopping armed, a per-group backend override) so the fuzzer
/// batters those key spellings too.
fn config_corpus() -> Vec<String> {
    let mut search = aiperf::scenarios::get("t4v100-mixed")
        .expect("preset exists")
        .config;
    search.hpo = aiperf::hpo::Backend::Grid;
    search.early_stop = true;
    search.early_stop_min_epochs = 5;
    search.early_stop_margin = 0.05;
    search.topology.groups[0].hpo = Some(aiperf::hpo::Backend::Evolutionary);
    vec![
        BenchmarkConfig::default().to_text(),
        aiperf::scenarios::get("t4v100-mixed")
            .expect("preset exists")
            .config
            .to_text(),
        search.to_text(),
    ]
}

/// One small real stream (2 nodes, 1 h) as the NDJSON corpus seed.
fn stream_corpus() -> String {
    let mut cfg = BenchmarkConfig::homogeneous(2);
    cfg.duration_s = 3600.0;
    cfg.seed = 5;
    let mut buf = Vec::new();
    run_benchmark_streaming(&cfg, Engine::Sequential, &mut buf);
    String::from_utf8(buf).expect("stream is UTF-8")
}

#[test]
fn fuzz_config_from_text_never_panics() {
    let corpus = config_corpus();
    for seed in 0..iters() {
        let mut rng = derive(seed, "fuzz-config", 0);
        let base = &corpus[rng.gen_range_usize(0, corpus.len())];
        let mutated = mutate_bytes(base.as_bytes(), &mut rng);
        let text = String::from_utf8_lossy(&mutated);
        // Must return, Ok or Err — a panic fails the test. A config
        // that still parses must also re-render without panicking.
        if let Ok(cfg) = BenchmarkConfig::from_text(&text) {
            let _ = cfg.to_text();
        }
    }
}

#[test]
fn fuzz_ndjson_reader_never_panics() {
    let stream = stream_corpus();
    for seed in 0..iters() {
        let mut rng = derive(seed, "fuzz-ndjson", 0);
        let mutated = mutate_bytes(stream.as_bytes(), &mut rng);
        let text = String::from_utf8_lossy(&mutated);
        // Drain the whole reader: every line yields Ok or a positional
        // Err, never a panic, and the iterator always terminates.
        let drained = NdjsonReader::new(&text).count();
        assert!(drained <= text.lines().count());
    }
}

#[test]
fn fuzz_reconstruct_summary_never_panics() {
    let stream = stream_corpus();
    // The unmutated corpus is complete and must reconstruct.
    assert!(reconstruct_summary(&stream).is_ok());
    for seed in 0..iters() {
        let mut rng = derive(seed, "fuzz-stream", 0);
        // Alternate byte-level and line-level mutations.
        let text = if seed % 2 == 0 {
            String::from_utf8_lossy(&mutate_bytes(stream.as_bytes(), &mut rng)).into_owned()
        } else {
            mutate_lines(&stream, &mut rng)
        };
        let _ = reconstruct_summary(&text);
    }
}

#[test]
fn fuzz_truncated_streams_always_detected() {
    let stream = stream_corpus();
    // Pure truncation (no other edits): every strict prefix that loses
    // at least the final newline's worth of trailer must be an Err —
    // the "crashed mid-write" file is reported, not silently summed.
    for seed in 0..iters() {
        let mut rng = derive(seed, "fuzz-truncate", 0);
        let mut cut = rng.gen_range_usize(0, stream.len() - 1);
        while !stream.is_char_boundary(cut) {
            cut -= 1;
        }
        assert!(
            reconstruct_summary(&stream[..cut]).is_err(),
            "truncation at byte {cut} went undetected"
        );
    }
}

#[test]
fn fuzz_json_parse_never_panics() {
    let docs = [
        BenchmarkConfig::default().to_text(),
        "{\"a\":[1,2.5,-3e9,null,true,\"x\\n\\u0041\"],\"b\":{\"c\":{}}}".to_string(),
    ];
    for seed in 0..iters() {
        let mut rng = derive(seed, "fuzz-json", 0);
        let base = &docs[rng.gen_range_usize(0, docs.len())];
        let mutated = mutate_bytes(base.as_bytes(), &mut rng);
        let text = String::from_utf8_lossy(&mutated);
        let _ = Json::parse(&text);
    }
}
