//! Runtime end-to-end tests: load the real AOT artifacts, execute them on
//! the PJRT CPU client, and verify training/eval semantics.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).
//! When artifacts are absent (plain `cargo test` in a fresh checkout) the
//! tests skip with a notice rather than fail — artifact production is
//! python's responsibility, exercised by pytest.

use aiperf::data::SyntheticDataset;
use aiperf::runtime::{Manifest, Runtime, Trainer};

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_grid_variants() {
    let Some(m) = manifest() else { return };
    assert!(!m.variants.is_empty());
    for v in &m.variants {
        assert!(v.num_params() == (3 + 3 * v.depth + 2) as usize);
        for kind in [&v.files.init, &v.files.train, &v.files.eval] {
            assert!(m.hlo_path(kind).exists(), "missing {kind}");
        }
    }
}

#[test]
fn init_params_match_manifest_shapes() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let t = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
    assert_eq!(t.variant.name, m.default_variant);
    assert!(t.variant.total_param_elems() > 0);
}

#[test]
fn train_step_reduces_loss() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let mut t = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
    let v = t.variant.clone();
    let data = SyntheticDataset::new(
        0,
        v.image as usize,
        v.channels as usize,
        v.num_classes as usize,
    );
    let b = v.batch as usize;
    let mut first = 0f32;
    let mut last = 0f32;
    for step in 0..40u64 {
        let (xs, ys) = data.batch(step * b as u64, b);
        let loss = t.train_step(&xs, &ys, 0.08).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {step}");
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.9,
        "loss did not decrease: {first} → {last}"
    );
    assert_eq!(t.steps_done, 40);
}

#[test]
fn eval_step_consistent_with_training() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let mut t = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
    let v = t.variant.clone();
    let data = SyntheticDataset::new(
        0,
        v.image as usize,
        v.channels as usize,
        v.num_classes as usize,
    );
    let b = v.batch as usize;
    // Untrained accuracy ≈ chance.
    let (l0, a0) = t.evaluate(&data, 500_000, 4).unwrap();
    assert!(l0 > 0.0);
    assert!(a0 < 0.45, "untrained accuracy suspiciously high: {a0}");
    // Train, then accuracy must improve.
    for step in 0..60u64 {
        let (xs, ys) = data.batch(step * b as u64, b);
        t.train_step(&xs, &ys, 0.08).unwrap();
    }
    let (_, a1) = t.evaluate(&data, 500_000, 4).unwrap();
    assert!(a1 > a0 + 0.1, "accuracy did not improve: {a0} → {a1}");
}

#[test]
fn deterministic_training_given_fixed_data() {
    let Some(m) = manifest() else { return };
    let run = || {
        let mut rt = Runtime::cpu().unwrap();
        let mut t = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
        let v = t.variant.clone();
        let data = SyntheticDataset::new(
            3,
            v.image as usize,
            v.channels as usize,
            v.num_classes as usize,
        );
        let mut losses = Vec::new();
        for step in 0..5u64 {
            let (xs, ys) = data.batch(step * v.batch, v.batch as usize);
            losses.push(t.train_step(&xs, &ys, 0.05).unwrap());
        }
        losses
    };
    assert_eq!(run(), run());
}

#[test]
fn executable_cache_reused_across_trainers() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let _a = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
    let n = rt.cache_len();
    let _b = Trainer::new(&mut rt, &m, &m.default_variant).unwrap();
    assert_eq!(rt.cache_len(), n, "same variant must not recompile");
}

#[test]
fn all_variants_compile_and_step() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::cpu().unwrap();
    for v in &m.variants {
        let mut t = Trainer::new(&mut rt, &m, &v.name).unwrap();
        let data = SyntheticDataset::new(
            0,
            v.image as usize,
            v.channels as usize,
            v.num_classes as usize,
        );
        let (xs, ys) = data.batch(0, v.batch as usize);
        let loss = t.train_step(&xs, &ys, 0.05).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "variant {}", v.name);
    }
}
