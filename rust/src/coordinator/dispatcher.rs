//! Trial dispatcher (paper §4.3 step 2: "Master dispatches benchmark
//! workloads with SLURM … in a parallel way to slave nodes
//! asynchronously").
//!
//! Exactly-once bookkeeping: every trial id is assigned to exactly one
//! node and completed exactly once — the routing invariant the proptest
//! suite (rust/tests/proptest_coordinator.rs) exercises.
//!
//! All state lives in deterministic containers (a `BTreeMap` for the
//! in-flight set, dense `Vec`s for the per-node totals): iteration order
//! is a pure function of the contents, so nothing here can perturb a
//! schedule even if a caller iterates.

use std::collections::BTreeMap;

/// Routing state.
#[derive(Debug, Clone, Default)]
pub struct Dispatcher {
    next_trial: u64,
    /// trial id → node, for in-flight trials. Ordered so that any
    /// iteration over the in-flight set is deterministic.
    in_flight: BTreeMap<u64, usize>,
    /// Per-node totals, indexed by node id (small dense indices; grown on
    /// demand so sparse node ids still work).
    assigned: Vec<u64>,
    completed: Vec<u64>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum DispatchError {
    NotInFlight(u64),
    WrongNode(u64, usize, usize),
    NodeBusy(usize),
}

impl std::fmt::Display for DispatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DispatchError::NotInFlight(trial) => write!(f, "trial {trial} is not in flight"),
            DispatchError::WrongNode(trial, owner, node) => {
                write!(f, "trial {trial} is owned by node {owner}, not {node}")
            }
            DispatchError::NodeBusy(node) => {
                write!(f, "node {node} already holds an in-flight trial")
            }
        }
    }
}

impl std::error::Error for DispatchError {}

/// Grow-on-demand increment of a dense per-node counter vector.
fn bump(counters: &mut Vec<u64>, node: usize) {
    if counters.len() <= node {
        counters.resize(node + 1, 0);
    }
    counters[node] += 1;
}

impl Dispatcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assign a fresh trial id to `node`. A node runs one trial at a time
    /// (each slave trains one candidate across its 8 GPUs).
    pub fn assign(&mut self, node: usize) -> Result<u64, DispatchError> {
        if self.in_flight.values().any(|&n| n == node) {
            return Err(DispatchError::NodeBusy(node));
        }
        let id = self.next_trial;
        self.next_trial += 1;
        self.in_flight.insert(id, node);
        bump(&mut self.assigned, node);
        Ok(id)
    }

    /// Mark a trial complete on `node`.
    pub fn complete(&mut self, trial: u64, node: usize) -> Result<(), DispatchError> {
        match self.in_flight.get(&trial) {
            None => Err(DispatchError::NotInFlight(trial)),
            Some(&owner) if owner != node => Err(DispatchError::WrongNode(trial, owner, node)),
            Some(_) => {
                self.in_flight.remove(&trial);
                bump(&mut self.completed, node);
                Ok(())
            }
        }
    }

    pub fn in_flight_count(&self) -> usize {
        self.in_flight.len()
    }

    pub fn total_assigned(&self) -> u64 {
        self.next_trial
    }

    pub fn completed_on(&self, node: usize) -> u64 {
        self.completed.get(node).copied().unwrap_or(0)
    }

    pub fn total_completed(&self) -> u64 {
        self.completed.iter().sum()
    }

    /// Invariant check: assigned = completed + in-flight, per node and
    /// globally.
    pub fn check_invariants(&self) -> Result<(), String> {
        let total_done: u64 = self.completed.iter().sum();
        if total_done + self.in_flight.len() as u64 != self.next_trial {
            return Err(format!(
                "assigned {} ≠ completed {} + in-flight {}",
                self.next_trial,
                total_done,
                self.in_flight.len()
            ));
        }
        let total_assigned: u64 = self.assigned.iter().sum();
        if total_assigned != self.next_trial {
            return Err(format!(
                "per-node assigned sum {} ≠ issued trial ids {}",
                total_assigned, self.next_trial
            ));
        }
        let nodes = self.assigned.len().max(self.completed.len());
        for node in 0..nodes {
            let a = self.assigned.get(node).copied().unwrap_or(0);
            let c = self.completed.get(node).copied().unwrap_or(0);
            let f = self.in_flight.values().filter(|&&n| n == node).count() as u64;
            if c + f != a {
                return Err(format!("node {node}: assigned {a} ≠ {c} + {f}"));
            }
        }
        if let Some((&trial, _)) = self.in_flight.last_key_value() {
            if trial >= self.next_trial {
                return Err(format!(
                    "in-flight trial {trial} was never issued (next id {})",
                    self.next_trial
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_complete_cycle() {
        let mut d = Dispatcher::new();
        let t0 = d.assign(0).unwrap();
        let t1 = d.assign(1).unwrap();
        assert_ne!(t0, t1);
        assert_eq!(d.in_flight_count(), 2);
        d.complete(t0, 0).unwrap();
        d.complete(t1, 1).unwrap();
        assert_eq!(d.in_flight_count(), 0);
        assert_eq!(d.total_completed(), 2);
        d.check_invariants().unwrap();
    }

    #[test]
    fn node_runs_one_trial_at_a_time() {
        let mut d = Dispatcher::new();
        let t = d.assign(3).unwrap();
        assert_eq!(d.assign(3), Err(DispatchError::NodeBusy(3)));
        d.complete(t, 3).unwrap();
        d.assign(3).unwrap();
    }

    #[test]
    fn double_complete_rejected() {
        let mut d = Dispatcher::new();
        let t = d.assign(0).unwrap();
        d.complete(t, 0).unwrap();
        assert_eq!(d.complete(t, 0), Err(DispatchError::NotInFlight(t)));
    }

    #[test]
    fn wrong_node_rejected() {
        let mut d = Dispatcher::new();
        let t = d.assign(0).unwrap();
        assert_eq!(d.complete(t, 1), Err(DispatchError::WrongNode(t, 0, 1)));
        d.check_invariants().unwrap();
    }

    #[test]
    fn per_node_counters() {
        let mut d = Dispatcher::new();
        for round in 0..5u64 {
            for node in 0..3usize {
                let t = d.assign(node).unwrap();
                d.complete(t, node).unwrap();
            }
            let _ = round;
        }
        for node in 0..3 {
            assert_eq!(d.completed_on(node), 5);
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn sparse_node_indices() {
        // Dense counter vectors must grow on demand: assigning to a high
        // node id first, then a low one, keeps every invariant.
        let mut d = Dispatcher::new();
        let t_hi = d.assign(17).unwrap();
        d.check_invariants().unwrap();
        let t_lo = d.assign(2).unwrap();
        d.check_invariants().unwrap();
        assert_eq!(d.completed_on(17), 0);
        assert_eq!(d.completed_on(40), 0, "never-seen node reads zero");
        d.complete(t_hi, 17).unwrap();
        d.complete(t_lo, 2).unwrap();
        assert_eq!(d.completed_on(17), 1);
        assert_eq!(d.completed_on(2), 1);
        assert_eq!(d.total_completed(), 2);
        d.check_invariants().unwrap();
    }

    #[test]
    fn in_flight_iteration_is_ordered() {
        // The in-flight map is a BTreeMap: snapshots of the in-flight set
        // are sorted by trial id, independent of insertion pattern.
        let mut d = Dispatcher::new();
        let mut ids = Vec::new();
        for node in [5usize, 1, 9, 3] {
            ids.push(d.assign(node).unwrap());
        }
        let snapshot: Vec<u64> = d.in_flight.keys().copied().collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(snapshot, sorted);
        d.check_invariants().unwrap();
    }
}
