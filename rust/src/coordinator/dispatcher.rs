//! Trial dispatcher (paper §4.3 step 2: "Master dispatches benchmark
//! workloads with SLURM … in a parallel way to slave nodes
//! asynchronously").
//!
//! Exactly-once bookkeeping: every trial id is assigned to exactly one
//! node and completed exactly once — the routing invariant the proptest
//! suite (rust/tests/proptest_coordinator.rs) exercises.

use std::collections::HashMap;

/// Routing state.
#[derive(Debug, Clone, Default)]
pub struct Dispatcher {
    next_trial: u64,
    /// trial id → node, for in-flight trials.
    in_flight: HashMap<u64, usize>,
    /// Per-node totals.
    assigned: HashMap<usize, u64>,
    completed: HashMap<usize, u64>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum DispatchError {
    NotInFlight(u64),
    WrongNode(u64, usize, usize),
    NodeBusy(usize),
}

impl std::fmt::Display for DispatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DispatchError::NotInFlight(trial) => write!(f, "trial {trial} is not in flight"),
            DispatchError::WrongNode(trial, owner, node) => {
                write!(f, "trial {trial} is owned by node {owner}, not {node}")
            }
            DispatchError::NodeBusy(node) => {
                write!(f, "node {node} already holds an in-flight trial")
            }
        }
    }
}

impl std::error::Error for DispatchError {}

impl Dispatcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assign a fresh trial id to `node`. A node runs one trial at a time
    /// (each slave trains one candidate across its 8 GPUs).
    pub fn assign(&mut self, node: usize) -> Result<u64, DispatchError> {
        if self.in_flight.values().any(|&n| n == node) {
            return Err(DispatchError::NodeBusy(node));
        }
        let id = self.next_trial;
        self.next_trial += 1;
        self.in_flight.insert(id, node);
        *self.assigned.entry(node).or_insert(0) += 1;
        Ok(id)
    }

    /// Mark a trial complete on `node`.
    pub fn complete(&mut self, trial: u64, node: usize) -> Result<(), DispatchError> {
        match self.in_flight.get(&trial) {
            None => Err(DispatchError::NotInFlight(trial)),
            Some(&owner) if owner != node => Err(DispatchError::WrongNode(trial, owner, node)),
            Some(_) => {
                self.in_flight.remove(&trial);
                *self.completed.entry(node).or_insert(0) += 1;
                Ok(())
            }
        }
    }

    pub fn in_flight_count(&self) -> usize {
        self.in_flight.len()
    }

    pub fn total_assigned(&self) -> u64 {
        self.next_trial
    }

    pub fn completed_on(&self, node: usize) -> u64 {
        self.completed.get(&node).copied().unwrap_or(0)
    }

    pub fn total_completed(&self) -> u64 {
        self.completed.values().sum()
    }

    /// Invariant check: assigned = completed + in-flight, per node and
    /// globally.
    pub fn check_invariants(&self) -> Result<(), String> {
        let total_done: u64 = self.completed.values().sum();
        if total_done + self.in_flight.len() as u64 != self.next_trial {
            return Err(format!(
                "assigned {} ≠ completed {} + in-flight {}",
                self.next_trial,
                total_done,
                self.in_flight.len()
            ));
        }
        for (&node, &a) in &self.assigned {
            let c = self.completed.get(&node).copied().unwrap_or(0);
            let f = self.in_flight.values().filter(|&&n| n == node).count() as u64;
            if c + f != a {
                return Err(format!("node {node}: assigned {a} ≠ {c} + {f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_complete_cycle() {
        let mut d = Dispatcher::new();
        let t0 = d.assign(0).unwrap();
        let t1 = d.assign(1).unwrap();
        assert_ne!(t0, t1);
        assert_eq!(d.in_flight_count(), 2);
        d.complete(t0, 0).unwrap();
        d.complete(t1, 1).unwrap();
        assert_eq!(d.in_flight_count(), 0);
        assert_eq!(d.total_completed(), 2);
        d.check_invariants().unwrap();
    }

    #[test]
    fn node_runs_one_trial_at_a_time() {
        let mut d = Dispatcher::new();
        let t = d.assign(3).unwrap();
        assert_eq!(d.assign(3), Err(DispatchError::NodeBusy(3)));
        d.complete(t, 3).unwrap();
        d.assign(3).unwrap();
    }

    #[test]
    fn double_complete_rejected() {
        let mut d = Dispatcher::new();
        let t = d.assign(0).unwrap();
        d.complete(t, 0).unwrap();
        assert_eq!(d.complete(t, 0), Err(DispatchError::NotInFlight(t)));
    }

    #[test]
    fn wrong_node_rejected() {
        let mut d = Dispatcher::new();
        let t = d.assign(0).unwrap();
        assert_eq!(d.complete(t, 1), Err(DispatchError::WrongNode(t, 0, 1)));
        d.check_invariants().unwrap();
    }

    #[test]
    fn per_node_counters() {
        let mut d = Dispatcher::new();
        for round in 0..5u64 {
            for node in 0..3usize {
                let t = d.assign(node).unwrap();
                d.complete(t, node).unwrap();
            }
            let _ = round;
        }
        for node in 0..3 {
            assert_eq!(d.completed_on(node), 5);
        }
        d.check_invariants().unwrap();
    }
}
