//! Per-trial training state: epoch budget, early stopping, accuracy curve.
//!
//! §4.5: "there is a maximum allowed training epoch and patience, which is
//! the number of epochs to wait before early stop if no progress on the
//! validation dataset."


use crate::flops::count::GraphOps;
use crate::nas::graph::Architecture;
use crate::sim::accuracy::HpPoint;

/// Verdict after recording an epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialStatus {
    Continue,
    /// Patience exhausted without `min_delta` improvement.
    EarlyStopped,
    /// Epoch budget reached.
    BudgetExhausted,
}

/// A candidate being trained on one slave node.
#[derive(Debug, Clone)]
pub struct ActiveTrial {
    pub trial_id: u64,
    pub arch: Architecture,
    pub arch_id: u64,
    pub hp: HpPoint,
    pub ops: GraphOps,
    pub params: u64,
    pub activation_elems: u64,
    /// Per-GPU batch after the memory-adaption fit.
    pub batch_per_gpu: u64,
    pub round: u64,
    pub epoch_budget: u64,
    pub epoch: u64,
    /// Accuracy per completed epoch (1-based epochs).
    pub accs: Vec<f64>,
    best_acc: f64,
    since_improve: u64,
}

impl ActiveTrial {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        trial_id: u64,
        arch: Architecture,
        arch_id: u64,
        hp: HpPoint,
        ops: GraphOps,
        batch_per_gpu: u64,
        round: u64,
        epoch_budget: u64,
    ) -> Self {
        assert!(epoch_budget >= 1);
        let params = arch.params();
        let activation_elems = arch.activation_elems();
        ActiveTrial {
            trial_id,
            arch,
            arch_id,
            hp,
            ops,
            params,
            activation_elems,
            batch_per_gpu,
            round,
            epoch_budget,
            epoch: 0,
            accs: Vec::new(),
            best_acc: 0.0,
            since_improve: 0,
        }
    }

    /// Record one epoch's validation accuracy and decide whether to stop.
    pub fn record_epoch(&mut self, acc: f64, patience: u64, min_delta: f64) -> TrialStatus {
        self.epoch += 1;
        self.accs.push(acc);
        if acc > self.best_acc + min_delta {
            self.best_acc = acc;
            self.since_improve = 0;
        } else {
            self.since_improve += 1;
        }
        if self.epoch >= self.epoch_budget {
            TrialStatus::BudgetExhausted
        } else if self.since_improve >= patience {
            TrialStatus::EarlyStopped
        } else {
            TrialStatus::Continue
        }
    }

    /// Best validation accuracy observed.
    pub fn best_accuracy(&self) -> f64 {
        self.best_acc
    }

    /// (epochs, accuracies) pairs for the Appendix-C log fit.
    pub fn curve(&self) -> (Vec<f64>, Vec<f64>) {
        (
            (1..=self.accs.len()).map(|e| e as f64).collect(),
            self.accs.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flops::{graph_ops_per_image, OpWeights};

    fn trial(budget: u64) -> ActiveTrial {
        let arch = Architecture::initial(32, 3, 10);
        let ops = graph_ops_per_image(&arch.lower(), &OpWeights::default());
        ActiveTrial::new(0, arch, 1, HpPoint::default(), ops, 64, 1, budget)
    }

    #[test]
    fn budget_exhaustion() {
        let mut t = trial(3);
        assert_eq!(t.record_epoch(0.1, 5, 0.001), TrialStatus::Continue);
        assert_eq!(t.record_epoch(0.2, 5, 0.001), TrialStatus::Continue);
        assert_eq!(t.record_epoch(0.3, 5, 0.001), TrialStatus::BudgetExhausted);
        assert_eq!(t.epoch, 3);
        assert!((t.best_accuracy() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn early_stop_on_plateau() {
        let mut t = trial(100);
        t.record_epoch(0.5, 3, 0.001);
        assert_eq!(t.record_epoch(0.5, 3, 0.001), TrialStatus::Continue);
        assert_eq!(t.record_epoch(0.5005, 3, 0.001), TrialStatus::Continue);
        assert_eq!(t.record_epoch(0.5, 3, 0.001), TrialStatus::EarlyStopped);
        assert_eq!(t.epoch, 4);
    }

    #[test]
    fn improvement_resets_patience() {
        let mut t = trial(100);
        t.record_epoch(0.3, 2, 0.001);
        t.record_epoch(0.3, 2, 0.001); // 1 stale
        assert_eq!(t.record_epoch(0.4, 2, 0.001), TrialStatus::Continue); // reset
        t.record_epoch(0.4, 2, 0.001); // 1 stale
        assert_eq!(t.record_epoch(0.4, 2, 0.001), TrialStatus::EarlyStopped);
    }

    #[test]
    fn curve_matches_records() {
        let mut t = trial(10);
        for (i, a) in [0.1, 0.2, 0.25].iter().enumerate() {
            let _ = t.record_epoch(*a, 5, 0.001);
            let _ = i;
        }
        let (es, accs) = t.curve();
        assert_eq!(es, vec![1.0, 2.0, 3.0]);
        assert_eq!(accs, vec![0.1, 0.2, 0.25]);
    }

    #[test]
    #[should_panic]
    fn zero_budget_rejected() {
        trial(0);
    }
}
