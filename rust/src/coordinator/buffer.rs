//! Candidate-architecture buffer (paper §4.3).
//!
//! Slave-node CPUs "generate new architectures (then store them in the
//! buffer)" — an NFS-backed queue the training side drains. Bounded so a
//! fast search loop cannot outrun the trainers unboundedly (backpressure);
//! FIFO so inherited-knowledge locality is preserved (children train soon
//! after their parent's result motivated them).

use std::collections::VecDeque;

use crate::nas::graph::Architecture;

/// A queued candidate with provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub arch: Architecture,
    /// Which node's search loop proposed it.
    pub proposed_by: usize,
    /// Proposal time (seconds since benchmark start).
    pub proposed_at: f64,
}

/// Bounded FIFO buffer.
#[derive(Debug, Clone)]
pub struct ArchBuffer {
    queue: VecDeque<Candidate>,
    capacity: usize,
    /// Total proposals ever accepted / rejected (report counters).
    pub accepted: u64,
    pub rejected: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum BufferError {
    Full(usize),
}

impl std::fmt::Display for BufferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BufferError::Full(capacity) => write!(f, "buffer full (capacity {capacity})"),
        }
    }
}

impl std::error::Error for BufferError {}

impl ArchBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        ArchBuffer {
            queue: VecDeque::with_capacity(capacity),
            capacity,
            accepted: 0,
            rejected: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.capacity
    }

    /// Push a candidate; rejects when full (the search loop then skips a
    /// beat — backpressure).
    pub fn push(&mut self, c: Candidate) -> Result<(), BufferError> {
        if self.is_full() {
            self.rejected += 1;
            return Err(BufferError::Full(self.capacity));
        }
        self.queue.push_back(c);
        self.accepted += 1;
        Ok(())
    }

    /// Pop the oldest candidate.
    pub fn pop(&mut self) -> Option<Candidate> {
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(i: usize) -> Candidate {
        Candidate {
            arch: Architecture::initial(32, 3, 10),
            proposed_by: i,
            proposed_at: i as f64,
        }
    }

    #[test]
    fn fifo_order() {
        let mut b = ArchBuffer::new(4);
        for i in 0..3 {
            b.push(cand(i)).unwrap();
        }
        assert_eq!(b.pop().unwrap().proposed_by, 0);
        assert_eq!(b.pop().unwrap().proposed_by, 1);
        assert_eq!(b.pop().unwrap().proposed_by, 2);
        assert!(b.pop().is_none());
    }

    #[test]
    fn capacity_enforced() {
        let mut b = ArchBuffer::new(2);
        b.push(cand(0)).unwrap();
        b.push(cand(1)).unwrap();
        assert_eq!(b.push(cand(2)), Err(BufferError::Full(2)));
        assert_eq!(b.len(), 2);
        assert_eq!(b.accepted, 2);
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn drain_then_refill() {
        let mut b = ArchBuffer::new(1);
        b.push(cand(0)).unwrap();
        assert!(b.is_full());
        b.pop();
        assert!(b.is_empty());
        b.push(cand(1)).unwrap();
        assert_eq!(b.pop().unwrap().proposed_by, 1);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        ArchBuffer::new(0);
    }
}
