//! The historical model list (paper §4.3).
//!
//! "The CPUs on slave nodes search for new neural architectures based on
//! the rank of models in the historical model list, which contains
//! detailed model information and accuracy on the test dataset." In the
//! paper the list lives on NFS; here it is the master-owned source of
//! truth the simulated nodes read (with an NFS latency charge) and the
//! live runner shares behind a lock.
//!
//! Everything the barrier hot path needs is maintained incrementally on
//! `push`, so taking a snapshot is O(new records) amortized instead of
//! O(all records) per window:
//!
//! * a ranked view whose entries share `Arc<Architecture>`s with the
//!   records (no deep clones — at exascale the old per-window rebuild
//!   cloned every recorded architecture every barrier);
//! * a stable accuracy-ascending index over that view, extended by
//!   merging each window's sorted delta (bit-equal to a full stable
//!   sort, which is what the selection math replays);
//! * a running best error and a per-record prefix-min series, so the
//!   score ticks' `best_measured_error_at` is a binary search, not a
//!   scan of the whole list per sample.

use std::sync::Arc;

use crate::nas::graph::Architecture;
use crate::nas::search::RankedModel;

/// One trained (or warm-up-predicted) model.
#[derive(Debug, Clone)]
pub struct ModelRecord {
    pub id: u64,
    /// Shared with every ranked view that includes this record.
    pub arch: Arc<Architecture>,
    pub signature: String,
    pub params: u64,
    /// Ranking accuracy: the Appendix-C prediction during warm-up rounds,
    /// the measured value afterwards. Drives parent selection.
    pub accuracy: f64,
    /// Best validation accuracy actually achieved while training — what
    /// Fig 5 plots as "achievable error".
    pub measured_accuracy: f64,
    pub predicted: bool,
    /// OOM-penalty marker: the candidate fit no batch size on its
    /// group's accelerator and was never trained. Penalty entries rank
    /// (teaching the search the memory boundary) but are never selected
    /// as morph parents while real records exist, and their error of
    /// 100 % never wins the achieved-error series.
    pub penalty: bool,
    /// Node that proposed this candidate. For migrated trials with
    /// feedback routing on, this is the *source* lane's node — the search
    /// loop the candidate came from — not the node that executed it.
    pub node: usize,
    /// Topology group of `node`. Scopes the OOM-penalty parent filter:
    /// the memory boundary a penalty records belongs to this group's
    /// accelerator only (see `SearchPolicy::select_parent_on`).
    pub group: usize,
    pub round: u64,
    pub epochs_trained: u64,
    /// Analytical ops spent training+validating this model.
    pub ops: f64,
    /// Hyperparameters used.
    pub dropout: f64,
    pub kernel: f64,
    /// Completion time, seconds since benchmark start.
    pub completed_at: f64,
}

impl ModelRecord {
    /// Achieved validation error (Fig 5 quantity).
    pub fn error(&self) -> f64 {
        1.0 - self.measured_accuracy
    }
}

/// Append-only ranked model list.
#[derive(Debug, Clone)]
pub struct HistoryList {
    records: Vec<ModelRecord>,
    /// Ranked view of every record, `Arc`-shared so barrier snapshots
    /// are O(1) to hand out. `Arc::make_mut` keeps pushes in-place
    /// whenever no snapshot is outstanding (the master drops its frozen
    /// view before merging a window).
    ranked: Arc<Vec<RankedModel>>,
    /// Stable accuracy-ascending order of `ranked[..sorted_len]`;
    /// refreshed lazily by [`HistoryList::sorted_shared`].
    sorted: Arc<Vec<u32>>,
    sorted_len: usize,
    /// Penalty entries in `ranked` (lets selection prove its filter
    /// inert without rescanning).
    penalties: u64,
    /// Running best over all non-penalty records (order-independent).
    best_error: Option<f64>,
    /// `(completed_at, prefix-min error)` per non-penalty record —
    /// valid while pushes arrive in nondecreasing completion order,
    /// which the coordinator guarantees (windows are merged in time
    /// order and each window's completions are sorted before pushing).
    prefix_min: Vec<(f64, f64)>,
    /// Cleared the moment an out-of-order push invalidates
    /// `prefix_min`; queries then fall back to the naive scan.
    time_ordered: bool,
}

impl Default for HistoryList {
    fn default() -> Self {
        HistoryList {
            records: Vec::new(),
            ranked: Arc::new(Vec::new()),
            sorted: Arc::new(Vec::new()),
            sorted_len: 0,
            penalties: 0,
            best_error: None,
            prefix_min: Vec::new(),
            time_ordered: true,
        }
    }
}

impl HistoryList {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: ModelRecord) {
        if rec.penalty {
            self.penalties += 1;
        } else {
            let e = rec.error();
            let better = match self.best_error {
                Some(b) => e < b,
                None => true,
            };
            if better {
                self.best_error = Some(e);
            }
            if self.time_ordered {
                match self.prefix_min.last() {
                    Some(&(last_t, last_min)) => {
                        if rec.completed_at < last_t {
                            // Out-of-order push (test/tooling path): the
                            // prefix series no longer answers time
                            // queries; fall back to scanning.
                            self.time_ordered = false;
                            self.prefix_min.clear();
                        } else {
                            let m = if e < last_min { e } else { last_min };
                            self.prefix_min.push((rec.completed_at, m));
                        }
                    }
                    None => self.prefix_min.push((rec.completed_at, e)),
                }
            }
        }
        Arc::make_mut(&mut self.ranked).push(RankedModel {
            arch: Arc::clone(&rec.arch),
            accuracy: rec.accuracy,
            penalty: rec.penalty,
            group: rec.group,
        });
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[ModelRecord] {
        &self.records
    }

    /// Best achieved error so far. Every trained record counts with its
    /// *measured* accuracy; Appendix-C predictions only influence
    /// ranking, never the achieved-error series — and OOM-penalty
    /// entries were never trained at all, so they are excluded outright.
    pub fn best_measured_error(&self) -> Option<f64> {
        self.best_error
    }

    /// Best error among trained records completed by time `t` (for the
    /// Fig 5 time series). A binary search over the prefix-min series on
    /// the coordinator's time-ordered push path; a full scan otherwise.
    pub fn best_measured_error_at(&self, t: f64) -> Option<f64> {
        if self.time_ordered {
            let idx = self.prefix_min.partition_point(|&(ct, _)| ct <= t);
            if idx == 0 {
                None
            } else {
                Some(self.prefix_min[idx - 1].1)
            }
        } else {
            self.records
                .iter()
                .filter(|r| !r.penalty && r.completed_at <= t)
                .map(|r| r.error())
                .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        }
    }

    /// View for the NAS search policy (all records rank, predicted too —
    /// that is the point of warm-up prediction).
    pub fn ranked_view(&self) -> &[RankedModel] {
        &self.ranked
    }

    /// The `Arc`-shared ranked view — what barrier snapshots hold.
    pub fn ranked_shared(&self) -> Arc<Vec<RankedModel>> {
        Arc::clone(&self.ranked)
    }

    /// The `Arc`-shared stable accuracy order of the ranked view,
    /// bringing it up to date first (amortized O(new records) per
    /// window: the delta is sorted alone, then merged).
    pub fn sorted_shared(&mut self) -> Arc<Vec<u32>> {
        self.flush_sorted();
        Arc::clone(&self.sorted)
    }

    /// Penalty entries recorded so far.
    pub fn penalty_count(&self) -> u64 {
        self.penalties
    }

    /// Extend `sorted` over any records pushed since the last flush.
    /// Merging the old order with the stable-sorted delta (ties keep the
    /// older element first) yields exactly the permutation a full stable
    /// sort of all entries produces — the property the selection math's
    /// bit-exact replay rests on.
    fn flush_sorted(&mut self) {
        let ranked = Arc::clone(&self.ranked);
        let len = ranked.len();
        if self.sorted_len == len {
            return;
        }
        let mut delta: Vec<u32> = (self.sorted_len as u32..len as u32).collect();
        delta.sort_by(|&a, &b| {
            ranked[a as usize]
                .accuracy
                .partial_cmp(&ranked[b as usize].accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let old = Arc::clone(&self.sorted);
        let mut merged = Vec::with_capacity(len);
        let (mut i, mut j) = (0usize, 0usize);
        while i < old.len() && j < delta.len() {
            let a = ranked[old[i] as usize].accuracy;
            let b = ranked[delta[j] as usize].accuracy;
            if b < a {
                merged.push(delta[j]);
                j += 1;
            } else {
                merged.push(old[i]);
                i += 1;
            }
        }
        merged.extend_from_slice(&old[i..]);
        merged.extend_from_slice(&delta[j..]);
        self.sorted = Arc::new(merged);
        self.sorted_len = len;
    }

    /// Serialized size estimate for the NFS charge (the paper stores the
    /// list as JSON-ish metadata; ~2 KB per record).
    pub fn nfs_bytes(&self) -> u64 {
        2048 * self.records.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, acc: f64, predicted: bool, t: f64) -> ModelRecord {
        ModelRecord {
            id,
            arch: Arc::new(Architecture::initial(32, 3, 10)),
            signature: format!("sig{id}"),
            params: 1000,
            accuracy: acc,
            measured_accuracy: acc,
            predicted,
            penalty: false,
            node: 0,
            group: 0,
            round: 1,
            epochs_trained: 10,
            ops: 1e12,
            dropout: 0.5,
            kernel: 3.0,
            completed_at: t,
        }
    }

    #[test]
    fn best_error_uses_measured_accuracy() {
        let mut h = HistoryList::new();
        // Predicted ranking accuracy 0.9 but measured only 0.4: the
        // achieved-error series must use the measured value.
        let mut r0 = rec(0, 0.9, true, 10.0);
        r0.measured_accuracy = 0.4;
        h.push(r0);
        h.push(rec(1, 0.6, false, 20.0));
        h.push(rec(2, 0.7, false, 30.0));
        assert!((h.best_measured_error().unwrap() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn best_error_at_time_respects_completion() {
        let mut h = HistoryList::new();
        h.push(rec(0, 0.5, false, 10.0));
        h.push(rec(1, 0.8, false, 100.0));
        assert!((h.best_measured_error_at(50.0).unwrap() - 0.5).abs() < 1e-12);
        assert!((h.best_measured_error_at(150.0).unwrap() - 0.2).abs() < 1e-12);
        assert!(h.best_measured_error_at(5.0).is_none());
    }

    #[test]
    fn out_of_order_pushes_fall_back_to_the_scan() {
        // Completion times arriving backwards invalidate the prefix-min
        // series; answers must stay correct through the fallback.
        let mut h = HistoryList::new();
        h.push(rec(0, 0.5, false, 100.0));
        h.push(rec(1, 0.9, false, 10.0)); // earlier than the last push
        h.push(rec(2, 0.7, false, 50.0));
        assert!((h.best_measured_error_at(20.0).unwrap() - 0.1).abs() < 1e-12);
        assert!((h.best_measured_error_at(60.0).unwrap() - 0.1).abs() < 1e-12);
        assert!((h.best_measured_error_at(200.0).unwrap() - 0.1).abs() < 1e-12);
        assert!(h.best_measured_error_at(5.0).is_none());
        assert!((h.best_measured_error().unwrap() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn ranked_view_includes_all() {
        let mut h = HistoryList::new();
        h.push(rec(0, 0.4, true, 1.0));
        h.push(rec(1, 0.6, false, 2.0));
        assert_eq!(h.ranked_view().len(), 2);
    }

    #[test]
    fn penalty_records_rank_but_never_set_the_error_series() {
        let mut h = HistoryList::new();
        let mut p = rec(0, 0.0, true, 1.0);
        p.penalty = true;
        p.measured_accuracy = 0.0;
        h.push(p);
        // Only a penalty so far: no achieved error exists yet.
        assert!(h.best_measured_error().is_none());
        assert!(h.best_measured_error_at(5.0).is_none());
        h.push(rec(1, 0.6, false, 2.0));
        assert!((h.best_measured_error().unwrap() - 0.4).abs() < 1e-12);
        // The penalty still ranks (search feedback)…
        let view = h.ranked_view();
        assert_eq!(view.len(), 2);
        assert!(view[0].penalty && !view[1].penalty);
        assert_eq!(h.penalty_count(), 1);
    }

    #[test]
    fn incremental_sort_matches_a_full_stable_sort() {
        // Push in window-sized bursts with plenty of accuracy ties,
        // flushing between bursts: the merged order must equal a single
        // stable sort of everything (crate::nas::search::sorted_order is
        // the reference permutation).
        let mut h = HistoryList::new();
        let accs = [
            0.5, 0.2, 0.5, 0.9, 0.2, 0.2, 0.7, 0.5, 0.1, 0.9, 0.5, 0.2,
        ];
        let mut pushed = 0u64;
        for burst in accs.chunks(3) {
            for &a in burst {
                h.push(rec(pushed, a, false, pushed as f64));
                pushed += 1;
            }
            let incremental = h.sorted_shared();
            let reference = crate::nas::search::sorted_order(h.ranked_view());
            assert_eq!(*incremental, reference, "after {pushed} pushes");
        }
    }

    #[test]
    fn shared_snapshot_survives_later_pushes() {
        // A frozen Arc view must keep its contents while the list grows
        // (copy-on-write kicks in only when a snapshot is outstanding).
        let mut h = HistoryList::new();
        h.push(rec(0, 0.4, false, 1.0));
        let frozen = h.ranked_shared();
        let frozen_sorted = h.sorted_shared();
        h.push(rec(1, 0.8, false, 2.0));
        assert_eq!(frozen.len(), 1);
        assert_eq!(frozen_sorted.len(), 1);
        assert_eq!(h.ranked_view().len(), 2);
        assert_eq!(h.sorted_shared().len(), 2);
    }

    #[test]
    fn nfs_bytes_scales() {
        let mut h = HistoryList::new();
        assert_eq!(h.nfs_bytes(), 0);
        h.push(rec(0, 0.4, false, 1.0));
        assert_eq!(h.nfs_bytes(), 2048);
    }
}
