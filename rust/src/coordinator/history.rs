//! The historical model list (paper §4.3).
//!
//! "The CPUs on slave nodes search for new neural architectures based on
//! the rank of models in the historical model list, which contains
//! detailed model information and accuracy on the test dataset." In the
//! paper the list lives on NFS; here it is the master-owned source of
//! truth the simulated nodes read (with an NFS latency charge) and the
//! live runner shares behind a lock.


use crate::nas::graph::Architecture;
use crate::nas::search::RankedModel;

/// One trained (or warm-up-predicted) model.
#[derive(Debug, Clone)]
pub struct ModelRecord {
    pub id: u64,
    pub arch: Architecture,
    pub signature: String,
    pub params: u64,
    /// Ranking accuracy: the Appendix-C prediction during warm-up rounds,
    /// the measured value afterwards. Drives parent selection.
    pub accuracy: f64,
    /// Best validation accuracy actually achieved while training — what
    /// Fig 5 plots as "achievable error".
    pub measured_accuracy: f64,
    pub predicted: bool,
    /// OOM-penalty marker: the candidate fit no batch size on its
    /// group's accelerator and was never trained. Penalty entries rank
    /// (teaching the search the memory boundary) but are never selected
    /// as morph parents while real records exist, and their error of
    /// 100 % never wins the achieved-error series.
    pub penalty: bool,
    /// Node that proposed this candidate. For migrated trials with
    /// feedback routing on, this is the *source* lane's node — the search
    /// loop the candidate came from — not the node that executed it.
    pub node: usize,
    /// Topology group of `node`. Scopes the OOM-penalty parent filter:
    /// the memory boundary a penalty records belongs to this group's
    /// accelerator only (see `SearchPolicy::select_parent_on`).
    pub group: usize,
    pub round: u64,
    pub epochs_trained: u64,
    /// Analytical ops spent training+validating this model.
    pub ops: f64,
    /// Hyperparameters used.
    pub dropout: f64,
    pub kernel: f64,
    /// Completion time, seconds since benchmark start.
    pub completed_at: f64,
}

impl ModelRecord {
    /// Achieved validation error (Fig 5 quantity).
    pub fn error(&self) -> f64 {
        1.0 - self.measured_accuracy
    }
}

/// Append-only ranked model list.
#[derive(Debug, Clone, Default)]
pub struct HistoryList {
    records: Vec<ModelRecord>,
}

impl HistoryList {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: ModelRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[ModelRecord] {
        &self.records
    }

    /// Best achieved error so far. Every trained record counts with its
    /// *measured* accuracy; Appendix-C predictions only influence
    /// ranking, never the achieved-error series — and OOM-penalty
    /// entries were never trained at all, so they are excluded outright.
    pub fn best_measured_error(&self) -> Option<f64> {
        self.records
            .iter()
            .filter(|r| !r.penalty)
            .map(|r| r.error())
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Best error among trained records completed by time `t` (for the
    /// Fig 5 time series).
    pub fn best_measured_error_at(&self, t: f64) -> Option<f64> {
        self.records
            .iter()
            .filter(|r| !r.penalty && r.completed_at <= t)
            .map(|r| r.error())
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// View for the NAS search policy (all records rank, predicted too —
    /// that is the point of warm-up prediction).
    pub fn ranked_view(&self) -> Vec<RankedModel> {
        self.records
            .iter()
            .map(|r| RankedModel {
                arch: r.arch.clone(),
                accuracy: r.accuracy,
                penalty: r.penalty,
                group: r.group,
            })
            .collect()
    }

    /// Serialized size estimate for the NFS charge (the paper stores the
    /// list as JSON-ish metadata; ~2 KB per record).
    pub fn nfs_bytes(&self) -> u64 {
        2048 * self.records.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, acc: f64, predicted: bool, t: f64) -> ModelRecord {
        ModelRecord {
            id,
            arch: Architecture::initial(32, 3, 10),
            signature: format!("sig{id}"),
            params: 1000,
            accuracy: acc,
            measured_accuracy: acc,
            predicted,
            penalty: false,
            node: 0,
            group: 0,
            round: 1,
            epochs_trained: 10,
            ops: 1e12,
            dropout: 0.5,
            kernel: 3.0,
            completed_at: t,
        }
    }

    #[test]
    fn best_error_uses_measured_accuracy() {
        let mut h = HistoryList::new();
        // Predicted ranking accuracy 0.9 but measured only 0.4: the
        // achieved-error series must use the measured value.
        let mut r0 = rec(0, 0.9, true, 10.0);
        r0.measured_accuracy = 0.4;
        h.push(r0);
        h.push(rec(1, 0.6, false, 20.0));
        h.push(rec(2, 0.7, false, 30.0));
        assert!((h.best_measured_error().unwrap() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn best_error_at_time_respects_completion() {
        let mut h = HistoryList::new();
        h.push(rec(0, 0.5, false, 10.0));
        h.push(rec(1, 0.8, false, 100.0));
        assert!((h.best_measured_error_at(50.0).unwrap() - 0.5).abs() < 1e-12);
        assert!((h.best_measured_error_at(150.0).unwrap() - 0.2).abs() < 1e-12);
        assert!(h.best_measured_error_at(5.0).is_none());
    }

    #[test]
    fn ranked_view_includes_all() {
        let mut h = HistoryList::new();
        h.push(rec(0, 0.4, true, 1.0));
        h.push(rec(1, 0.6, false, 2.0));
        assert_eq!(h.ranked_view().len(), 2);
    }

    #[test]
    fn penalty_records_rank_but_never_set_the_error_series() {
        let mut h = HistoryList::new();
        let mut p = rec(0, 0.0, true, 1.0);
        p.penalty = true;
        p.measured_accuracy = 0.0;
        h.push(p);
        // Only a penalty so far: no achieved error exists yet.
        assert!(h.best_measured_error().is_none());
        assert!(h.best_measured_error_at(5.0).is_none());
        h.push(rec(1, 0.6, false, 2.0));
        assert!((h.best_measured_error().unwrap() - 0.4).abs() < 1e-12);
        // The penalty still ranks (search feedback)…
        let view = h.ranked_view();
        assert_eq!(view.len(), 2);
        assert!(view[0].penalty && !view[1].penalty);
    }

    #[test]
    fn nfs_bytes_scales() {
        let mut h = HistoryList::new();
        assert_eq!(h.nfs_bytes(), 0);
        h.push(rec(0, 0.4, false, 1.0));
        assert_eq!(h.nfs_bytes(), 2048);
    }
}
