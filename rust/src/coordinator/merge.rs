//! K-way merge of per-lane, time-sorted event deltas.
//!
//! Every barrier window the master drains each shard's completion and
//! ops deltas. Those vectors are appended at event-pop time, so each is
//! already nondecreasing in time — re-sorting the whole concatenation
//! (the historic path) costs O(n log n) per window for work that is
//! k-way-merge-shaped. [`merge_by_time`] merges them with a small heap
//! in O(n log k), and reproduces the historic order *exactly*: the
//! stable sort of the node-order concatenation orders ties by lane,
//! then by within-lane position, which is precisely what a min-heap
//! keyed on `(t, lane)` with FIFO consumption per lane emits.
//! `rust/tests/properties.rs` pins the equivalence across seeds and
//! heterogeneous lane counts.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One lane's current front item. Ordering is reversed on `(t, lane)`
/// so `BinaryHeap` (a max-heap) pops the earliest time, ties to the
/// lowest lane — the stable-sort tie order of the node-order concat.
struct Head<T> {
    t: f64,
    lane: usize,
    item: T,
}

impl<T> PartialEq for Head<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Head<T> {}

impl<T> PartialOrd for Head<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Head<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // `completed_at` is never NaN in practice; `unwrap_or(Equal)`
        // matches the defensive comparator of the historic full sort.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.lane.cmp(&self.lane))
    }
}

fn is_time_sorted<T>(xs: &[T], time: &impl Fn(&T) -> f64) -> bool {
    for w in xs.windows(2) {
        if time(&w[0]) > time(&w[1]) {
            return false;
        }
    }
    true
}

/// Merge per-lane deltas into one time-ordered vector, ties older lane
/// first, FIFO within a lane — byte-identical output order to stably
/// sorting the lane-order concatenation by time.
///
/// Deltas are expected pre-sorted (shards push at event-pop time); a
/// delta that is not is stably sorted first, which keeps the overall
/// result exactly equal to the historic full re-sort even then.
pub fn merge_by_time<T>(mut lanes: Vec<Vec<T>>, time: impl Fn(&T) -> f64) -> Vec<T> {
    for lane in lanes.iter_mut() {
        if !is_time_sorted(lane, &time) {
            lane.sort_by(|a, b| time(a).partial_cmp(&time(b)).unwrap_or(Ordering::Equal));
        }
    }
    // detlint: allow(float_fold) — integer length sum, not a float
    // accumulation; order cannot change the result.
    let total: usize = lanes.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    let mut cursors: Vec<std::vec::IntoIter<T>> =
        lanes.into_iter().map(Vec::into_iter).collect();
    let mut heap: BinaryHeap<Head<T>> = BinaryHeap::with_capacity(cursors.len());
    for (lane, cursor) in cursors.iter_mut().enumerate() {
        if let Some(item) = cursor.next() {
            heap.push(Head { t: time(&item), lane, item });
        }
    }
    while let Some(Head { lane, item, .. }) = heap.pop() {
        out.push(item);
        if let Some(next) = cursors[lane].next() {
            heap.push(Head { t: time(&next), lane, item: next });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted_concat(lanes: &[Vec<(f64, usize)>]) -> Vec<(f64, usize)> {
        let mut all: Vec<(f64, usize)> = lanes.iter().flatten().copied().collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(Ordering::Equal));
        all
    }

    #[test]
    fn merges_sorted_lanes_in_time_order() {
        let lanes = vec![vec![(1.0, 0), (4.0, 0)], vec![(2.0, 1), (3.0, 1)]];
        let merged = merge_by_time(lanes.clone(), |x| x.0);
        assert_eq!(merged, sorted_concat(&lanes));
    }

    #[test]
    fn ties_break_to_the_older_lane_then_fifo() {
        // Three lanes all emitting at t=1.0 and t=2.0: the stable sort of
        // the concat keeps lane order within a tie, and within a lane the
        // earlier-pushed item first.
        let lanes: Vec<Vec<(f64, usize)>> = (0..3)
            .map(|lane| vec![(1.0, lane), (1.0, lane + 10), (2.0, lane)])
            .collect();
        let merged = merge_by_time(lanes.clone(), |x| x.0);
        assert_eq!(merged, sorted_concat(&lanes));
        assert_eq!(
            merged,
            vec![
                (1.0, 0),
                (1.0, 10),
                (1.0, 1),
                (1.0, 11),
                (1.0, 2),
                (1.0, 12),
                (2.0, 0),
                (2.0, 1),
                (2.0, 2),
            ]
        );
    }

    #[test]
    fn empty_lanes_are_skipped() {
        let lanes = vec![vec![], vec![(1.0, 1)], vec![], vec![(0.5, 3)]];
        let merged = merge_by_time(lanes, |x: &(f64, usize)| x.0);
        assert_eq!(merged, vec![(0.5, 3), (1.0, 1)]);
        assert!(merge_by_time(Vec::<Vec<(f64, usize)>>::new(), |x| x.0).is_empty());
    }

    #[test]
    fn unsorted_delta_falls_back_to_full_sort_equivalence() {
        // Defensive path: an out-of-order lane is stably pre-sorted, so
        // the merge still equals the historic sort of the concat.
        let lanes = vec![vec![(3.0, 0), (1.0, 1)], vec![(2.0, 2)]];
        let mut expect: Vec<(f64, usize)> = lanes.iter().flatten().copied().collect();
        expect.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert_eq!(merge_by_time(lanes, |x| x.0), expect);
    }
}
