//! Real-training mini-benchmark (the end-to-end validation path).
//!
//! Everything the simulated master does — history-ranked NAS proposals,
//! warm-up epochs, TPE HPO, analytical-FLOPS scoring, regulated score —
//! but with *real* training: candidates are projected onto the compiled
//! artifact grid (DESIGN.md §3) and trained via the PJRT runtime on the
//! synthetic corpus. Wall-clock timed; Python nowhere on the path.
//!
//! The HPO dimension here is the learning rate (a runtime scalar input of
//! the AOT train step); dropout/kernel are baked into the grid at compile
//! time — the substitution is documented in DESIGN.md §2.

// detlint: allow-file(wall_clock) — live runtime path: real training is
// wall-clock timed by definition (paper §4.2 measures elapsed seconds).

use anyhow::Result;

use crate::coordinator::history::{HistoryList, ModelRecord};
use crate::flops::count::{graph_ops_per_image, LoweredLayer};
use crate::flops::layers::{LayerKind, LayerShape, OpWeights};
use crate::hpo::{Optimizer, ParamSpec, SearchSpace, Tpe};
use crate::metrics::score::regulated_score;
use crate::nas::graph::{Architecture, Block, Stage};
use crate::nas::search::SearchPolicy;
use crate::runtime::{Manifest, Runtime, Trainer};
use crate::util::rng::derive;

/// Live-run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub artifacts_dir: String,
    /// Candidate trials to run.
    pub trials: u64,
    /// Training epochs per trial (one epoch = `batches_per_epoch` steps).
    pub epochs_per_trial: u64,
    pub batches_per_epoch: u64,
    /// Validation batches per evaluation.
    pub val_batches: u64,
    pub seed: u64,
    /// TPE warm-up trials before the estimator activates.
    pub hpo_start_trial: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            artifacts_dir: "artifacts".into(),
            trials: 4,
            epochs_per_trial: 3,
            batches_per_epoch: 24,
            val_batches: 4,
            seed: 0,
            hpo_start_trial: 2,
        }
    }
}

/// One completed live trial.
#[derive(Debug, Clone)]
pub struct LiveTrial {
    pub variant: String,
    pub learning_rate: f64,
    pub epochs: u64,
    pub losses: Vec<f32>,
    pub val_accuracy: f64,
    pub ops: f64,
    pub seconds: f64,
}

/// Live-run report.
#[derive(Debug, Clone)]
pub struct LiveResult {
    pub trials: Vec<LiveTrial>,
    pub total_ops: f64,
    pub duration_s: f64,
    pub score_flops: f64,
    pub best_error: f64,
    pub regulated_score: f64,
}

/// Lower the compiled model family (python/compile/model.py) to the layer
/// inventory: stem conv-BN-ReLU, `depth` residual blocks with one mid
/// max-pool, global pool, dense, softmax — the analytical-FLOPS twin of
/// the artifact actually executed.
pub fn variant_layers(v: &crate::runtime::Variant) -> Vec<LoweredLayer> {
    let mut h = v.image;
    let w = v.width;
    let k = v.kernel;
    let mut l = Vec::new();
    let conv = |h: u64, ci: u64, co: u64, k: u64| {
        LoweredLayer::new(
            LayerKind::Conv,
            LayerShape {
                hi: h,
                wi: h,
                ci,
                ho: h,
                wo: h,
                co,
                k,
            },
        )
    };
    let bn = |h: u64, c: u64| {
        LoweredLayer::new(
            LayerKind::BatchNorm,
            LayerShape {
                hi: h,
                wi: h,
                ci: c,
                ..Default::default()
            },
        )
    };
    let relu = |h: u64, c: u64| {
        LoweredLayer::new(
            LayerKind::Relu,
            LayerShape {
                ho: h,
                wo: h,
                co: c,
                ..Default::default()
            },
        )
    };
    l.push(conv(h, v.channels, w, k));
    l.push(bn(h, w));
    l.push(relu(h, w));
    let pool_at = v.depth / 2;
    for i in 0..v.depth {
        l.push(conv(h, w, w, k));
        l.push(bn(h, w));
        l.push(LoweredLayer::new(
            LayerKind::Add,
            LayerShape {
                ho: h,
                wo: h,
                co: w,
                ..Default::default()
            },
        ));
        l.push(relu(h, w));
        if i == pool_at && h >= 2 {
            l.push(LoweredLayer::new(
                LayerKind::MaxPool,
                LayerShape {
                    hi: h,
                    wi: h,
                    ci: w,
                    ho: h / 2,
                    wo: h / 2,
                    co: w,
                    k: 2,
                },
            ));
            h /= 2;
        }
    }
    l.push(LoweredLayer::new(
        LayerKind::GlobalPool,
        LayerShape {
            hi: h,
            wi: h,
            ci: w,
            ..Default::default()
        },
    ));
    l.push(LoweredLayer::new(
        LayerKind::Dense,
        LayerShape {
            ci: w,
            co: v.num_classes,
            ..Default::default()
        },
    ));
    l.push(LoweredLayer::new(
        LayerKind::Softmax,
        LayerShape {
            co: v.num_classes,
            ..Default::default()
        },
    ));
    l
}

/// A grid-shaped Architecture for the NAS policy to morph (so proposals
/// stay comparable to compiled capacities).
fn grid_arch(v: &crate::runtime::Variant) -> Architecture {
    Architecture {
        image: v.image,
        channels: v.channels,
        num_classes: v.num_classes,
        stem_pool: 0,
        stages: vec![Stage {
            width: v.width,
            blocks: vec![
                Block {
                    kernel: v.kernel,
                    residual: true,
                };
                v.depth as usize
            ],
            pool_after: true,
        }],
    }
}

/// Run the live benchmark.
pub fn run_live(cfg: &LiveConfig) -> Result<LiveResult> {
    let weights = OpWeights::default();
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let mut rt = Runtime::cpu()?;
    let mut rng = derive(cfg.seed, "live", 0);
    let policy = SearchPolicy::default();
    let mut history = HistoryList::new();

    // HPO over learning rate (runtime input of the train step).
    let lr_space = SearchSpace {
        params: vec![ParamSpec {
            name: "lr".into(),
            lo: 0.01,
            hi: 0.25,
            integer: false,
        }],
    };
    let mut tpe = Tpe::new(lr_space.clone());
    tpe.n_startup = cfg.hpo_start_trial as usize;

    let started = std::time::Instant::now();
    let mut trials = Vec::new();
    let mut total_ops = 0f64;

    for trial_idx in 0..cfg.trials {
        // --- NAS: propose from history, project onto the compiled grid.
        let variant = if history.is_empty() {
            manifest.default_variant().clone()
        } else {
            let (proposal, _) = policy.propose(&history.ranked_view(), &mut rng);
            let depth = proposal.depth() as u64;
            let width = proposal.stages.iter().map(|s| s.width).max().unwrap_or(8);
            manifest.nearest_variant(depth, width).clone()
        };

        // --- HPO: TPE-suggested learning rate.
        let lr_cfg = tpe.suggest(&mut rng);
        let lr = lr_cfg[0];

        // --- Real training via PJRT.
        let data = crate::data::SyntheticDataset::new(
            cfg.seed,
            variant.image as usize,
            variant.channels as usize,
            variant.num_classes as usize,
        );
        let mut trainer = Trainer::new(&mut rt, &manifest, &variant.name)?;
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        let b = variant.batch as usize;
        for epoch in 0..cfg.epochs_per_trial {
            let mut epoch_loss = 0f32;
            for step in 0..cfg.batches_per_epoch {
                let start = (epoch * cfg.batches_per_epoch + step) * b as u64;
                let (xs, ys) = data.batch(start, b);
                epoch_loss += trainer.train_step(&xs, &ys, lr as f32)?;
            }
            losses.push(epoch_loss / cfg.batches_per_epoch as f32);
        }
        // Validation on held-out indices (disjoint from training range).
        let (_, acc) = trainer.evaluate(&data, 1_000_000, cfg.val_batches)?;
        let seconds = t0.elapsed().as_secs_f64();

        // --- Analytical FLOPs of the work just performed.
        let ops_per_image = graph_ops_per_image(&variant_layers(&variant), &weights);
        let train_images =
            (cfg.epochs_per_trial * cfg.batches_per_epoch * variant.batch) as f64;
        let val_images = (cfg.val_batches * variant.batch) as f64;
        let ops = ops_per_image.train_per_image() as f64 * train_images
            + ops_per_image.val_per_image() as f64 * val_images;
        total_ops += ops;

        tpe.observe(lr_cfg, 1.0 - acc as f64);
        history.push(ModelRecord {
            id: trial_idx,
            arch: std::sync::Arc::new(grid_arch(&variant)),
            signature: variant.name.clone(),
            params: variant.total_param_elems() as u64,
            accuracy: acc as f64,
            measured_accuracy: acc as f64,
            predicted: false,
            penalty: false,
            node: 0,
            group: 0,
            round: trial_idx + 1,
            epochs_trained: cfg.epochs_per_trial,
            ops,
            dropout: 0.0,
            kernel: variant.kernel as f64,
            completed_at: started.elapsed().as_secs_f64(),
        });
        trials.push(LiveTrial {
            variant: variant.name.clone(),
            learning_rate: lr,
            epochs: cfg.epochs_per_trial,
            losses,
            val_accuracy: acc as f64,
            ops,
            seconds,
        });
    }

    let duration_s = started.elapsed().as_secs_f64();
    let best_error = history.best_measured_error().unwrap_or(1.0);
    let score_flops = total_ops / duration_s;
    Ok(LiveResult {
        trials,
        total_ops,
        duration_s,
        score_flops,
        best_error,
        regulated_score: regulated_score(best_error, score_flops),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_layer_inventory_shape() {
        let v = crate::runtime::Variant {
            name: "d2w8k3i16b32".into(),
            depth: 2,
            width: 8,
            kernel: 3,
            image: 16,
            channels: 3,
            num_classes: 10,
            batch: 32,
            seed: 0,
            params: vec![],
            files: crate::runtime::artifact::VariantFiles {
                init: String::new(),
                train: String::new(),
                eval: String::new(),
            },
        };
        let layers = variant_layers(&v);
        let convs = layers.iter().filter(|l| l.kind == LayerKind::Conv).count();
        assert_eq!(convs, 3); // stem + 2 blocks
        let pools = layers
            .iter()
            .filter(|l| l.kind == LayerKind::MaxPool)
            .count();
        assert_eq!(pools, 1);
        let g = graph_ops_per_image(&layers, &OpWeights::default());
        assert!(g.fp > 0 && g.bp > g.fp);
    }
}
