//! The AIPerf benchmark framework (paper §4.3) — Layer 3.
//!
//! AIPerf modifies NNI's master–slave design so nothing centralizes on the
//! master: slave-node CPUs generate candidate architectures from the
//! ranked historical model list into a buffer; slave-node GPUs train the
//! candidates asynchronously with data parallelism; the master only
//! dispatches workloads and aggregates results.
//!
//! * [`history`] — the historical model list (NFS-shared in the paper);
//! * [`buffer`] — the candidate-architecture buffer;
//! * [`dispatcher`] — trial routing with exactly-once bookkeeping;
//! * [`trial`] — per-trial training state: epoch budget, early stopping;
//! * [`shard`] — one slave node's simulation shard: search loop, TPE,
//!   RNG streams, local event queue (the parallel scale-out unit);
//! * [`sched`] — the elastic scheduler: lane registry, intra-node steal
//!   pass, and the cluster-wide inter-group migration pass (every
//!   placement policy, extracted out of shard/master mechanics);
//! * [`merge`] — the k-way heap merge of per-lane time-sorted event
//!   deltas used at every epoch barrier (O(n log k), order-identical
//!   to the historic full re-sort);
//! * [`active`] — the dormancy index over per-shard next-event times;
//!   each window only touches shards with an event inside it (skipped
//!   shards are bit-identical by construction);
//! * [`master`] — the simulated end-to-end benchmark run (sharded
//!   discrete-event loops with deterministic epoch-barrier merges)
//!   producing a [`crate::metrics::BenchmarkReport`];
//! * [`live`] — the real-training mini-benchmark over the AOT artifact
//!   grid (PJRT execution; wall-clock timed; requires the `pjrt`
//!   feature).

pub mod active;
pub mod buffer;
pub mod dispatcher;
pub mod history;
#[cfg(feature = "pjrt")]
pub mod live;
pub mod master;
pub mod merge;
pub mod sched;
pub mod shard;
pub mod trial;

pub use active::ActiveSet;
pub use buffer::ArchBuffer;
pub use dispatcher::Dispatcher;
pub use history::{HistoryList, ModelRecord};
pub use master::{run_benchmark, run_benchmark_streaming, run_benchmark_with};
pub use merge::merge_by_time;
pub use sched::ElasticScheduler;
pub use shard::SlaveShard;
pub use trial::{ActiveTrial, TrialStatus};
