//! Intra-node steal pass: the per-node placement decisions of the
//! elastic scheduler.
//!
//! A sub-shard lane whose remaining runway cannot fit another full epoch
//! before the benchmark deadline would classically start a doomed trial
//! whose first epoch never completes. The steal pass instead lends the
//! lane's devices to the most-loaded sibling lane's trial (all lanes of
//! a node share its NVLink domain, which is what makes joining the
//! allreduce ring cheap). The *decision* lives here — runway predicate
//! and seed-derived victim scan — while the shard applies it (epoch
//! re-timing, helper bookkeeping), so `coordinator::sched` owns every
//! placement policy and `coordinator::shard` stays pure mechanics.
//!
//! Determinism: one `StealScheduler` per node, seeded from
//! `derive(seed, "steal", node)`, draws exactly one rotation offset per
//! eligible steal attempt — the same stream and call sequence as the
//! pre-extraction scheduler, so schedules are bit-identical to PR 3's.

use crate::config::BenchmarkConfig;
use crate::util::rng::{derive, Rng};

/// A sibling lane's load as seen by the victim scan.
#[derive(Debug, Clone, Copy)]
pub struct LaneLoad {
    /// Whether the lane currently trains a trial (only busy lanes can be
    /// stolen from).
    pub busy: bool,
    /// Whether that trial was adopted from another group. Migrated trials
    /// sync over InfiniBand, so the NVLink-domain re-timing does not
    /// apply: they are only victims when [`StealScheduler::into_migrants`]
    /// is on (feedback routing), and the shard then re-times the widened
    /// ring through the single-sourced IB helper
    /// ([`crate::coordinator::sched::migrant_ring`]).
    pub migrated: bool,
    /// Absolute end time of the lane's in-flight epoch.
    pub epoch_end_t: f64,
    /// Seconds per epoch at the lane's current effective width.
    pub epoch_seconds: f64,
    /// Full epochs remaining after the in-flight one.
    pub remaining_epochs: f64,
}

/// Per-node steal decision state: the seed-derived rotation stream.
pub struct StealScheduler {
    rng: Rng,
    /// Whether stealing is enabled at all (`BenchmarkConfig::work_stealing`).
    pub enabled: bool,
    /// Steal-into-migrant (`BenchmarkConfig::feedback_routing`): adopted
    /// migrants become eligible victims, so a stranded sibling joins
    /// their InfiniBand ring instead of idling. Off keeps the historic
    /// never-a-victim rule, filter for filter.
    pub into_migrants: bool,
}

impl StealScheduler {
    /// The scheduler for global node `node` — same stream the
    /// pre-extraction shard used.
    pub fn new(cfg: &BenchmarkConfig, node: usize) -> Self {
        StealScheduler {
            rng: derive(cfg.seed, "steal", node as u64),
            enabled: cfg.work_stealing,
            into_migrants: cfg.feedback_routing,
        }
    }

    /// Whether a lane whose latest solo epoch took `own_epoch_s` has no
    /// runway for another full trial epoch (search + setup + one epoch)
    /// before `duration_s`. A lane that never trained (`own_epoch_s <= 0`)
    /// has no estimate and must start a real trial.
    pub fn out_of_runway(
        t: f64,
        search_seconds: f64,
        setup_seconds: f64,
        own_epoch_s: f64,
        duration_s: f64,
    ) -> bool {
        own_epoch_s > 0.0 && t + search_seconds + setup_seconds + own_epoch_s > duration_s
    }

    /// The victim scan: pick the most-loaded busy sibling of `thief`
    /// (largest projected remaining trial work), scanned in a fixed
    /// seed-derived rotation that decides ties deterministically.
    ///
    /// Draws exactly one rotation offset per call — callers must gate on
    /// [`StealScheduler::enabled`], lane count, and
    /// [`StealScheduler::out_of_runway`] first, preserving the historic
    /// stream alignment.
    pub fn pick_victim(&mut self, thief: usize, t: f64, lanes: &[LaneLoad]) -> Option<usize> {
        let k = lanes.len();
        let start = self.rng.gen_range_usize(0, k);
        let mut best: Option<(usize, f64)> = None;
        for j in 0..k {
            let i = (start + j) % k;
            if i == thief {
                continue;
            }
            let l = &lanes[i];
            if !l.busy || (l.migrated && !self.into_migrants) {
                continue;
            }
            let load = (l.epoch_end_t - t).max(0.0) + l.remaining_epochs * l.epoch_seconds;
            let better = match best {
                None => true,
                Some((_, b)) => load > b,
            };
            if better {
                best = Some((i, load));
            }
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy(epoch_end_t: f64, epoch_seconds: f64, remaining: f64) -> LaneLoad {
        LaneLoad {
            busy: true,
            migrated: false,
            epoch_end_t,
            epoch_seconds,
            remaining_epochs: remaining,
        }
    }

    fn idle() -> LaneLoad {
        LaneLoad {
            busy: false,
            migrated: false,
            epoch_end_t: 0.0,
            epoch_seconds: 0.0,
            remaining_epochs: 0.0,
        }
    }

    #[test]
    fn runway_predicate_matches_deadline_arithmetic() {
        // 100 s in, 5 s search + 10 s setup, 80 s epochs, 200 s budget:
        // 100+5+10+80 = 195 ≤ 200 → still has runway.
        assert!(!StealScheduler::out_of_runway(100.0, 5.0, 10.0, 80.0, 200.0));
        assert!(StealScheduler::out_of_runway(110.0, 5.0, 10.0, 80.0, 200.0));
        // No estimate yet ⇒ never "out of runway".
        assert!(!StealScheduler::out_of_runway(199.0, 5.0, 10.0, 0.0, 200.0));
    }

    #[test]
    fn picks_most_loaded_busy_sibling() {
        let cfg = BenchmarkConfig::default();
        let mut s = StealScheduler::new(&cfg, 0);
        // Lane 2 has 5 epochs of 100 s left; lane 1 only one.
        let lanes = vec![idle(), busy(50.0, 100.0, 0.0), busy(50.0, 100.0, 4.0)];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), Some(2));
        // Idle-only siblings: no victim.
        let lanes = vec![idle(), idle()];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), None);
    }

    #[test]
    fn migrated_trials_are_never_victims_without_feedback_routing() {
        let cfg = BenchmarkConfig {
            feedback_routing: false,
            ..BenchmarkConfig::default()
        };
        let mut s = StealScheduler::new(&cfg, 0);
        assert!(!s.into_migrants);
        let mut m = busy(50.0, 100.0, 9.0);
        m.migrated = true;
        let lanes = vec![idle(), m, busy(50.0, 100.0, 1.0)];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), Some(2));
        let lanes = vec![idle(), m];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), None);
    }

    #[test]
    fn feedback_routing_makes_migrants_eligible_victims() {
        // Steal-into-migrant: with the loop closed (the default), an
        // adopted migrant is an eligible victim like any busy sibling —
        // here it is also the most loaded, so the scan picks it.
        let cfg = BenchmarkConfig::default();
        let mut s = StealScheduler::new(&cfg, 0);
        assert!(s.into_migrants, "feedback routing defaults on");
        let mut m = busy(50.0, 100.0, 9.0);
        m.migrated = true;
        let lanes = vec![idle(), m, busy(50.0, 100.0, 1.0)];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), Some(1));
        // A lone migrated sibling is enough to join.
        let lanes = vec![idle(), m];
        assert_eq!(s.pick_victim(0, 40.0, &lanes), Some(1));
    }

    #[test]
    fn scan_is_deterministic_per_node_seed() {
        let cfg = BenchmarkConfig::default();
        let lanes = vec![busy(10.0, 5.0, 1.0), busy(10.0, 5.0, 1.0), idle()];
        let picks: Vec<Option<usize>> = (0..8)
            .map(|_| StealScheduler::new(&cfg, 3).pick_victim(2, 0.0, &lanes))
            .collect();
        assert!(picks.windows(2).all(|w| w[0] == w[1]));
    }
}
