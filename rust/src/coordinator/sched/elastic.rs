//! Cluster-wide elastic scheduler: the inter-group migration pass.
//!
//! Work stealing stops at the node boundary (the NVLink domain). The
//! heterogeneous-fleet scenario AIPerf's single OPS metric is meant to
//! rank — a saturated T4 group next to an idle Ascend group — needs the
//! complement: *cross-group trial migration*. A candidate proposed on a
//! lane with no runway left in its own group (and no sibling trial to
//! steal into) is staged to NFS and parked into the scheduler's pending
//! set; at every epoch barrier the [`ElasticScheduler`] tries to place
//! each pending migrant onto the least-loaded idle lane of *another*
//! node group that `accepts_migrants`, paying
//!
//! * the NFS checkpoint-staging cost (`migration_nfs_bytes_per_param ×
//!   params`, written by the source, read by the destination —
//!   [`crate::cluster::NfsModel::stage_out_seconds`] /
//!   [`crate::cluster::NfsModel::stage_in_seconds`]), and
//! * the cross-node gradient-sync penalty: the adopted trial's allreduce
//!   ring runs over InfiniBand instead of NVLink
//!   ([`crate::sim::timing::TimingModel::epoch_spanning`] with
//!   `crosses_nodes = true`),
//!
//! re-timed under the destination group's `TimingModel` and
//! `batch_per_gpu` (memory adaption re-runs against the destination
//! accelerator). A migrant that fits nowhere yet stays pending and is
//! retried at later barriers; whatever never fits is dropped at the end
//! of the run.
//!
//! Determinism: migrants are collected in shard order and placed in
//! posting order; candidate destinations are scanned in global lane
//! order with a strict `<` on accumulated busy seconds (ties keep the
//! lowest unit). The pass runs only at barriers, between the windows the
//! engines parallelize, so `Engine::Sequential` and `Engine::Parallel`
//! stay bit-identical with migration enabled.

use crate::config::BenchmarkConfig;
use crate::coordinator::shard::{SimContext, SlaveShard};
use crate::flops::count::GraphOps;
use crate::nas::graph::Architecture;
use crate::sim::accuracy::HpPoint;

use super::feedback::FeedbackRouter;
use super::registry::LaneRegistry;
use super::{adapted_batch, migrant_ring};

/// A candidate trial staged for cross-group adoption: everything the
/// destination lane needs to train it, plus provenance for the report
/// counters.
#[derive(Debug, Clone)]
pub struct MigrantCandidate {
    pub arch: Architecture,
    pub hp: HpPoint,
    pub params: u64,
    pub activation_elems: u64,
    pub ops: GraphOps,
    /// Source lane's search round (fixes the warm-up epoch budget).
    pub round: u64,
    /// Epoch budget derived from `round` on the source side.
    pub budget: u64,
    /// Global node index of the proposing shard.
    pub from_node: usize,
    /// Lane index within the proposing shard — the address feedback
    /// routing delivers the trial's observation back to.
    pub from_sub: usize,
    /// Topology group of the proposing shard (migration is inter-group).
    pub from_group: usize,
    /// Simulation time the candidate was staged out.
    pub posted_at: f64,
}

/// Cost/timing facts of adopting one migrant on one destination lane —
/// computed identically by the placement probe and the adopting shard so
/// the two can never drift.
#[derive(Debug, Clone, Copy)]
pub struct MigrantFit {
    /// Per-GPU batch after memory adaption on the destination device.
    pub batch: u64,
    /// NFS checkpoint stage-in seconds on the destination side.
    pub stage_s: f64,
    /// Inter-trial setup seconds on the destination host.
    pub setup_s: f64,
    /// One full (train + validation) epoch, cross-node ring included.
    pub epoch_s: f64,
}

impl MigrantCandidate {
    /// Bytes staged through NFS for this candidate.
    pub fn checkpoint_bytes(&self, cfg: &BenchmarkConfig) -> u64 {
        cfg.migration_nfs_bytes_per_param.saturating_mul(self.params)
    }

    /// Evaluate adopting this migrant on a lane of `gpus` devices in
    /// topology `group`: memory adaption against the destination
    /// accelerator, stage-in cost, and the cross-node epoch re-timing.
    /// `None` when no batch fits the destination device at all.
    pub fn fit_on(&self, ctx: &SimContext, group: usize, gpus: u64) -> Option<MigrantFit> {
        let cfg = ctx.cfg;
        let node = ctx.node(group);
        let batch = adapted_batch(
            &node.gpu,
            self.params,
            self.activation_elems,
            cfg.group_batch(group),
        )?;
        let timing = ctx.timing(group);
        let ring = migrant_ring(timing, &self.ops, self.params, &cfg.dataset, batch, gpus);
        Some(MigrantFit {
            batch,
            stage_s: timing.nfs.transfer_seconds(self.checkpoint_bytes(cfg)),
            setup_s: node.host.setup_seconds,
            epoch_s: ring.total_s,
        })
    }
}

/// The cluster-wide elastic scheduler: owns the lane registry and the
/// pending-migrant set; the per-node steal pass it also owns is handed
/// to each shard at construction (see
/// [`super::steal::StealScheduler::new`]).
pub struct ElasticScheduler {
    registry: LaneRegistry,
    enabled: bool,
    pending: Vec<MigrantCandidate>,
    /// The barrier-time search-feedback router riding the same pass
    /// (inert when `feedback_routing` is off).
    feedback: FeedbackRouter,
}

impl ElasticScheduler {
    pub fn new(cfg: &BenchmarkConfig) -> Self {
        ElasticScheduler {
            registry: LaneRegistry::new(cfg),
            enabled: cfg.migration,
            pending: Vec::new(),
            feedback: FeedbackRouter::new(cfg),
        }
    }

    /// The cluster-wide lane view this scheduler places over.
    pub fn registry(&self) -> &LaneRegistry {
        &self.registry
    }

    /// Migrants staged but not yet adopted anywhere.
    pub fn pending_migrants(&self) -> usize {
        self.pending.len()
    }

    /// Whether the migration pass runs at all (`migration` knob). The
    /// coordinator's dormancy index uses this to decide whether a
    /// barrier pass could have re-armed shard queues (migrant adoption,
    /// `NodeReady`) and therefore needs a full index refresh.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// The inter-group migration pass, run at every epoch barrier (time
    /// `t`, single-threaded in both engines): route finished migrated
    /// trials' observations back to their source lanes, drain every
    /// shard's migrant outbox in shard order, then try to place each
    /// pending migrant. Takes the coordinator's dense `&mut` reference
    /// slice (shards live inside the worker pool's cells between
    /// barriers), indexed by global node like the registry.
    pub fn barrier_pass(&mut self, t: f64, shards: &mut [&mut SlaveShard], ctx: &SimContext) {
        if !self.enabled {
            return;
        }
        debug_assert!(
            shards.iter().enumerate().all(|(i, s)| s.node == i),
            "shard vector must be indexed by global node"
        );
        // Feedback first: observations belong to trials that finalized
        // during the window just merged, before any new placement.
        self.feedback.barrier_pass(shards);
        for s in shards.iter_mut() {
            self.pending.append(&mut s.migrant_outbox);
        }
        let pending = std::mem::take(&mut self.pending);
        for m in pending {
            if !self.try_place(t, &m, shards, ctx) {
                self.pending.push(m);
            }
        }
    }

    /// Place one migrant on the least-loaded idle lane of another
    /// accepting group, if any destination has the memory and the runway
    /// for at least one full epoch before the deadline.
    fn try_place(
        &self,
        t: f64,
        m: &MigrantCandidate,
        shards: &mut [&mut SlaveShard],
        ctx: &SimContext,
    ) -> bool {
        let cfg = ctx.cfg;
        let mut best: Option<(usize, MigrantFit, f64)> = None;
        for (li, lane) in self.registry.lanes().iter().enumerate() {
            if lane.group == m.from_group {
                continue; // migration is inter-group by definition
            }
            if !cfg.topology.groups[lane.group].accepts_migrants {
                continue;
            }
            if !shards[lane.node].lane_parked(lane.sub) {
                continue;
            }
            let Some(fit) = m.fit_on(ctx, lane.group, lane.gpus) else {
                continue; // does not fit the destination device at any batch
            };
            if t + fit.stage_s + fit.setup_s + fit.epoch_s > cfg.duration_s {
                continue; // not even one epoch of runway on this lane
            }
            // Least-loaded = least accumulated busy time; the strict `<`
            // keeps the lowest-unit lane on ties (registry order).
            let load = shards[lane.node].lane_busy_seconds(lane.sub);
            let better = match &best {
                None => true,
                Some((_, _, b)) => load < *b,
            };
            if better {
                best = Some((li, fit, load));
            }
        }
        let Some((li, fit, _)) = best else {
            return false;
        };
        let lane = self.registry.lanes()[li];
        if !shards[lane.node].accept_migrant(t, lane.sub, m, &fit, ctx) {
            return false; // defensive refusal: keep the migrant pending
        }
        // Count the dispatch only once the adoption is committed, so the
        // in/out counters stay conserved even on a refusal.
        shards[m.from_node].note_migration_out();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
    use crate::flops::OpWeights;

    fn mixed_cfg() -> BenchmarkConfig {
        let mut t4 = NodeGroup::new("t4", 1, 8, GpuModel::t4());
        t4.batch_per_gpu = Some(256);
        BenchmarkConfig {
            topology: ClusterTopology {
                groups: vec![t4, NodeGroup::new("v100", 1, 8, GpuModel::v100())],
            },
            subshards_per_node: 2,
            migration: true,
            ..BenchmarkConfig::default()
        }
    }

    fn migrant(ctx: &SimContext, from_group: usize) -> MigrantCandidate {
        let arch = ctx.initial.clone();
        let stats = arch.stats(&OpWeights::default());
        MigrantCandidate {
            arch,
            hp: HpPoint::default(),
            params: stats.params,
            activation_elems: stats.activation_elems,
            ops: stats.ops,
            round: 1,
            budget: 2,
            from_node: 0,
            from_sub: 0,
            from_group,
            posted_at: 0.0,
        }
    }

    #[test]
    fn fit_probe_prices_stage_and_cross_node_ring() {
        let cfg = mixed_cfg();
        cfg.validate().unwrap();
        let ctx = SimContext::new(&cfg);
        let m = migrant(&ctx, 0);
        let fit = m.fit_on(&ctx, 1, 4).expect("initial arch fits a V100");
        // Destination batch follows the destination group's configuration.
        assert!(fit.batch <= cfg.group_batch(1));
        assert!(fit.stage_s > 0.0);
        assert!(fit.epoch_s > 0.0);
        // The cross-node ring must price above the NVLink-domain epoch.
        let timing = ctx.timing(1);
        let local = timing
            .epoch_with_gpus(
                m.ops.train_per_image(),
                m.params,
                cfg.dataset.train_images,
                fit.batch,
                4,
            )
            .total_s
            + timing.validation_with_gpus(
                m.ops.val_per_image(),
                cfg.dataset.val_images,
                fit.batch,
                4,
            );
        assert!(fit.epoch_s > local, "{} vs {}", fit.epoch_s, local);
    }

    #[test]
    fn checkpoint_bytes_scale_with_params() {
        let cfg = mixed_cfg();
        let ctx = SimContext::new(&cfg);
        let m = migrant(&ctx, 0);
        assert_eq!(
            m.checkpoint_bytes(&cfg),
            cfg.migration_nfs_bytes_per_param * m.params
        );
    }

    #[test]
    fn disabled_scheduler_is_inert() {
        let mut cfg = mixed_cfg();
        cfg.migration = false;
        cfg.validate().unwrap();
        let ctx = SimContext::new(&cfg);
        let mut sched = ElasticScheduler::new(&cfg);
        let mut shards: Vec<SlaveShard> = cfg
            .topology
            .nodes()
            .map(|(group, node)| SlaveShard::new(node, group, &cfg))
            .collect();
        let mut refs: Vec<&mut SlaveShard> = shards.iter_mut().collect();
        sched.barrier_pass(600.0, &mut refs, &ctx);
        assert!(!sched.is_enabled());
        assert_eq!(sched.pending_migrants(), 0);
        assert!(shards.iter().all(|s| s.migrations_in == 0 && s.migrations_out == 0));
    }

    #[test]
    fn registry_spans_every_lane() {
        let cfg = mixed_cfg();
        let sched = ElasticScheduler::new(&cfg);
        assert_eq!(sched.registry().len() as u64, cfg.total_subshards());
    }
}
