//! Barrier-time search-feedback routing: closing the elastic loop.
//!
//! Migration (see [`super::elastic`]) moves a *trial* to another node
//! group, but the search state it came from — the source lane's TPE
//! optimizer — stays behind. Pre-feedback, a migrated trial's result was
//! recorded into the shared history and then dropped on the optimizer
//! side: the destination lane must not observe foreign hyperparameters
//! (they came from the source lane's TPE stream), and the source lane
//! never heard back. Exactly the heterogeneous scenarios migration
//! exists for ran with a degraded search.
//!
//! [`FeedbackRouter`] closes that loop. When a migrated trial finalizes,
//! the destination shard posts a [`RoutedObservation`] — the source
//! lane's coordinates plus the trial's `(hyperparameters, loss)` — into
//! its feedback outbox, exactly when a native trial of that round would
//! have observed its own TPE. At the next epoch barrier the router
//! drains every shard's outbox in shard order (the same flat node order
//! as [`super::registry::LaneRegistry`] — shards are indexed by global
//! node) and injects each observation into the source lane's TPE, in
//! posting order. The pass runs single-threaded at the barrier, between
//! the windows the engines parallelize, so `Engine::Sequential` and
//! `Engine::Parallel` stay bit-identical with routing enabled — and with
//! `feedback_routing = false` no observation is ever posted, reproducing
//! the pre-feedback schedules exactly.
//!
//! The same `feedback_routing` knob gates the two siblings of this
//! subsystem that ride on the same provenance plumbing:
//!
//! * **group-scoped OOM penalties** — penalty records carry the group
//!   whose accelerator the candidate failed to fit, and
//!   `SearchPolicy::select_parent_on` only disqualifies parenthood for
//!   proposals on that group (`ModelRecord::group`);
//! * **steal-into-migrant** — a sibling lane out of runway (parked or
//!   not) may join an adopted migrant's gradient ring, re-timed with the
//!   combined device count over InfiniBand via the single-sourced
//!   [`super::migrant_ring`] helper, so steal and migration compose.

use crate::config::BenchmarkConfig;
use crate::coordinator::shard::SlaveShard;
use crate::sim::accuracy::HpPoint;

/// One migrated trial's optimizer feedback, addressed back to the source
/// lane that proposed it.
#[derive(Debug, Clone, Copy)]
pub struct RoutedObservation {
    /// Global node index of the source lane's shard.
    pub to_node: usize,
    /// Lane index within the source shard.
    pub to_sub: usize,
    /// The hyperparameters the source lane's TPE suggested.
    pub hp: HpPoint,
    /// TPE loss: `1 − best validation accuracy` of the migrated trial.
    pub loss: f64,
}

/// The barrier-time router: drains destination-side feedback outboxes
/// and injects each observation into its source lane's TPE.
pub struct FeedbackRouter {
    enabled: bool,
}

impl FeedbackRouter {
    pub fn new(cfg: &BenchmarkConfig) -> Self {
        FeedbackRouter {
            enabled: cfg.feedback_routing,
        }
    }

    /// Whether the loop is closed at all (`feedback_routing`).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The routing pass, run at every epoch barrier (single-threaded in
    /// both engines): drain every shard's feedback outbox in shard order
    /// — the registry's flat node order — then deliver each observation
    /// to its source lane in posting order. Returns the number of
    /// observations delivered. Like the elastic pass, takes the
    /// coordinator's dense `&mut` reference slice indexed by global
    /// node.
    pub fn barrier_pass(&self, shards: &mut [&mut SlaveShard]) -> u64 {
        if !self.enabled {
            debug_assert!(
                shards.iter().all(|s| s.feedback_outbox.is_empty()),
                "observations posted with feedback routing off"
            );
            return 0;
        }
        let mut routed: Vec<RoutedObservation> = Vec::new();
        for s in shards.iter_mut() {
            routed.append(&mut s.feedback_outbox);
        }
        let n = routed.len() as u64;
        for obs in routed {
            shards[obs.to_node].inject_feedback(&obs);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};

    fn mixed_cfg(feedback: bool) -> BenchmarkConfig {
        let mut t4 = NodeGroup::new("t4", 1, 8, GpuModel::t4());
        t4.batch_per_gpu = Some(256);
        BenchmarkConfig {
            topology: ClusterTopology {
                groups: vec![t4, NodeGroup::new("v100", 1, 8, GpuModel::v100())],
            },
            subshards_per_node: 2,
            migration: true,
            feedback_routing: feedback,
            ..BenchmarkConfig::default()
        }
    }

    fn shards(cfg: &BenchmarkConfig) -> Vec<SlaveShard> {
        let mut shards = Vec::new();
        for (group, node) in cfg.topology.nodes() {
            shards.push(SlaveShard::new(node, group, cfg));
        }
        shards
    }

    /// Adapt an owned shard vector to the router's reference-slice
    /// signature, the way the coordinator's barrier phase does.
    fn pass(router: &FeedbackRouter, sh: &mut [SlaveShard]) -> u64 {
        let mut refs: Vec<&mut SlaveShard> = sh.iter_mut().collect();
        router.barrier_pass(&mut refs)
    }

    #[test]
    fn routes_posted_observations_to_the_source_lane() {
        let cfg = mixed_cfg(true);
        cfg.validate().unwrap();
        let router = FeedbackRouter::new(&cfg);
        assert!(router.enabled());
        let mut sh = shards(&cfg);
        // Destination shard 1 finished two migrated trials proposed by
        // shard 0's lanes.
        for (sub, loss) in [(0usize, 0.4f64), (1, 0.3)] {
            sh[1].feedback_outbox.push(RoutedObservation {
                to_node: 0,
                to_sub: sub,
                hp: HpPoint::default(),
                loss,
            });
        }
        assert_eq!(pass(&router, &mut sh), 2);
        assert_eq!(sh[0].feedback_routed, 2, "source shard counts the landings");
        assert_eq!(sh[1].feedback_routed, 0);
        assert!(sh[1].feedback_outbox.is_empty(), "outbox drained");
        // A second pass with nothing posted delivers nothing.
        assert_eq!(pass(&router, &mut sh), 0);
        assert_eq!(sh[0].feedback_routed, 2);
    }

    #[test]
    fn disabled_router_is_inert() {
        let cfg = mixed_cfg(false);
        cfg.validate().unwrap();
        let router = FeedbackRouter::new(&cfg);
        assert!(!router.enabled());
        let mut sh = shards(&cfg);
        assert_eq!(pass(&router, &mut sh), 0);
        assert!(sh.iter().all(|s| s.feedback_routed == 0));
    }
}
