//! Cluster-wide lane registry.
//!
//! The elastic scheduler reasons about *lanes* — the sub-shard trial
//! trainers of every node — across the whole cluster, so it needs one
//! flat, deterministically ordered view of them. [`LaneRegistry`]
//! materializes that view from the validated configuration: one
//! [`LaneSlot`] per lane, in global unit order (group 0's nodes' lanes
//! first, then group 1's, … — the same numbering that fixes RNG streams
//! and the coordinator's merge order, see
//! [`crate::config::BenchmarkConfig::subshard_base`]).

use crate::config::BenchmarkConfig;

/// One sub-shard lane's static placement facts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneSlot {
    /// Topology group the lane's node belongs to.
    pub group: usize,
    /// Global node index (equals the owning shard's index in the
    /// coordinator's shard vector).
    pub node: usize,
    /// Lane index within its node (`0..subshards_per_node`).
    pub sub: usize,
    /// Globally unique lane id (the RNG-stream / trial-id stride unit).
    pub unit: u64,
    /// Devices the lane trains on when running solo.
    pub gpus: u64,
}

/// Flat, deterministically ordered view of every lane in the cluster.
pub struct LaneRegistry {
    lanes: Vec<LaneSlot>,
}

impl LaneRegistry {
    /// Build the registry from a (validated) configuration. Lane order is
    /// ascending `unit`.
    pub fn new(cfg: &BenchmarkConfig) -> Self {
        let mut lanes = Vec::with_capacity(cfg.total_subshards() as usize);
        for (group, node) in cfg.topology.nodes() {
            let k = cfg.group_subshards(group).max(1) as usize;
            let g = &cfg.topology.groups[group];
            let lane_gpus = (g.gpus_per_node / k as u64).max(1);
            let base = cfg.subshard_base(group, node);
            for sub in 0..k {
                lanes.push(LaneSlot {
                    group,
                    node,
                    sub,
                    unit: base + sub as u64,
                    gpus: lane_gpus,
                });
            }
        }
        debug_assert!(
            lanes.windows(2).all(|w| w[0].unit + 1 == w[1].unit),
            "lane units must be dense and ascending"
        );
        LaneRegistry { lanes }
    }

    /// Every lane, in global unit order.
    pub fn lanes(&self) -> &[LaneSlot] {
        &self.lanes
    }

    /// Total lanes across the cluster.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};

    #[test]
    fn registry_matches_config_unit_numbering() {
        let mut v100 = NodeGroup::new("v100", 2, 8, GpuModel::v100());
        v100.subshards_per_node = Some(2);
        let cfg = BenchmarkConfig {
            topology: ClusterTopology {
                groups: vec![NodeGroup::new("t4", 2, 8, GpuModel::t4()), v100],
            },
            subshards_per_node: 1,
            ..BenchmarkConfig::default()
        };
        cfg.validate().unwrap();
        let reg = LaneRegistry::new(&cfg);
        assert_eq!(reg.len() as u64, cfg.total_subshards());
        assert_eq!(reg.len(), 2 * 1 + 2 * 2);
        // Units are dense, ascending, and agree with subshard_base.
        for (i, lane) in reg.lanes().iter().enumerate() {
            assert_eq!(lane.unit, i as u64);
            assert_eq!(
                lane.unit,
                cfg.subshard_base(lane.group, lane.node) + lane.sub as u64
            );
        }
        // Node indices are global (group 0's nodes first) and lane widths
        // split each node's devices.
        assert_eq!(reg.lanes()[0], LaneSlot { group: 0, node: 0, sub: 0, unit: 0, gpus: 8 });
        assert_eq!(reg.lanes()[2], LaneSlot { group: 1, node: 2, sub: 0, unit: 2, gpus: 4 });
        assert_eq!(reg.lanes()[5], LaneSlot { group: 1, node: 3, sub: 1, unit: 5, gpus: 4 });
    }

    #[test]
    fn single_group_single_lane_is_node_numbering() {
        let cfg = BenchmarkConfig::homogeneous(3);
        let reg = LaneRegistry::new(&cfg);
        assert_eq!(reg.len(), 3);
        for (i, lane) in reg.lanes().iter().enumerate() {
            assert_eq!((lane.node, lane.sub, lane.unit), (i, 0, i as u64));
            assert_eq!(lane.gpus, 8);
        }
        assert!(!reg.is_empty());
    }
}
