//! The elastic scheduler subsystem: every placement policy of the
//! simulated benchmark, extracted from the shard/master mechanics.
//!
//! AIPerf's near-linear weak scaling rests on keeping every accelerator
//! busy. Three layers of elasticity serve that goal, in increasing
//! radius, and this module owns all of them:
//!
//! * [`registry`] — the cluster-wide lane registry: one deterministic,
//!   flat view of every sub-shard trial lane (group, node, lane, unit,
//!   width);
//! * [`steal`] — the intra-node steal pass: runway predicate +
//!   seed-derived victim scan; a lane out of runway lends its devices to
//!   the most-loaded sibling trial inside the NVLink domain;
//! * [`elastic`] — the inter-group migration pass: a candidate proposed
//!   on a lane with no runway and no sibling to steal into is staged to
//!   NFS and adopted, at an epoch barrier, by the least-loaded idle lane
//!   of another accepting group — re-timed under the destination group's
//!   device model with its gradient ring over InfiniBand.
//!
//! The scheduler decides; [`crate::coordinator::shard`] executes (event
//! scheduling, epoch re-timing, NFS charging) and
//! [`crate::coordinator::master`] merges. Decisions during a window are
//! node-local and decisions at a barrier are single-threaded, so both
//! execution engines stay bit-identical per seed — with migration off,
//! the whole subsystem reproduces the pure steal schedules exactly.

pub mod elastic;
pub mod registry;
pub mod steal;

pub use elastic::{ElasticScheduler, MigrantCandidate, MigrantFit};
pub use registry::{LaneRegistry, LaneSlot};
pub use steal::{LaneLoad, StealScheduler};

use crate::cluster::GpuModel;

/// Memory adaption (paper §4.2): halve the requested per-GPU batch until
/// the candidate fits the accelerator; when the halving ladder bottoms
/// out without fitting, clamp to the exact largest fitting batch; `None`
/// when no batch fits at all. One policy shared by native trial starts
/// and migration placement, so a migrant is re-adapted against its
/// *destination* device exactly like a local candidate would be.
pub fn adapted_batch(
    gpu: &GpuModel,
    params: u64,
    activation_elems: u64,
    requested: u64,
) -> Option<u64> {
    let mut batch = requested;
    while batch > 8 && !gpu.fits(params, activation_elems, batch) {
        batch /= 2;
    }
    if gpu.fits(params, activation_elems, batch) {
        Some(batch)
    } else {
        gpu.max_fitting_batch(params, activation_elems)
            .map(|b| b.min(requested))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PARAMS: u64 = 25_600_000;
    const ACT: u64 = 11_000_000;

    #[test]
    fn adapted_batch_keeps_fitting_requests() {
        let gpu = GpuModel::v100();
        assert_eq!(adapted_batch(&gpu, PARAMS, ACT, 448), Some(448));
    }

    #[test]
    fn adapted_batch_halves_to_fit_then_clamps_exactly() {
        let gpu = GpuModel::t4();
        // Find a model that fits at some power-of-two rung below the
        // request: the ladder must land on a fitting batch ≤ request.
        let b = adapted_batch(&gpu, PARAMS, 40_000_000, 448).expect("fits at some batch");
        assert!(b <= 448);
        assert!(gpu.fits(PARAMS, 40_000_000, b));
        // When even batch 8 does not fit, the exact boundary is used.
        let heavy_act = 2_000_000_000;
        match adapted_batch(&gpu, PARAMS, heavy_act, 448) {
            Some(b) => {
                assert!(gpu.fits(PARAMS, heavy_act, b));
                assert!(!gpu.fits(PARAMS, heavy_act, b + 1));
            }
            None => assert!(gpu.max_fitting_batch(PARAMS, heavy_act).is_none()),
        }
        // A model whose fixed residents exceed memory fits nowhere.
        assert_eq!(adapted_batch(&gpu, gpu.memory_bytes, ACT, 448), None);
    }
}
