//! The elastic scheduler subsystem: every placement policy of the
//! simulated benchmark, extracted from the shard/master mechanics.
//!
//! AIPerf's near-linear weak scaling rests on keeping every accelerator
//! busy. Three layers of elasticity serve that goal, in increasing
//! radius, and this module owns all of them:
//!
//! * [`registry`] — the cluster-wide lane registry: one deterministic,
//!   flat view of every sub-shard trial lane (group, node, lane, unit,
//!   width);
//! * [`steal`] — the intra-node steal pass: runway predicate +
//!   seed-derived victim scan; a lane out of runway lends its devices to
//!   the most-loaded sibling trial inside the NVLink domain;
//! * [`elastic`] — the inter-group migration pass: a candidate proposed
//!   on a lane with no runway and no sibling to steal into is staged to
//!   NFS and adopted, at an epoch barrier, by the least-loaded idle lane
//!   of another accepting group — re-timed under the destination group's
//!   device model with its gradient ring over InfiniBand;
//! * [`feedback`] — the barrier-time search-feedback router: a migrated
//!   trial's `(hyperparameters, loss)` observation travels back to the
//!   source lane's TPE instead of being dropped, OOM penalties scope to
//!   the group whose accelerator refused the candidate, and sibling
//!   lanes may steal into an adopted migrant's InfiniBand ring.
//!
//! The scheduler decides; [`crate::coordinator::shard`] executes (event
//! scheduling, epoch re-timing, NFS charging) and
//! [`crate::coordinator::master`] merges. Decisions during a window are
//! node-local and decisions at a barrier are single-threaded, so both
//! execution engines stay bit-identical per seed — with migration off,
//! the whole subsystem reproduces the pure steal schedules exactly.

pub mod elastic;
pub mod feedback;
pub mod registry;
pub mod steal;

pub use elastic::{ElasticScheduler, MigrantCandidate, MigrantFit};
pub use feedback::{FeedbackRouter, RoutedObservation};
pub use registry::{LaneRegistry, LaneSlot};
pub use steal::{LaneLoad, StealScheduler};

use crate::cluster::GpuModel;
use crate::data::DatasetDescriptor;
use crate::flops::count::GraphOps;
use crate::sim::timing::{EpochTiming, TimingModel};

/// Memory adaption (paper §4.2): halve the requested per-GPU batch until
/// the candidate fits the accelerator; when the halving ladder bottoms
/// out without fitting, clamp to the exact largest fitting batch; `None`
/// when no batch fits at all. One policy shared by native trial starts
/// and migration placement, so a migrant is re-adapted against its
/// *destination* device exactly like a local candidate would be.
pub fn adapted_batch(
    gpu: &GpuModel,
    params: u64,
    activation_elems: u64,
    requested: u64,
) -> Option<u64> {
    let mut batch = requested;
    while batch > 8 && !gpu.fits(params, activation_elems, batch) {
        batch /= 2;
    }
    if gpu.fits(params, activation_elems, batch) {
        Some(batch)
    } else {
        gpu.max_fitting_batch(params, activation_elems)
            .map(|b| b.min(requested))
    }
}

/// Timing of a gradient ring that crosses the NVLink boundary — an
/// adopted migrant's allreduce runs over InfiniBand whatever its width.
#[derive(Debug, Clone, Copy)]
pub struct RingTiming {
    /// One training epoch over the cross-node ring.
    pub epoch: EpochTiming,
    /// One validation epoch at the same width.
    pub val_s: f64,
    /// Full (train + validation) epoch seconds.
    pub total_s: f64,
    /// IB-vs-NVLink sync delta the ring pays per completed epoch
    /// (accrued into the migration-overhead counter as epochs finish).
    pub sync_penalty_s: f64,
}

/// The single source of the InfiniBand re-timing every migrant ring uses
/// — the placement probe ([`MigrantCandidate::fit_on`]), the adopting
/// shard, and the steal-into-migrant widening all price an epoch through
/// this one function, so the three can never drift.
pub fn migrant_ring(
    timing: &TimingModel,
    ops: &GraphOps,
    params: u64,
    dataset: &DatasetDescriptor,
    batch: u64,
    gpus: u64,
) -> RingTiming {
    let epoch = timing.epoch_spanning(
        ops.train_per_image(),
        params,
        dataset.train_images,
        batch,
        gpus,
        true,
    );
    let val_s = timing.validation_with_gpus(ops.val_per_image(), dataset.val_images, batch, gpus);
    RingTiming {
        epoch,
        val_s,
        total_s: epoch.total_s + val_s,
        sync_penalty_s: timing.network.migration_sync_penalty_seconds(gpus, params)
            * epoch.steps as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PARAMS: u64 = 25_600_000;
    const ACT: u64 = 11_000_000;

    #[test]
    fn adapted_batch_keeps_fitting_requests() {
        let gpu = GpuModel::v100();
        assert_eq!(adapted_batch(&gpu, PARAMS, ACT, 448), Some(448));
    }

    #[test]
    fn adapted_batch_halves_to_fit_then_clamps_exactly() {
        let gpu = GpuModel::t4();
        // Find a model that fits at some power-of-two rung below the
        // request: the ladder must land on a fitting batch ≤ request.
        let b = adapted_batch(&gpu, PARAMS, 40_000_000, 448).expect("fits at some batch");
        assert!(b <= 448);
        assert!(gpu.fits(PARAMS, 40_000_000, b));
        // When even batch 8 does not fit, the exact boundary is used.
        let heavy_act = 2_000_000_000;
        match adapted_batch(&gpu, PARAMS, heavy_act, 448) {
            Some(b) => {
                assert!(gpu.fits(PARAMS, heavy_act, b));
                assert!(!gpu.fits(PARAMS, heavy_act, b + 1));
            }
            None => assert!(gpu.max_fitting_batch(PARAMS, heavy_act).is_none()),
        }
        // A model whose fixed residents exceed memory fits nowhere.
        assert_eq!(adapted_batch(&gpu, gpu.memory_bytes, ACT, 448), None);
    }

    #[test]
    fn migrant_ring_prices_above_the_nvlink_epoch_and_widens_down() {
        use crate::flops::OpWeights;
        use crate::nas::graph::Architecture;
        let timing = TimingModel::default();
        let dataset = DatasetDescriptor::imagenet();
        let stats = Architecture::initial(dataset.image, dataset.channels, dataset.num_classes)
            .stats(&OpWeights::default());
        let ring4 = migrant_ring(&timing, &stats.ops, stats.params, &dataset, 448, 4);
        // Cross-node ring: strictly above the NVLink-domain epoch of the
        // same width, by more than zero sync penalty.
        let train = stats.ops.train_per_image();
        let local = timing
            .epoch_with_gpus(train, stats.params, dataset.train_images, 448, 4)
            .total_s
            + timing.validation_with_gpus(stats.ops.val_per_image(), dataset.val_images, 448, 4);
        assert!(ring4.total_s > local);
        assert!(ring4.sync_penalty_s > 0.0);
        assert_eq!(ring4.total_s.to_bits(), (ring4.epoch.total_s + ring4.val_s).to_bits());
        // Steal-into-migrant widening: more devices, shorter epoch.
        let ring8 = migrant_ring(&timing, &stats.ops, stats.params, &dataset, 448, 8);
        assert!(ring8.total_s < ring4.total_s);
    }
}
