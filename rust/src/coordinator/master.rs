//! The end-to-end simulated benchmark run (paper §4.3 workflow).
//!
//! A discrete-event loop over the cluster substrate executes the paper's
//! exact protocol: per slave node, the CPU search loop proposes a morphed
//! candidate from the ranked history into the buffer; the node's GPUs
//! drain the buffer and train it with synchronous data parallelism,
//! epoch by epoch, with early stopping; warm-up rounds use the Appendix-C
//! predicted accuracy; HPO (TPE) activates at round 5; the run terminates
//! at the user-defined wall-clock budget and the analysis toolkit computes
//! score, achieved error, regulated score, and telemetry (Figs 4–6, 9–12).
//!
//! Simulation time is *modelled* cluster time (the 16×8-V100 testbed is a
//! hardware gate — DESIGN.md §2); every decision the framework makes —
//! routing, ranking, morphing, HPO, stopping — executes for real.

use crate::util::rng::Rng;

use crate::cluster::nfs::NfsStats;
use crate::config::BenchmarkConfig;
use crate::coordinator::buffer::{ArchBuffer, Candidate};
use crate::coordinator::dispatcher::Dispatcher;
use crate::coordinator::history::{HistoryList, ModelRecord};
use crate::coordinator::trial::{ActiveTrial, TrialStatus};
use crate::flops::OpWeights;
use crate::hpo::{aiperf_space, Optimizer, Tpe};
use crate::metrics::report::BenchmarkReport;
use crate::metrics::score::{validate_result, ScoreSample};
use crate::metrics::telemetry::{NodeReading, Telemetry};
use crate::nas::graph::Architecture;
use crate::nas::search::SearchPolicy;
use crate::predict::logfit::LogFit;
use crate::sim::accuracy::{arch_id, AccuracySurrogate, HpPoint};
use crate::sim::engine::EventQueue;
use crate::sim::timing::TimingModel;
use crate::util::rng::derive;

/// Discrete events of the run.
#[derive(Debug, Clone, Copy)]
enum Event {
    /// Node is free: run the search loop and start the next trial.
    NodeReady(usize),
    /// Node finished one training epoch (incl. validation).
    EpochDone(usize),
    /// Telemetry sampling tick.
    Telemetry,
    /// Score sampling tick (hourly in the paper).
    Score,
}

/// Per-slave mutable state.
struct SlaveState {
    round: u64,
    tpe: Tpe,
    rng: Rng,
    trial: Option<ActiveTrial>,
    /// Seconds per (train + validate) epoch for the current trial.
    epoch_seconds: f64,
    /// GPU busy fraction while the current trial trains.
    busy_fraction: f64,
    /// GPU memory utilization fraction for the current trial.
    mem_fraction: f64,
    /// Until when the node is in inter-trial setup (telemetry dent).
    setup_until: f64,
}

/// Run the full simulated benchmark and produce the report.
pub fn run_benchmark(cfg: &BenchmarkConfig) -> BenchmarkReport {
    cfg.validate().expect("invalid benchmark configuration");
    let weights = OpWeights::default();
    let timing = TimingModel {
        node: cfg.node,
        ..TimingModel::default()
    };
    let surrogate = AccuracySurrogate {
        seed: cfg.seed,
        ..AccuracySurrogate::default()
    };
    let policy = SearchPolicy {
        limits: cfg.morph_limits,
        ..SearchPolicy::default()
    };
    let initial = Architecture::initial(
        cfg.dataset.image,
        cfg.dataset.channels,
        cfg.dataset.num_classes,
    );

    let mut history = HistoryList::new();
    let mut buffer = ArchBuffer::new((cfg.nodes as usize * 2).max(4));
    let mut dispatcher = Dispatcher::new();
    let mut telemetry = Telemetry::new(cfg.telemetry_interval_s);
    let mut score_series: Vec<ScoreSample> = Vec::new();
    let mut nfs_stats = NfsStats::default();
    let mut cumulative_ops = 0f64;
    let mut tele_rng = derive(cfg.seed, "telemetry", 0);

    let mut slaves: Vec<SlaveState> = (0..cfg.nodes as usize)
        .map(|i| SlaveState {
            round: 0,
            tpe: Tpe::new(aiperf_space()),
            rng: derive(cfg.seed, "slave", i as u64),
            trial: None,
            epoch_seconds: 0.0,
            busy_fraction: 0.0,
            mem_fraction: 0.0,
            setup_until: 0.0,
        })
        .collect();

    let mut q = EventQueue::new();
    for i in 0..cfg.nodes as usize {
        // Asynchronous dispatch: SLURM stagger of a few seconds per node.
        q.schedule(i as f64 * 2.0, Event::NodeReady(i));
    }
    q.schedule(cfg.telemetry_interval_s, Event::Telemetry);
    q.schedule(cfg.score_interval_s, Event::Score);

    while let Some((t, ev)) = q.pop() {
        if t > cfg.duration_s {
            continue; // termination rule: user-defined running time
        }
        match ev {
            Event::NodeReady(i) => {
                let trial_id = match dispatcher.assign(i) {
                    Ok(id) => id,
                    Err(_) => continue, // defensive: node already busy
                };
                let s = &mut slaves[i];
                s.round += 1;

                // --- CPU search loop: propose a candidate into the buffer.
                let arch = if history.is_empty() {
                    initial.clone()
                } else {
                    policy.propose(&history.ranked_view(), &mut s.rng).0
                };
                let _ = buffer.push(Candidate {
                    arch: arch.clone(),
                    proposed_by: i,
                    proposed_at: t,
                });
                // --- Trainer drains the buffer (NFS round trips charged).
                let cand = buffer.pop().map(|c| c.arch).unwrap_or(arch);
                let mut setup = cfg.node.search_seconds + cfg.node.setup_seconds;
                setup += timing.nfs.read_seconds(history.nfs_bytes(), &mut nfs_stats);
                setup += timing.nfs.write_seconds(2048, &mut nfs_stats);
                setup += timing.nfs.read_seconds(2048, &mut nfs_stats);

                // --- Hyperparameters: defaults in warm-up, TPE afterwards.
                let hp = if cfg.warmup.hpo_active(s.round) {
                    let c = s.tpe.suggest(&mut s.rng);
                    HpPoint {
                        dropout: c[0],
                        kernel: c[1],
                    }
                } else {
                    HpPoint::default()
                };

                // --- Memory adaption: halve the batch until the model fits.
                // Single lowering pass per trial (EXPERIMENTS.md §Perf/L3).
                let stats = cand.stats(&weights);
                let (params, act, ops) = (stats.params, stats.activation_elems, stats.ops);
                let mut batch = cfg.batch_per_gpu;
                while batch > 8 && !cfg.node.gpu.fits(params, act, batch) {
                    batch /= 2;
                }
                let budget = cfg.warmup.epochs_for_round(s.round);
                let epoch = timing.epoch(
                    ops.train_per_image(),
                    params,
                    cfg.dataset.train_images,
                    batch,
                );
                let val_s =
                    timing.validation(ops.val_per_image(), cfg.dataset.val_images, batch);
                let total_epoch_s = epoch.total_s + val_s;

                s.epoch_seconds = total_epoch_s;
                s.busy_fraction =
                    (epoch.compute_s + val_s) / total_epoch_s * epoch.gpu_busy_fraction.max(0.9);
                s.mem_fraction = (cfg.node.gpu.memory_demand(params, act, batch) as f64
                    / cfg.node.gpu.memory_bytes as f64)
                    .min(1.0);
                s.setup_until = t + setup;
                s.trial = Some(ActiveTrial::new(
                    trial_id,
                    cand.clone(),
                    arch_id(&cand.signature()),
                    hp,
                    ops,
                    batch,
                    s.round,
                    budget,
                ));
                q.schedule(t + setup + total_epoch_s, Event::EpochDone(i));
            }

            Event::EpochDone(i) => {
                let s = &mut slaves[i];
                let Some(trial) = s.trial.as_mut() else {
                    continue;
                };
                // Account analytical ops for the finished epoch.
                cumulative_ops += trial.ops.train_per_image() as f64
                    * cfg.dataset.train_images as f64
                    + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64;

                let acc = surrogate.accuracy(
                    trial.arch_id,
                    trial.params,
                    &trial.hp,
                    trial.epoch + 1,
                );
                let status = trial.record_epoch(acc, cfg.patience, cfg.min_delta);
                let next_epoch_end = t + s.epoch_seconds;

                if status == TrialStatus::Continue && next_epoch_end <= cfg.duration_s {
                    q.schedule(next_epoch_end, Event::EpochDone(i));
                } else {
                    // --- Trial complete: record into the history.
                    let trial = s.trial.take().unwrap();
                    let warmup_round = !cfg.warmup.hpo_active(trial.round);
                    let (accuracy, predicted) = if warmup_round
                        && trial.epoch < cfg.warmup.max_epochs
                        && trial.accs.len() >= 2
                    {
                        // Appendix C: conservative log-fit prediction.
                        let (es, accs) = trial.curve();
                        (LogFit::fit(&es, &accs).conservative(60.0), true)
                    } else {
                        (trial.best_accuracy(), false)
                    };
                    let ops_spent = (trial.ops.train_per_image() as f64
                        * cfg.dataset.train_images as f64
                        + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64)
                        * trial.epoch as f64;
                    if cfg.warmup.hpo_active(trial.round) {
                        s.tpe.observe(
                            vec![trial.hp.dropout, trial.hp.kernel],
                            1.0 - trial.best_accuracy(),
                        );
                    }
                    history.push(ModelRecord {
                        id: trial.trial_id,
                        signature: trial.arch.signature(),
                        params: trial.params,
                        measured_accuracy: trial.best_accuracy(),
                        arch: trial.arch,
                        accuracy,
                        predicted,
                        node: i,
                        round: trial.round,
                        epochs_trained: trial.epoch,
                        ops: ops_spent,
                        dropout: trial.hp.dropout,
                        kernel: trial.hp.kernel,
                        completed_at: t,
                    });
                    let _ = dispatcher.complete(trial.trial_id, i);
                    debug_assert!(dispatcher.check_invariants().is_ok());
                    q.schedule(t, Event::NodeReady(i));
                }
            }

            Event::Telemetry => {
                let readings: Vec<NodeReading> = slaves
                    .iter()
                    .map(|s| {
                        let training = s.trial.is_some() && t >= s.setup_until;
                        let jitter = tele_rng.gen_range_f64(-0.02, 0.02);
                        if training {
                            NodeReading {
                                gpu_util: (s.busy_fraction + jitter).clamp(0.0, 1.0),
                                gpu_mem_util: s.mem_fraction.clamp(0.0, 1.0),
                                cpu_util: (cfg.node.cpu_util_training() + jitter / 4.0)
                                    .clamp(0.0, 1.0),
                                host_mem_util: cfg.node.host_memory_util(30 << 30),
                            }
                        } else {
                            // The inter-stage "dent" of Figs 9/10.
                            NodeReading {
                                gpu_util: (0.02 + jitter.abs()).min(0.1),
                                gpu_mem_util: 0.10,
                                cpu_util: (0.30 + jitter).clamp(0.0, 1.0), // search burst
                                host_mem_util: cfg.node.host_memory_util(30 << 30),
                            }
                        }
                    })
                    .collect();
                telemetry.record(t, &readings);
                if t + cfg.telemetry_interval_s <= cfg.duration_s {
                    q.schedule(t + cfg.telemetry_interval_s, Event::Telemetry);
                }
            }

            Event::Score => {
                let best = history.best_measured_error_at(t).unwrap_or(1.0 - 1e-9);
                score_series.push(ScoreSample::new(t, cumulative_ops, best));
                if t + cfg.score_interval_s <= cfg.duration_s {
                    q.schedule(t + cfg.score_interval_s, Event::Score);
                }
            }
        }
    }

    let final_error = history.best_measured_error().unwrap_or(1.0 - 1e-9);
    let (score_flops, regulated) =
        BenchmarkReport::stable_scores(&score_series, cfg.duration_s);
    BenchmarkReport {
        nodes: cfg.nodes,
        gpus_per_node: cfg.node.gpus_per_node,
        duration_s: cfg.duration_s,
        score_series,
        score_flops,
        final_error,
        regulated_score: regulated,
        architectures_evaluated: dispatcher.total_completed(),
        telemetry: telemetry.samples().to_vec(),
        validity: validate_result(
            final_error,
            cfg.precision_bits,
            cfg.duration_s,
            6.0 * 3600.0,
        ),
        nfs_bytes_read: nfs_stats.bytes_read,
        nfs_bytes_written: nfs_stats.bytes_written,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(nodes: u64, hours: f64, seed: u64) -> BenchmarkConfig {
        BenchmarkConfig {
            nodes,
            duration_s: hours * 3600.0,
            seed,
            ..BenchmarkConfig::default()
        }
    }

    #[test]
    fn run_completes_and_reports() {
        let r = run_benchmark(&small_cfg(2, 12.0, 0));
        assert!(r.score_flops > 0.0);
        assert!(r.architectures_evaluated > 0);
        assert!(!r.score_series.is_empty());
        assert!(!r.telemetry.is_empty());
        assert!(r.final_error > 0.0 && r.final_error < 1.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run_benchmark(&small_cfg(2, 8.0, 7));
        let b = run_benchmark(&small_cfg(2, 8.0, 7));
        assert_eq!(a.score_flops, b.score_flops);
        assert_eq!(a.architectures_evaluated, b.architectures_evaluated);
        assert_eq!(a.final_error, b.final_error);
        let c = run_benchmark(&small_cfg(2, 8.0, 8));
        assert_ne!(a.score_flops, c.score_flops);
    }

    #[test]
    fn score_scales_roughly_linearly() {
        // Fig 4's headline: double the nodes ⇒ ~double the score.
        let s2 = run_benchmark(&small_cfg(2, 12.0, 1)).score_flops;
        let s4 = run_benchmark(&small_cfg(4, 12.0, 1)).score_flops;
        let ratio = s4 / s2;
        assert!((1.6..2.4).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn architectures_in_paper_ballpark() {
        // §5.2: 96 architectures at 16 nodes / 12 h ⇒ ~6 per node.
        let r = run_benchmark(&small_cfg(2, 12.0, 2));
        let per_node = r.architectures_evaluated as f64 / 2.0;
        assert!(
            (3.0..14.0).contains(&per_node),
            "archs/node = {per_node}"
        );
    }

    #[test]
    fn error_meets_validity_threshold() {
        let r = run_benchmark(&small_cfg(2, 12.0, 3));
        assert!(r.final_error < 0.35, "error={}", r.final_error);
        assert_eq!(r.validity, crate::metrics::score::Validity::Valid);
    }

    #[test]
    fn error_decreases_over_time() {
        let r = run_benchmark(&small_cfg(2, 12.0, 4));
        let first = r
            .score_series
            .iter()
            .find(|s| s.best_error < 0.999)
            .map(|s| s.best_error)
            .unwrap();
        let last = r.score_series.last().unwrap().best_error;
        assert!(last <= first, "first={first} last={last}");
    }

    #[test]
    fn gpu_utilization_high_during_stable_phase() {
        let r = run_benchmark(&small_cfg(2, 12.0, 5));
        let stable: Vec<&crate::metrics::telemetry::TelemetrySample> = r
            .telemetry
            .iter()
            .filter(|s| s.t > 2.0 * 3600.0)
            .collect();
        let mean_util: f64 =
            stable.iter().map(|s| s.gpu_util_mean).sum::<f64>() / stable.len() as f64;
        assert!(mean_util > 0.6, "mean gpu util = {mean_util}");
    }

    #[test]
    fn nfs_traffic_recorded() {
        let r = run_benchmark(&small_cfg(2, 8.0, 6));
        assert!(r.nfs_bytes_read > 0);
        assert!(r.nfs_bytes_written > 0);
    }
}
