//! The end-to-end simulated benchmark run (paper §4.3 workflow).
//!
//! The run is sharded by slave node (see [`crate::coordinator::shard`]):
//! every [`SlaveShard`] executes the paper's exact per-node protocol —
//! the CPU search loop proposes a morphed candidate from the ranked
//! history into the buffer; the node's GPUs drain the buffer and train it
//! with synchronous data parallelism, epoch by epoch, with early
//! stopping; warm-up rounds use the Appendix-C predicted accuracy; HPO
//! (the pluggable `hpo` backend — TPE by default, per-group
//! overridable) activates at round 5; with `early_stop` on, lanes also
//! terminate doomed trials by LogFit curve extrapolation; the run
//! terminates at the user-defined
//! wall-clock budget and the analysis toolkit computes score, achieved
//! error, regulated score, and telemetry (Figs 4–6, 9–12).
//!
//! Shards advance independently within an epoch-barrier window
//! ([`BenchmarkConfig::sync_interval_s`]) against a frozen snapshot of
//! the shared historical model list, and the coordinator merges their
//! outputs in deterministic node order at every barrier. Each window
//! only visits the *active* shards — those whose next queued event lies
//! inside the window, per the dormancy index
//! ([`crate::coordinator::active`]); a skipped shard would pop nothing,
//! so skipping is bit-identical by construction (the
//! `AIPERF_FORCE_FULL_SWEEP=1` escape hatch restores the historic full
//! sweep, and `tests/active_set.rs` pins the byte-equality). The
//! [`Engine::Parallel`] path executes a window's active shards on a
//! persistent worker pool ([`crate::sim::pool`]) parked between
//! barriers; [`Engine::Sequential`] runs the same active set in a loop.
//! Both are bit-identical for the same seed
//! (`rust/tests/engine_parity.rs`).
//!
//! Simulation time is *modelled* cluster time (the 16×8-V100 testbed is a
//! hardware gate — DESIGN.md §2); every decision the framework makes —
//! routing, ranking, morphing, HPO, stopping — executes for real.

use crate::cluster::nfs::NfsStats;
use crate::config::{BenchmarkConfig, Engine};
use crate::coordinator::active::ActiveSet;
use crate::coordinator::history::{HistoryList, ModelRecord};
use crate::coordinator::merge::merge_by_time;
use crate::coordinator::sched::ElasticScheduler;
use crate::coordinator::shard::{HistorySnapshot, SimContext, SlaveShard};
use crate::sim::pool::with_pool;
use crate::metrics::report::{BenchmarkReport, GroupBreakdown, LaneUtil};
use crate::metrics::score::{validate_result, ScoreSample};
use crate::metrics::stream::{OnlineScores, ReportStream};
use crate::metrics::telemetry::{self, GroupTelemetry, NodeReading, Telemetry};

/// Where merged window events land.
///
/// `Buffered` is the classic path: score samples, telemetry ticks, and
/// lane rows accumulate in [`GlobalState`] and ship inside the final
/// [`BenchmarkReport`]. `Streaming` writes each record to the NDJSON
/// stream the moment it is merged and keeps only O(groups) running
/// state, so a 102,400-lane run's report memory does not grow with
/// ticks × lanes; the returned report then carries empty series (the
/// stream holds them) but bit-identical scalars.
enum ReportSink<W: std::io::Write> {
    Buffered,
    Streaming(StreamState<W>),
}

/// O(groups) running state of the streaming sink.
struct StreamState<W: std::io::Write> {
    stream: ReportStream<W>,
    /// Per-group online utilization stats (index = topology group).
    groups: Vec<GroupTelemetry>,
    /// Online stable-window score fold, bit-identical to the buffered
    /// [`BenchmarkReport::stable_scores`].
    scores: OnlineScores,
}

/// Mutable global state merged at every epoch barrier.
struct GlobalState {
    history: HistoryList,
    telemetry: Telemetry,
    score_series: Vec<ScoreSample>,
    cumulative_ops: f64,
    /// Analytical ops attributed to each topology group (index = group).
    group_ops: Vec<f64>,
    /// Barrier-slack accumulation per group: sum of per-lane overshoots
    /// past each window boundary, and the sample count (lanes × windows).
    group_slack_sum: Vec<f64>,
    group_slack_samples: Vec<u64>,
    /// Index of the next score boundary: tick `i` samples at
    /// `i * score_interval_s`. An index (rather than an accumulated
    /// `next_score_t += interval`) keeps boundaries drift-free over the
    /// hundreds of thousands of ticks an exascale run emits.
    next_score_idx: u64,
}

/// Merge one window's shard outputs into the global state, in
/// deterministic node order, then emit any score samples due.
///
/// Takes the coordinator's dense `&mut` reference slice (the shards
/// live inside the worker pool's cells between barriers). The merge
/// still iterates *all* shards — barrier slack samples every lane and
/// the telemetry zip needs every stride — but a window-skipped shard's
/// takes/clears here are empty and cost O(1).
fn merge_window<W: std::io::Write>(
    global: &mut GlobalState,
    shards: &mut [&mut SlaveShard],
    window_idx: u64,
    window_end: f64,
    cfg: &BenchmarkConfig,
    sink: &mut ReportSink<W>,
) {
    // Barrier slack: how far each solo lane's in-flight epoch overshoots
    // this barrier — the amount a synchronous barrier would stretch
    // waiting on that lane (work stealing tightens it on victim lanes).
    for s in shards.iter() {
        for o in s.barrier_overshoots(window_end) {
            global.group_slack_sum[s.group] += o;
            global.group_slack_samples[s.group] += 1;
        }
    }

    // Completed models: each shard's window delta is already time-sorted
    // (completions push at event-pop time), so a k-way heap merge in
    // node order reproduces — exactly — the order the historic full
    // re-sort gave the shared history (ties older node first).
    let deltas: Vec<Vec<ModelRecord>> = shards
        .iter_mut()
        .map(|s| std::mem::take(&mut s.completed))
        .collect();
    let completions = merge_by_time(deltas, |r: &ModelRecord| r.completed_at);
    let window_completions = completions.len() as u64;
    for rec in completions {
        if let ReportSink::Streaming(st) = sink {
            st.stream.trial(&rec).expect("stream report write failed");
        }
        global.history.push(rec);
    }

    // Analytical-ops events, same deterministic order. Summation order is
    // fixed so the f64 accumulation is engine-independent — the per-group
    // attribution too (shard order, then within-shard event order).
    let mut ops_deltas: Vec<Vec<(f64, f64)>> = Vec::with_capacity(shards.len());
    for s in shards.iter_mut() {
        for &(_, ops) in &s.epoch_ops {
            global.group_ops[s.group] += ops;
        }
        ops_deltas.push(std::mem::take(&mut s.epoch_ops));
    }
    let ops_events = merge_by_time(ops_deltas, |e: &(f64, f64)| e.0);

    // Telemetry: every lane of every shard ticks on the same schedule;
    // zip the per-lane readings per tick (a shard's readings vector holds
    // its `subshard_count()` lane readings consecutively per tick, in
    // lane order). The tick count is a real cross-shard invariant —
    // checked in release builds too, because a shard emitting a
    // different tick count would otherwise zip readings from different
    // instants (or index out of bounds) silently.
    let ticks = shards
        .first()
        .map_or(0, |s| s.readings.len() / s.subshard_count().max(1));
    for s in shards.iter() {
        let k = s.subshard_count().max(1);
        assert_eq!(
            s.readings.len(),
            ticks * k,
            "telemetry tick count diverged: node {} has {} readings across {k} lanes, expected {ticks} ticks",
            s.node,
            s.readings.len(),
        );
    }
    for j in 0..ticks {
        let t = shards[0].readings[j * shards[0].subshard_count()].0;
        // The flat per-tick vector is O(lanes) and transient in both
        // modes — the cross-node mean/std math reads it identically, so
        // the aggregated sample is bit-equal on either sink.
        let mut readings: Vec<NodeReading> = Vec::new();
        for s in shards.iter() {
            let k = s.subshard_count();
            for u in 0..k {
                let (rt, r) = s.readings[j * k + u];
                assert_eq!(
                    rt.to_bits(),
                    t.to_bits(),
                    "telemetry ticks diverged: node {} lane {u} sampled at {rt}, expected {t}",
                    s.node
                );
                if let ReportSink::Streaming(st) = &mut *sink {
                    st.groups[s.group].push(&r);
                }
                readings.push(r);
            }
        }
        let sample = telemetry::aggregate(t, &readings);
        match sink {
            ReportSink::Buffered => global.telemetry.push_sample(sample),
            ReportSink::Streaming(st) => st
                .stream
                .telemetry(&sample)
                .expect("stream report write failed"),
        }
    }
    for s in shards.iter_mut() {
        s.readings.clear();
    }

    // Score samples due in this window (hourly in the paper). Boundaries
    // are exact multiples of the interval — accumulating `t += interval`
    // drifts at exascale tick counts.
    let mut op_i = 0;
    loop {
        let ts = global.next_score_idx as f64 * cfg.score_interval_s;
        if ts > window_end {
            break;
        }
        while op_i < ops_events.len() && ops_events[op_i].0 <= ts {
            global.cumulative_ops += ops_events[op_i].1;
            op_i += 1;
        }
        let best = global
            .history
            .best_measured_error_at(ts)
            .unwrap_or(1.0 - 1e-9);
        let sample = ScoreSample::new(ts, global.cumulative_ops, best);
        match sink {
            ReportSink::Buffered => global.score_series.push(sample),
            ReportSink::Streaming(st) => {
                st.stream.score(&sample).expect("stream report write failed");
                st.scores.push(&sample);
            }
        }
        global.next_score_idx += 1;
    }
    while op_i < ops_events.len() {
        global.cumulative_ops += ops_events[op_i].1;
        op_i += 1;
    }
    if let ReportSink::Streaming(st) = sink {
        st.stream
            .window(window_idx, window_end, window_completions)
            .expect("stream report write failed");
    }
}

/// Epoch-barrier boundaries: multiples of `sync_interval_s`, closed with
/// the benchmark duration.
fn window_ends(cfg: &BenchmarkConfig) -> Vec<f64> {
    // Boundaries as exact multiples of the interval: the accumulated
    // `t += interval` form drifts at high window counts — an exa-scale
    // run with a short sync interval could emit a near-duplicate final
    // window (boundary at duration − ε, then duration) or shift every
    // barrier by the accumulated error. For the integer-valued intervals
    // of the pinned presets, `i * interval` is bit-equal to the old
    // accumulation, so their schedules are unchanged.
    let mut ends = Vec::new();
    let mut i = 1u64;
    loop {
        let t = i as f64 * cfg.sync_interval_s;
        if t >= cfg.duration_s {
            break;
        }
        ends.push(t);
        i += 1;
    }
    ends.push(cfg.duration_s);
    ends
}

/// Run the full simulated benchmark with an explicit engine.
///
/// With `cfg.stream_report` unset this is the buffered path, unchanged
/// byte for byte. With it set, every record streams to the named NDJSON
/// file as it is merged and the returned report carries empty
/// series/lane vectors (the stream holds them) but identical scalars.
pub fn run_benchmark_with(cfg: &BenchmarkConfig, engine: Engine) -> BenchmarkReport {
    match &cfg.stream_report {
        None => run_with_sink::<std::io::Sink>(cfg, engine, ReportSink::Buffered),
        Some(path) => {
            let file = std::fs::File::create(path)
                .unwrap_or_else(|e| panic!("cannot create stream report {path}: {e}"));
            run_benchmark_streaming(cfg, engine, std::io::BufWriter::new(file))
        }
    }
}

/// Run the benchmark streaming the NDJSON report into `out` (ignores
/// `cfg.stream_report` — the caller owns the destination). Used by the
/// CLI via [`run_benchmark_with`], and directly by tests/benches that
/// stream into memory.
pub fn run_benchmark_streaming<W: std::io::Write>(
    cfg: &BenchmarkConfig,
    engine: Engine,
    out: W,
) -> BenchmarkReport {
    let mut stream = ReportStream::new(out);
    stream.header(cfg).expect("stream report write failed");
    let st = StreamState {
        stream,
        groups: vec![GroupTelemetry::default(); cfg.topology.groups.len()],
        scores: OnlineScores::new(cfg.duration_s),
    };
    run_with_sink(cfg, engine, ReportSink::Streaming(st))
}

fn run_with_sink<W: std::io::Write>(
    cfg: &BenchmarkConfig,
    engine: Engine,
    mut sink: ReportSink<W>,
) -> BenchmarkReport {
    cfg.validate().expect("invalid benchmark configuration");
    let ctx = SimContext::new(cfg);

    // Shards in topology order: group 0's nodes first, then group 1's, …
    // — the global node numbering that fixes RNG streams and merge order.
    let shards: Vec<SlaveShard> = cfg
        .topology
        .nodes()
        .map(|(group, node)| SlaveShard::new(node, group, cfg))
        .collect();
    // The cluster-wide elastic scheduler: owns the lane registry and the
    // inter-group migration pass, run at every barrier (the per-node
    // steal pass it also owns was handed to each shard at construction).
    let mut sched = ElasticScheduler::new(cfg);
    let mut global = GlobalState {
        history: HistoryList::new(),
        telemetry: Telemetry::new(cfg.telemetry_interval_s),
        score_series: Vec::new(),
        cumulative_ops: 0.0,
        group_ops: vec![0.0; cfg.topology.groups.len()],
        group_slack_sum: vec![0.0; cfg.topology.groups.len()],
        group_slack_samples: vec![0; cfg.topology.groups.len()],
        next_score_idx: 1,
    };
    // The dormancy index: per-shard next-event times, refreshed after
    // every mutation point (window run, barrier pass). A window only
    // visits shards with an event inside it; the rest are skipped
    // untouched — bit-identical, since `run_until` on them would pop
    // nothing. The counters make the active-set win observable in every
    // report surface.
    let n_shards = shards.len();
    let mut active = ActiveSet::new(n_shards);
    let mut shards_touched = 0u64;
    let mut shards_skipped = 0u64;
    // detlint: allow(env_read) — AIPERF_FORCE_FULL_SWEEP is the
    // debugging escape hatch that restores the historic visit-every-
    // shard sweep. It changes which shards are *visited*, never any
    // outcome (tests/active_set.rs pins byte-identical reports and
    // streams, counters included), so it is deliberately not a config
    // knob: a config key would imply it can change results.
    let force_full_sweep = std::env::var_os("AIPERF_FORCE_FULL_SWEEP")
        .is_some_and(|v| v == "1");

    // One persistent worker pool for the whole run ([`crate::sim::pool`]):
    // workers park on a condvar between windows — no per-window
    // spawn/join, no per-window batch/Mutex scaffolding rebuild. With
    // `Engine::Sequential` the pool has zero workers and `run_window`
    // executes the same active set inline, so both engines share one
    // filter path. Batch claiming inside the pool only decides *which
    // thread* runs a shard; a shard's evolution depends solely on (its
    // own state, the frozen snapshot, the window end), and merging stays
    // in node order — determinism is untouched.
    let workers = match engine {
        Engine::Sequential => 0,
        Engine::Parallel => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(shards.len())
            .max(1),
    };
    let (shards, ()) = with_pool(
        shards,
        workers,
        |s: &mut SlaveShard, window_end, snapshot: &HistorySnapshot| {
            s.run_until(window_end, snapshot, &ctx)
        },
        |pool| {
            // Seed the dormancy index from the initial queues (every
            // shard schedules its staggered first event at build time).
            pool.with_items(|all| {
                for (i, s) in all.iter().enumerate() {
                    active.record(i, s.next_event_time());
                }
            });
            for (window, window_end) in window_ends(cfg).into_iter().enumerate() {
                let eligible = active.collect(window_end);
                shards_touched += eligible.len() as u64;
                shards_skipped += (n_shards - eligible.len()) as u64;
                // The escape hatch visits everything but reports the
                // *filtered* counters, so a force-full run is byte-
                // identical to a normal one on every surface.
                let to_run: Vec<usize> = if force_full_sweep {
                    (0..n_shards).collect()
                } else {
                    eligible.to_vec()
                };
                // Refresh the frozen history view from the previous
                // barrier's merge — O(1): the ranked list and its sort
                // order are Arc-shared with the history, which extends
                // both incrementally.
                let snapshot = if window > 0 {
                    HistorySnapshot {
                        ranked: global.history.ranked_shared(),
                        sorted: global.history.sorted_shared(),
                        records: global.history.len() as u64,
                        penalties: global.history.penalty_count(),
                    }
                } else {
                    HistorySnapshot::default()
                };
                pool.run_window(window_end, snapshot, to_run.clone());
                // `run_window` releases the frozen view before returning:
                // with no snapshot outstanding the history is the ranked
                // list's sole owner, so this window's completions append
                // in place instead of forcing a copy-on-write of the
                // whole list. The barrier phase below holds every shard
                // lock with no window in flight.
                pool.with_items(|all| {
                    // Shards that ran may have drained or advanced their
                    // queues; re-index them before anything else.
                    for &i in &to_run {
                        active.record(i, all[i].next_event_time());
                    }
                    merge_window(&mut global, all, window as u64, window_end, cfg, &mut sink);
                    // Inter-group migration: place staged candidates onto
                    // idle lanes of other groups. Runs single-threaded at
                    // the barrier in both engines, so the placements are
                    // engine-independent.
                    sched.barrier_pass(window_end, all, &ctx);
                    // Barrier-time wakeups (migrant adoption, NodeReady)
                    // re-arm shard queues, so the index refreshes across
                    // the whole fleet — but only when the pass can
                    // actually mutate anything (it early-returns with the
                    // migration knob off, and merge_window never touches
                    // a queue).
                    if sched.is_enabled() {
                        for (i, s) in all.iter().enumerate() {
                            active.record(i, s.next_event_time());
                        }
                    }
                });
            }
        },
    );

    let mut nfs_stats = NfsStats::default();
    let mut architectures_evaluated = 0;
    let mut group_steals = vec![0u64; cfg.topology.groups.len()];
    let mut group_oom_skips = vec![0u64; cfg.topology.groups.len()];
    let mut group_migrations_in = vec![0u64; cfg.topology.groups.len()];
    let mut group_migrations_out = vec![0u64; cfg.topology.groups.len()];
    let mut group_migration_overhead = vec![0.0f64; cfg.topology.groups.len()];
    let mut group_feedback_routed = vec![0u64; cfg.topology.groups.len()];
    let mut group_ring_joins = vec![0u64; cfg.topology.groups.len()];
    let mut group_early_stops = vec![0u64; cfg.topology.groups.len()];
    let mut group_epochs_saved = vec![0u64; cfg.topology.groups.len()];
    let mut lane_util: Vec<LaneUtil> = Vec::new();
    for s in &shards {
        nfs_stats.reads += s.nfs.reads;
        nfs_stats.writes += s.nfs.writes;
        nfs_stats.bytes_read += s.nfs.bytes_read;
        nfs_stats.bytes_written += s.nfs.bytes_written;
        architectures_evaluated += s.total_completed();
        group_steals[s.group] += s.steals;
        group_oom_skips[s.group] += s.oom_skips;
        group_migrations_in[s.group] += s.migrations_in;
        group_migrations_out[s.group] += s.migrations_out;
        group_migration_overhead[s.group] += s.migration_overhead_s;
        group_feedback_routed[s.group] += s.feedback_routed;
        group_ring_joins[s.group] += s.migrant_ring_joins;
        group_early_stops[s.group] += s.early_stops;
        group_epochs_saved[s.group] += s.epochs_saved;
        for (lane, busy) in s.lane_busy_fractions(cfg.duration_s).into_iter().enumerate() {
            match &mut sink {
                ReportSink::Buffered => lane_util.push(LaneUtil {
                    group: cfg.topology.groups[s.group].label.clone(),
                    node: s.node as u64,
                    lane: lane as u64,
                    busy_fraction: busy,
                }),
                ReportSink::Streaming(st) => st
                    .stream
                    .lane(
                        &cfg.topology.groups[s.group].label,
                        s.node as u64,
                        lane as u64,
                        busy,
                    )
                    .expect("stream report write failed"),
            }
        }
    }

    let final_error = global.history.best_measured_error().unwrap_or(1.0 - 1e-9);
    let (score_flops, regulated) = match &sink {
        ReportSink::Buffered => {
            BenchmarkReport::stable_scores(&global.score_series, cfg.duration_s)
        }
        ReportSink::Streaming(st) => st.scores.stable_scores(),
    };
    let groups: Vec<GroupBreakdown> = cfg
        .topology
        .groups
        .iter()
        .enumerate()
        .map(|(i, g)| GroupBreakdown {
            label: g.label.clone(),
            nodes: g.count,
            gpus_per_node: g.gpus_per_node,
            ops: global.group_ops[i],
            ops_per_second: global.group_ops[i] / cfg.duration_s,
            steals: group_steals[i],
            oom_skips: group_oom_skips[i],
            migrations_in: group_migrations_in[i],
            migrations_out: group_migrations_out[i],
            migration_overhead_s: group_migration_overhead[i],
            feedback_routed: group_feedback_routed[i],
            migrant_ring_joins: group_ring_joins[i],
            early_stops: group_early_stops[i],
            epochs_saved: group_epochs_saved[i],
            barrier_slack_s: if global.group_slack_samples[i] > 0 {
                global.group_slack_sum[i] / global.group_slack_samples[i] as f64
            } else {
                0.0
            },
        })
        .collect();
    let report = BenchmarkReport {
        nodes: cfg.topology.total_nodes(),
        total_gpus: cfg.topology.total_gpus(),
        groups,
        lane_util,
        duration_s: cfg.duration_s,
        score_series: global.score_series,
        score_flops,
        final_error,
        regulated_score: regulated,
        architectures_evaluated,
        telemetry: global.telemetry.samples().to_vec(),
        validity: validate_result(
            final_error,
            cfg.precision_bits,
            cfg.duration_s,
            6.0 * 3600.0,
        ),
        nfs_bytes_read: nfs_stats.bytes_read,
        nfs_bytes_written: nfs_stats.bytes_written,
        shards_touched,
        shards_skipped,
    };
    if let ReportSink::Streaming(mut st) = sink {
        for (i, g) in cfg.topology.groups.iter().enumerate() {
            st.stream
                .group_telemetry(i as u64, &g.label, &st.groups[i])
                .expect("stream report write failed");
        }
        st.stream.summary(&report).expect("stream report write failed");
        st.stream.flush().expect("stream report flush failed");
    }
    report
}

/// Run the full simulated benchmark with the engine from the config.
pub fn run_benchmark(cfg: &BenchmarkConfig) -> BenchmarkReport {
    run_benchmark_with(cfg, cfg.engine)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(nodes: u64, hours: f64, seed: u64) -> BenchmarkConfig {
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = hours * 3600.0;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn run_completes_and_reports() {
        let r = run_benchmark(&small_cfg(2, 12.0, 0));
        assert!(r.score_flops > 0.0);
        assert!(r.architectures_evaluated > 0);
        assert!(!r.score_series.is_empty());
        assert!(!r.telemetry.is_empty());
        assert!(r.final_error > 0.0 && r.final_error < 1.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run_benchmark(&small_cfg(2, 8.0, 7));
        let b = run_benchmark(&small_cfg(2, 8.0, 7));
        assert_eq!(a.score_flops, b.score_flops);
        assert_eq!(a.architectures_evaluated, b.architectures_evaluated);
        assert_eq!(a.final_error, b.final_error);
        let c = run_benchmark(&small_cfg(2, 8.0, 8));
        assert_ne!(a.score_flops, c.score_flops);
    }

    #[test]
    fn engines_agree_on_a_short_run() {
        let cfg = small_cfg(3, 4.0, 5);
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert_eq!(seq.score_flops.to_bits(), par.score_flops.to_bits());
        assert_eq!(seq.final_error.to_bits(), par.final_error.to_bits());
        assert_eq!(seq.architectures_evaluated, par.architectures_evaluated);
    }

    #[test]
    fn score_scales_roughly_linearly() {
        // Fig 4's headline: double the nodes ⇒ ~double the score.
        let s2 = run_benchmark(&small_cfg(2, 12.0, 1)).score_flops;
        let s4 = run_benchmark(&small_cfg(4, 12.0, 1)).score_flops;
        let ratio = s4 / s2;
        assert!((1.6..2.4).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn architectures_in_paper_ballpark() {
        // §5.2: 96 architectures at 16 nodes / 12 h ⇒ ~6 per node.
        let r = run_benchmark(&small_cfg(2, 12.0, 2));
        let per_node = r.architectures_evaluated as f64 / 2.0;
        assert!(
            (3.0..14.0).contains(&per_node),
            "archs/node = {per_node}"
        );
    }

    #[test]
    fn error_meets_validity_threshold() {
        let r = run_benchmark(&small_cfg(2, 12.0, 3));
        assert!(r.final_error < 0.35, "error={}", r.final_error);
        assert_eq!(r.validity, crate::metrics::score::Validity::Valid);
    }

    #[test]
    fn error_decreases_over_time() {
        let r = run_benchmark(&small_cfg(2, 12.0, 4));
        let first = r
            .score_series
            .iter()
            .find(|s| s.best_error < 0.999)
            .map(|s| s.best_error)
            .unwrap();
        let last = r.score_series.last().unwrap().best_error;
        assert!(last <= first, "first={first} last={last}");
    }

    #[test]
    fn gpu_utilization_high_during_stable_phase() {
        let r = run_benchmark(&small_cfg(2, 12.0, 5));
        let stable: Vec<&crate::metrics::telemetry::TelemetrySample> = r
            .telemetry
            .iter()
            .filter(|s| s.t > 2.0 * 3600.0)
            .collect();
        let mean_util: f64 =
            stable.iter().map(|s| s.gpu_util_mean).sum::<f64>() / stable.len() as f64;
        assert!(mean_util > 0.6, "mean gpu util = {mean_util}");
    }

    #[test]
    fn group_breakdown_accounts_all_ops() {
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        let mut cfg = small_cfg(2, 6.0, 9);
        cfg.batch_per_gpu = 256;
        cfg.topology = ClusterTopology {
            groups: vec![
                NodeGroup::new("t4", 2, 8, GpuModel::t4()),
                NodeGroup::new("v100", 2, 8, GpuModel::v100()),
            ],
        };
        let r = run_benchmark(&cfg);
        assert_eq!(r.groups.len(), 2);
        assert_eq!(r.nodes, 4);
        assert_eq!(r.total_gpus, 32);
        // Every group trained something, and the V100 half outproduced
        // the T4 half (8x the per-device throughput).
        assert!(r.groups.iter().all(|g| g.ops > 0.0));
        assert!(r.groups[1].ops > r.groups[0].ops);
        // Attribution is complete: group ops sum to the series total
        // (only float summation order differs between the two).
        let total: f64 = r.groups.iter().map(|g| g.ops).sum();
        let series_total = r.score_series.last().unwrap().cumulative_ops;
        assert!(
            ((total - series_total) / total).abs() < 1e-9,
            "group ops {total:e} != sampled cumulative {series_total:e}"
        );
    }

    #[test]
    fn single_group_breakdown_matches_shape() {
        let r = run_benchmark(&small_cfg(2, 4.0, 0));
        assert_eq!(r.groups.len(), 1);
        assert_eq!(r.groups[0].nodes, 2);
        assert_eq!(r.groups[0].gpus_per_node, 8);
        assert!(r.groups[0].ops_per_second > 0.0);
    }

    #[test]
    fn subshards_preserve_report_shape_and_throughput() {
        let mut cfg = small_cfg(2, 6.0, 4);
        cfg.subshards_per_node = 2;
        let r = run_benchmark(&cfg);
        let base = run_benchmark(&small_cfg(2, 6.0, 4));
        assert!(r.score_flops > 0.0);
        assert!(r.architectures_evaluated > 0);
        assert_eq!(r.groups.len(), 1);
        assert_eq!(r.groups[0].steals, 0, "stealing is opt-in");
        // Two half-width lanes per node keep aggregate throughput in the
        // same ballpark as the classic one-lane layout.
        let ratio = r.score_flops / base.score_flops;
        assert!(
            (0.4..2.5).contains(&ratio),
            "subshard throughput ratio {ratio}"
        );
        // Telemetry still zips per tick across all lanes.
        assert_eq!(r.telemetry.len(), base.telemetry.len());
    }

    #[test]
    fn work_stealing_recovers_truncated_tail_ops() {
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        use crate::config::WarmupSchedule;
        // Crafted endgame: two T4 lanes whose identical first trials
        // (2 epochs ≈ 2.5 modelled hours each) finish just before the
        // deadline, leaving less than one epoch of runway. Without
        // stealing, the follow-up trials never complete an epoch (their
        // ops are lost); with stealing, a drained lane joins its
        // sibling's trial and the widened ring finishes epochs in time.
        let run = |stealing: bool, seed: u64| {
            let mut cfg = BenchmarkConfig {
                topology: ClusterTopology::single(NodeGroup::new("t4", 1, 8, GpuModel::t4())),
                batch_per_gpu: 256,
                subshards_per_node: 2,
                work_stealing: stealing,
                warmup: WarmupSchedule {
                    first_epochs: 2,
                    step_epochs: 2,
                    max_epochs: 6,
                    hpo_start_round: 5,
                },
                duration_s: 12_000.0,
                ..BenchmarkConfig::default()
            };
            cfg.seed = seed;
            run_benchmark(&cfg)
        };
        let mut any_steal = false;
        let mut any_gain = false;
        for seed in 0..6u64 {
            let with = run(true, seed);
            let without = run(false, seed);
            if with.groups[0].steals > 0 {
                any_steal = true;
            }
            if with.groups[0].ops > without.groups[0].ops {
                any_gain = true;
            }
            // The steal schedule is deterministic: same seed, same count.
            assert_eq!(with.groups[0].steals, run(true, seed).groups[0].steals);
        }
        assert!(any_steal, "steal scheduler never fired across seeds");
        assert!(
            any_gain,
            "stealing never recovered truncated-tail ops across seeds"
        );
    }

    #[test]
    fn per_group_batch_override_raises_mixed_throughput() {
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        // The V100 half of a mixed site trained at the T4-friendly batch
        // understates its utilization; the per-group override recovers it.
        let mixed = |v100_batch: Option<u64>| {
            let mut v100 = NodeGroup::new("v100", 2, 8, GpuModel::v100());
            v100.batch_per_gpu = v100_batch;
            let mut cfg = BenchmarkConfig {
                batch_per_gpu: 256,
                topology: ClusterTopology {
                    groups: vec![NodeGroup::new("t4", 2, 8, GpuModel::t4()), v100],
                },
                ..BenchmarkConfig::default()
            };
            cfg.duration_s = 6.0 * 3600.0;
            run_benchmark(&cfg)
        };
        let flat = mixed(None);
        let tuned = mixed(Some(448));
        assert!(
            tuned.groups[1].ops > flat.groups[1].ops,
            "V100 group at batch 448 must outproduce batch 256: {:e} vs {:e}",
            tuned.groups[1].ops,
            flat.groups[1].ops
        );
        assert!(tuned.score_flops > flat.score_flops);
    }

    #[test]
    fn nfs_traffic_recorded() {
        let r = run_benchmark(&small_cfg(2, 8.0, 6));
        assert!(r.nfs_bytes_read > 0);
        assert!(r.nfs_bytes_written > 0);
    }

    #[test]
    fn window_ends_cover_duration() {
        let mut cfg = small_cfg(1, 1.0, 0);
        cfg.sync_interval_s = 1000.0;
        let ends = window_ends(&cfg);
        assert_eq!(ends, vec![1000.0, 2000.0, 3000.0, 3600.0]);
        cfg.sync_interval_s = 7200.0; // longer than the run: one window
        assert_eq!(window_ends(&cfg), vec![3600.0]);
        cfg.sync_interval_s = 1800.0; // exact divisor: no duplicate end
        assert_eq!(window_ends(&cfg), vec![1800.0, 3600.0]);
    }

    #[test]
    fn window_boundaries_do_not_drift_at_high_window_counts() {
        // 100k windows of a non-dyadic interval: repeated `t += 0.1`
        // accumulates ~1e-10 of drift per step, enough for the old
        // accumulation to emit a near-duplicate final window (a boundary
        // at duration − ε followed by duration). Multiples stay exact.
        let mut cfg = small_cfg(1, 1.0, 0);
        cfg.duration_s = 10_000.0;
        cfg.sync_interval_s = 0.1;
        let ends = window_ends(&cfg);
        assert_eq!(ends.len(), 100_000);
        assert_eq!(*ends.last().unwrap(), 10_000.0);
        for (i, w) in ends.iter().enumerate().take(ends.len() - 1) {
            assert_eq!(
                w.to_bits(),
                ((i + 1) as f64 * 0.1).to_bits(),
                "window {i} drifted: {w}"
            );
        }
        // Strictly increasing with no near-duplicate final window — the
        // failure mode of the accumulated form.
        assert!(ends.windows(2).all(|w| w[1] > w[0]));
        let final_gap = ends[ends.len() - 1] - ends[ends.len() - 2];
        assert!(
            final_gap > 0.05,
            "near-duplicate final window: gap {final_gap:e}"
        );
    }

    #[test]
    fn telemetry_zips_across_heterogeneous_subshard_counts() {
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        // Per-group lane counts differ (1 vs 2 lanes per node): the
        // telemetry merge must zip per tick across shards with different
        // per-shard reading strides, and its tick-count invariant must
        // hold window after window.
        let mut one_lane = NodeGroup::new("t4", 2, 8, GpuModel::t4());
        one_lane.subshards_per_node = Some(1);
        let mut two_lane = NodeGroup::new("v100", 2, 8, GpuModel::v100());
        two_lane.subshards_per_node = Some(2);
        let mut cfg = BenchmarkConfig {
            batch_per_gpu: 256,
            topology: ClusterTopology {
                groups: vec![one_lane, two_lane],
            },
            ..BenchmarkConfig::default()
        };
        cfg.duration_s = 4.0 * 3600.0;
        cfg.seed = 3;
        let seq = run_benchmark_with(&cfg, Engine::Sequential);
        let par = run_benchmark_with(&cfg, Engine::Parallel);
        assert!(!seq.telemetry.is_empty());
        assert_eq!(seq.telemetry.len(), par.telemetry.len());
        for (x, y) in seq.telemetry.iter().zip(&par.telemetry) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.gpu_util_mean.to_bits(), y.gpu_util_mean.to_bits());
        }
        // Ticks are cluster-wide instants on the telemetry schedule.
        for (i, s) in seq.telemetry.iter().enumerate() {
            assert_eq!(
                s.t.to_bits(),
                ((i + 1) as f64 * cfg.telemetry_interval_s).to_bits(),
                "tick {i} off-schedule at {}",
                s.t
            );
        }
    }
}
