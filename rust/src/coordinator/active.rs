//! Dormancy index: per-shard next-event times, used to hand each window
//! only the shards that actually have work inside it.
//!
//! A shard whose next event lies past `window_end` would pop nothing in
//! `run_until` — skipping it entirely leaves bit-identical state, so the
//! active-set filter is a pure perf optimization. The index must be
//! refreshed after every point that can mutate a shard's queue: the
//! window run itself, and the barrier pass (migrant adoption and
//! `NodeReady` re-arm parked shards). The coordinator owns that
//! discipline; this module is just the bookkeeping.

/// Next-event index over a fixed set of shards.
pub struct ActiveSet {
    /// Next-event time per shard; `f64::INFINITY` means drained (no
    /// pending events — never eligible again until re-armed).
    next_event: Vec<f64>,
    /// Scratch buffer reused across windows for the eligible indices.
    active: Vec<usize>,
}

impl ActiveSet {
    pub fn new(n: usize) -> Self {
        ActiveSet {
            next_event: vec![f64::INFINITY; n],
            active: Vec::with_capacity(n),
        }
    }

    /// Record shard `i`'s next-event time (`None` = queue drained).
    pub fn record(&mut self, i: usize, next: Option<f64>) {
        self.next_event[i] = next.unwrap_or(f64::INFINITY);
    }

    /// Indices (ascending) of shards with an event at or before
    /// `window_end`. The returned slice borrows internal scratch and is
    /// valid until the next `collect` call.
    pub fn collect(&mut self, window_end: f64) -> &[usize] {
        self.active.clear();
        for (i, &t) in self.next_event.iter().enumerate() {
            if t <= window_end {
                self.active.push(i);
            }
        }
        &self.active
    }

    /// Number of shards tracked.
    pub fn len(&self) -> usize {
        self.next_event.len()
    }

    pub fn is_empty(&self) -> bool {
        self.next_event.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_fully_dormant() {
        let mut set = ActiveSet::new(4);
        assert_eq!(set.len(), 4);
        assert!(set.collect(1e18).is_empty());
    }

    #[test]
    fn collect_filters_by_window_end_inclusive() {
        let mut set = ActiveSet::new(5);
        set.record(0, Some(10.0));
        set.record(1, Some(600.0)); // exactly at the boundary: eligible
        set.record(2, Some(600.000001));
        set.record(3, None); // drained
        set.record(4, Some(0.0));
        assert_eq!(set.collect(600.0), &[0, 1, 4]);
        assert_eq!(set.collect(1000.0), &[0, 1, 2, 4]);
    }

    #[test]
    fn record_overwrites_and_rearms() {
        let mut set = ActiveSet::new(2);
        set.record(0, Some(50.0));
        set.record(1, None);
        assert_eq!(set.collect(100.0), &[0]);
        // Shard 0 drains; shard 1 is re-armed (e.g. migrant adoption).
        set.record(0, None);
        set.record(1, Some(75.0));
        assert_eq!(set.collect(100.0), &[1]);
    }

    #[test]
    fn scratch_is_reused_across_collects() {
        let mut set = ActiveSet::new(3);
        for i in 0..3 {
            set.record(i, Some(i as f64));
        }
        assert_eq!(set.collect(2.0), &[0, 1, 2]);
        assert_eq!(set.collect(0.5), &[0]);
        assert_eq!(set.collect(-1.0), &[] as &[usize]);
    }
}
