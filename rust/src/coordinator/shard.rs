//! Per-slave simulation shard (the parallel scale-out refactor).
//!
//! The discrete-event benchmark is sharded by slave node: each
//! [`SlaveShard`] owns its CPU search loop, TPE optimizer, RNG streams,
//! candidate buffer, trial dispatcher bookkeeping, and local event queue.
//! A shard belongs to one topology node group and draws its device
//! parameters (GPU model, GPUs per node) from that group's
//! [`crate::sim::timing::TimingModel`], so heterogeneous clusters run
//! mixed-speed shards side by side.
//! Shards advance independently inside an epoch-barrier window
//! (`BenchmarkConfig::sync_interval_s`) against a frozen
//! [`HistorySnapshot`] of the shared historical model list, then the
//! coordinator merges their window outputs (completed models, analytical
//! ops, telemetry readings) in deterministic node order.
//!
//! Because a shard's evolution depends only on (its own state, the
//! snapshot, the window end), executing shards on a thread pool is
//! bit-identical to executing them sequentially — which is what
//! `rust/tests/engine_parity.rs` enforces.

use crate::cluster::nfs::NfsStats;
use crate::config::BenchmarkConfig;
use crate::coordinator::buffer::{ArchBuffer, Candidate};
use crate::coordinator::dispatcher::Dispatcher;
use crate::coordinator::history::ModelRecord;
use crate::coordinator::trial::{ActiveTrial, TrialStatus};
use crate::flops::OpWeights;
use crate::hpo::{aiperf_space, Optimizer, Tpe};
use crate::metrics::telemetry::NodeReading;
use crate::nas::graph::Architecture;
use crate::nas::search::{RankedModel, SearchPolicy};
use crate::predict::logfit::LogFit;
use crate::sim::accuracy::{arch_id, AccuracySurrogate, HpPoint};
use crate::sim::engine::EventQueue;
use crate::sim::timing::TimingModel;
use crate::util::rng::{derive, Rng};

/// Discrete events local to one shard.
#[derive(Debug, Clone, Copy)]
pub enum ShardEvent {
    /// Node is free: run the search loop and start the next trial.
    NodeReady,
    /// Node finished one training epoch (incl. validation).
    EpochDone,
    /// Telemetry sampling tick.
    Telemetry,
}

/// Immutable per-run context shared (read-only) by every shard.
pub struct SimContext<'a> {
    pub cfg: &'a BenchmarkConfig,
    pub weights: OpWeights,
    /// One timing model per topology node group (per-group accelerator
    /// parameters; index = group index).
    pub timings: Vec<TimingModel>,
    pub surrogate: AccuracySurrogate,
    pub policy: SearchPolicy,
    pub initial: Architecture,
    pub total_nodes: u64,
}

impl<'a> SimContext<'a> {
    /// Build the per-run context from a (validated) configuration.
    pub fn new(cfg: &'a BenchmarkConfig) -> Self {
        SimContext {
            cfg,
            weights: OpWeights::default(),
            timings: cfg
                .topology
                .groups
                .iter()
                .map(|g| TimingModel {
                    node: g.node_model(cfg.host),
                    ..TimingModel::default()
                })
                .collect(),
            surrogate: AccuracySurrogate {
                seed: cfg.seed,
                ..AccuracySurrogate::default()
            },
            policy: SearchPolicy {
                limits: cfg.morph_limits,
                ..SearchPolicy::default()
            },
            initial: Architecture::initial(
                cfg.dataset.image,
                cfg.dataset.channels,
                cfg.dataset.num_classes,
            ),
            total_nodes: cfg.topology.total_nodes(),
        }
    }

    /// Timing model of a node group.
    pub fn timing(&self, group: usize) -> &TimingModel {
        &self.timings[group]
    }

    /// Fully-specified node model of a node group.
    pub fn node(&self, group: usize) -> &crate::cluster::NodeModel {
        &self.timings[group].node
    }
}

/// Frozen view of the shared historical model list, rebuilt at each
/// epoch barrier. `records` is the global record count (drives the NFS
/// read charge exactly like `HistoryList::nfs_bytes`).
#[derive(Default)]
pub struct HistorySnapshot {
    pub ranked: Vec<RankedModel>,
    pub records: u64,
}

/// One slave node's complete simulation state.
pub struct SlaveShard {
    pub node: usize,
    /// Topology group this node belongs to (selects its device model).
    pub group: usize,
    round: u64,
    tpe: Tpe,
    rng: Rng,
    tele_rng: Rng,
    queue: EventQueue<ShardEvent>,
    buffer: ArchBuffer,
    pub dispatcher: Dispatcher,
    pub nfs: NfsStats,
    trial: Option<ActiveTrial>,
    /// Dispatcher-local id of the in-flight trial.
    current_local: u64,
    /// Seconds per (train + validate) epoch for the current trial.
    epoch_seconds: f64,
    /// GPU busy fraction while the current trial trains.
    busy_fraction: f64,
    /// GPU memory utilization fraction for the current trial.
    mem_fraction: f64,
    /// Until when the node is in inter-trial setup (telemetry dent).
    setup_until: f64,
    /// Window outputs, drained by the coordinator at each barrier.
    pub completed: Vec<ModelRecord>,
    pub epoch_ops: Vec<(f64, f64)>,
    pub readings: Vec<(f64, NodeReading)>,
}

impl SlaveShard {
    /// A fresh shard for `node` in topology group `group`, with its
    /// stream-derived RNGs and the SLURM-stagger initial schedule.
    pub fn new(node: usize, group: usize, cfg: &BenchmarkConfig) -> Self {
        let mut queue = EventQueue::new();
        // Asynchronous dispatch: SLURM stagger of a few seconds per node.
        queue.schedule(node as f64 * 2.0, ShardEvent::NodeReady);
        queue.schedule(cfg.telemetry_interval_s, ShardEvent::Telemetry);
        SlaveShard {
            node,
            group,
            round: 0,
            tpe: Tpe::new(aiperf_space()),
            rng: derive(cfg.seed, "slave", node as u64),
            tele_rng: derive(cfg.seed, "telemetry", node as u64),
            queue,
            // Per-shard buffer: the search loop pushes one candidate and
            // the trainer drains it within the same NodeReady event, so a
            // small constant capacity captures the actual invariant.
            buffer: ArchBuffer::new(4),
            dispatcher: Dispatcher::new(),
            nfs: NfsStats::default(),
            trial: None,
            current_local: 0,
            epoch_seconds: 0.0,
            busy_fraction: 0.0,
            mem_fraction: 0.0,
            setup_until: 0.0,
            completed: Vec::new(),
            epoch_ops: Vec::new(),
            readings: Vec::new(),
        }
    }

    /// Advance this shard's local event loop up to (and including)
    /// `window_end`. Events past the benchmark duration stay unpopped.
    pub fn run_until(&mut self, window_end: f64, snapshot: &HistorySnapshot, ctx: &SimContext) {
        while let Some(t) = self.queue.peek_time() {
            if t > window_end {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            match ev {
                ShardEvent::NodeReady => self.on_node_ready(t, snapshot, ctx),
                ShardEvent::EpochDone => self.on_epoch_done(t, ctx),
                ShardEvent::Telemetry => self.on_telemetry(t, ctx),
            }
        }
    }

    /// The CPU search loop + trial start (paper §4.3 steps 3–5).
    fn on_node_ready(&mut self, t: f64, snapshot: &HistorySnapshot, ctx: &SimContext) {
        let local = match self.dispatcher.assign(self.node) {
            Ok(id) => id,
            Err(_) => return, // defensive: node already busy
        };
        self.current_local = local;
        // Globally unique, execution-order-independent trial id.
        let trial_id = local * ctx.total_nodes + self.node as u64;
        self.round += 1;
        let cfg = ctx.cfg;

        // --- CPU search loop: propose a candidate into the buffer. The
        // shard ranks the frozen global snapshot plus its own completions
        // since the last barrier (a node always sees its own results).
        // The snapshot is only cloned when there are local completions to
        // append — the common case borrows it directly.
        let arch = if snapshot.ranked.is_empty() && self.completed.is_empty() {
            ctx.initial.clone()
        } else if self.completed.is_empty() {
            ctx.policy.propose(&snapshot.ranked, &mut self.rng).0
        } else {
            let mut ranked = snapshot.ranked.clone();
            ranked.extend(self.completed.iter().map(|r| RankedModel {
                arch: r.arch.clone(),
                accuracy: r.accuracy,
            }));
            ctx.policy.propose(&ranked, &mut self.rng).0
        };
        let _ = self.buffer.push(Candidate {
            arch: arch.clone(),
            proposed_by: self.node,
            proposed_at: t,
        });
        // --- Trainer drains the buffer (NFS round trips charged).
        let cand = self.buffer.pop().map(|c| c.arch).unwrap_or(arch);
        let timing = ctx.timing(self.group);
        let node = &timing.node;
        let mut setup = node.host.search_seconds + node.host.setup_seconds;
        let history_bytes = 2048 * (snapshot.records + self.completed.len() as u64);
        setup += timing.nfs.read_seconds(history_bytes, &mut self.nfs);
        setup += timing.nfs.write_seconds(2048, &mut self.nfs);
        setup += timing.nfs.read_seconds(2048, &mut self.nfs);

        // --- Hyperparameters: defaults in warm-up, TPE afterwards.
        let hp = if cfg.warmup.hpo_active(self.round) {
            let c = self.tpe.suggest(&mut self.rng);
            HpPoint {
                dropout: c[0],
                kernel: c[1],
            }
        } else {
            HpPoint::default()
        };

        // --- Memory adaption: halve the batch until the model fits this
        // group's accelerator (a 16 GB T4 adapts sooner than a 32 GB V100).
        let stats = cand.stats(&ctx.weights);
        let (params, act, ops) = (stats.params, stats.activation_elems, stats.ops);
        let mut batch = cfg.batch_per_gpu;
        while batch > 8 && !node.gpu.fits(params, act, batch) {
            batch /= 2;
        }
        let budget = cfg.warmup.epochs_for_round(self.round);
        let epoch = timing.epoch(
            ops.train_per_image(),
            params,
            cfg.dataset.train_images,
            batch,
        );
        let val_s = timing.validation(ops.val_per_image(), cfg.dataset.val_images, batch);
        let total_epoch_s = epoch.total_s + val_s;

        self.epoch_seconds = total_epoch_s;
        self.busy_fraction =
            (epoch.compute_s + val_s) / total_epoch_s * epoch.gpu_busy_fraction.max(0.9);
        self.mem_fraction = (node.gpu.memory_demand(params, act, batch) as f64
            / node.gpu.memory_bytes as f64)
            .min(1.0);
        self.setup_until = t + setup;
        self.trial = Some(ActiveTrial::new(
            trial_id,
            cand.clone(),
            arch_id(&cand.signature()),
            hp,
            ops,
            batch,
            self.round,
            budget,
        ));
        self.queue.schedule(t + setup + total_epoch_s, ShardEvent::EpochDone);
    }

    /// One finished training epoch: account ops, record accuracy, decide
    /// whether to continue, early-stop, or finalize into the history.
    fn on_epoch_done(&mut self, t: f64, ctx: &SimContext) {
        let cfg = ctx.cfg;
        let Some(trial) = self.trial.as_mut() else {
            return;
        };
        // Account analytical ops for the finished epoch.
        let epoch_ops = trial.ops.train_per_image() as f64 * cfg.dataset.train_images as f64
            + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64;
        self.epoch_ops.push((t, epoch_ops));

        let acc = ctx.surrogate.accuracy(
            trial.arch_id,
            trial.params,
            &trial.hp,
            trial.epoch + 1,
        );
        let status = trial.record_epoch(acc, cfg.patience, cfg.min_delta);
        let next_epoch_end = t + self.epoch_seconds;

        if status == TrialStatus::Continue && next_epoch_end <= cfg.duration_s {
            self.queue.schedule(next_epoch_end, ShardEvent::EpochDone);
        } else {
            // --- Trial complete: record into the window output.
            let trial = self.trial.take().unwrap();
            let warmup_round = !cfg.warmup.hpo_active(trial.round);
            let (accuracy, predicted) = if warmup_round
                && trial.epoch < cfg.warmup.max_epochs
                && trial.accs.len() >= 2
            {
                // Appendix C: conservative log-fit prediction.
                let (es, accs) = trial.curve();
                (LogFit::fit(&es, &accs).conservative(60.0), true)
            } else {
                (trial.best_accuracy(), false)
            };
            let ops_spent = (trial.ops.train_per_image() as f64
                * cfg.dataset.train_images as f64
                + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64)
                * trial.epoch as f64;
            if cfg.warmup.hpo_active(trial.round) {
                self.tpe.observe(
                    vec![trial.hp.dropout, trial.hp.kernel],
                    1.0 - trial.best_accuracy(),
                );
            }
            self.completed.push(ModelRecord {
                id: trial.trial_id,
                signature: trial.arch.signature(),
                params: trial.params,
                measured_accuracy: trial.best_accuracy(),
                arch: trial.arch,
                accuracy,
                predicted,
                node: self.node,
                round: trial.round,
                epochs_trained: trial.epoch,
                ops: ops_spent,
                dropout: trial.hp.dropout,
                kernel: trial.hp.kernel,
                completed_at: t,
            });
            let _ = self.dispatcher.complete(self.current_local, self.node);
            debug_assert!(self.dispatcher.check_invariants().is_ok());
            self.queue.schedule(t, ShardEvent::NodeReady);
        }
    }

    /// One telemetry tick: sample this node's utilization (per-node jitter
    /// stream keeps the readings engine-independent).
    fn on_telemetry(&mut self, t: f64, ctx: &SimContext) {
        let cfg = ctx.cfg;
        let host = &ctx.node(self.group).host;
        let training = self.trial.is_some() && t >= self.setup_until;
        let jitter = self.tele_rng.gen_range_f64(-0.02, 0.02);
        let reading = if training {
            NodeReading {
                gpu_util: (self.busy_fraction + jitter).clamp(0.0, 1.0),
                gpu_mem_util: self.mem_fraction.clamp(0.0, 1.0),
                cpu_util: (host.cpu_util_training() + jitter / 4.0).clamp(0.0, 1.0),
                host_mem_util: host.host_memory_util(30 << 30),
            }
        } else {
            // The inter-stage "dent" of Figs 9/10.
            NodeReading {
                gpu_util: (0.02 + jitter.abs()).min(0.1),
                gpu_mem_util: 0.10,
                cpu_util: (0.30 + jitter).clamp(0.0, 1.0), // search burst
                host_mem_util: host.host_memory_util(30 << 30),
            }
        };
        self.readings.push((t, reading));
        if t + cfg.telemetry_interval_s <= cfg.duration_s {
            self.queue
                .schedule(t + cfg.telemetry_interval_s, ShardEvent::Telemetry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_for(cfg: &BenchmarkConfig) -> SimContext<'_> {
        SimContext::new(cfg)
    }

    #[test]
    fn shard_is_deterministic_and_snapshot_driven() {
        let mut cfg = BenchmarkConfig::homogeneous(2);
        cfg.duration_s = 4.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let run = || {
            let mut s = SlaveShard::new(0, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            (
                s.completed.len(),
                s.epoch_ops.len(),
                s.readings.len(),
                s.completed.iter().map(|r| r.accuracy).collect::<Vec<_>>(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.0 > 0, "no trials completed in 4 h");
        assert!(a.1 > 0);
        assert!(a.2 > 0);
    }

    #[test]
    fn windowed_run_equals_single_window() {
        let mut cfg = BenchmarkConfig::homogeneous(1);
        cfg.duration_s = 3.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        // Without barrier merges (snapshot never refreshed), splitting the
        // run into windows must not change anything.
        let mut whole = SlaveShard::new(0, 0, &cfg);
        whole.run_until(cfg.duration_s, &snapshot, &ctx);
        let mut split = SlaveShard::new(0, 0, &cfg);
        let mut t = 600.0;
        while t < cfg.duration_s {
            split.run_until(t, &snapshot, &ctx);
            t += 600.0;
        }
        split.run_until(cfg.duration_s, &snapshot, &ctx);
        assert_eq!(whole.completed.len(), split.completed.len());
        assert_eq!(whole.epoch_ops, split.epoch_ops);
        assert_eq!(
            whole.readings.iter().map(|r| r.0).collect::<Vec<_>>(),
            split.readings.iter().map(|r| r.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn trial_ids_unique_per_node_stride() {
        let mut cfg = BenchmarkConfig::homogeneous(3);
        cfg.duration_s = 6.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut ids = Vec::new();
        for node in 0..3 {
            let mut s = SlaveShard::new(node, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            ids.extend(s.completed.iter().map(|r| r.id));
        }
        let mut deduped = ids.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), ids.len(), "trial ids collide across shards");
    }

    #[test]
    fn groups_with_different_gpus_diverge() {
        // Same node index, same seed streams, different device model ⇒
        // different trial timings and counts.
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        let cfg = BenchmarkConfig {
            duration_s: 4.0 * 3600.0,
            batch_per_gpu: 256,
            topology: ClusterTopology {
                groups: vec![
                    NodeGroup::new("t4", 1, 8, GpuModel::t4()),
                    NodeGroup::new("ascend", 1, 8, GpuModel::ascend910()),
                ],
            },
            ..BenchmarkConfig::default()
        };
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let ops_of = |group: usize| {
            let mut s = SlaveShard::new(0, group, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            s.epoch_ops.iter().map(|e| e.1).sum::<f64>()
        };
        let slow = ops_of(0);
        let fast = ops_of(1);
        assert!(
            fast > 2.0 * slow,
            "ascend shard should finish far more epochs: t4={slow:e} ascend={fast:e}"
        );
    }
}
