//! Per-slave simulation shard (the parallel scale-out refactor), now at
//! sub-shard granularity.
//!
//! The discrete-event benchmark is sharded by slave node: each
//! [`SlaveShard`] owns its node's event queue, candidate buffer, and NFS
//! bookkeeping, and splits the node's GPUs into one or more *sub-shard
//! lanes* (`BenchmarkConfig::subshards_per_node`, per-group overridable).
//! Every lane is an independent trial trainer with its own CPU search
//! loop, HPO optimizer (a [`crate::hpo::Optimizer`] trait object built
//! by [`crate::hpo::build`] from the `hpo` config key, per-group
//! overridable — TPE by default), RNG streams, and dispatcher lane — a
//! node with
//! `k` lanes trains `k` candidates concurrently, each with synchronous
//! data parallelism across `gpus_per_node / k` devices. With one lane
//! per node this reduces exactly to the classic layout (same RNG
//! streams, same event order, bit-identical results).
//!
//! A shard belongs to one topology node group and draws its device
//! parameters from that group's [`crate::sim::timing::TimingModel`], so
//! heterogeneous clusters run mixed-speed shards side by side. Each
//! group can also train at its own batch (`[group.NAME] batch_per_gpu`),
//! so a mixed T4/V100 site no longer understates the larger card.
//!
//! # Elasticity
//!
//! The epoch barrier serializes a window on its slowest lane: a lane
//! whose remaining runway cannot fit another full epoch before the
//! benchmark deadline would classically start a doomed trial whose
//! first epoch never completes — wasted devices, exactly the
//! fixed-synchronization pitfall AIPerf's elastic design avoids. The
//! placement *policies* that recover that tail live in
//! [`crate::coordinator::sched`]; this shard only executes them:
//!
//! * **Work stealing** (`BenchmarkConfig::work_stealing`): the lane
//!   attaches to the most-loaded sibling lane's trial as extra
//!   data-parallel devices (all lanes of a node share its NVLink
//!   domain, which is what makes joining the allreduce ring cheap);
//!   the victim's remaining epochs re-time with the wider ring and the
//!   helper is released at trial finalize. Victims come from the
//!   seed-derived scan of [`crate::coordinator::sched::StealScheduler`],
//!   resolved inside the node's own event loop — so `Engine::Sequential`
//!   and `Engine::Parallel` remain bit-identical, enforced by
//!   `rust/tests/engine_parity.rs`.
//! * **Inter-group migration** (`BenchmarkConfig::migration`): when no
//!   sibling has a trial to steal into, the lane still runs its search
//!   loop, stages the proposed candidate's checkpoint to NFS, posts it
//!   into the shard's migrant outbox, and *parks*. At the next epoch
//!   barrier the cluster-wide
//!   [`crate::coordinator::sched::ElasticScheduler`] may dispatch the
//!   candidate to an idle lane of another node group, which adopts it
//!   via [`SlaveShard::accept_migrant`] — re-timed under the
//!   destination group's device model with its gradient ring over
//!   InfiniBand. A parked lane idles (visible in the per-lane busy
//!   fractions) until it adopts a migrant itself.
//! * **LogFit early stopping** (`BenchmarkConfig::early_stop`): after
//!   each validation epoch past `early_stop_min_epochs` the lane fits
//!   the trial's partial learning curve
//!   ([`crate::predict::LearningCurve`], the paper's Appendix-C log
//!   fit) and extrapolates it to the convergence horizon. When even the
//!   optimistic error floor cannot beat the best model known to this
//!   shard by `early_stop_margin`, the trial is doomed: a deterministic
//!   [`ShardEvent::EarlyStopped`] finalizes it early, and the freed
//!   lane re-enters the search loop immediately — where it is a fresh
//!   steal victim or migrant-adoption opportunity for the elastic
//!   passes above. With the knob off (the default) no curve is ever
//!   fitted and schedules are byte-identical to a build without the
//!   feature.
//!
//! Shards advance independently inside an epoch-barrier window
//! (`BenchmarkConfig::sync_interval_s`) against a frozen
//! [`HistorySnapshot`] of the shared historical model list, then the
//! coordinator merges their window outputs (completed models, analytical
//! ops, telemetry readings, barrier-slack samples) in deterministic node
//! order. Because a shard's evolution depends only on (its own state,
//! the snapshot, the window end), executing shards on a thread pool is
//! bit-identical to executing them sequentially.

use std::sync::Arc;

use crate::cluster::nfs::NfsStats;
use crate::config::BenchmarkConfig;
use crate::coordinator::buffer::{ArchBuffer, Candidate};
use crate::coordinator::dispatcher::Dispatcher;
use crate::coordinator::history::ModelRecord;
use crate::coordinator::sched::{
    adapted_batch, migrant_ring, LaneLoad, MigrantCandidate, MigrantFit, RoutedObservation,
    StealScheduler,
};
use crate::coordinator::trial::{ActiveTrial, TrialStatus};
use crate::flops::OpWeights;
use crate::hpo::{aiperf_space, Optimizer};
use crate::metrics::telemetry::NodeReading;
use crate::nas::graph::Architecture;
use crate::nas::search::{RankedModel, SearchPolicy};
use crate::predict::logfit::LogFit;
use crate::predict::LearningCurve;
use crate::sim::accuracy::{arch_id, AccuracySurrogate, HpPoint};
use crate::sim::engine::EventQueue;
use crate::sim::timing::TimingModel;
use crate::util::rng::{derive, Rng};

/// Discrete events local to one shard, tagged with the sub-shard lane
/// they belong to.
#[derive(Debug, Clone, Copy)]
pub enum ShardEvent {
    /// Lane is free: run the search loop and start (or steal) the next
    /// trial.
    NodeReady { sub: usize },
    /// Lane finished one training epoch (incl. validation). `gen` is the
    /// lane's epoch generation: a steal re-times the pending epoch by
    /// bumping the generation and scheduling a replacement, so a stale
    /// event is recognizable and dropped on pop.
    EpochDone { sub: usize, gen: u64 },
    /// The lane's learning-curve extrapolation declared the in-flight
    /// trial doomed (`BenchmarkConfig::early_stop`): finalize it now
    /// instead of training out its epoch budget. Carries the same epoch
    /// generation as `EpochDone` so a steal re-timing that lands in
    /// between supersedes the termination (the widened ring changes the
    /// trial's economics, so the doomed verdict is stale with it).
    EarlyStopped { sub: usize, gen: u64 },
    /// Telemetry sampling tick for one lane.
    Telemetry { sub: usize },
}

/// Immutable per-run context shared (read-only) by every shard.
pub struct SimContext<'a> {
    pub cfg: &'a BenchmarkConfig,
    pub weights: OpWeights,
    /// One timing model per topology node group (per-group accelerator
    /// parameters; index = group index).
    pub timings: Vec<TimingModel>,
    pub surrogate: AccuracySurrogate,
    pub policy: SearchPolicy,
    pub initial: Architecture,
    /// Total sub-shard lanes across the cluster (strides trial ids).
    pub total_units: u64,
}

impl<'a> SimContext<'a> {
    /// Build the per-run context from a (validated) configuration.
    pub fn new(cfg: &'a BenchmarkConfig) -> Self {
        SimContext {
            cfg,
            weights: OpWeights::default(),
            timings: cfg
                .topology
                .groups
                .iter()
                .map(|g| TimingModel {
                    node: g.node_model(cfg.host),
                    ..TimingModel::default()
                })
                .collect(),
            surrogate: AccuracySurrogate {
                seed: cfg.seed,
                ..AccuracySurrogate::default()
            },
            policy: SearchPolicy {
                limits: cfg.morph_limits,
                // Feedback routing scopes OOM penalties to the group whose
                // accelerator refused the candidate (the memory boundary
                // is per-device, not cluster-wide).
                group_scoped_penalties: cfg.feedback_routing,
                ..SearchPolicy::default()
            },
            initial: Architecture::initial(
                cfg.dataset.image,
                cfg.dataset.channels,
                cfg.dataset.num_classes,
            ),
            total_units: cfg.total_subshards(),
        }
    }

    /// Timing model of a node group.
    pub fn timing(&self, group: usize) -> &TimingModel {
        &self.timings[group]
    }

    /// Fully-specified node model of a node group.
    pub fn node(&self, group: usize) -> &crate::cluster::NodeModel {
        &self.timings[group].node
    }
}

/// Frozen view of the shared historical model list, refreshed at each
/// epoch barrier. The ranked list and its stable accuracy-ascending
/// order are `Arc`-shared with the master's [`super::HistoryList`], so a
/// refresh is O(1) and never clones an architecture (the entries share
/// `Arc<Architecture>`s with the records themselves). `records` is the
/// global record count (drives the NFS read charge exactly like
/// `HistoryList::nfs_bytes`); `penalties` counts penalty entries so the
/// selection fast path can prove its filter inert without a scan.
#[derive(Default, Clone)]
pub struct HistorySnapshot {
    pub ranked: Arc<Vec<RankedModel>>,
    pub sorted: Arc<Vec<u32>>,
    pub records: u64,
    pub penalties: u64,
}

/// One sub-shard lane: an independent trial trainer over a slice of the
/// node's GPUs.
struct SubShard {
    /// Globally unique lane index (fixes RNG streams and trial-id
    /// striding; equals the node index when `subshards_per_node` is 1).
    unit: u64,
    /// Devices this lane trains on when running solo.
    gpus: u64,
    round: u64,
    /// The lane's hyperparameter optimizer — a trait object from
    /// [`crate::hpo::build`], selected by the `hpo` config key (with the
    /// lane's group override). TPE by default; every backend draws from
    /// the lane's RNG stream at `suggest` time, so the default draws
    /// exactly the stream the old concrete `Tpe` field drew.
    opt: Box<dyn Optimizer>,
    rng: Rng,
    tele_rng: Rng,
    dispatcher: Dispatcher,
    trial: Option<ActiveTrial>,
    /// Dispatcher-local id of the in-flight trial.
    current_local: u64,
    /// Seconds per (train + validate) epoch for the current trial, at the
    /// lane's *current* effective width (helpers included).
    epoch_seconds: f64,
    /// Seconds per epoch of this lane's latest trial at its solo width —
    /// the runway estimate the steal scheduler uses (never sped up by
    /// helpers, unlike `epoch_seconds`).
    own_epoch_s: f64,
    /// GPU busy fraction while the current trial trains.
    busy_fraction: f64,
    /// GPU memory utilization fraction for the current trial.
    mem_fraction: f64,
    /// Until when the lane is in inter-trial setup (telemetry dent).
    setup_until: f64,
    /// Epoch generation: bumped whenever the pending `EpochDone` is
    /// superseded (trial start or steal re-timing).
    epoch_gen: u64,
    /// Absolute time of the pending `EpochDone` (barrier-slack metric and
    /// steal re-timing).
    epoch_end_t: f64,
    /// Sibling lanes currently lending this lane their devices.
    helpers: Vec<usize>,
    /// `Some(victim)` while this lane's devices are lent to a sibling.
    assisting: Option<usize>,
    /// Out of runway with nothing to steal: the lane posted its proposed
    /// candidate into the migrant outbox and idles until the elastic
    /// scheduler hands it a migrated trial (or the run ends).
    parked: bool,
    /// The current trial was adopted from another group: it syncs over
    /// InfiniBand and skips the lane-local TPE feedback at finalize (the
    /// hyperparameters were the source lane's — with feedback routing on
    /// the observation travels back to that lane instead, and sibling
    /// lanes may steal into this trial's IB ring).
    migrated: bool,
    /// Source coordinates of the adopted trial: `(node, sub, group)` of
    /// the lane whose search loop proposed it — the address feedback
    /// routing posts the finalize observation back to.
    migrant_from: Option<(usize, usize, usize)>,
    /// Cross-node sync penalty per completed epoch of the migrated trial
    /// (accrued into the shard's migration-overhead counter).
    migrant_epoch_overhead_s: f64,
    /// When the lane last became busy (trial start, steal attach, or
    /// migrant adoption); `None` while idle.
    busy_since: Option<f64>,
    /// Accumulated busy seconds over the run (per-lane utilization
    /// telemetry — the recovered tail the elastic passes make visible).
    busy_s: f64,
}

/// One slave node's complete simulation state: `k` sub-shard lanes over
/// a shared event queue, candidate buffer, and NFS accounting.
pub struct SlaveShard {
    pub node: usize,
    /// Topology group this node belongs to (selects its device model).
    pub group: usize,
    queue: EventQueue<ShardEvent>,
    buffer: ArchBuffer,
    pub nfs: NfsStats,
    /// This node's slice of the elastic scheduler: the seed-derived
    /// intra-node steal pass (see `coordinator::sched::steal`).
    steal: StealScheduler,
    /// Whether this node can migrate work out at all: migration is
    /// enabled cluster-wide AND at least one *other* group accepts
    /// migrants. Without an eligible destination, staging a checkpoint
    /// and parking would strand the lane and charge overhead that can
    /// never place — the lane keeps the classic behavior instead.
    migration: bool,
    /// Steal events performed by this node's lanes (report counter).
    pub steals: u64,
    /// Candidates skipped because no batch size fit the accelerator.
    pub oom_skips: u64,
    /// Count of penalty records fed back for OOM-skipped candidates
    /// (strides their synthetic record ids).
    oom_penalties: u64,
    /// Trials this node's lanes dispatched to other groups (placed by the
    /// elastic scheduler at a barrier).
    pub migrations_out: u64,
    /// Trials this node's lanes adopted from other groups.
    pub migrations_in: u64,
    /// Seconds of migration overhead charged on this node: NFS checkpoint
    /// staging (both directions) plus the cross-node gradient-sync
    /// penalty of adopted trials' completed epochs.
    pub migration_overhead_s: f64,
    /// Candidates staged for cross-group adoption, drained by the elastic
    /// scheduler at each epoch barrier.
    pub migrant_outbox: Vec<MigrantCandidate>,
    /// Finished migrated trials' optimizer observations, addressed to
    /// their source lanes — drained by the feedback router at each epoch
    /// barrier (`coordinator::sched::feedback`).
    pub feedback_outbox: Vec<RoutedObservation>,
    /// Observations routed back into this shard's lanes' TPEs (the
    /// source side of the feedback loop; report counter).
    pub feedback_routed: u64,
    /// Steal events whose victim was an adopted migrant (steal-into-
    /// migrant ring joins; subset of `steals`).
    pub migrant_ring_joins: u64,
    /// Trials terminated by the learning-curve rule (report counter;
    /// zero unless `BenchmarkConfig::early_stop`).
    pub early_stops: u64,
    /// Budgeted epochs the early-stopped trials never trained — the
    /// device time the rule handed back to the search (report counter).
    pub epochs_saved: u64,
    /// Error of the best model this shard knows of: the top of the last
    /// barrier snapshot merged with its own window completions. Only
    /// the early-stop rule reads it.
    best_error: Option<f64>,
    subs: Vec<SubShard>,
    /// Window outputs, drained by the coordinator at each barrier.
    pub completed: Vec<ModelRecord>,
    pub epoch_ops: Vec<(f64, f64)>,
    pub readings: Vec<(f64, NodeReading)>,
}

impl SlaveShard {
    /// A fresh shard for `node` in topology group `group`, with its
    /// stream-derived RNGs and the SLURM-stagger initial schedule. The
    /// node's GPUs split evenly across `cfg.group_subshards(group)`
    /// lanes (validation requires divisibility).
    pub fn new(node: usize, group: usize, cfg: &BenchmarkConfig) -> Self {
        let k = cfg.group_subshards(group).max(1) as usize;
        let g = &cfg.topology.groups[group];
        let lane_gpus = (g.gpus_per_node / k as u64).max(1);
        let unit0 = cfg.subshard_base(group, node);
        let mut queue = EventQueue::new();
        let mut subs = Vec::with_capacity(k);
        for s in 0..k {
            let unit = unit0 + s as u64;
            // Asynchronous dispatch: SLURM stagger of a few seconds per
            // lane (per node in the classic one-lane layout). The stagger
            // wraps past STAGGER_PERIOD lanes: an unwrapped `unit * 2 s`
            // would push lane 100k's first event out to t ≈ 56 h — past
            // any benchmark duration, leaving most of an exascale cluster
            // permanently idle. Every pinned preset has at most 1024
            // lanes, so their schedules are untouched by the wrap.
            const STAGGER_PERIOD: u64 = 2048;
            queue.schedule(
                (unit % STAGGER_PERIOD) as f64 * 2.0,
                ShardEvent::NodeReady { sub: s },
            );
            subs.push(SubShard {
                unit,
                gpus: lane_gpus,
                round: 0,
                // `seed ^ unit` only de-phases deterministic backends
                // (grid's lattice cursor); the stochastic ones draw from
                // the lane RNG below and ignore it.
                opt: crate::hpo::build(cfg.group_hpo(group), aiperf_space(), cfg.seed ^ unit),
                rng: derive(cfg.seed, "slave", unit),
                tele_rng: derive(cfg.seed, "telemetry", unit),
                dispatcher: Dispatcher::new(),
                trial: None,
                current_local: 0,
                epoch_seconds: 0.0,
                own_epoch_s: 0.0,
                busy_fraction: 0.0,
                mem_fraction: 0.0,
                setup_until: 0.0,
                epoch_gen: 0,
                epoch_end_t: 0.0,
                helpers: Vec::new(),
                assisting: None,
                parked: false,
                migrated: false,
                migrant_from: None,
                migrant_epoch_overhead_s: 0.0,
                busy_since: None,
                busy_s: 0.0,
            });
        }
        for s in 0..k {
            queue.schedule(cfg.telemetry_interval_s, ShardEvent::Telemetry { sub: s });
        }
        SlaveShard {
            node,
            group,
            queue,
            // Per-shard buffer: the search loop pushes one candidate and
            // the trainer drains it within the same NodeReady event, so a
            // small constant capacity captures the actual invariant.
            buffer: ArchBuffer::new(4),
            nfs: NfsStats::default(),
            steal: StealScheduler::new(cfg, node),
            migration: cfg.migration
                && cfg
                    .topology
                    .groups
                    .iter()
                    .enumerate()
                    .any(|(i, g)| i != group && g.accepts_migrants),
            steals: 0,
            oom_skips: 0,
            oom_penalties: 0,
            migrations_out: 0,
            migrations_in: 0,
            migration_overhead_s: 0.0,
            migrant_outbox: Vec::new(),
            feedback_outbox: Vec::new(),
            feedback_routed: 0,
            migrant_ring_joins: 0,
            early_stops: 0,
            epochs_saved: 0,
            best_error: None,
            subs,
            completed: Vec::new(),
            epoch_ops: Vec::new(),
            readings: Vec::new(),
        }
    }

    /// Number of sub-shard lanes on this node.
    pub fn subshard_count(&self) -> usize {
        self.subs.len()
    }

    /// Trials completed across all lanes (report counter).
    pub fn total_completed(&self) -> u64 {
        self.subs.iter().map(|s| s.dispatcher.total_completed()).sum()
    }

    /// Per-lane barrier overshoot at a window boundary: how far each
    /// solo lane's in-flight epoch extends past the barrier — the time
    /// by which this lane alone would stretch a synchronous epoch
    /// barrier. Lanes currently lending their devices are not samples
    /// (their work is accounted on the victim lane); idle lanes sample
    /// as zero.
    pub fn barrier_overshoots(&self, window_end: f64) -> Vec<f64> {
        self.subs
            .iter()
            .filter(|s| s.assisting.is_none())
            .map(|s| {
                if s.trial.is_some() {
                    (s.epoch_end_t - window_end).max(0.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Whether lane `sub` is parked — idle after a migrate-out, awaiting
    /// an adopted trial or the end of the run. The destination predicate
    /// of the elastic scheduler's migration pass.
    pub fn lane_parked(&self, sub: usize) -> bool {
        let s = &self.subs[sub];
        s.parked && s.trial.is_none() && s.assisting.is_none()
    }

    /// Accumulated busy seconds of lane `sub` — the migration pass's
    /// least-loaded metric (open intervals of in-flight trials are not
    /// yet included).
    pub fn lane_busy_seconds(&self, sub: usize) -> f64 {
        self.subs[sub].busy_s
    }

    /// Counter hook for the elastic scheduler: one of this node's staged
    /// candidates was dispatched to another group.
    pub fn note_migration_out(&mut self) {
        self.migrations_out += 1;
    }

    /// Deliver a migrated trial's observation back into the source
    /// lane's optimizer (feedback-router dispatch at an epoch barrier):
    /// the lane's optimizer sees the result of its own suggestion
    /// exactly as if the trial had trained locally.
    pub fn inject_feedback(&mut self, obs: &RoutedObservation) {
        let lane = &mut self.subs[obs.to_sub];
        lane.opt.observe(vec![obs.hp.dropout, obs.hp.kernel], obs.loss);
        self.feedback_routed += 1;
    }

    /// Per-lane busy fraction over a run of `duration_s` seconds: time
    /// holding a trial (setup included, doomed trials too — the devices
    /// are occupied either way), assisting a sibling, or training an
    /// adopted migrant. Search-only gaps and parked tails read as idle —
    /// exactly the headroom the steal/migration passes recover. Lanes
    /// still busy at the cutoff accrue up to `duration_s`.
    pub fn lane_busy_fractions(&self, duration_s: f64) -> Vec<f64> {
        self.subs
            .iter()
            .map(|s| {
                let mut busy = s.busy_s;
                if let Some(b) = s.busy_since {
                    busy += (duration_s - b).max(0.0);
                }
                if duration_s > 0.0 {
                    (busy / duration_s).clamp(0.0, 1.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Adopt a migrated trial on lane `sub` (elastic-scheduler dispatch
    /// at an epoch barrier, time `t`): charge the NFS checkpoint
    /// stage-in, re-time the trial under this group's device model and
    /// batch with its gradient ring over InfiniBand, and schedule its
    /// first epoch. `fit` is the scheduler's priced evaluation of this
    /// exact destination ([`MigrantCandidate::fit_on`]). Returns whether
    /// the lane actually adopted the trial — the defensive refusal path
    /// charges nothing, so the scheduler's counters stay conserved.
    pub fn accept_migrant(
        &mut self,
        t: f64,
        sub: usize,
        m: &MigrantCandidate,
        fit: &MigrantFit,
        ctx: &SimContext,
    ) -> bool {
        assert!(self.lane_parked(sub), "migrant dispatched to a busy lane");
        assert_ne!(self.group, m.from_group, "migration is inter-group");
        let cfg = ctx.cfg;
        let timing = ctx.timing(self.group);
        let node = &timing.node;
        let local = match self.subs[sub].dispatcher.assign(self.node) {
            Ok(id) => id,
            Err(_) => return false, // defensive: lane already holds a trial
        };
        self.subs[sub].current_local = local;
        // Stage-in, counters charged here (the placement probe priced the
        // identical transfer without charging them).
        let stage = timing
            .nfs
            .stage_in_seconds(m.checkpoint_bytes(cfg), &mut self.nfs);
        assert_eq!(stage.to_bits(), fit.stage_s.to_bits());
        let trial_id = local * ctx.total_units + self.subs[sub].unit;
        let gpus = self.subs[sub].gpus;
        // The single-sourced IB ring timing (same helper as the placement
        // probe and the steal-into-migrant widening).
        let ring = migrant_ring(timing, &m.ops, m.params, &cfg.dataset, fit.batch, gpus);
        let total_epoch_s = ring.total_s;
        // The IB-vs-NVLink sync delta this trial pays per epoch, accrued
        // into the overhead counter as epochs actually complete.
        let penalty_per_epoch = ring.sync_penalty_s;
        // Same association as the placement probe's runway check, so the
        // scheduled first epoch lands exactly where the probe priced it.
        let end_t = t + stage + fit.setup_s + total_epoch_s;
        let mem_fraction = (node.gpu.memory_demand(m.params, m.activation_elems, fit.batch) as f64
            / node.gpu.memory_bytes as f64)
            .min(1.0);
        let lane = &mut self.subs[sub];
        lane.parked = false;
        lane.migrated = true;
        lane.migrant_from = Some((m.from_node, m.from_sub, m.from_group));
        lane.migrant_epoch_overhead_s = penalty_per_epoch;
        assert!(lane.busy_since.is_none(), "adopting lane was already busy");
        lane.busy_since = Some(t);
        lane.epoch_seconds = total_epoch_s;
        lane.own_epoch_s = total_epoch_s;
        lane.busy_fraction = (ring.epoch.compute_s + ring.val_s) / total_epoch_s
            * ring.epoch.gpu_busy_fraction.max(0.9);
        lane.mem_fraction = mem_fraction;
        lane.setup_until = t + stage + fit.setup_s;
        lane.trial = Some(ActiveTrial::new(
            trial_id,
            m.arch.clone(),
            arch_id(&m.arch.signature()),
            m.hp,
            m.ops,
            fit.batch,
            m.round,
            m.budget,
        ));
        lane.epoch_gen += 1;
        lane.epoch_end_t = end_t;
        let gen = lane.epoch_gen;
        self.queue.schedule(end_t, ShardEvent::EpochDone { sub, gen });
        self.migrations_in += 1;
        self.migration_overhead_s += stage;
        // Steal-into-migrant: parked siblings get a fresh chance to join
        // this trial's IB ring instead of idling out the run (their
        // NodeReady lands in the next window; the parked branch of
        // `on_node_ready` only ever steals, never proposes again).
        if ctx.cfg.feedback_routing && self.steal.enabled {
            for s in 0..self.subs.len() {
                if s != sub && self.lane_parked(s) {
                    self.queue.schedule(t, ShardEvent::NodeReady { sub: s });
                }
            }
        }
        true
    }

    /// Timestamp of this shard's next pending event, if any — the
    /// coordinator's dormancy index ([`crate::coordinator::active`])
    /// reads this after every mutation point (window run, barrier pass)
    /// to decide whether the shard needs to be handed to a worker for a
    /// given window at all. A shard whose next event lies past the
    /// window boundary would pop nothing in `run_until`, so skipping it
    /// leaves bit-identical state.
    pub fn next_event_time(&self) -> Option<f64> {
        self.queue.peek_time()
    }

    /// Advance this shard's local event loop up to (and including)
    /// `window_end`. Events past the benchmark duration stay unpopped.
    pub fn run_until(&mut self, window_end: f64, snapshot: &HistorySnapshot, ctx: &SimContext) {
        // The incumbent the early-stop rule competes against: the top of
        // the frozen snapshot, folded into whatever this shard already
        // knew (its own window completions keep updating it below).
        if let Some(&i) = snapshot.sorted.last() {
            let r = &snapshot.ranked[i as usize];
            if !r.penalty {
                let e = 1.0 - r.accuracy;
                self.best_error = Some(self.best_error.map_or(e, |b| b.min(e)));
            }
        }
        while let Some(t) = self.queue.peek_time() {
            if t > window_end {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            match ev {
                ShardEvent::NodeReady { sub } => self.on_node_ready(t, sub, snapshot, ctx),
                ShardEvent::EpochDone { sub, gen } => self.on_epoch_done(t, sub, gen, ctx),
                ShardEvent::EarlyStopped { sub, gen } => self.on_early_stopped(t, sub, gen, ctx),
                ShardEvent::Telemetry { sub } => self.on_telemetry(t, sub, ctx),
            }
        }
    }

    /// The intra-node steal pass: when `sub` has no runway for another
    /// full epoch before the benchmark deadline, attach it to the
    /// most-loaded sibling lane's trial instead of starting a doomed
    /// one. The decision (runway predicate + seed-derived victim scan)
    /// belongs to [`StealScheduler`]; this method applies it. Returns
    /// `true` when the lane was lent out.
    fn try_steal(&mut self, t: f64, sub: usize, ctx: &SimContext) -> bool {
        if !self.steal.enabled || self.subs.len() < 2 {
            return false;
        }
        let cfg = ctx.cfg;
        // Runway estimate: this lane's latest solo epoch duration. A lane
        // that never trained yet (run start) has no estimate and must
        // start a real trial.
        let est = self.subs[sub].own_epoch_s;
        let host = &ctx.node(self.group).host;
        if !StealScheduler::out_of_runway(
            t,
            host.search_seconds,
            host.setup_seconds,
            est,
            cfg.duration_s,
        ) {
            return false;
        }
        let loads: Vec<LaneLoad> = self
            .subs
            .iter()
            .map(|s| LaneLoad {
                busy: s.trial.is_some(),
                migrated: s.migrated,
                epoch_end_t: s.epoch_end_t,
                epoch_seconds: s.epoch_seconds,
                remaining_epochs: s
                    .trial
                    .as_ref()
                    .map_or(0.0, |tr| tr.epoch_budget.saturating_sub(tr.epoch + 1) as f64),
            })
            .collect();
        let Some(victim) = self.steal.pick_victim(sub, t, &loads) else {
            return false;
        };

        // Attach: the thief's devices join the victim trial's allreduce
        // ring (all lanes of a node share its NVLink domain; an adopted
        // migrant's ring runs over InfiniBand at any width).
        let victim_migrated = self.subs[victim].migrated;
        self.subs[victim].helpers.push(sub);
        self.subs[sub].assisting = Some(victim);
        self.steals += 1;
        if victim_migrated {
            self.migrant_ring_joins += 1;
        }

        // Re-time the victim's epochs at the widened data-parallel span.
        let helper_gpus: u64 = self.subs[victim]
            .helpers
            .iter()
            .map(|&h| self.subs[h].gpus)
            .sum();
        let gpus_eff = self.subs[victim].gpus + helper_gpus;
        let (ops, params, batch) = {
            let tr = self.subs[victim].trial.as_ref().expect("victim has a trial");
            (tr.ops, tr.params, tr.batch_per_gpu)
        };
        let timing = ctx.timing(self.group);
        // Migrated victims re-time through the single-sourced IB helper
        // (steal and migration compose); native victims keep the NVLink
        // ring. The sync penalty per epoch tracks the widened ring.
        let (epoch, val_s, sync_penalty_s) = if victim_migrated {
            let ring = migrant_ring(timing, &ops, params, &cfg.dataset, batch, gpus_eff);
            (ring.epoch, ring.val_s, ring.sync_penalty_s)
        } else {
            let epoch = timing.epoch_with_gpus(
                ops.train_per_image(),
                params,
                cfg.dataset.train_images,
                batch,
                gpus_eff,
            );
            let val_s = timing.validation_with_gpus(
                ops.val_per_image(),
                cfg.dataset.val_images,
                batch,
                gpus_eff,
            );
            (epoch, val_s, 0.0)
        };
        let new_epoch_s = epoch.total_s + val_s;
        let old_epoch_s = self.subs[victim].epoch_seconds;
        // Only the compute portion of the victim's in-flight epoch speeds
        // up with extra devices; any leftover search/NFS setup (a first
        // epoch stolen mid-setup) is width-independent and keeps its
        // original duration.
        let remaining = (self.subs[victim].epoch_end_t - t).max(0.0);
        let setup_left = (self.subs[victim].setup_until - t).max(0.0).min(remaining);
        let compute_left = remaining - setup_left;
        let scaled = setup_left
            + if old_epoch_s > 0.0 {
                compute_left * new_epoch_s / old_epoch_s
            } else {
                compute_left
            };
        let v = &mut self.subs[victim];
        v.epoch_seconds = new_epoch_s;
        v.busy_fraction =
            (epoch.compute_s + val_s) / new_epoch_s * epoch.gpu_busy_fraction.max(0.9);
        if victim_migrated {
            v.migrant_epoch_overhead_s = sync_penalty_s;
        }
        v.epoch_gen += 1;
        v.epoch_end_t = t + scaled;
        let gen = v.epoch_gen;
        let (busy, mem) = (v.busy_fraction, v.mem_fraction);
        self.queue
            .schedule(t + scaled, ShardEvent::EpochDone { sub: victim, gen });
        // The helper lane's telemetry mirrors the trial it joined.
        let me = &mut self.subs[sub];
        me.busy_fraction = busy;
        me.mem_fraction = mem;
        me.setup_until = t;
        assert!(me.busy_since.is_none(), "helper lane was already busy");
        me.busy_since = Some(t);
        true
    }

    /// The CPU search loop (paper §4.3 steps 3–4): advance the lane's
    /// round, propose a candidate from the frozen snapshot plus the
    /// node's own completions since the last barrier (a node always sees
    /// its own results), push/drain it through the buffer, charge the
    /// search + NFS setup time, and suggest hyperparameters (defaults in
    /// warm-up, TPE afterwards). Shared by the native trial start and
    /// the migrate-out path so the two cannot drift — same RNG draws,
    /// same NFS charges. Returns `(candidate, setup seconds, hp, round)`.
    fn search_and_setup(
        &mut self,
        t: f64,
        sub: usize,
        snapshot: &HistorySnapshot,
        ctx: &SimContext,
    ) -> (Architecture, f64, HpPoint, u64) {
        let cfg = ctx.cfg;
        self.subs[sub].round += 1;
        let round = self.subs[sub].round;

        // The node's local completions since the barrier ride along as a
        // small extras tail, merged into the frozen snapshot's sorted
        // order on the fly — the snapshot is never cloned or re-sorted,
        // and the draws replay the historic concatenate-and-sort form
        // bit for bit (see `SearchPolicy::propose_merged`).
        // Proposals carry this shard's group so the penalty filter knows
        // which accelerator's memory boundary applies (scoping itself is
        // gated by `SearchPolicy::group_scoped_penalties`).
        let on_group = Some(self.group);
        let arch = if snapshot.ranked.is_empty() && self.completed.is_empty() {
            ctx.initial.clone()
        } else {
            let extras: Vec<RankedModel> = self
                .completed
                .iter()
                .map(|r| RankedModel {
                    arch: Arc::clone(&r.arch),
                    accuracy: r.accuracy,
                    penalty: r.penalty,
                    group: r.group,
                })
                .collect();
            ctx.policy
                .propose_merged(
                    &snapshot.ranked,
                    &snapshot.sorted,
                    snapshot.penalties,
                    &extras,
                    on_group,
                    &mut self.subs[sub].rng,
                )
                .0
        };
        let _ = self.buffer.push(Candidate {
            arch: arch.clone(),
            proposed_by: self.node,
            proposed_at: t,
        });
        // --- Trainer drains the buffer (NFS round trips charged).
        let cand = self.buffer.pop().map(|c| c.arch).unwrap_or(arch);
        let timing = ctx.timing(self.group);
        let node = &timing.node;
        let mut setup = node.host.search_seconds + node.host.setup_seconds;
        let history_bytes = 2048 * (snapshot.records + self.completed.len() as u64);
        setup += timing.nfs.read_seconds(history_bytes, &mut self.nfs);
        setup += timing.nfs.write_seconds(2048, &mut self.nfs);
        setup += timing.nfs.read_seconds(2048, &mut self.nfs);

        let lane = &mut self.subs[sub];
        let hp = match ctx
            .policy
            .suggest_hp(lane.opt.as_mut(), cfg.warmup.hpo_active(round), &mut lane.rng)
        {
            Some(c) => HpPoint {
                dropout: c[0],
                kernel: c[1],
            },
            None => HpPoint::default(),
        };
        (cand, setup, hp, round)
    }

    /// The migrate-out path: `sub` is out of runway and found no sibling
    /// trial to steal into. With migration enabled, run the same search
    /// loop a native start would, stage the candidate's checkpoint out
    /// to NFS, post it into the migrant outbox for the elastic
    /// scheduler's next barrier pass, and park the lane. Returns `true`
    /// when the lane parked.
    fn try_migrate_out(
        &mut self,
        t: f64,
        sub: usize,
        snapshot: &HistorySnapshot,
        ctx: &SimContext,
    ) -> bool {
        if !self.migration {
            return false;
        }
        let cfg = ctx.cfg;
        let est = self.subs[sub].own_epoch_s;
        let host = &ctx.node(self.group).host;
        if !StealScheduler::out_of_runway(
            t,
            host.search_seconds,
            host.setup_seconds,
            est,
            cfg.duration_s,
        ) {
            return false;
        }
        let (cand, _setup, hp, round) = self.search_and_setup(t, sub, snapshot, ctx);
        let stats = cand.stats(&ctx.weights);
        let m = MigrantCandidate {
            arch: cand,
            hp,
            params: stats.params,
            activation_elems: stats.activation_elems,
            ops: stats.ops,
            round,
            budget: cfg.warmup.epochs_for_round(round),
            from_node: self.node,
            from_sub: sub,
            from_group: self.group,
            posted_at: t,
        };
        let stage = ctx
            .timing(self.group)
            .nfs
            .stage_out_seconds(m.checkpoint_bytes(cfg), &mut self.nfs);
        self.migration_overhead_s += stage;
        self.migrant_outbox.push(m);
        let lane = &mut self.subs[sub];
        lane.parked = true;
        lane.setup_until = t; // telemetry reads the idle dent from here on
        true
    }

    /// Feed an OOM-skipped candidate back into the ranked history as a
    /// zero-accuracy penalty entry, so parent selection learns the
    /// memory boundary instead of re-proposing the same unfittable
    /// neighborhood (the record merges into the shared history at the
    /// next barrier; `SearchPolicy` never selects penalty entries as
    /// parents while real ones exist). The synthetic id lives in the
    /// top-bit range so it can never collide with a dispatched trial id.
    fn push_oom_penalty(
        &mut self,
        t: f64,
        arch: Architecture,
        params: u64,
        hp: HpPoint,
        round: u64,
        ctx: &SimContext,
    ) {
        let id = (1u64 << 63) | (self.oom_penalties * ctx.total_units + self.node as u64);
        self.oom_penalties += 1;
        self.completed.push(ModelRecord {
            id,
            signature: arch.signature(),
            params,
            measured_accuracy: 0.0,
            arch: Arc::new(arch),
            accuracy: 0.0,
            predicted: true,
            penalty: true,
            node: self.node,
            group: self.group,
            round,
            epochs_trained: 0,
            ops: 0.0,
            dropout: hp.dropout,
            kernel: hp.kernel,
            completed_at: t,
        });
    }

    /// The CPU search loop + trial start (paper §4.3 steps 3–5), or a
    /// steal / migrate-out when the lane is out of runway.
    fn on_node_ready(&mut self, t: f64, sub: usize, snapshot: &HistorySnapshot, ctx: &SimContext) {
        if self.subs[sub].trial.is_some() || self.subs[sub].assisting.is_some() {
            return; // defensive: lane already busy
        }
        if self.subs[sub].parked {
            // A parked lane already staged its candidate out; it never
            // proposes again, but with the feedback loop closed it may
            // still lend its devices — typically joining an adopted
            // migrant's IB ring (steal-into-migrant).
            if ctx.cfg.feedback_routing {
                self.try_steal(t, sub, ctx);
            }
            return;
        }
        if self.try_steal(t, sub, ctx) {
            return;
        }
        if self.try_migrate_out(t, sub, snapshot, ctx) {
            return;
        }
        let cfg = ctx.cfg;
        let (cand, setup, hp, round) = self.search_and_setup(t, sub, snapshot, ctx);

        // --- Memory adaption: halve the batch until the model fits this
        // group's accelerator (a 16 GB T4 adapts sooner than a 32 GB
        // V100), clamping to the exact fit boundary when the ladder
        // bottoms out (`sched::adapted_batch` — the same policy the
        // migration pass re-runs against a destination device). When no
        // batch fits at all, skip the candidate (charging the wasted
        // search/setup), feed a penalty into the ranked history and the
        // TPE loss so the search learns the memory boundary, and propose
        // a different candidate.
        let stats = cand.stats(&ctx.weights);
        let (params, act, ops) = (stats.params, stats.activation_elems, stats.ops);
        let timing = ctx.timing(self.group);
        let node = &timing.node;
        let batch_cfg = cfg.group_batch(self.group);
        let Some(batch) = adapted_batch(&node.gpu, params, act, batch_cfg) else {
            self.oom_skips += 1;
            if cfg.warmup.hpo_active(round) {
                let lane = &mut self.subs[sub];
                lane.opt.observe(vec![hp.dropout, hp.kernel], 1.0);
            }
            self.push_oom_penalty(t, cand, params, hp, round, ctx);
            self.subs[sub].round -= 1; // the skipped proposal is not a round
            self.queue.schedule(t + setup, ShardEvent::NodeReady { sub });
            return;
        };
        let local = match self.subs[sub].dispatcher.assign(self.node) {
            Ok(id) => id,
            Err(_) => return, // defensive: lane already holds a trial
        };
        self.subs[sub].current_local = local;
        // Globally unique, execution-order-independent trial id.
        let trial_id = local * ctx.total_units + self.subs[sub].unit;
        let budget = cfg.warmup.epochs_for_round(round);
        let gpus = self.subs[sub].gpus;
        let epoch = timing.epoch_with_gpus(
            ops.train_per_image(),
            params,
            cfg.dataset.train_images,
            batch,
            gpus,
        );
        let val_s =
            timing.validation_with_gpus(ops.val_per_image(), cfg.dataset.val_images, batch, gpus);
        let total_epoch_s = epoch.total_s + val_s;

        let mem_fraction = (node.gpu.memory_demand(params, act, batch) as f64
            / node.gpu.memory_bytes as f64)
            .min(1.0);
        let lane = &mut self.subs[sub];
        lane.epoch_seconds = total_epoch_s;
        lane.own_epoch_s = total_epoch_s;
        lane.busy_fraction =
            (epoch.compute_s + val_s) / total_epoch_s * epoch.gpu_busy_fraction.max(0.9);
        lane.mem_fraction = mem_fraction;
        lane.setup_until = t + setup;
        assert!(lane.busy_since.is_none(), "starting lane was already busy");
        lane.busy_since = Some(t);
        lane.trial = Some(ActiveTrial::new(
            trial_id,
            cand.clone(),
            arch_id(&cand.signature()),
            hp,
            ops,
            batch,
            round,
            budget,
        ));
        lane.epoch_gen += 1;
        lane.epoch_end_t = t + setup + total_epoch_s;
        let gen = lane.epoch_gen;
        self.queue
            .schedule(t + setup + total_epoch_s, ShardEvent::EpochDone { sub, gen });
    }

    /// One finished training epoch: account ops, record accuracy, decide
    /// whether to continue, early-stop, or finalize into the history.
    fn on_epoch_done(&mut self, t: f64, sub: usize, gen: u64, ctx: &SimContext) {
        if gen != self.subs[sub].epoch_gen {
            return; // superseded by a steal re-timing
        }
        let cfg = ctx.cfg;
        let migrated = self.subs[sub].migrated;
        let migrant_overhead = self.subs[sub].migrant_epoch_overhead_s;
        let Some(trial) = self.subs[sub].trial.as_mut() else {
            return;
        };
        // Account analytical ops for the finished epoch.
        let epoch_ops = trial.ops.train_per_image() as f64 * cfg.dataset.train_images as f64
            + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64;
        self.epoch_ops.push((t, epoch_ops));
        if migrated {
            // Each completed epoch of an adopted trial paid the IB-ring
            // sync penalty over its steps.
            self.migration_overhead_s += migrant_overhead;
        }

        let acc = ctx.surrogate.accuracy(
            trial.arch_id,
            trial.params,
            &trial.hp,
            trial.epoch + 1,
        );
        let status = trial.record_epoch(acc, cfg.patience, cfg.min_delta);
        let next_epoch_end = t + self.subs[sub].epoch_seconds;

        if status == TrialStatus::Continue && next_epoch_end <= cfg.duration_s {
            if self.curve_says_doomed(sub, ctx) {
                // The verdict fires as its own deterministic event, at
                // this same timestamp and generation: a steal re-timing
                // that lands in between bumps the generation and
                // supersedes it (the widened ring changes the trial's
                // economics).
                self.queue.schedule(t, ShardEvent::EarlyStopped { sub, gen });
                return;
            }
            self.subs[sub].epoch_end_t = next_epoch_end;
            self.queue
                .schedule(next_epoch_end, ShardEvent::EpochDone { sub, gen });
        } else {
            self.finalize_trial(t, sub, ctx);
        }
    }

    /// The LogFit early-stop rule (`BenchmarkConfig::early_stop`): fit
    /// the lane's partial learning curve and declare the trial doomed
    /// when even the optimistic error floor at the convergence horizon
    /// ([`LearningCurve::converged_floor`]) cannot beat the best model
    /// this shard knows of by `early_stop_margin`. Consumes no RNG, so
    /// the knob is provably inert when off.
    fn curve_says_doomed(&self, sub: usize, ctx: &SimContext) -> bool {
        let cfg = ctx.cfg;
        if !cfg.early_stop {
            return false;
        }
        let Some(best) = self.best_error else {
            return false; // no incumbent yet: nothing to compete against
        };
        let Some(trial) = self.subs[sub].trial.as_ref() else {
            return false;
        };
        if trial.epoch < cfg.early_stop_min_epochs || trial.accs.len() < 2 {
            return false;
        }
        let mut lc = LearningCurve::new();
        for (i, &a) in trial.accs.iter().enumerate() {
            lc.observe(i as u64 + 1, 1.0 - a);
        }
        lc.converged_floor() > best + cfg.early_stop_margin
    }

    /// An early-stop verdict arrived for lane `sub`'s in-flight trial:
    /// count it, credit the epochs its budget would still have trained,
    /// and finalize it now — the freed lane's `NodeReady` makes it an
    /// immediate steal victim / migrant-adoption opportunity.
    fn on_early_stopped(&mut self, t: f64, sub: usize, gen: u64, ctx: &SimContext) {
        if gen != self.subs[sub].epoch_gen {
            return; // superseded by a steal re-timing
        }
        let Some(trial) = self.subs[sub].trial.as_ref() else {
            return; // defensive: verdict outlived its trial
        };
        self.early_stops += 1;
        self.epochs_saved += trial.epoch_budget.saturating_sub(trial.epoch);
        self.finalize_trial(t, sub, ctx);
    }

    /// Finalize the lane's in-flight trial into the window output —
    /// shared by budget/patience completion (`on_epoch_done`) and the
    /// early-stop verdict (`on_early_stopped`), so the two paths cannot
    /// drift: Appendix-C accuracy prediction for short warm-up trials,
    /// the optimizer observation (local, or routed back to a migrant's
    /// source lane), the history record, helper-lane release, and the
    /// lane's next `NodeReady`.
    fn finalize_trial(&mut self, t: f64, sub: usize, ctx: &SimContext) {
        let cfg = ctx.cfg;
        let migrated = self.subs[sub].migrated;
        // --- Trial complete: record into the window output.
        let trial = self.subs[sub].trial.take().unwrap();
        let migrant_from = self.subs[sub].migrant_from.take();
        let warmup_round = !cfg.warmup.hpo_active(trial.round);
        let (accuracy, predicted) = if warmup_round
            && trial.epoch < cfg.warmup.max_epochs
            && trial.accs.len() >= 2
        {
            // Appendix C: conservative log-fit prediction.
            let (es, accs) = trial.curve();
            (LogFit::fit(&es, &accs).conservative(60.0), true)
        } else {
            (trial.best_accuracy(), false)
        };
        let ops_spent = (trial.ops.train_per_image() as f64
            * cfg.dataset.train_images as f64
            + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64)
            * trial.epoch as f64;
        // An adopted trial's hyperparameters came from the source
        // lane's optimizer; feeding them into this lane's model would
        // corrupt its stream, so only native trials observe locally.
        // With feedback routing on, the observation instead travels
        // back to the source lane at the next barrier — exactly when
        // a native trial of that round would have observed.
        if cfg.warmup.hpo_active(trial.round) && !migrated {
            let lane = &mut self.subs[sub];
            lane.opt.observe(
                vec![trial.hp.dropout, trial.hp.kernel],
                1.0 - trial.best_accuracy(),
            );
        } else if migrated && cfg.feedback_routing && cfg.warmup.hpo_active(trial.round) {
            let (to_node, to_sub, _) =
                migrant_from.expect("migrated trial lost its source coordinates");
            self.feedback_outbox.push(RoutedObservation {
                to_node,
                to_sub,
                hp: trial.hp,
                loss: 1.0 - trial.best_accuracy(),
            });
        }
        // Record provenance: with the loop closed, a migrated trial
        // belongs to the search that proposed it — the source lane's
        // node and group — not to the hardware that executed it.
        let (rec_node, rec_group) = match migrant_from {
            Some((n, _, g)) if cfg.feedback_routing => (n, g),
            _ => (self.node, self.group),
        };
        self.completed.push(ModelRecord {
            id: trial.trial_id,
            signature: trial.arch.signature(),
            params: trial.params,
            measured_accuracy: trial.best_accuracy(),
            arch: Arc::new(trial.arch),
            accuracy,
            predicted,
            penalty: false,
            node: rec_node,
            group: rec_group,
            round: trial.round,
            epochs_trained: trial.epoch,
            ops: ops_spent,
            dropout: trial.hp.dropout,
            kernel: trial.hp.kernel,
            completed_at: t,
        });
        // Fold the fresh result into the shard's incumbent (the
        // early-stop rule's competitor) without waiting for a barrier.
        let e = 1.0 - accuracy;
        self.best_error = Some(self.best_error.map_or(e, |b| b.min(e)));
        let local = self.subs[sub].current_local;
        let _ = self.subs[sub].dispatcher.complete(local, self.node);
        debug_assert!(self.subs[sub].dispatcher.check_invariants().is_ok());
        // Close the lane's busy interval and clear any migration
        // markers before it reschedules itself.
        let lane = &mut self.subs[sub];
        lane.migrated = false;
        lane.migrant_epoch_overhead_s = 0.0;
        lane.parked = false;
        if let Some(b) = lane.busy_since.take() {
            lane.busy_s += t - b;
        }
        // Release any helper lanes back to their own search loops
        // before this lane reschedules itself.
        let helpers: Vec<usize> = std::mem::take(&mut self.subs[sub].helpers);
        for h in helpers {
            self.subs[h].assisting = None;
            if let Some(b) = self.subs[h].busy_since.take() {
                self.subs[h].busy_s += t - b;
            }
            self.queue.schedule(t, ShardEvent::NodeReady { sub: h });
        }
        self.queue.schedule(t, ShardEvent::NodeReady { sub });
    }

    /// One telemetry tick: sample this lane's utilization (per-lane jitter
    /// stream keeps the readings engine-independent). A lane lending its
    /// devices to a sibling trial reads as busy with that trial's
    /// fractions.
    fn on_telemetry(&mut self, t: f64, sub: usize, ctx: &SimContext) {
        let cfg = ctx.cfg;
        let host = &ctx.node(self.group).host;
        let lane = &mut self.subs[sub];
        let training =
            (lane.trial.is_some() || lane.assisting.is_some()) && t >= lane.setup_until;
        let jitter = lane.tele_rng.gen_range_f64(-0.02, 0.02);
        let reading = if training {
            NodeReading {
                gpu_util: (lane.busy_fraction + jitter).clamp(0.0, 1.0),
                gpu_mem_util: lane.mem_fraction.clamp(0.0, 1.0),
                cpu_util: (host.cpu_util_training() + jitter / 4.0).clamp(0.0, 1.0),
                host_mem_util: host.host_memory_util(30 << 30),
            }
        } else {
            // The inter-stage "dent" of Figs 9/10.
            NodeReading {
                gpu_util: (0.02 + jitter.abs()).min(0.1),
                gpu_mem_util: 0.10,
                cpu_util: (0.30 + jitter).clamp(0.0, 1.0), // search burst
                host_mem_util: host.host_memory_util(30 << 30),
            }
        };
        self.readings.push((t, reading));
        if t + cfg.telemetry_interval_s <= cfg.duration_s {
            self.queue
                .schedule(t + cfg.telemetry_interval_s, ShardEvent::Telemetry { sub });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_for(cfg: &BenchmarkConfig) -> SimContext<'_> {
        SimContext::new(cfg)
    }

    #[test]
    fn shard_is_deterministic_and_snapshot_driven() {
        let mut cfg = BenchmarkConfig::homogeneous(2);
        cfg.duration_s = 4.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let run = || {
            let mut s = SlaveShard::new(0, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            (
                s.completed.len(),
                s.epoch_ops.len(),
                s.readings.len(),
                s.completed.iter().map(|r| r.accuracy).collect::<Vec<_>>(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.0 > 0, "no trials completed in 4 h");
        assert!(a.1 > 0);
        assert!(a.2 > 0);
    }

    #[test]
    fn windowed_run_equals_single_window() {
        let mut cfg = BenchmarkConfig::homogeneous(1);
        cfg.duration_s = 3.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        // Without barrier merges (snapshot never refreshed), splitting the
        // run into windows must not change anything.
        let mut whole = SlaveShard::new(0, 0, &cfg);
        whole.run_until(cfg.duration_s, &snapshot, &ctx);
        let mut split = SlaveShard::new(0, 0, &cfg);
        let mut t = 600.0;
        while t < cfg.duration_s {
            split.run_until(t, &snapshot, &ctx);
            t += 600.0;
        }
        split.run_until(cfg.duration_s, &snapshot, &ctx);
        assert_eq!(whole.completed.len(), split.completed.len());
        assert_eq!(whole.epoch_ops, split.epoch_ops);
        assert_eq!(
            whole.readings.iter().map(|r| r.0).collect::<Vec<_>>(),
            split.readings.iter().map(|r| r.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn trial_ids_unique_per_lane_stride() {
        let mut cfg = BenchmarkConfig::homogeneous(3);
        cfg.duration_s = 6.0 * 3600.0;
        cfg.subshards_per_node = 2;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut ids = Vec::new();
        for node in 0..3 {
            let mut s = SlaveShard::new(node, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            assert_eq!(s.subshard_count(), 2);
            ids.extend(s.completed.iter().map(|r| r.id));
        }
        let mut deduped = ids.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), ids.len(), "trial ids collide across lanes");
    }

    #[test]
    fn groups_with_different_gpus_diverge() {
        // Different device model ⇒ different trial timings and counts
        // (the hardware gap dominates any RNG-stream variance).
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        let cfg = BenchmarkConfig {
            duration_s: 4.0 * 3600.0,
            batch_per_gpu: 256,
            topology: ClusterTopology {
                groups: vec![
                    NodeGroup::new("t4", 1, 8, GpuModel::t4()),
                    NodeGroup::new("ascend", 1, 8, GpuModel::ascend910()),
                ],
            },
            ..BenchmarkConfig::default()
        };
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let ops_of = |group: usize, node: usize| {
            let mut s = SlaveShard::new(node, group, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            s.epoch_ops.iter().map(|e| e.1).sum::<f64>()
        };
        let slow = ops_of(0, 0);
        let fast = ops_of(1, 1);
        assert!(
            fast > 2.0 * slow,
            "ascend shard should finish far more epochs: t4={slow:e} ascend={fast:e}"
        );
    }

    #[test]
    fn subshard_lanes_train_concurrently() {
        // Two lanes over half the GPUs each: both make progress, the
        // node's total epoch-ops rate stays in the same ballpark as the
        // one-lane layout, and more architectures are explored.
        let mut one = BenchmarkConfig::homogeneous(1);
        one.duration_s = 6.0 * 3600.0;
        let mut two = one.clone();
        two.subshards_per_node = 2;
        let snapshot = HistorySnapshot::default();
        let run = |cfg: &BenchmarkConfig| {
            let ctx = ctx_for(cfg);
            let mut s = SlaveShard::new(0, 0, cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            (
                s.epoch_ops.iter().map(|e| e.1).sum::<f64>(),
                s.total_completed(),
                s.subshard_count(),
            )
        };
        let (ops1, done1, k1) = run(&one);
        let (ops2, done2, k2) = run(&two);
        assert_eq!((k1, k2), (1, 2));
        assert!(done1 > 0 && done2 > 0);
        assert!(
            ops2 > 0.4 * ops1 && ops2 < 2.5 * ops1,
            "sub-sharding should not change aggregate throughput wildly: {ops1:e} vs {ops2:e}"
        );
    }

    #[test]
    fn work_stealing_off_by_default_and_lanes_balanced() {
        let mut cfg = BenchmarkConfig::homogeneous(1);
        cfg.duration_s = 4.0 * 3600.0;
        cfg.subshards_per_node = 2;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut s = SlaveShard::new(0, 0, &cfg);
        s.run_until(cfg.duration_s, &snapshot, &ctx);
        assert_eq!(s.steals, 0, "stealing must be opt-in");
        // Barrier overshoots report one sample per solo lane.
        assert_eq!(s.barrier_overshoots(cfg.duration_s).len(), 2);
    }
}
