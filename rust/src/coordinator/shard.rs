//! Per-slave simulation shard (the parallel scale-out refactor), now at
//! sub-shard granularity.
//!
//! The discrete-event benchmark is sharded by slave node: each
//! [`SlaveShard`] owns its node's event queue, candidate buffer, and NFS
//! bookkeeping, and splits the node's GPUs into one or more *sub-shard
//! lanes* (`BenchmarkConfig::subshards_per_node`, per-group overridable).
//! Every lane is an independent trial trainer with its own CPU search
//! loop, TPE optimizer, RNG streams, and dispatcher lane — a node with
//! `k` lanes trains `k` candidates concurrently, each with synchronous
//! data parallelism across `gpus_per_node / k` devices. With one lane
//! per node this reduces exactly to the classic layout (same RNG
//! streams, same event order, bit-identical results).
//!
//! A shard belongs to one topology node group and draws its device
//! parameters from that group's [`crate::sim::timing::TimingModel`], so
//! heterogeneous clusters run mixed-speed shards side by side. Each
//! group can also train at its own batch (`[group.NAME] batch_per_gpu`),
//! so a mixed T4/V100 site no longer understates the larger card.
//!
//! # Work stealing
//!
//! The epoch barrier serializes a window on its slowest lane: a lane
//! whose remaining runway cannot fit another full epoch before the
//! benchmark deadline would classically start a doomed trial whose
//! first epoch never completes — wasted devices, exactly the
//! fixed-synchronization pitfall AIPerf's elastic design avoids. With
//! `BenchmarkConfig::work_stealing` on, such a lane instead *steals
//! queued trial work* from the most-loaded sibling lane in its node
//! (all lanes of a node belong to the same topology node group and
//! share its NVLink domain, which is what makes joining a trial's
//! allreduce ring cheap): it attaches to that trial as extra
//! data-parallel devices, the victim's remaining epochs re-time with
//! the wider ring, and the helper is released when the trial
//! finalizes. Victims are picked by largest remaining work, scanned in
//! a fixed seed-derived rotation, and the whole exchange happens
//! inside the node's own event loop — so `Engine::Sequential` and
//! `Engine::Parallel` remain bit-identical, enforced by
//! `rust/tests/engine_parity.rs`.
//!
//! Shards advance independently inside an epoch-barrier window
//! (`BenchmarkConfig::sync_interval_s`) against a frozen
//! [`HistorySnapshot`] of the shared historical model list, then the
//! coordinator merges their window outputs (completed models, analytical
//! ops, telemetry readings, barrier-slack samples) in deterministic node
//! order. Because a shard's evolution depends only on (its own state,
//! the snapshot, the window end), executing shards on a thread pool is
//! bit-identical to executing them sequentially.

use crate::cluster::nfs::NfsStats;
use crate::config::BenchmarkConfig;
use crate::coordinator::buffer::{ArchBuffer, Candidate};
use crate::coordinator::dispatcher::Dispatcher;
use crate::coordinator::history::ModelRecord;
use crate::coordinator::trial::{ActiveTrial, TrialStatus};
use crate::flops::OpWeights;
use crate::hpo::{aiperf_space, Optimizer, Tpe};
use crate::metrics::telemetry::NodeReading;
use crate::nas::graph::Architecture;
use crate::nas::search::{RankedModel, SearchPolicy};
use crate::predict::logfit::LogFit;
use crate::sim::accuracy::{arch_id, AccuracySurrogate, HpPoint};
use crate::sim::engine::EventQueue;
use crate::sim::timing::TimingModel;
use crate::util::rng::{derive, Rng};

/// Discrete events local to one shard, tagged with the sub-shard lane
/// they belong to.
#[derive(Debug, Clone, Copy)]
pub enum ShardEvent {
    /// Lane is free: run the search loop and start (or steal) the next
    /// trial.
    NodeReady { sub: usize },
    /// Lane finished one training epoch (incl. validation). `gen` is the
    /// lane's epoch generation: a steal re-times the pending epoch by
    /// bumping the generation and scheduling a replacement, so a stale
    /// event is recognizable and dropped on pop.
    EpochDone { sub: usize, gen: u64 },
    /// Telemetry sampling tick for one lane.
    Telemetry { sub: usize },
}

/// Immutable per-run context shared (read-only) by every shard.
pub struct SimContext<'a> {
    pub cfg: &'a BenchmarkConfig,
    pub weights: OpWeights,
    /// One timing model per topology node group (per-group accelerator
    /// parameters; index = group index).
    pub timings: Vec<TimingModel>,
    pub surrogate: AccuracySurrogate,
    pub policy: SearchPolicy,
    pub initial: Architecture,
    /// Total sub-shard lanes across the cluster (strides trial ids).
    pub total_units: u64,
}

impl<'a> SimContext<'a> {
    /// Build the per-run context from a (validated) configuration.
    pub fn new(cfg: &'a BenchmarkConfig) -> Self {
        SimContext {
            cfg,
            weights: OpWeights::default(),
            timings: cfg
                .topology
                .groups
                .iter()
                .map(|g| TimingModel {
                    node: g.node_model(cfg.host),
                    ..TimingModel::default()
                })
                .collect(),
            surrogate: AccuracySurrogate {
                seed: cfg.seed,
                ..AccuracySurrogate::default()
            },
            policy: SearchPolicy {
                limits: cfg.morph_limits,
                ..SearchPolicy::default()
            },
            initial: Architecture::initial(
                cfg.dataset.image,
                cfg.dataset.channels,
                cfg.dataset.num_classes,
            ),
            total_units: cfg.total_subshards(),
        }
    }

    /// Timing model of a node group.
    pub fn timing(&self, group: usize) -> &TimingModel {
        &self.timings[group]
    }

    /// Fully-specified node model of a node group.
    pub fn node(&self, group: usize) -> &crate::cluster::NodeModel {
        &self.timings[group].node
    }
}

/// Frozen view of the shared historical model list, rebuilt at each
/// epoch barrier. `records` is the global record count (drives the NFS
/// read charge exactly like `HistoryList::nfs_bytes`).
#[derive(Default)]
pub struct HistorySnapshot {
    pub ranked: Vec<RankedModel>,
    pub records: u64,
}

/// One sub-shard lane: an independent trial trainer over a slice of the
/// node's GPUs.
struct SubShard {
    /// Globally unique lane index (fixes RNG streams and trial-id
    /// striding; equals the node index when `subshards_per_node` is 1).
    unit: u64,
    /// Devices this lane trains on when running solo.
    gpus: u64,
    round: u64,
    tpe: Tpe,
    rng: Rng,
    tele_rng: Rng,
    dispatcher: Dispatcher,
    trial: Option<ActiveTrial>,
    /// Dispatcher-local id of the in-flight trial.
    current_local: u64,
    /// Seconds per (train + validate) epoch for the current trial, at the
    /// lane's *current* effective width (helpers included).
    epoch_seconds: f64,
    /// Seconds per epoch of this lane's latest trial at its solo width —
    /// the runway estimate the steal scheduler uses (never sped up by
    /// helpers, unlike `epoch_seconds`).
    own_epoch_s: f64,
    /// GPU busy fraction while the current trial trains.
    busy_fraction: f64,
    /// GPU memory utilization fraction for the current trial.
    mem_fraction: f64,
    /// Until when the lane is in inter-trial setup (telemetry dent).
    setup_until: f64,
    /// Epoch generation: bumped whenever the pending `EpochDone` is
    /// superseded (trial start or steal re-timing).
    epoch_gen: u64,
    /// Absolute time of the pending `EpochDone` (barrier-slack metric and
    /// steal re-timing).
    epoch_end_t: f64,
    /// Sibling lanes currently lending this lane their devices.
    helpers: Vec<usize>,
    /// `Some(victim)` while this lane's devices are lent to a sibling.
    assisting: Option<usize>,
}

/// One slave node's complete simulation state: `k` sub-shard lanes over
/// a shared event queue, candidate buffer, and NFS accounting.
pub struct SlaveShard {
    pub node: usize,
    /// Topology group this node belongs to (selects its device model).
    pub group: usize,
    queue: EventQueue<ShardEvent>,
    buffer: ArchBuffer,
    pub nfs: NfsStats,
    /// Seed-derived stream ordering the steal scheduler's victim scan.
    steal_rng: Rng,
    work_stealing: bool,
    /// Steal events performed by this node's lanes (report counter).
    pub steals: u64,
    /// Candidates skipped because no batch size fit the accelerator.
    pub oom_skips: u64,
    subs: Vec<SubShard>,
    /// Window outputs, drained by the coordinator at each barrier.
    pub completed: Vec<ModelRecord>,
    pub epoch_ops: Vec<(f64, f64)>,
    pub readings: Vec<(f64, NodeReading)>,
}

impl SlaveShard {
    /// A fresh shard for `node` in topology group `group`, with its
    /// stream-derived RNGs and the SLURM-stagger initial schedule. The
    /// node's GPUs split evenly across `cfg.group_subshards(group)`
    /// lanes (validation requires divisibility).
    pub fn new(node: usize, group: usize, cfg: &BenchmarkConfig) -> Self {
        let k = cfg.group_subshards(group).max(1) as usize;
        let g = &cfg.topology.groups[group];
        let lane_gpus = (g.gpus_per_node / k as u64).max(1);
        let unit0 = cfg.subshard_base(group, node);
        let mut queue = EventQueue::new();
        let mut subs = Vec::with_capacity(k);
        for s in 0..k {
            let unit = unit0 + s as u64;
            // Asynchronous dispatch: SLURM stagger of a few seconds per
            // lane (per node in the classic one-lane layout).
            queue.schedule(unit as f64 * 2.0, ShardEvent::NodeReady { sub: s });
            subs.push(SubShard {
                unit,
                gpus: lane_gpus,
                round: 0,
                tpe: Tpe::new(aiperf_space()),
                rng: derive(cfg.seed, "slave", unit),
                tele_rng: derive(cfg.seed, "telemetry", unit),
                dispatcher: Dispatcher::new(),
                trial: None,
                current_local: 0,
                epoch_seconds: 0.0,
                own_epoch_s: 0.0,
                busy_fraction: 0.0,
                mem_fraction: 0.0,
                setup_until: 0.0,
                epoch_gen: 0,
                epoch_end_t: 0.0,
                helpers: Vec::new(),
                assisting: None,
            });
        }
        for s in 0..k {
            queue.schedule(cfg.telemetry_interval_s, ShardEvent::Telemetry { sub: s });
        }
        SlaveShard {
            node,
            group,
            queue,
            // Per-shard buffer: the search loop pushes one candidate and
            // the trainer drains it within the same NodeReady event, so a
            // small constant capacity captures the actual invariant.
            buffer: ArchBuffer::new(4),
            nfs: NfsStats::default(),
            steal_rng: derive(cfg.seed, "steal", node as u64),
            work_stealing: cfg.work_stealing,
            steals: 0,
            oom_skips: 0,
            subs,
            completed: Vec::new(),
            epoch_ops: Vec::new(),
            readings: Vec::new(),
        }
    }

    /// Number of sub-shard lanes on this node.
    pub fn subshard_count(&self) -> usize {
        self.subs.len()
    }

    /// Trials completed across all lanes (report counter).
    pub fn total_completed(&self) -> u64 {
        self.subs.iter().map(|s| s.dispatcher.total_completed()).sum()
    }

    /// Per-lane barrier overshoot at a window boundary: how far each
    /// solo lane's in-flight epoch extends past the barrier — the time
    /// by which this lane alone would stretch a synchronous epoch
    /// barrier. Lanes currently lending their devices are not samples
    /// (their work is accounted on the victim lane); idle lanes sample
    /// as zero.
    pub fn barrier_overshoots(&self, window_end: f64) -> Vec<f64> {
        self.subs
            .iter()
            .filter(|s| s.assisting.is_none())
            .map(|s| {
                if s.trial.is_some() {
                    (s.epoch_end_t - window_end).max(0.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Advance this shard's local event loop up to (and including)
    /// `window_end`. Events past the benchmark duration stay unpopped.
    pub fn run_until(&mut self, window_end: f64, snapshot: &HistorySnapshot, ctx: &SimContext) {
        while let Some(t) = self.queue.peek_time() {
            if t > window_end {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            match ev {
                ShardEvent::NodeReady { sub } => self.on_node_ready(t, sub, snapshot, ctx),
                ShardEvent::EpochDone { sub, gen } => self.on_epoch_done(t, sub, gen, ctx),
                ShardEvent::Telemetry { sub } => self.on_telemetry(t, sub, ctx),
            }
        }
    }

    /// The steal scheduler: when `sub` has no runway for another full
    /// epoch before the benchmark deadline, attach it to the most-loaded
    /// sibling lane's trial instead of starting a doomed one. Returns
    /// `true` when the lane was lent out.
    fn try_steal(&mut self, t: f64, sub: usize, ctx: &SimContext) -> bool {
        if !self.work_stealing || self.subs.len() < 2 {
            return false;
        }
        let cfg = ctx.cfg;
        // Runway estimate: this lane's latest solo epoch duration. A lane
        // that never trained yet (run start) has no estimate and must
        // start a real trial.
        let est = self.subs[sub].own_epoch_s;
        if est <= 0.0 {
            return false;
        }
        let host = &ctx.node(self.group).host;
        if t + host.search_seconds + host.setup_seconds + est <= cfg.duration_s {
            return false;
        }
        // Victim scan in a fixed seed-derived rotation; the most-loaded
        // sibling (largest projected remaining trial work) wins, with the
        // rotation deciding ties deterministically.
        let k = self.subs.len();
        let start = self.steal_rng.gen_range_usize(0, k);
        let mut best: Option<(usize, f64)> = None;
        for j in 0..k {
            let i = (start + j) % k;
            if i == sub {
                continue;
            }
            let s = &self.subs[i];
            let Some(trial) = s.trial.as_ref() else {
                continue;
            };
            let remaining_epochs = trial.epoch_budget.saturating_sub(trial.epoch + 1) as f64;
            let load = (s.epoch_end_t - t).max(0.0) + remaining_epochs * s.epoch_seconds;
            let better = match best {
                None => true,
                Some((_, l)) => load > l,
            };
            if better {
                best = Some((i, load));
            }
        }
        let Some((victim, _)) = best else {
            return false;
        };

        // Attach: the thief's devices join the victim trial's allreduce
        // ring (all lanes of a node share its NVLink domain).
        self.subs[victim].helpers.push(sub);
        self.subs[sub].assisting = Some(victim);
        self.steals += 1;

        // Re-time the victim's epochs at the widened data-parallel span.
        let helper_gpus: u64 = self.subs[victim]
            .helpers
            .iter()
            .map(|&h| self.subs[h].gpus)
            .sum();
        let gpus_eff = self.subs[victim].gpus + helper_gpus;
        let (train_ops, val_ops, params, batch) = {
            let tr = self.subs[victim].trial.as_ref().expect("victim has a trial");
            (
                tr.ops.train_per_image(),
                tr.ops.val_per_image(),
                tr.params,
                tr.batch_per_gpu,
            )
        };
        let timing = ctx.timing(self.group);
        let epoch = timing.epoch_with_gpus(
            train_ops,
            params,
            cfg.dataset.train_images,
            batch,
            gpus_eff,
        );
        let val_s = timing.validation_with_gpus(val_ops, cfg.dataset.val_images, batch, gpus_eff);
        let new_epoch_s = epoch.total_s + val_s;
        let old_epoch_s = self.subs[victim].epoch_seconds;
        // Only the compute portion of the victim's in-flight epoch speeds
        // up with extra devices; any leftover search/NFS setup (a first
        // epoch stolen mid-setup) is width-independent and keeps its
        // original duration.
        let remaining = (self.subs[victim].epoch_end_t - t).max(0.0);
        let setup_left = (self.subs[victim].setup_until - t).max(0.0).min(remaining);
        let compute_left = remaining - setup_left;
        let scaled = setup_left
            + if old_epoch_s > 0.0 {
                compute_left * new_epoch_s / old_epoch_s
            } else {
                compute_left
            };
        let v = &mut self.subs[victim];
        v.epoch_seconds = new_epoch_s;
        v.busy_fraction =
            (epoch.compute_s + val_s) / new_epoch_s * epoch.gpu_busy_fraction.max(0.9);
        v.epoch_gen += 1;
        v.epoch_end_t = t + scaled;
        let gen = v.epoch_gen;
        let (busy, mem) = (v.busy_fraction, v.mem_fraction);
        self.queue
            .schedule(t + scaled, ShardEvent::EpochDone { sub: victim, gen });
        // The helper lane's telemetry mirrors the trial it joined.
        let me = &mut self.subs[sub];
        me.busy_fraction = busy;
        me.mem_fraction = mem;
        me.setup_until = t;
        true
    }

    /// The CPU search loop + trial start (paper §4.3 steps 3–5), or a
    /// steal when the lane is out of runway.
    fn on_node_ready(&mut self, t: f64, sub: usize, snapshot: &HistorySnapshot, ctx: &SimContext) {
        if self.subs[sub].trial.is_some() || self.subs[sub].assisting.is_some() {
            return; // defensive: lane already busy
        }
        if self.try_steal(t, sub, ctx) {
            return;
        }
        let cfg = ctx.cfg;
        self.subs[sub].round += 1;
        let round = self.subs[sub].round;

        // --- CPU search loop: propose a candidate into the buffer. The
        // lane ranks the frozen global snapshot plus its node's own
        // completions since the last barrier (a node always sees its own
        // results). The snapshot is only cloned when there are local
        // completions to append — the common case borrows it directly.
        let arch = if snapshot.ranked.is_empty() && self.completed.is_empty() {
            ctx.initial.clone()
        } else if self.completed.is_empty() {
            ctx.policy.propose(&snapshot.ranked, &mut self.subs[sub].rng).0
        } else {
            let mut ranked = snapshot.ranked.clone();
            ranked.extend(self.completed.iter().map(|r| RankedModel {
                arch: r.arch.clone(),
                accuracy: r.accuracy,
            }));
            ctx.policy.propose(&ranked, &mut self.subs[sub].rng).0
        };
        let _ = self.buffer.push(Candidate {
            arch: arch.clone(),
            proposed_by: self.node,
            proposed_at: t,
        });
        // --- Trainer drains the buffer (NFS round trips charged).
        let cand = self.buffer.pop().map(|c| c.arch).unwrap_or(arch);
        let timing = ctx.timing(self.group);
        let node = &timing.node;
        let mut setup = node.host.search_seconds + node.host.setup_seconds;
        let history_bytes = 2048 * (snapshot.records + self.completed.len() as u64);
        setup += timing.nfs.read_seconds(history_bytes, &mut self.nfs);
        setup += timing.nfs.write_seconds(2048, &mut self.nfs);
        setup += timing.nfs.read_seconds(2048, &mut self.nfs);

        // --- Hyperparameters: defaults in warm-up, TPE afterwards.
        let hp = if cfg.warmup.hpo_active(round) {
            let lane = &mut self.subs[sub];
            let c = lane.tpe.suggest(&mut lane.rng);
            HpPoint {
                dropout: c[0],
                kernel: c[1],
            }
        } else {
            HpPoint::default()
        };

        // --- Memory adaption: halve the batch until the model fits this
        // group's accelerator (a 16 GB T4 adapts sooner than a 32 GB
        // V100). When the halving ladder bottoms out without fitting,
        // clamp to the exact largest fitting batch instead of silently
        // simulating an OOM configuration — and when no batch fits at
        // all, skip the candidate (charging the wasted search/setup) and
        // propose a different one.
        let stats = cand.stats(&ctx.weights);
        let (params, act, ops) = (stats.params, stats.activation_elems, stats.ops);
        let batch_cfg = cfg.group_batch(self.group);
        let mut batch = batch_cfg;
        while batch > 8 && !node.gpu.fits(params, act, batch) {
            batch /= 2;
        }
        if !node.gpu.fits(params, act, batch) {
            match node.gpu.max_fitting_batch(params, act) {
                Some(b) => batch = b.min(batch_cfg),
                None => {
                    self.oom_skips += 1;
                    self.subs[sub].round -= 1; // the skipped proposal is not a round
                    self.queue.schedule(t + setup, ShardEvent::NodeReady { sub });
                    return;
                }
            }
        }
        let local = match self.subs[sub].dispatcher.assign(self.node) {
            Ok(id) => id,
            Err(_) => return, // defensive: lane already holds a trial
        };
        self.subs[sub].current_local = local;
        // Globally unique, execution-order-independent trial id.
        let trial_id = local * ctx.total_units + self.subs[sub].unit;
        let budget = cfg.warmup.epochs_for_round(round);
        let gpus = self.subs[sub].gpus;
        let epoch = timing.epoch_with_gpus(
            ops.train_per_image(),
            params,
            cfg.dataset.train_images,
            batch,
            gpus,
        );
        let val_s =
            timing.validation_with_gpus(ops.val_per_image(), cfg.dataset.val_images, batch, gpus);
        let total_epoch_s = epoch.total_s + val_s;

        let mem_fraction = (node.gpu.memory_demand(params, act, batch) as f64
            / node.gpu.memory_bytes as f64)
            .min(1.0);
        let lane = &mut self.subs[sub];
        lane.epoch_seconds = total_epoch_s;
        lane.own_epoch_s = total_epoch_s;
        lane.busy_fraction =
            (epoch.compute_s + val_s) / total_epoch_s * epoch.gpu_busy_fraction.max(0.9);
        lane.mem_fraction = mem_fraction;
        lane.setup_until = t + setup;
        lane.trial = Some(ActiveTrial::new(
            trial_id,
            cand.clone(),
            arch_id(&cand.signature()),
            hp,
            ops,
            batch,
            round,
            budget,
        ));
        lane.epoch_gen += 1;
        lane.epoch_end_t = t + setup + total_epoch_s;
        let gen = lane.epoch_gen;
        self.queue
            .schedule(t + setup + total_epoch_s, ShardEvent::EpochDone { sub, gen });
    }

    /// One finished training epoch: account ops, record accuracy, decide
    /// whether to continue, early-stop, or finalize into the history.
    fn on_epoch_done(&mut self, t: f64, sub: usize, gen: u64, ctx: &SimContext) {
        if gen != self.subs[sub].epoch_gen {
            return; // superseded by a steal re-timing
        }
        let cfg = ctx.cfg;
        let Some(trial) = self.subs[sub].trial.as_mut() else {
            return;
        };
        // Account analytical ops for the finished epoch.
        let epoch_ops = trial.ops.train_per_image() as f64 * cfg.dataset.train_images as f64
            + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64;
        self.epoch_ops.push((t, epoch_ops));

        let acc = ctx.surrogate.accuracy(
            trial.arch_id,
            trial.params,
            &trial.hp,
            trial.epoch + 1,
        );
        let status = trial.record_epoch(acc, cfg.patience, cfg.min_delta);
        let next_epoch_end = t + self.subs[sub].epoch_seconds;

        if status == TrialStatus::Continue && next_epoch_end <= cfg.duration_s {
            self.subs[sub].epoch_end_t = next_epoch_end;
            self.queue
                .schedule(next_epoch_end, ShardEvent::EpochDone { sub, gen });
        } else {
            // --- Trial complete: record into the window output.
            let trial = self.subs[sub].trial.take().unwrap();
            let warmup_round = !cfg.warmup.hpo_active(trial.round);
            let (accuracy, predicted) = if warmup_round
                && trial.epoch < cfg.warmup.max_epochs
                && trial.accs.len() >= 2
            {
                // Appendix C: conservative log-fit prediction.
                let (es, accs) = trial.curve();
                (LogFit::fit(&es, &accs).conservative(60.0), true)
            } else {
                (trial.best_accuracy(), false)
            };
            let ops_spent = (trial.ops.train_per_image() as f64
                * cfg.dataset.train_images as f64
                + trial.ops.val_per_image() as f64 * cfg.dataset.val_images as f64)
                * trial.epoch as f64;
            if cfg.warmup.hpo_active(trial.round) {
                let lane = &mut self.subs[sub];
                lane.tpe.observe(
                    vec![trial.hp.dropout, trial.hp.kernel],
                    1.0 - trial.best_accuracy(),
                );
            }
            self.completed.push(ModelRecord {
                id: trial.trial_id,
                signature: trial.arch.signature(),
                params: trial.params,
                measured_accuracy: trial.best_accuracy(),
                arch: trial.arch,
                accuracy,
                predicted,
                node: self.node,
                round: trial.round,
                epochs_trained: trial.epoch,
                ops: ops_spent,
                dropout: trial.hp.dropout,
                kernel: trial.hp.kernel,
                completed_at: t,
            });
            let local = self.subs[sub].current_local;
            let _ = self.subs[sub].dispatcher.complete(local, self.node);
            debug_assert!(self.subs[sub].dispatcher.check_invariants().is_ok());
            // Release any helper lanes back to their own search loops
            // before this lane reschedules itself.
            let helpers: Vec<usize> = std::mem::take(&mut self.subs[sub].helpers);
            for h in helpers {
                self.subs[h].assisting = None;
                self.queue.schedule(t, ShardEvent::NodeReady { sub: h });
            }
            self.queue.schedule(t, ShardEvent::NodeReady { sub });
        }
    }

    /// One telemetry tick: sample this lane's utilization (per-lane jitter
    /// stream keeps the readings engine-independent). A lane lending its
    /// devices to a sibling trial reads as busy with that trial's
    /// fractions.
    fn on_telemetry(&mut self, t: f64, sub: usize, ctx: &SimContext) {
        let cfg = ctx.cfg;
        let host = &ctx.node(self.group).host;
        let lane = &mut self.subs[sub];
        let training =
            (lane.trial.is_some() || lane.assisting.is_some()) && t >= lane.setup_until;
        let jitter = lane.tele_rng.gen_range_f64(-0.02, 0.02);
        let reading = if training {
            NodeReading {
                gpu_util: (lane.busy_fraction + jitter).clamp(0.0, 1.0),
                gpu_mem_util: lane.mem_fraction.clamp(0.0, 1.0),
                cpu_util: (host.cpu_util_training() + jitter / 4.0).clamp(0.0, 1.0),
                host_mem_util: host.host_memory_util(30 << 30),
            }
        } else {
            // The inter-stage "dent" of Figs 9/10.
            NodeReading {
                gpu_util: (0.02 + jitter.abs()).min(0.1),
                gpu_mem_util: 0.10,
                cpu_util: (0.30 + jitter).clamp(0.0, 1.0), // search burst
                host_mem_util: host.host_memory_util(30 << 30),
            }
        };
        self.readings.push((t, reading));
        if t + cfg.telemetry_interval_s <= cfg.duration_s {
            self.queue
                .schedule(t + cfg.telemetry_interval_s, ShardEvent::Telemetry { sub });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_for(cfg: &BenchmarkConfig) -> SimContext<'_> {
        SimContext::new(cfg)
    }

    #[test]
    fn shard_is_deterministic_and_snapshot_driven() {
        let mut cfg = BenchmarkConfig::homogeneous(2);
        cfg.duration_s = 4.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let run = || {
            let mut s = SlaveShard::new(0, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            (
                s.completed.len(),
                s.epoch_ops.len(),
                s.readings.len(),
                s.completed.iter().map(|r| r.accuracy).collect::<Vec<_>>(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.0 > 0, "no trials completed in 4 h");
        assert!(a.1 > 0);
        assert!(a.2 > 0);
    }

    #[test]
    fn windowed_run_equals_single_window() {
        let mut cfg = BenchmarkConfig::homogeneous(1);
        cfg.duration_s = 3.0 * 3600.0;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        // Without barrier merges (snapshot never refreshed), splitting the
        // run into windows must not change anything.
        let mut whole = SlaveShard::new(0, 0, &cfg);
        whole.run_until(cfg.duration_s, &snapshot, &ctx);
        let mut split = SlaveShard::new(0, 0, &cfg);
        let mut t = 600.0;
        while t < cfg.duration_s {
            split.run_until(t, &snapshot, &ctx);
            t += 600.0;
        }
        split.run_until(cfg.duration_s, &snapshot, &ctx);
        assert_eq!(whole.completed.len(), split.completed.len());
        assert_eq!(whole.epoch_ops, split.epoch_ops);
        assert_eq!(
            whole.readings.iter().map(|r| r.0).collect::<Vec<_>>(),
            split.readings.iter().map(|r| r.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn trial_ids_unique_per_lane_stride() {
        let mut cfg = BenchmarkConfig::homogeneous(3);
        cfg.duration_s = 6.0 * 3600.0;
        cfg.subshards_per_node = 2;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut ids = Vec::new();
        for node in 0..3 {
            let mut s = SlaveShard::new(node, 0, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            assert_eq!(s.subshard_count(), 2);
            ids.extend(s.completed.iter().map(|r| r.id));
        }
        let mut deduped = ids.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), ids.len(), "trial ids collide across lanes");
    }

    #[test]
    fn groups_with_different_gpus_diverge() {
        // Different device model ⇒ different trial timings and counts
        // (the hardware gap dominates any RNG-stream variance).
        use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
        let cfg = BenchmarkConfig {
            duration_s: 4.0 * 3600.0,
            batch_per_gpu: 256,
            topology: ClusterTopology {
                groups: vec![
                    NodeGroup::new("t4", 1, 8, GpuModel::t4()),
                    NodeGroup::new("ascend", 1, 8, GpuModel::ascend910()),
                ],
            },
            ..BenchmarkConfig::default()
        };
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let ops_of = |group: usize, node: usize| {
            let mut s = SlaveShard::new(node, group, &cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            s.epoch_ops.iter().map(|e| e.1).sum::<f64>()
        };
        let slow = ops_of(0, 0);
        let fast = ops_of(1, 1);
        assert!(
            fast > 2.0 * slow,
            "ascend shard should finish far more epochs: t4={slow:e} ascend={fast:e}"
        );
    }

    #[test]
    fn subshard_lanes_train_concurrently() {
        // Two lanes over half the GPUs each: both make progress, the
        // node's total epoch-ops rate stays in the same ballpark as the
        // one-lane layout, and more architectures are explored.
        let mut one = BenchmarkConfig::homogeneous(1);
        one.duration_s = 6.0 * 3600.0;
        let mut two = one.clone();
        two.subshards_per_node = 2;
        let snapshot = HistorySnapshot::default();
        let run = |cfg: &BenchmarkConfig| {
            let ctx = ctx_for(cfg);
            let mut s = SlaveShard::new(0, 0, cfg);
            s.run_until(cfg.duration_s, &snapshot, &ctx);
            (
                s.epoch_ops.iter().map(|e| e.1).sum::<f64>(),
                s.total_completed(),
                s.subshard_count(),
            )
        };
        let (ops1, done1, k1) = run(&one);
        let (ops2, done2, k2) = run(&two);
        assert_eq!((k1, k2), (1, 2));
        assert!(done1 > 0 && done2 > 0);
        assert!(
            ops2 > 0.4 * ops1 && ops2 < 2.5 * ops1,
            "sub-sharding should not change aggregate throughput wildly: {ops1:e} vs {ops2:e}"
        );
    }

    #[test]
    fn work_stealing_off_by_default_and_lanes_balanced() {
        let mut cfg = BenchmarkConfig::homogeneous(1);
        cfg.duration_s = 4.0 * 3600.0;
        cfg.subshards_per_node = 2;
        let ctx = ctx_for(&cfg);
        let snapshot = HistorySnapshot::default();
        let mut s = SlaveShard::new(0, 0, &cfg);
        s.run_until(cfg.duration_s, &snapshot, &ctx);
        assert_eq!(s.steals, 0, "stealing must be opt-in");
        // Barrier overshoots report one sample per solo lane.
        assert_eq!(s.barrier_overshoots(cfg.duration_s).len(), 2);
    }
}
