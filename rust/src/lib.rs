//! AIPerf — Automated machine learning as an AI-HPC benchmark.
//!
//! Rust + JAX + Pallas reproduction of Ren et al. (2020), arXiv:2008.07141.
//!
//! The crate is the Layer-3 coordinator of the three-layer stack described
//! in DESIGN.md: it implements the paper's benchmark framework (master–slave
//! AutoML orchestration, analytical FLOPS measurement, regulated score)
//! plus every substrate the paper depends on (network-morphism NAS, TPE
//! HPO, a discrete-event cluster simulator standing in for the 16×8-V100
//! testbed, and a PJRT runtime that executes the AOT-compiled JAX/Pallas
//! training step for the real end-to-end path).

#![forbid(unsafe_code)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distributed;
pub mod flops;
pub mod hpo;
pub mod metrics;
pub mod nas;
pub mod predict;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenarios;
pub mod sim;
pub mod util;
