//! Persistent deterministic worker pool for epoch-barrier window
//! execution.
//!
//! The coordinator's window loop used to spawn a fresh
//! `std::thread::scope` per window and rebuild its batch/Mutex
//! scaffolding each time — O(windows) thread churn on top of the
//! O(lanes × windows) sweep cost. This module keeps one set of workers
//! alive for the whole run, parked on a condvar between windows, and
//! hands them only the *active* item indices for each window.
//!
//! Determinism is preserved by construction: each item is advanced
//! independently under its own lock (a worker never observes another
//! item's state), batch claiming through the atomic counter only
//! affects *which thread* runs an item, never the item's inputs, and
//! the caller merges results in index order afterwards via
//! [`WindowPool::with_items`]. With `workers == 0` the same entry
//! points run inline on the calling thread, so the sequential engine
//! exercises the identical active-set code path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// The work closure: advance one item up to `window_end` under the
/// frozen per-window payload (e.g. a history snapshot + sim context).
type RunFn<'p, T, J> = &'p (dyn Fn(&mut T, f64, &J) + Sync);

/// One window's worth of work, shared read-only with every worker.
struct WindowJob<J> {
    window_end: f64,
    payload: J,
    /// Active item indices for this window, in ascending order. Items
    /// not listed here are not touched at all.
    active: Vec<usize>,
    /// Contiguous range size each `fetch_add` claim takes.
    batch: usize,
    next: AtomicUsize,
}

struct Slot<J> {
    /// Bumped once per published job; workers compare against their
    /// last-seen generation to detect fresh work.
    gen: u64,
    job: Option<Arc<WindowJob<J>>>,
    shutdown: bool,
}

struct Shared<J> {
    slot: Mutex<Slot<J>>,
    work_cv: Condvar,
    /// Count of workers that have finished the current job.
    done: Mutex<usize>,
    done_cv: Condvar,
}

/// Increments the done counter when dropped — on the normal path and
/// during unwinding alike, so a panicking worker can never leave the
/// master parked on `done_cv` forever.
struct DoneGuard<'a, J> {
    shared: &'a Shared<J>,
}

impl<J> Drop for DoneGuard<'_, J> {
    fn drop(&mut self) {
        let mut done = match self.shared.done.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *done += 1;
        self.shared.done_cv.notify_all();
    }
}

/// Sets the shutdown flag when dropped, so workers exit and the scope
/// can join even if the master's body panics mid-run.
struct ShutdownGuard<'a, J> {
    shared: &'a Shared<J>,
}

impl<J> Drop for ShutdownGuard<'_, J> {
    fn drop(&mut self) {
        let mut slot = match self.shared.slot.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        slot.shutdown = true;
        self.shared.work_cv.notify_all();
    }
}

fn worker_loop<T, J>(cells: &[Mutex<T>], run: RunFn<'_, T, J>, shared: &Shared<J>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().expect("pool slot poisoned");
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.gen != last_gen {
                    if let Some(job) = slot.job.as_ref() {
                        last_gen = slot.gen;
                        break Arc::clone(job);
                    }
                }
                slot = shared.work_cv.wait(slot).expect("pool slot poisoned");
            }
        };
        let done = DoneGuard { shared };
        loop {
            let start = job.next.fetch_add(job.batch, Ordering::Relaxed);
            if start >= job.active.len() {
                break;
            }
            let end = (start + job.batch).min(job.active.len());
            for &idx in &job.active[start..end] {
                let mut item = cells[idx].lock().expect("pool item poisoned");
                run(&mut item, job.window_end, &job.payload);
            }
        }
        // Release this worker's handle on the job (and its payload —
        // typically an Arc-shared snapshot) *before* signalling done,
        // so the master sees the payload fully released when it starts
        // merging.
        drop(job);
        drop(done);
    }
}

/// Handle the master uses inside [`with_pool`]'s body to drive windows.
pub struct WindowPool<'p, T, J> {
    cells: &'p [Mutex<T>],
    run: RunFn<'p, T, J>,
    shared: &'p Shared<J>,
    workers: usize,
}

impl<T, J> WindowPool<'_, T, J> {
    /// Number of worker threads (0 means windows run inline on the
    /// calling thread).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run one window: every index in `active` (ascending) has its item
    /// advanced to `window_end` via the pool's run closure; all other
    /// items are untouched. Blocks until the window is fully executed.
    pub fn run_window(&mut self, window_end: f64, payload: J, active: Vec<usize>) {
        if active.is_empty() {
            return;
        }
        if self.workers == 0 {
            // Sequential engine: identical filter, no threads.
            for &idx in &active {
                let mut item = self.cells[idx].lock().expect("pool item poisoned");
                (self.run)(&mut item, window_end, &payload);
            }
            return;
        }
        let batch = (active.len() / (self.workers * 4)).max(1);
        let job = Arc::new(WindowJob {
            window_end,
            payload,
            active,
            batch,
            next: AtomicUsize::new(0),
        });
        {
            let mut slot = self.shared.slot.lock().expect("pool slot poisoned");
            slot.gen += 1;
            slot.job = Some(job);
            self.shared.work_cv.notify_all();
        }
        let mut done = self.shared.done.lock().expect("pool done poisoned");
        while *done < self.workers {
            done = self.shared.done_cv.wait(done).expect("pool done poisoned");
        }
        *done = 0;
        drop(done);
        // Drop the master-side job handle so the payload is gone before
        // the caller's merge phase mutates shared state.
        self.shared.slot.lock().expect("pool slot poisoned").job = None;
    }

    /// Lock every item and hand them to `f` as a dense `&mut` slice in
    /// index order — the master's barrier phase (merge, scheduler pass,
    /// dormancy-index refresh) runs here, with no window in flight.
    pub fn with_items<R>(&mut self, f: impl FnOnce(&mut [&mut T]) -> R) -> R {
        let mut guards: Vec<MutexGuard<'_, T>> = self
            .cells
            .iter()
            .map(|m| m.lock().expect("pool item poisoned"))
            .collect();
        let mut refs: Vec<&mut T> = guards.iter_mut().map(|g| &mut **g).collect();
        f(&mut refs)
    }
}

/// Wrap `items` in a persistent worker pool for the duration of `body`.
///
/// Spawns `workers` long-lived threads (none if `workers == 0`), runs
/// `body` with a [`WindowPool`] handle, then shuts the workers down and
/// returns the items (moved back out of their locks) together with the
/// body's result. The one `std::thread::scope` spans the entire run —
/// no per-window spawn/join.
pub fn with_pool<T, J, R>(
    items: Vec<T>,
    workers: usize,
    run: impl Fn(&mut T, f64, &J) + Sync,
    body: impl FnOnce(&mut WindowPool<'_, T, J>) -> R,
) -> (Vec<T>, R)
where
    T: Send,
    J: Send + Sync,
{
    let cells: Vec<Mutex<T>> = items.into_iter().map(Mutex::new).collect();
    let shared = Shared {
        slot: Mutex::new(Slot {
            gen: 0,
            job: None,
            shutdown: false,
        }),
        work_cv: Condvar::new(),
        done: Mutex::new(0),
        done_cv: Condvar::new(),
    };
    let run_ref: RunFn<'_, T, J> = &run;
    let result = std::thread::scope(|scope| {
        let guard = ShutdownGuard { shared: &shared };
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&cells, run_ref, &shared));
        }
        let mut pool = WindowPool {
            cells: &cells,
            run: run_ref,
            shared: &shared,
            workers,
        };
        let r = body(&mut pool);
        drop(guard);
        r
    });
    let items = cells
        .into_iter()
        .map(|m| m.into_inner().expect("worker panicked while holding an item"))
        .collect();
    (items, result)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Advance = record (window_end, payload) on the touched item.
    fn run_rec(item: &mut Vec<(f64, u32)>, window_end: f64, payload: &u32) {
        item.push((window_end, *payload));
    }

    fn drive(workers: usize) -> Vec<Vec<(f64, u32)>> {
        let items: Vec<Vec<(f64, u32)>> = vec![Vec::new(); 8];
        let (items, ()) = with_pool(items, workers, run_rec, |pool| {
            pool.run_window(10.0, 1, vec![0, 2, 4, 6]);
            pool.run_window(20.0, 2, (0..8).collect());
            pool.run_window(30.0, 3, vec![7]);
            pool.run_window(40.0, 4, Vec::new()); // empty active set: no-op
        });
        items
    }

    #[test]
    fn sequential_and_parallel_touch_identical_items() {
        let seq = drive(0);
        for workers in [1, 3, 8] {
            assert_eq!(drive(workers), seq, "workers={workers}");
        }
        // Skipped items saw nothing in the windows that excluded them.
        assert_eq!(seq[1], vec![(20.0, 2)]);
        assert_eq!(seq[0], vec![(10.0, 1), (20.0, 2)]);
        assert_eq!(seq[7], vec![(20.0, 2), (30.0, 3)]);
    }

    #[test]
    fn with_items_sees_all_items_in_index_order() {
        let items: Vec<usize> = vec![0; 5];
        let (items, sum) = with_pool(
            items,
            2,
            |item: &mut usize, _end, add: &usize| *item += add,
            |pool| {
                pool.run_window(1.0, 10, vec![1, 3]);
                pool.with_items(|all| {
                    for (i, item) in all.iter_mut().enumerate() {
                        **item += i;
                    }
                    all.iter().map(|v| **v).sum::<usize>()
                })
            },
        );
        assert_eq!(items, vec![0, 11, 2, 13, 4]);
        assert_eq!(sum, 30);
    }

    #[test]
    fn workers_persist_across_many_windows() {
        let items: Vec<u64> = vec![0; 16];
        let (items, ()) = with_pool(
            items,
            4,
            |item: &mut u64, _end, _j: &()| *item += 1,
            |pool| {
                for _ in 0..100 {
                    pool.run_window(1.0, (), (0..16).collect());
                }
            },
        );
        assert!(items.iter().all(|&v| v == 100));
    }

    #[test]
    fn items_return_in_original_order() {
        let items: Vec<String> = (0..6).map(|i| format!("item-{i}")).collect();
        let (items, ()) = with_pool(
            items,
            3,
            |_item: &mut String, _end, _j: &()| {},
            |pool| {
                pool.run_window(1.0, (), vec![5, 0, 3]);
            },
        );
        let expect: Vec<String> = (0..6).map(|i| format!("item-{i}")).collect();
        assert_eq!(items, expect);
    }
}
