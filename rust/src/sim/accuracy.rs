//! Learning-curve accuracy surrogate (DESIGN.md §2 substitution).
//!
//! The paper measures real ImageNet validation accuracy of every candidate;
//! training 90-epoch ImageNet runs is a hardware/data gate here, so the
//! simulate path models accuracy with a capacity-aware saturating learning
//! curve:
//!
//!   ceiling(P, hp) = base + gain·(1 − e^(−P/P₀)) − overfit(P) − hpo(hp)
//!   acc(e)         = ceiling · (1 − e^(−e/τ)) + ε(arch, hp, e)
//!
//! Shape guarantees (what Figs 5/7 need): monotone saturating in epochs;
//! increasing in capacity until an overfit knee; a unique optimum in the
//! HPO space at (dropout 0.45, kernel 3) so TPE has something to find; and
//! deterministic per-(architecture, hyperparameter, seed) noise so early
//! stopping and reproducibility behave like a real run. Calibrated so the
//! best reachable error ≈ 22–28 % at 90 epochs — the paper's Fig 5 band
//! (and under its 35 % validity threshold), with early morphs in the
//! 45–70 % range.
//!
//! The *real* accuracy path exists too: `examples/train_e2e.rs` trains the
//! compiled L2/L1 artifacts on the synthetic corpus via PJRT.


use crate::util::rng::splitmix64;

/// Hyperparameters the surrogate is sensitive to (the paper's HPO group 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HpPoint {
    pub dropout: f64,
    pub kernel: f64,
}

impl Default for HpPoint {
    fn default() -> Self {
        // Pre-HPO defaults used during warm-up rounds.
        HpPoint {
            dropout: 0.5,
            kernel: 3.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracySurrogate {
    pub seed: u64,
    /// Accuracy floor of a barely-trained tiny model.
    pub base: f64,
    /// Capacity gain ceiling.
    pub gain: f64,
    /// Capacity scale (parameters) of the saturating gain.
    pub p0: f64,
    /// Overfit knee: parameters beyond which quality degrades (log10 slope).
    pub overfit_knee: f64,
    pub overfit_slope: f64,
    /// Learning-curve time constant, epochs.
    pub tau: f64,
    /// Per-epoch noise amplitude.
    pub noise: f64,
}

impl Default for AccuracySurrogate {
    fn default() -> Self {
        AccuracySurrogate {
            seed: 0,
            base: 0.30,
            gain: 0.48,
            p0: 3.0e6,
            overfit_knee: 3.0e7,
            overfit_slope: 0.06,
            tau: 20.0,
            noise: 0.004,
        }
    }
}

impl AccuracySurrogate {
    /// HPO penalty: quadratic bowls around the optimum (0.45, 3).
    fn hpo_penalty(hp: &HpPoint) -> f64 {
        0.35 * (hp.dropout - 0.45).powi(2) + 0.012 * (hp.kernel - 3.0).powi(2)
    }

    /// Converged accuracy ceiling for an architecture + hyperparameters.
    pub fn ceiling(&self, params: u64, hp: &HpPoint) -> f64 {
        let p = params.max(1) as f64;
        let capacity = self.base + self.gain * (1.0 - (-p / self.p0).exp());
        let overfit = if p > self.overfit_knee {
            self.overfit_slope * (p / self.overfit_knee).log10()
        } else {
            0.0
        };
        (capacity - overfit - Self::hpo_penalty(hp)).clamp(0.01, 0.99)
    }

    /// Deterministic noise for (architecture id, hp, epoch).
    fn eps(&self, arch_id: u64, hp: &HpPoint, epoch: u64) -> f64 {
        let h = splitmix64(
            self.seed
                ^ splitmix64(arch_id)
                ^ splitmix64((hp.dropout * 1e6) as u64)
                ^ splitmix64((hp.kernel * 1e3) as u64)
                ^ splitmix64(epoch.wrapping_mul(0x9E37)),
        );
        // Uniform in [-noise, +noise].
        ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) * self.noise
    }

    /// Validation accuracy after `epoch` epochs of training.
    ///
    /// `arch_id` is a stable hash of the architecture (noise stream key).
    pub fn accuracy(&self, arch_id: u64, params: u64, hp: &HpPoint, epoch: u64) -> f64 {
        assert!(epoch >= 1);
        let c = self.ceiling(params, hp);
        let curve = c * (1.0 - (-(epoch as f64) / self.tau).exp());
        (curve + self.eps(arch_id, hp, epoch)).clamp(0.001, 0.999)
    }

    /// Validation error (1 − accuracy), the paper's Fig 5 quantity.
    pub fn error(&self, arch_id: u64, params: u64, hp: &HpPoint, epoch: u64) -> f64 {
        1.0 - self.accuracy(arch_id, params, hp, epoch)
    }
}

/// Stable architecture id from its signature string.
pub fn arch_id(signature: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64; // FNV offset
    for b in signature.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    splitmix64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sur() -> AccuracySurrogate {
        AccuracySurrogate::default()
    }

    #[test]
    fn monotone_saturating_in_epochs() {
        let s = sur();
        let hp = HpPoint::default();
        let mut prev = 0.0;
        for e in [1u64, 5, 10, 20, 40, 90] {
            // Smooth component only (strip noise by averaging ids).
            let a: f64 = (0..64)
                .map(|i| s.accuracy(i, 25_000_000, &hp, e))
                .sum::<f64>()
                / 64.0;
            assert!(a > prev - 0.002, "epoch {e}: {a} < {prev}");
            prev = a;
        }
        // 90-epoch value close to the ceiling.
        let c = s.ceiling(25_000_000, &hp);
        assert!((prev - c).abs() < 0.02);
    }

    #[test]
    fn capacity_helps_until_overfit() {
        let s = sur();
        let hp = HpPoint::default();
        let small = s.ceiling(50_000, &hp);
        let mid = s.ceiling(25_000_000, &hp);
        let huge = s.ceiling(500_000_000, &hp);
        assert!(small < mid);
        assert!(huge < mid);
    }

    #[test]
    fn best_error_in_paper_band() {
        // Best reachable error at 90 epochs with optimal HPO: 20–30 %.
        let s = sur();
        let hp = HpPoint {
            dropout: 0.45,
            kernel: 3.0,
        };
        let err = s.error(1, 28_000_000, &hp, 90);
        assert!((0.18..0.30).contains(&err), "err={err}");
        // And it satisfies the paper's 35 % validity requirement.
        assert!(err < 0.35);
    }

    #[test]
    fn early_models_much_worse() {
        let s = sur();
        let hp = HpPoint::default();
        let err = s.error(2, 60_000, &hp, 10);
        assert!(err > 0.45, "err={err}");
    }

    #[test]
    fn hpo_optimum_at_paper_point() {
        let s = sur();
        let best = s.ceiling(
            25_000_000,
            &HpPoint {
                dropout: 0.45,
                kernel: 3.0,
            },
        );
        for (d, k) in [(0.2, 3.0), (0.8, 3.0), (0.45, 5.0), (0.45, 2.0)] {
            let c = s.ceiling(25_000_000, &HpPoint { dropout: d, kernel: k });
            assert!(c < best, "({d},{k}) not worse than optimum");
        }
    }

    #[test]
    fn deterministic_per_seed_and_inputs() {
        let s = sur();
        let hp = HpPoint::default();
        assert_eq!(
            s.accuracy(7, 1_000_000, &hp, 30),
            s.accuracy(7, 1_000_000, &hp, 30)
        );
        let s2 = AccuracySurrogate { seed: 1, ..sur() };
        assert_ne!(
            s.accuracy(7, 1_000_000, &hp, 30),
            s2.accuracy(7, 1_000_000, &hp, 30)
        );
    }

    #[test]
    fn noise_bounded() {
        let s = sur();
        let hp = HpPoint::default();
        for id in 0..200u64 {
            let a = s.accuracy(id, 25_000_000, &hp, 90);
            let c = s.ceiling(25_000_000, &hp);
            let clean = c * (1.0 - (-90.0f64 / s.tau).exp());
            assert!((a - clean).abs() <= s.noise + 1e-12);
        }
    }

    #[test]
    fn arch_id_stable_and_distinct() {
        assert_eq!(arch_id("16x2p-32x2p"), arch_id("16x2p-32x2p"));
        assert_ne!(arch_id("16x2p-32x2p"), arch_id("16x3p-32x2p"));
    }
}
