//! Data-parallel training-time model (paper §4.3).
//!
//! Composes the substrate models into per-epoch durations for one slave
//! node training one candidate with synchronous data parallelism
//! (MirroredStrategy across the node's 8 GPUs):
//!
//!   step  = max(compute(batch/gpu), input_pipeline) + allreduce(params)
//!   epoch = ceil(images / global_batch) · step
//!
//! The input pipeline is pipelined with compute (prefetching), so only the
//! slower of the two bounds the step; gradient sync is serialized after
//! compute (the synchronous strategy of §4.3).


use crate::cluster::{GpuModel, NetworkModel, NfsModel, NodeModel};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    pub node: NodeModel,
    pub network: NetworkModel,
    pub nfs: NfsModel,
    /// Decoded bytes per training image (224² RGB fp16 + label overhead).
    pub bytes_per_image: u64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            node: NodeModel::default(),
            network: NetworkModel::default(),
            nfs: NfsModel::default(),
            bytes_per_image: 150_000,
        }
    }
}

/// Per-epoch timing breakdown (for telemetry and the perf report).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpochTiming {
    pub steps: u64,
    pub compute_s: f64,
    pub input_s: f64,
    pub allreduce_s: f64,
    pub total_s: f64,
    /// Fraction of wall time the GPUs spend computing (telemetry basis).
    pub gpu_busy_fraction: f64,
}

impl TimingModel {
    pub fn gpu(&self) -> &GpuModel {
        &self.node.gpu
    }

    /// Duration of one training epoch of `images` images for a model with
    /// `ops_per_image` (train FP+BP) and `params` parameters, at
    /// `batch_per_gpu`, across all of this node's GPUs.
    pub fn epoch(&self, ops_per_image: u64, params: u64, images: u64, batch_per_gpu: u64) -> EpochTiming {
        self.epoch_with_gpus(ops_per_image, params, images, batch_per_gpu, self.node.gpus_per_node)
    }

    /// [`TimingModel::epoch`] over an explicit data-parallel width — the
    /// sub-shard path, where a trial spans a lane of `gpus` devices (a
    /// fraction of the node, or the lane plus stolen helper lanes) rather
    /// than the whole node. The ring stays inside the NVLink domain.
    pub fn epoch_with_gpus(
        &self,
        ops_per_image: u64,
        params: u64,
        images: u64,
        batch_per_gpu: u64,
        gpus: u64,
    ) -> EpochTiming {
        self.epoch_spanning(ops_per_image, params, images, batch_per_gpu, gpus, false)
    }

    /// [`TimingModel::epoch_with_gpus`] with an explicit allreduce link
    /// choice: `crosses_nodes` re-times the trial with its gradient ring
    /// over InfiniBand instead of NVLink — the cross-group migration
    /// path, where a trial adopted by another node group keeps syncing
    /// through the cluster fabric (its candidate state and data pipeline
    /// stay rooted on NFS outside the adopting node's NVLink domain).
    pub fn epoch_spanning(
        &self,
        ops_per_image: u64,
        params: u64,
        images: u64,
        batch_per_gpu: u64,
        gpus: u64,
        crosses_nodes: bool,
    ) -> EpochTiming {
        let gpus = gpus.max(1);
        let global_batch = batch_per_gpu * gpus;
        let steps = images.div_ceil(global_batch).max(1);

        let compute_step = self.node.gpu.step_seconds(ops_per_image, batch_per_gpu);
        let input_step = self
            .nfs
            .epoch_input_seconds(global_batch, self.bytes_per_image, gpus);
        let sync_step = self
            .network
            .gradient_sync_seconds(gpus, params, crosses_nodes);

        let step = compute_step.max(input_step) + sync_step;
        let total = step * steps as f64;
        EpochTiming {
            steps,
            compute_s: compute_step * steps as f64,
            input_s: input_step * steps as f64,
            allreduce_s: sync_step * steps as f64,
            total_s: total,
            gpu_busy_fraction: (compute_step / step).min(1.0),
        }
    }

    /// Duration of one validation epoch (forward only, no sync).
    pub fn validation(&self, fp_per_image: u64, images: u64, batch_per_gpu: u64) -> f64 {
        self.validation_with_gpus(fp_per_image, images, batch_per_gpu, self.node.gpus_per_node)
    }

    /// [`TimingModel::validation`] over an explicit data-parallel width.
    pub fn validation_with_gpus(
        &self,
        fp_per_image: u64,
        images: u64,
        batch_per_gpu: u64,
        gpus: u64,
    ) -> f64 {
        let global_batch = batch_per_gpu * gpus.max(1);
        let steps = images.div_ceil(global_batch).max(1);
        self.node.gpu.step_seconds(fp_per_image, batch_per_gpu) * steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RESNET_TRAIN_OPS: u64 = 23_100_000_000;
    const RESNET_FP_OPS: u64 = 7_810_000_000;
    const RESNET_PARAMS: u64 = 25_600_000;

    #[test]
    fn imagenet_epoch_duration_plausible() {
        // 8 V100s, batch 448/GPU: published ResNet-50 epochs are ~4–10 min.
        let t = TimingModel::default();
        let e = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448);
        assert!(
            (120.0..900.0).contains(&e.total_s),
            "epoch={}s",
            e.total_s
        );
    }

    #[test]
    fn gpu_busy_fraction_high_at_large_batch() {
        let t = TimingModel::default();
        let e = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448);
        assert!(e.gpu_busy_fraction > 0.85, "{}", e.gpu_busy_fraction);
        let small = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 8);
        assert!(small.gpu_busy_fraction < e.gpu_busy_fraction);
    }

    #[test]
    fn validation_cheaper_than_training() {
        let t = TimingModel::default();
        let e = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 50_000, 448);
        let v = t.validation(RESNET_FP_OPS, 50_000, 448);
        assert!(v < e.total_s);
    }

    #[test]
    fn steps_round_up() {
        let t = TimingModel::default();
        // 100 images, global batch 8×448 → 1 step.
        let e = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 100, 448);
        assert_eq!(e.steps, 1);
        let e2 = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 3585, 448);
        assert_eq!(e2.steps, 2);
    }

    #[test]
    fn narrower_lane_trains_slower_wider_lane_faster() {
        // A 4-GPU sub-shard lane halves the global batch: ~2x the steps,
        // ~2x the epoch. A stolen-helper 16-GPU span goes the other way.
        let t = TimingModel::default();
        let full = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448);
        let lane = t.epoch_with_gpus(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 4);
        let wide = t.epoch_with_gpus(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 16);
        assert!(lane.total_s > 1.8 * full.total_s, "lane={} full={}", lane.total_s, full.total_s);
        assert!(wide.total_s < full.total_s);
        // The default-width variant is exactly the classic method.
        let explicit = t.epoch_with_gpus(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 8);
        assert_eq!(full, explicit);
        let v = t.validation(RESNET_FP_OPS, 50_000, 448);
        let v8 = t.validation_with_gpus(RESNET_FP_OPS, 50_000, 448, 8);
        assert_eq!(v.to_bits(), v8.to_bits());
    }

    #[test]
    fn cross_node_ring_slows_the_epoch_by_the_sync_delta() {
        // A migrated trial syncs over IB: strictly slower than the same
        // trial inside the NVLink domain, by exactly the allreduce delta
        // (compute and input are link-independent).
        let t = TimingModel::default();
        let local = t.epoch_spanning(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 4, false);
        let cross = t.epoch_spanning(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 4, true);
        assert!(cross.total_s > local.total_s);
        assert_eq!(cross.steps, local.steps);
        assert_eq!(cross.compute_s.to_bits(), local.compute_s.to_bits());
        assert!(cross.allreduce_s > local.allreduce_s);
        // The NVLink-domain variant is exactly the classic method.
        let classic = t.epoch_with_gpus(RESNET_TRAIN_OPS, RESNET_PARAMS, 1_281_167, 448, 4);
        assert_eq!(local, classic);
    }

    #[test]
    fn heavier_model_slower_epoch() {
        let t = TimingModel::default();
        let light = t.epoch(RESNET_TRAIN_OPS, RESNET_PARAMS, 100_000, 448);
        let heavy = t.epoch(3 * RESNET_TRAIN_OPS, RESNET_PARAMS, 100_000, 448);
        assert!(heavy.total_s > 2.0 * light.total_s);
    }
}
