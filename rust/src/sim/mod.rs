//! Discrete-event simulation substrate.
//!
//! * [`engine`] — a generic deterministic event queue (time-ordered, FIFO
//!   within a timestamp);
//! * [`timing`] — the data-parallel training-time model composing GPU,
//!   network and NFS costs into per-epoch durations;
//! * [`accuracy`] — the learning-curve surrogate standing in for real
//!   ImageNet validation accuracy (DESIGN.md §2 substitution; the *real*
//!   accuracy path is `examples/train_e2e.rs` at toy scale).
//! * [`pool`] — the persistent deterministic worker pool the coordinator
//!   parks between epoch-barrier windows (active-set execution; workers
//!   live for the whole run instead of one `thread::scope` per window).

pub mod accuracy;
pub mod engine;
pub mod pool;
pub mod timing;

pub use accuracy::AccuracySurrogate;
pub use engine::EventQueue;
pub use pool::{with_pool, WindowPool};
pub use timing::TimingModel;
