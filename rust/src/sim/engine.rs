//! Deterministic discrete-event queue.
//!
//! A minimal DES core: events carry an `f64` timestamp; `pop` yields them
//! in time order with FIFO tie-breaking (a monotone sequence number), so
//! simulations are bit-reproducible regardless of insertion pattern.
//!
//! Storage is arena-based: the heap orders small plain-data handles
//! (`time`, `seq`, arena slot) while event payloads live in a slab of
//! recycled slots. Scheduling an event therefore never allocates once the
//! queue reaches its steady-state size — at exascale lane counts the
//! engine pushes hundreds of millions of events through each queue, and
//! per-event boxing/allocation was the dominant hot-path cost.

/// Heap handle: everything the ordering needs, payload stays in the arena.
#[derive(Clone, Copy)]
struct HeapEntry {
    time: f64,
    seq: u64,
    slot: u32,
}

impl HeapEntry {
    /// Strict weak order, earliest first: time then insertion sequence.
    /// `seq` is unique per queue, so two entries never compare equal and
    /// the heap's order is total (times are asserted finite on entry).
    fn earlier(&self, other: &HeapEntry) -> bool {
        match self.time.partial_cmp(&other.time) {
            Some(std::cmp::Ordering::Less) => true,
            Some(std::cmp::Ordering::Greater) => false,
            _ => self.seq < other.seq,
        }
    }
}

/// Time-ordered event queue.
pub struct EventQueue<E> {
    /// Hand-rolled binary min-heap of handles (std's `BinaryHeap` would
    /// need an `Ord` payload wrapper and gives no control over moves of
    /// the payload itself).
    heap: Vec<HeapEntry>,
    /// Slab of event payloads; `None` marks a recyclable slot.
    arena: Vec<Option<E>>,
    /// Free slots awaiting reuse.
    free: Vec<u32>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: Vec::new(),
            arena: Vec::new(),
            free: Vec::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t`. Scheduling in the past is a
    /// logic error.
    pub fn schedule(&mut self, t: f64, event: E) {
        assert!(
            t >= self.now,
            "cannot schedule into the past: {t} < {}",
            self.now
        );
        assert!(t.is_finite(), "non-finite event time");
        let slot = match self.free.pop() {
            Some(s) => {
                self.arena[s as usize] = Some(event);
                s
            }
            None => {
                let s = self.arena.len() as u32;
                self.arena.push(Some(event));
                s
            }
        };
        self.heap.push(HeapEntry {
            time: t,
            seq: self.seq,
            slot,
        });
        self.seq += 1;
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule relative to now.
    pub fn schedule_in(&mut self, dt: f64, event: E) {
        assert!(dt >= 0.0);
        self.schedule(self.now + dt, event);
    }

    /// Timestamp of the earliest pending event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.first().map(|e| e.time)
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        if self.heap.is_empty() {
            return None;
        }
        let root = self.heap.swap_remove(0);
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        self.now = root.time;
        let event = self.arena[root.slot as usize]
            .take()
            .expect("heap handle points at an empty arena slot");
        self.free.push(root.slot);
        Some((root.time, event))
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].earlier(&self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.heap[l].earlier(&self.heap[smallest]) {
                smallest = l;
            }
            if r < n && self.heap[r].earlier(&self.heap[smallest]) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// Arena footprint (occupied + recyclable slots); test hook for the
    /// no-allocation-at-steady-state property.
    #[cfg(test)]
    fn arena_len(&self) -> usize {
        self.arena.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_within_timestamp() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.schedule_in(0.5, ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 2.5);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.0);
    }

    #[test]
    #[should_panic]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(2.0, ());
        q.pop();
        q.schedule(1.0, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule(4.0, ());
        q.schedule(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.peek_time(), Some(4.0));
    }

    #[test]
    fn interleaved_schedule_pop_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut order = Vec::new();
            q.schedule(1.0, 10);
            q.schedule(4.0, 40);
            while let Some((t, e)) = q.pop() {
                order.push(e);
                if e == 10 {
                    q.schedule(t + 1.0, 20);
                    q.schedule(t + 1.0, 21);
                }
            }
            order
        };
        assert_eq!(run(), vec![10, 20, 21, 40]);
        assert_eq!(run(), run());
    }

    #[test]
    fn arena_slots_recycle_at_steady_state() {
        // A schedule/pop ping-pong holding at most 2 pending events must
        // not grow the arena past its high-water mark: slots recycle, so
        // steady-state operation allocates nothing.
        let mut q = EventQueue::new();
        q.schedule(0.0, 0u64);
        q.schedule(0.5, 1u64);
        let high_water = q.arena_len();
        let mut popped = 0u64;
        for i in 2..10_000u64 {
            let (t, _) = q.pop().unwrap();
            popped += 1;
            q.schedule(t + 1.0, i);
        }
        assert_eq!(popped, 9_998);
        assert_eq!(q.len(), 2);
        assert_eq!(
            q.arena_len(),
            high_water,
            "arena grew despite constant pending-event count"
        );
    }

    #[test]
    fn random_order_matches_sorted_replay() {
        // Pseudo-random insertion times must come back exactly sorted
        // (stable within equal timestamps) — cross-checks the hand-rolled
        // sift logic against a plain sort.
        let mut q = EventQueue::new();
        let mut x = 0x2545F4914F6CDD1Du64;
        let mut expect: Vec<(f64, u64)> = Vec::new();
        for i in 0..500u64 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            // Coarse buckets force plenty of timestamp ties.
            let t = (x % 64) as f64;
            q.schedule(t, i);
            expect.push((t, i));
        }
        expect.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut got = Vec::new();
        while let Some(e) = q.pop() {
            got.push(e);
        }
        assert_eq!(got, expect);
    }
}
