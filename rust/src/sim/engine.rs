//! Deterministic discrete-event queue.
//!
//! A minimal DES core: events carry an `f64` timestamp; `pop` yields them
//! in time order with FIFO tie-breaking (a monotone sequence number), so
//! simulations are bit-reproducible regardless of insertion pattern.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t`. Scheduling in the past is a
    /// logic error.
    pub fn schedule(&mut self, t: f64, event: E) {
        assert!(
            t >= self.now,
            "cannot schedule into the past: {t} < {}",
            self.now
        );
        assert!(t.is_finite(), "non-finite event time");
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule relative to now.
    pub fn schedule_in(&mut self, dt: f64, event: E) {
        assert!(dt >= 0.0);
        self.schedule(self.now + dt, event);
    }

    /// Timestamp of the earliest pending event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.event)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_within_timestamp() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.schedule_in(0.5, ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 2.5);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.0);
    }

    #[test]
    #[should_panic]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(2.0, ());
        q.pop();
        q.schedule(1.0, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule(4.0, ());
        q.schedule(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.peek_time(), Some(4.0));
    }

    #[test]
    fn interleaved_schedule_pop_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut order = Vec::new();
            q.schedule(1.0, 10);
            q.schedule(4.0, 40);
            while let Some((t, e)) = q.pop() {
                order.push(e);
                if e == 10 {
                    q.schedule(t + 1.0, 20);
                    q.schedule(t + 1.0, 21);
                }
            }
            order
        };
        assert_eq!(run(), vec![10, 20, 21, 40]);
        assert_eq!(run(), run());
    }
}
