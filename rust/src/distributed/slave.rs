//! Slave worker: the per-node search + train loop (paper §4.3 slave role).
//!
//! Connects to the master, and per work item: reconstructs the ranked
//! history, proposes a morphed candidate on the CPU (rank-softmax parent
//! selection + random legal morph — identical code to the simulated
//! coordinator), evaluates it through the accuracy surrogate with the
//! warm-up epoch schedule and early stopping, and reports the result with
//! its analytical-FLOPs charge. Swap `evaluate` for a PJRT trainer to run
//! real training per trial (the live runner does exactly that in-process).

use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::protocol::{Connection, Message, WireModel};
use crate::config::WarmupSchedule;
use crate::flops::OpWeights;
use crate::nas::graph::{Architecture, Block, Stage};
use crate::nas::search::{RankedModel, SearchPolicy};
use crate::sim::accuracy::{arch_id, AccuracySurrogate, HpPoint};
use crate::util::rng::derive;

/// Slave configuration.
#[derive(Debug, Clone)]
pub struct SlaveWorker {
    pub node: u64,
    pub seed: u64,
    /// Dataset shape the candidates are evaluated against.
    pub image: u64,
    pub channels: u64,
    pub num_classes: u64,
    pub warmup: WarmupSchedule,
    pub patience: u64,
    pub min_delta: f64,
}

impl SlaveWorker {
    pub fn new(node: u64, seed: u64) -> Self {
        SlaveWorker {
            node,
            seed,
            image: 32,
            channels: 3,
            num_classes: 10,
            warmup: WarmupSchedule::default(),
            patience: 5,
            min_delta: 1e-3,
        }
    }

    /// Rebuild a morphable architecture from a wire entry.
    fn rebuild(&self, m: &WireModel) -> Architecture {
        let stages = m
            .widths
            .iter()
            .zip(&m.blocks)
            .enumerate()
            .map(|(i, (&width, &nblocks))| Stage {
                width,
                blocks: vec![
                    Block {
                        kernel: 3,
                        residual: true,
                    };
                    nblocks.max(1) as usize
                ],
                pool_after: i + 1 < m.widths.len(),
            })
            .collect();
        Architecture {
            image: self.image,
            channels: self.channels,
            num_classes: self.num_classes,
            stem_pool: 0,
            stages,
        }
    }

    /// Run until the master says Stop. Returns completed trial count.
    pub fn run(&self, addr: std::net::SocketAddr) -> Result<u64> {
        let stream = TcpStream::connect(addr).context("connecting to master")?;
        let mut conn = Connection::new(stream)?;
        conn.send(&Message::Hello { node: self.node })?;

        let weights = OpWeights::default();
        let policy = SearchPolicy::default();
        let surrogate = AccuracySurrogate {
            seed: self.seed,
            ..AccuracySurrogate::default()
        };
        let mut rng = derive(self.seed, "dist-slave", self.node);
        let mut completed = 0u64;

        loop {
            conn.send(&Message::RequestWork { node: self.node })?;
            let (trial, round, history) = match conn.recv()? {
                Message::Work {
                    trial,
                    round,
                    history,
                } => (trial, round, history),
                Message::Stop => return Ok(completed),
                other => anyhow::bail!("unexpected message: {other:?}"),
            };

            // --- CPU search: propose from the ranked history.
            let arch = if history.is_empty() {
                Architecture::initial(self.image, self.channels, self.num_classes)
            } else {
                let ranked: Vec<RankedModel> = history
                    .iter()
                    .map(|m| RankedModel {
                        arch: Arc::new(self.rebuild(m)),
                        accuracy: m.accuracy,
                        penalty: false,
                        group: 0,
                    })
                    .collect();
                policy.propose(&ranked, &mut rng).0
            };

            // --- Trial: warm-up schedule + early stopping on the surrogate.
            let stats = arch.stats(&weights);
            let budget = self.warmup.epochs_for_round(round);
            let id = arch_id(&arch.signature());
            let hp = HpPoint::default();
            let mut best = 0.0f64;
            let mut stale = 0u64;
            let mut epochs = 0u64;
            for e in 1..=budget {
                let acc = surrogate.accuracy(id, stats.params, &hp, e);
                epochs = e;
                if acc > best + self.min_delta {
                    best = acc;
                    stale = 0;
                } else {
                    stale += 1;
                    if stale >= self.patience {
                        break;
                    }
                }
            }
            // Analytical op charge: train + validate per epoch on the
            // CIFAR-scale dataset (50k/10k images).
            let ops = (stats.ops.train_per_image() as f64 * 50_000.0
                + stats.ops.val_per_image() as f64 * 10_000.0)
                * epochs as f64;

            conn.send(&Message::Result {
                node: self.node,
                trial,
                signature: arch.signature(),
                accuracy: best,
                error: 1.0 - best,
                params: stats.params,
                ops,
                epochs,
                widths: arch.stages.iter().map(|s| s.width).collect(),
                blocks: arch.stages.iter().map(|s| s.blocks.len() as u64).collect(),
            })?;
            completed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebuild_roundtrips_signature() {
        let w = SlaveWorker::new(0, 0);
        let arch = Architecture::initial(32, 3, 10);
        let wire = WireModel {
            signature: arch.signature(),
            accuracy: 0.5,
            widths: arch.stages.iter().map(|s| s.width).collect(),
            blocks: arch.stages.iter().map(|s| s.blocks.len() as u64).collect(),
        };
        let rebuilt = w.rebuild(&wire);
        assert_eq!(rebuilt.signature(), arch.signature());
        rebuilt.validate().unwrap();
    }
}
