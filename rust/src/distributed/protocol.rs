//! Wire protocol between the master and slave workers.
//!
//! Newline-delimited JSON messages. The history snapshot travels as
//! (signature, accuracy, depth, widths) tuples — enough for the slave's
//! rank-softmax parent selection without shipping full layer graphs.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// A ranked-history entry compact enough for the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct WireModel {
    pub signature: String,
    pub accuracy: f64,
    /// Stage widths — enough to reconstruct a morphable architecture.
    pub widths: Vec<u64>,
    pub blocks: Vec<u64>,
}

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Slave → master: join the cluster.
    Hello { node: u64 },
    /// Slave → master: ready for the next trial.
    RequestWork { node: u64 },
    /// Master → slave: run one trial. Carries the trial id, the node's
    /// round number, and the current ranked history.
    Work {
        trial: u64,
        round: u64,
        history: Vec<WireModel>,
    },
    /// Master → slave: budget exhausted, disconnect.
    Stop,
    /// Slave → master: trial finished.
    Result {
        node: u64,
        trial: u64,
        signature: String,
        accuracy: f64,
        error: f64,
        params: u64,
        ops: f64,
        epochs: u64,
        widths: Vec<u64>,
        blocks: Vec<u64>,
    },
}

fn u64s(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("missing/invalid `{key}`"))
}

fn f64s(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing/invalid `{key}`"))
}

fn strs(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing/invalid `{key}`"))?
        .to_string())
}

fn u64_arr(j: &Json, key: &str) -> Result<Vec<u64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing `{key}`"))?
        .iter()
        .map(|v| v.as_u64().context("non-integer array element"))
        .collect()
}

impl Message {
    pub fn to_json(&self) -> Json {
        match self {
            Message::Hello { node } => obj(vec![("t", s("hello")), ("node", num(*node as f64))]),
            Message::RequestWork { node } => {
                obj(vec![("t", s("request")), ("node", num(*node as f64))])
            }
            Message::Stop => obj(vec![("t", s("stop"))]),
            Message::Work {
                trial,
                round,
                history,
            } => obj(vec![
                ("t", s("work")),
                ("trial", num(*trial as f64)),
                ("round", num(*round as f64)),
                (
                    "history",
                    arr(history
                        .iter()
                        .map(|m| {
                            obj(vec![
                                ("sig", s(m.signature.clone())),
                                ("acc", num(m.accuracy)),
                                (
                                    "widths",
                                    arr(m.widths.iter().map(|w| num(*w as f64)).collect()),
                                ),
                                (
                                    "blocks",
                                    arr(m.blocks.iter().map(|b| num(*b as f64)).collect()),
                                ),
                            ])
                        })
                        .collect()),
                ),
            ]),
            Message::Result {
                node,
                trial,
                signature,
                accuracy,
                error,
                params,
                ops,
                epochs,
                widths,
                blocks,
            } => obj(vec![
                ("t", s("result")),
                ("node", num(*node as f64)),
                ("trial", num(*trial as f64)),
                ("sig", s(signature.clone())),
                ("acc", num(*accuracy)),
                ("err", num(*error)),
                ("params", num(*params as f64)),
                ("ops", num(*ops)),
                ("epochs", num(*epochs as f64)),
                ("widths", arr(widths.iter().map(|w| num(*w as f64)).collect())),
                ("blocks", arr(blocks.iter().map(|b| num(*b as f64)).collect())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Message> {
        let t = strs(j, "t")?;
        Ok(match t.as_str() {
            "hello" => Message::Hello {
                node: u64s(j, "node")?,
            },
            "request" => Message::RequestWork {
                node: u64s(j, "node")?,
            },
            "stop" => Message::Stop,
            "work" => {
                let history = j
                    .get("history")
                    .and_then(Json::as_arr)
                    .context("missing history")?
                    .iter()
                    .map(|m| {
                        Ok(WireModel {
                            signature: strs(m, "sig")?,
                            accuracy: f64s(m, "acc")?,
                            widths: u64_arr(m, "widths")?,
                            blocks: u64_arr(m, "blocks")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Message::Work {
                    trial: u64s(j, "trial")?,
                    round: u64s(j, "round")?,
                    history,
                }
            }
            "result" => Message::Result {
                node: u64s(j, "node")?,
                trial: u64s(j, "trial")?,
                signature: strs(j, "sig")?,
                accuracy: f64s(j, "acc")?,
                error: f64s(j, "err")?,
                params: u64s(j, "params")?,
                ops: f64s(j, "ops")?,
                epochs: u64s(j, "epochs")?,
                widths: u64_arr(j, "widths")?,
                blocks: u64_arr(j, "blocks")?,
            },
            other => bail!("unknown message type `{other}`"),
        })
    }
}

/// Framed connection: one JSON message per line.
pub struct Connection {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Connection {
    pub fn new(stream: TcpStream) -> Result<Self> {
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Connection {
            reader,
            writer: stream,
        })
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        let mut line = msg.to_json().to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("send")?;
        self.writer.flush().context("flush")?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Message> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("recv")?;
        if n == 0 {
            bail!("peer closed the connection");
        }
        let j = Json::parse(line.trim_end()).context("parsing message")?;
        Message::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let j = m.to_json();
        let back = Message::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello { node: 3 });
        roundtrip(Message::RequestWork { node: 0 });
        roundtrip(Message::Stop);
        roundtrip(Message::Work {
            trial: 7,
            round: 2,
            history: vec![WireModel {
                signature: "16x2p-32x2".into(),
                accuracy: 0.61,
                widths: vec![16, 32],
                blocks: vec![2, 2],
            }],
        });
        roundtrip(Message::Result {
            node: 1,
            trial: 7,
            signature: "16x3p".into(),
            accuracy: 0.55,
            error: 0.45,
            params: 12345,
            ops: 1.5e12,
            epochs: 30,
            widths: vec![16],
            blocks: vec![3],
        });
    }

    #[test]
    fn rejects_unknown_type() {
        let j = Json::parse(r#"{"t": "bogus"}"#).unwrap();
        assert!(Message::from_json(&j).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"t": "result", "node": 1}"#).unwrap();
        assert!(Message::from_json(&j).is_err());
    }
}
