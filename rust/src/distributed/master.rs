//! Master server: owns the history, dispatches trials, applies the
//! termination rule, aggregates the report (paper §4.3 master role).
//!
//! This is the *real* wall-clock path (a TCP master timing actual slave
//! processes), not the simulated one — the deterministic-schedule rules
//! are relaxed here, with each exception pragma'd below.

// detlint: allow-file(wall_clock) — real distributed runtime: the budget
// deadline and measured duration are genuine wall-clock quantities.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::protocol::{Connection, Message, WireModel};
use crate::metrics::score::regulated_score;

/// One aggregated trial result (master-side record).
#[derive(Debug, Clone)]
pub struct TrialResult {
    pub node: u64,
    pub trial: u64,
    pub signature: String,
    pub accuracy: f64,
    pub error: f64,
    pub ops: f64,
    pub epochs: u64,
}

/// Final report of a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    pub slaves: u64,
    pub trials: Vec<TrialResult>,
    pub duration_s: f64,
    pub total_ops: f64,
    pub score_flops: f64,
    pub best_error: f64,
    pub regulated_score: f64,
}

impl DistributedReport {
    pub fn summary(&self) -> String {
        format!(
            "slaves={} trials={} score={:.3} GFLOPS best_error={:.3} regulated={:.3} GFLOPS ({:.1}s)",
            self.slaves,
            self.trials.len(),
            self.score_flops / 1e9,
            self.best_error,
            self.regulated_score / 1e9,
            self.duration_s
        )
    }
}

struct Shared {
    history: Mutex<Vec<WireModel>>,
    results: Mutex<Vec<TrialResult>>,
    rounds: Mutex<std::collections::BTreeMap<u64, u64>>,
    next_trial: AtomicU64,
    stop: AtomicBool,
    deadline: Instant,
}

/// The master: binds a port, accepts `expected_slaves` connections, serves
/// work until the wall-clock budget expires or `max_trials` complete.
pub struct MasterServer {
    listener: TcpListener,
    expected_slaves: u64,
    max_trials: u64,
    budget_s: f64,
}

impl MasterServer {
    /// Bind on 127.0.0.1 with an OS-assigned port.
    pub fn bind(expected_slaves: u64, max_trials: u64, budget_s: f64) -> Result<Self> {
        assert!(expected_slaves >= 1);
        let listener = TcpListener::bind("127.0.0.1:0").context("binding master port")?;
        Ok(MasterServer {
            listener,
            expected_slaves,
            max_trials,
            budget_s,
        })
    }

    /// The address slaves should connect to.
    pub fn addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("local_addr")
    }

    /// Serve until termination; returns the aggregated report.
    pub fn serve(self) -> Result<DistributedReport> {
        let started = Instant::now();
        let shared = Arc::new(Shared {
            history: Mutex::new(Vec::new()),
            results: Mutex::new(Vec::new()),
            rounds: Mutex::new(Default::default()),
            next_trial: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            deadline: started + std::time::Duration::from_secs_f64(self.budget_s),
        });

        let mut handles = Vec::new();
        for _ in 0..self.expected_slaves {
            let (stream, _) = self.listener.accept().context("accepting slave")?;
            let shared = shared.clone();
            let max_trials = self.max_trials;
            // detlint: allow(thread_spawn) — one handler thread per
            // connected slave; ordering is owned by the wire protocol.
            handles.push(std::thread::spawn(move || {
                serve_slave(stream, shared, max_trials)
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("slave handler panicked"))??;
        }

        let duration_s = started.elapsed().as_secs_f64();
        let results = shared.results.lock().unwrap().clone();
        let total_ops: f64 = results.iter().map(|r| r.ops).sum();
        let best_error = results
            .iter()
            .map(|r| r.error)
            .fold(1.0f64, f64::min)
            .clamp(1e-9, 1.0 - 1e-9);
        let score_flops = total_ops / duration_s.max(1e-9);
        Ok(DistributedReport {
            slaves: self.expected_slaves,
            trials: results,
            duration_s,
            total_ops,
            score_flops,
            best_error,
            regulated_score: regulated_score(best_error, score_flops),
        })
    }
}

fn serve_slave(stream: TcpStream, shared: Arc<Shared>, max_trials: u64) -> Result<()> {
    let mut conn = Connection::new(stream)?;
    // Handshake.
    let node = match conn.recv()? {
        Message::Hello { node } => node,
        other => anyhow::bail!("expected Hello, got {other:?}"),
    };
    loop {
        match conn.recv()? {
            Message::RequestWork { .. } => {
                let done = shared.results.lock().unwrap().len() as u64;
                if shared.stop.load(Ordering::SeqCst)
                    || done >= max_trials
                    || Instant::now() >= shared.deadline
                {
                    shared.stop.store(true, Ordering::SeqCst);
                    conn.send(&Message::Stop)?;
                    return Ok(());
                }
                let trial = shared.next_trial.fetch_add(1, Ordering::SeqCst);
                let round = {
                    let mut rounds = shared.rounds.lock().unwrap();
                    let r = rounds.entry(node).or_insert(0);
                    *r += 1;
                    *r
                };
                let history = shared.history.lock().unwrap().clone();
                conn.send(&Message::Work {
                    trial,
                    round,
                    history,
                })?;
            }
            Message::Result {
                node,
                trial,
                signature,
                accuracy,
                error,
                params: _,
                ops,
                epochs,
                widths,
                blocks,
            } => {
                shared.history.lock().unwrap().push(WireModel {
                    signature: signature.clone(),
                    accuracy,
                    widths,
                    blocks,
                });
                shared.results.lock().unwrap().push(TrialResult {
                    node,
                    trial,
                    signature,
                    accuracy,
                    error,
                    ops,
                    epochs,
                });
            }
            Message::Hello { .. } => anyhow::bail!("duplicate Hello"),
            other => anyhow::bail!("unexpected message from slave: {other:?}"),
        }
    }
}
