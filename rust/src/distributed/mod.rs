//! Distributed master–slave runtime (paper §4.3 / §4.5 deployment shape).
//!
//! The simulated coordinator ([`crate::coordinator::master`]) models the
//! cluster; this module is the *real* networked deployment of the same
//! protocol: a master process binds a TCP port, slave workers connect
//! (in the paper: SLURM-launched containers on separate hosts; here:
//! threads or processes on localhost — the wire protocol is identical),
//! request work, run trials, and stream results back. The master owns the
//! historical model list and the termination rule; slaves own the CPU
//! search loop and trial execution — exactly the paper's division of
//! labour with NFS replaced by the message channel.
//!
//! Framing is newline-delimited JSON (in-tree codec; serde/tokio are not
//! vendored offline — blocking std::net with one thread per slave, which
//! matches the paper's one-container-per-slave deployment).

pub mod master;
pub mod protocol;
pub mod slave;

pub use master::{DistributedReport, MasterServer};
pub use protocol::Message;
pub use slave::SlaveWorker;
