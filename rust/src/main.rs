//! aiperf — the benchmark launcher (paper §4.3 step 1: the user-facing
//! entry point that configures and dispatches the benchmark).
//!
//! CLI parsing is hand-rolled (clap is not vendored offline): flat
//! `--key value` flags per subcommand.

#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};

use aiperf::config::{BenchmarkConfig, Engine};
#[cfg(feature = "pjrt")]
use aiperf::coordinator::live::{run_live, LiveConfig};
use aiperf::coordinator::run_benchmark;
use aiperf::flops::layers::LayerKind;
use aiperf::flops::resnet50::resnet50_imagenet;
use aiperf::flops::{graph_ops_per_image, OpWeights};

const USAGE: &str = "\
aiperf — AIPerf: Automated machine learning as an AI-HPC benchmark (Ren et al., 2020)

USAGE:
    aiperf run   [--scenario NAME] [--nodes N] [--hours H] [--seed S]
                 [--engine sequential|parallel] [--config FILE]
                 [--subshards K] [--work-stealing [on|off]]
                 [--migration [on|off]] [--feedback-routing [on|off]]
                 [--hpo tpe|evolutionary|random|grid] [--early-stop [on|off]]
                 [--stream-report OUT.ndjson]
                 [--json OUT] [--csv OUT] [--chart] [--list-scenarios]
        Simulated benchmark on the modelled cluster (Figs 4-6, 9-12).
        Scenario presets reproduce the paper's evaluated systems:
          smoke         2 x 8 V100, 2 h — CI-sized sanity run
          elastic-mixed 2 x 8 T4 + 2 x 8 V100, imbalanced deadline —
                        cross-group migration showcase
          t4v100-mixed  2 x 8 T4 + 2 x 8 V100, 6 h — heterogeneous site
                        (per-group batch, 2 sub-shards, stealing +
                        migration)
          t4-32         4 x 8 NVIDIA T4, 12 h (paper: 56.1 Tera-OPS)
          v100-128      16 x 8 V100 NVLink, 12 h (the paper testbed)
          ascend-4096   512 x 8 Ascend 910, 12 h (paper: 194.53 Peta-OPS)
        `--list-scenarios` prints every preset with its topology and
        exits. A `--config FILE` may describe a heterogeneous cluster
        with `[group.NAME]` sections (see `aiperf config`); the legacy
        flat `nodes`/`gpus_per_node` keys still work as a single-group
        shorthand. `--subshards K` splits every node's GPUs into K
        independent trial lanes (groups may override per section), and
        `--work-stealing` lets a lane out of runway join the most-loaded
        sibling lane's trial instead of starting a doomed one — both
        deterministic. `--migration` adds the cluster-wide elastic pass:
        a lane with no runway and no sibling to steal from stages its
        proposed candidate to NFS, and at the next epoch barrier an idle
        lane of another node group adopts it (unless that group sets
        `accepts_migrants = false`), re-timed under the destination's
        device model with its gradient ring over InfiniBand. A run with
        no other accepting group is unaffected by the flag. The staged
        checkpoint size is `migration_nfs_bytes_per_param` bytes per
        model parameter (config key, default 8), and a group opts out of
        adopting with `accepts_migrants = false` in its section.
        `--feedback-routing` (config key `feedback_routing`, ON by
        default) closes the search-feedback loop over migration: a
        migrated trial's TPE observation is routed back to the lane that
        proposed it at the next epoch barrier instead of being dropped,
        OOM penalties only bar parenthood on the node group whose
        accelerator refused the candidate, and a stranded sibling lane
        may steal into an adopted migrant's InfiniBand gradient ring.
        Turning it off reproduces the pre-feedback schedules exactly.
        `--hpo` (config key `hpo`, default `tpe`) selects the search
        backend every lane proposes candidates with — the paper's TPE
        or one of its Fig-7b baselines (`evolutionary`, `random`,
        `grid`); `[group.NAME]` sections may override it per group.
        `--early-stop` (config key `early_stop`, OFF by default) turns
        on LogFit learning-curve early stopping: after each validation
        epoch past `early_stop_min_epochs` the lane extrapolates the
        trial's curve to the convergence horizon and terminates it when
        even the optimistic error floor cannot beat the incumbent best
        by `early_stop_margin` — the freed lane immediately becomes a
        steal victim or migrant-adoption opportunity, and per-group
        `early_stops` / `epochs_saved` counters appear on every report
        surface. With the flag off, schedules are byte-identical to a
        build without the feature.
        Per-group migrations in/out, overhead seconds, routed-feedback
        and ring-join counters appear in the summary and JSON, and the
        JSON report adds per-lane busy fractions (rendered as ASCII bars
        under --chart). `--stream-report OUT.ndjson` (config key
        `stream_report`) streams every score/telemetry/trial/lane record
        to the named NDJSON file as it occurs instead of buffering the
        series in RAM — the constant-memory output mode for 100k-lane
        runs; the printed summary is unchanged, the per-sample series
        live in the stream (schema in USAGE.md). The engine defaults to
        `parallel` (sharded slave nodes on a thread pool); `sequential`
        is bit-identical for the same seed.
    aiperf sweep [--scenarios A,B,C] [--hours H] [--seed S]
                 [--engine sequential|parallel] [--csv OUT]
        Run several scenario presets and print the Fig-4-style scaling
        table: nodes, devices, measured OPS, per-device OPS, and weak-
        scaling efficiency vs the smallest sweep entry with the same
        accelerator mix (a scenario whose mix appears only once, or
        whose baseline scored zero, shows — instead of a fake ratio),
        with a per-group breakdown for heterogeneous presets. `--csv`
        writes the same table as CSV (one row per scenario plus one per
        group). Defaults to smoke,v100-128,t4v100-mixed.
    aiperf scenarios
        List the scenario presets with their cluster topologies.
    aiperf live  [--artifacts DIR] [--trials N] [--epochs E]
                 [--batches-per-epoch B] [--seed S]
        Real-training mini-benchmark over the AOT artifacts (PJRT;
        requires building with `--features pjrt`).
    aiperf cluster [--slaves N] [--trials T] [--seed S]
        Distributed master-slave run over real TCP (localhost workers).
    aiperf report FILE.ndjson
        Validate a streamed NDJSON report (truncation detection plus a
        bit-exact stable-score cross-check, the same integrity pass as
        `reconstruct_summary`) and pretty-print its summary: score,
        error, validity, the active-set shard counters, and the per-
        record-type counts.
    aiperf flops
        Analytical ResNet-50 op breakdown (paper Table 4).
    aiperf config
        Print the default configuration file.
    aiperf help
";

/// Minimal flag parser: `--key value` pairs after the subcommand, plus a
/// fixed set of valueless boolean flags (`--chart`, `--list-scenarios`).
struct Flags {
    pairs: Vec<(String, String)>,
}

/// Flags that take no value (or an optional on/off); every other flag
/// still requires one, so a forgotten value fails up front instead of
/// mid-run.
const BOOLEAN_FLAGS: &[&str] = &[
    "chart",
    "list-scenarios",
    "work-stealing",
    "migration",
    "feedback-routing",
    "early-stop",
];

/// Parse an on/off flag value (`--work-stealing`, `--work-stealing on`).
fn parse_onoff(flag: &str, v: &str) -> Result<bool> {
    match v {
        "" | "1" | "true" | "on" => Ok(true),
        "0" | "false" | "off" => Ok(false),
        other => bail!("--{flag}: expected on/off, got `{other}`"),
    }
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if !k.starts_with("--") {
                bail!("unexpected argument `{k}` (flags are `--key value`)");
            }
            let key = k.trim_start_matches("--").to_string();
            if BOOLEAN_FLAGS.contains(&key.as_str()) {
                // Accept both `--chart` and the legacy `--chart 1`.
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        pairs.push((key, v.clone()));
                        i += 2;
                    }
                    _ => {
                        pairs.push((key, String::new()));
                        i += 1;
                    }
                }
            } else {
                let v = args
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .with_context(|| format!("flag `{k}` needs a value"))?;
                pairs.push((key, v.clone()));
                i += 2;
            }
        }
        Ok(Flags { pairs })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad integer `{v}`")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad number `{v}`")),
        }
    }

    fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for (k, _) in &self.pairs {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag `--{k}`");
            }
        }
        Ok(())
    }
}

fn cmd_run(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&[
        "scenario", "nodes", "hours", "seed", "engine", "config", "json", "csv", "chart",
        "list-scenarios", "subshards", "work-stealing", "migration", "feedback-routing",
        "hpo", "early-stop", "stream-report",
    ])?;
    if flags.get("list-scenarios").is_some() {
        cmd_scenarios();
        return Ok(());
    }
    let mut cfg = match (flags.get("scenario"), flags.get("config")) {
        (Some(_), Some(_)) => bail!("--scenario and --config are mutually exclusive"),
        (Some(name), None) => {
            aiperf::scenarios::get(name)
                .with_context(|| {
                    format!(
                        "unknown scenario `{name}` (available: {})",
                        aiperf::scenarios::names().join(", ")
                    )
                })?
                .config
        }
        (None, Some(path)) => BenchmarkConfig::from_text(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?,
        )
        .map_err(|e| anyhow::anyhow!(e))?,
        (None, None) => BenchmarkConfig::default(),
    };
    if flags.get("nodes").is_some() {
        let n = flags.get_u64("nodes", 0)?;
        cfg.topology.scale_to_nodes(n).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.duration_s = flags.get_f64("hours", cfg.duration_s / 3600.0)? * 3600.0;
    cfg.seed = flags.get_u64("seed", cfg.seed)?;
    if let Some(engine) = flags.get("engine") {
        cfg.engine = Engine::parse(engine).map_err(|e| anyhow::anyhow!(e))?;
    }
    if flags.get("subshards").is_some() {
        // Sets the all-groups default; per-group `[group.NAME]` overrides
        // from a --config file keep precedence.
        cfg.subshards_per_node = flags.get_u64("subshards", cfg.subshards_per_node)?;
    }
    if let Some(v) = flags.get("work-stealing") {
        cfg.work_stealing = parse_onoff("work-stealing", v)?;
    }
    if let Some(v) = flags.get("migration") {
        cfg.migration = parse_onoff("migration", v)?;
    }
    if let Some(v) = flags.get("feedback-routing") {
        cfg.feedback_routing = parse_onoff("feedback-routing", v)?;
    }
    if let Some(v) = flags.get("hpo") {
        // Sets the all-groups default; per-group `[group.NAME]` overrides
        // from a --config file keep precedence.
        cfg.hpo = aiperf::hpo::Backend::parse(v).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(v) = flags.get("early-stop") {
        cfg.early_stop = parse_onoff("early-stop", v)?;
    }
    if let Some(path) = flags.get("stream-report") {
        if path.is_empty() {
            bail!("--stream-report needs a file path");
        }
        cfg.stream_report = Some(path.to_string());
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    println!("topology: {}", cfg.topology.summary());
    let report = run_benchmark(&cfg);
    println!("{}", report.summary());
    if let Some(path) = &cfg.stream_report {
        println!("NDJSON report streamed to {path}");
    }
    if report.groups.len() > 1 {
        print!("{}", report.group_table());
    }
    println!("score series (hourly):");
    for s in &report.score_series {
        println!(
            "  t={:>5.1}h  score={:.4} PFLOPS  best_error={:.3}  regulated={:.4} PFLOPS",
            s.t / 3600.0,
            s.flops / 1e15,
            s.best_error,
            s.regulated / 1e15
        );
    }
    let xs: Vec<f64> = report.score_series.iter().map(|s| s.t / 3600.0).collect();
    let score: Vec<f64> = report.score_series.iter().map(|s| s.flops / 1e15).collect();
    let err: Vec<f64> = report.score_series.iter().map(|s| s.best_error).collect();
    let reg: Vec<f64> = report.score_series.iter().map(|s| s.regulated / 1e15).collect();
    if flags.get("chart").is_some() {
        println!();
        print!(
            "{}",
            aiperf::metrics::ascii_chart(
                "score / regulated (PFLOPS) and best error over hours",
                &xs,
                &[("score", score.clone()), ("error", err.clone()), ("regulated", reg.clone())],
                12,
            )
        );
        // The Figs 9–12 pipeline's lane-level complement: the node
        // aggregates above hide the parked/stranded tails the steal and
        // migration schedulers recover; one bar per sub-shard lane shows
        // them.
        println!();
        print!(
            "{}",
            aiperf::metrics::lane_util_chart(
                "per-lane busy fraction over the run (idle tails read as -)",
                &report.lane_util,
                40,
            )
        );
    }
    if let Some(path) = flags.get("csv") {
        std::fs::write(
            path,
            aiperf::metrics::csv(
                "hours",
                &xs,
                &[("score_pflops", score), ("best_error", err), ("regulated_pflops", reg)],
            ),
        )?;
        println!("CSV written to {path}");
    }
    if let Some(path) = flags.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_cluster(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["slaves", "trials", "seed"])?;
    let slaves = flags.get_u64("slaves", 4)?;
    let trials = flags.get_u64("trials", 24)?;
    let seed = flags.get_u64("seed", 0)?;
    let master = aiperf::distributed::MasterServer::bind(slaves, trials, 600.0)?;
    let addr = master.addr()?;
    println!("master listening on {addr}; launching {slaves} slave workers");
    let mut handles = Vec::new();
    for node in 0..slaves {
        let worker = aiperf::distributed::SlaveWorker::new(node, seed);
        // detlint: allow(thread_spawn) — real multi-process-style worker
        // threads for `aiperf cluster`; determinism is owned by the
        // protocol layer, not this launcher.
        handles.push(std::thread::spawn(move || worker.run(addr)));
    }
    let report = master.serve()?;
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("slave panicked"))??;
    }
    for t in &report.trials {
        println!(
            "  trial {:>3} node {} round-arch {:<24} acc={:.3} epochs={}",
            t.trial, t.node, t.signature, t.accuracy, t.epochs
        );
    }
    println!("{}", report.summary());
    Ok(())
}

fn cmd_scenarios() {
    println!("scenario presets (aiperf run --scenario NAME):");
    for p in aiperf::scenarios::all() {
        println!(
            "  {:<13} {:<28} {:>4.1} h  — {}",
            p.name,
            p.topology_summary(),
            p.config.duration_s / 3600.0,
            p.description
        );
    }
}

/// `aiperf sweep`: run several presets and print the Fig-4-style scaling
/// table (nodes, devices, measured OPS, weak-scaling efficiency vs the
/// smallest sweep entry of the same accelerator mix — see
/// `aiperf::metrics::sweep`), optionally exporting it as CSV.
fn cmd_sweep(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["scenarios", "hours", "seed", "engine", "csv"])?;
    // Default list: two scales of the V100 mix (so the efficiency column
    // measures real weak scaling) plus the heterogeneous preset (so the
    // per-group breakdown shows).
    let list = flags
        .get("scenarios")
        .unwrap_or("smoke,v100-128,t4v100-mixed");
    let names: Vec<&str> = list
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        bail!("--scenarios needs a comma-separated list of preset names");
    }
    let mut runs: Vec<aiperf::metrics::sweep::SweepRun> = Vec::new();
    for name in &names {
        let mut preset = aiperf::scenarios::get(name).with_context(|| {
            format!(
                "unknown scenario `{name}` (available: {})",
                aiperf::scenarios::names().join(", ")
            )
        })?;
        let cfg = &mut preset.config;
        if flags.get("hours").is_some() {
            cfg.duration_s = flags.get_f64("hours", cfg.duration_s / 3600.0)? * 3600.0;
        }
        cfg.seed = flags.get_u64("seed", cfg.seed)?;
        if let Some(engine) = flags.get("engine") {
            cfg.engine = Engine::parse(engine).map_err(|e| anyhow::anyhow!(e))?;
        }
        cfg.validate()
            .map_err(|e| anyhow::anyhow!("scenario `{name}`: {e}"))?;
        eprintln!("[sweep] running {name} ({}) ...", cfg.topology.summary());
        let report = run_benchmark(cfg);
        runs.push(aiperf::metrics::sweep::SweepRun {
            scenario: name.to_string(),
            report,
        });
    }

    print!("{}", aiperf::metrics::sweep::render_table(&runs));
    if let Some(path) = flags.get("csv") {
        std::fs::write(path, aiperf::metrics::sweep::render_csv(&runs))?;
        println!("sweep CSV written to {path}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_live(_flags: &Flags) -> Result<()> {
    bail!(
        "`aiperf live` needs the PJRT runtime: rebuild with `--features pjrt` \
         (requires the `xla` bindings crate, which is not vendored offline)"
    );
}

#[cfg(feature = "pjrt")]
fn cmd_live(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["artifacts", "trials", "epochs", "batches-per-epoch", "seed"])?;
    let result = run_live(&LiveConfig {
        artifacts_dir: flags.get("artifacts").unwrap_or("artifacts").to_string(),
        trials: flags.get_u64("trials", 4)?,
        epochs_per_trial: flags.get_u64("epochs", 3)?,
        batches_per_epoch: flags.get_u64("batches-per-epoch", 24)?,
        seed: flags.get_u64("seed", 0)?,
        ..LiveConfig::default()
    })?;
    for (i, t) in result.trials.iter().enumerate() {
        println!(
            "trial {i}: variant={} lr={:.4} loss {:.3}→{:.3} val_acc={:.3} ({:.2}s)",
            t.variant,
            t.learning_rate,
            t.losses.first().copied().unwrap_or(f32::NAN),
            t.losses.last().copied().unwrap_or(f32::NAN),
            t.val_accuracy,
            t.seconds
        );
    }
    println!(
        "live: score={:.3} GFLOPS  best_error={:.3}  regulated={:.3} GFLOPS  ({:.1}s)",
        result.score_flops / 1e9,
        result.best_error,
        result.regulated_score / 1e9,
        result.duration_s
    );
    Ok(())
}

/// `aiperf report FILE.ndjson`: validate a streamed NDJSON report and
/// pretty-print its summary. The validation is `reconstruct_summary`'s
/// full integrity pass — every line parses, the trailer's record count
/// matches the records observed, and the stable-window scores recomputed
/// from the streamed score records equal the trailer's bit for bit — so
/// a truncated or tampered stream fails loudly instead of summarizing
/// garbage.
fn cmd_report(rest: &[String]) -> Result<()> {
    let (path, extra) = match rest.split_first() {
        Some((p, extra)) if !p.starts_with("--") => (p.as_str(), extra),
        _ => bail!("usage: aiperf report FILE.ndjson"),
    };
    if let Some(unexpected) = extra.first() {
        bail!("unexpected argument `{unexpected}` (usage: aiperf report FILE.ndjson)");
    }
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let s = aiperf::metrics::reconstruct_summary(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!(
        "stream OK: {} records + summary trailer (scores cross-checked bit-exact)",
        s.records
    );
    println!(
        "  nodes={} gpus={} duration={:.1}h validity={}",
        s.nodes,
        s.total_gpus,
        s.duration_s / 3600.0,
        s.validity
    );
    println!(
        "  score={:.3} PFLOPS  error={:.1}%  regulated={:.3} PFLOPS  archs={}",
        s.score_flops / 1e15,
        s.final_error * 100.0,
        s.regulated_score / 1e15,
        s.architectures_evaluated
    );
    println!(
        "  shards_touched={}  shards_skipped={}  nfs_bytes_read={}  nfs_bytes_written={}",
        s.shards_touched, s.shards_skipped, s.nfs_bytes_read, s.nfs_bytes_written
    );
    println!(
        "  records: trials={} windows={} scores={} telemetry={} lanes={}",
        s.trials, s.windows, s.score_samples, s.telemetry_ticks, s.lanes
    );
    Ok(())
}

fn cmd_flops() {
    let w = OpWeights::default();
    let net = resnet50_imagenet();
    println!("ResNet-50 / ImageNet per-image analytical ops (Table 4):");
    println!(
        "{:<22}{:>12}{:>12}{:>9}{:>12}",
        "layer", "FP", "BP", "BP/FP", "total"
    );
    for kind in [
        LayerKind::Conv,
        LayerKind::Dense,
        LayerKind::BatchNorm,
        LayerKind::Relu,
        LayerKind::MaxPool,
        LayerKind::GlobalPool,
        LayerKind::Add,
        LayerKind::Softmax,
    ] {
        let layers: Vec<_> = net.iter().filter(|l| l.kind == kind).copied().collect();
        let g = graph_ops_per_image(&layers, &w);
        println!(
            "{:<22}{:>12.3e}{:>12.3e}{:>9.4}{:>12.3e}",
            format!("{kind:?}"),
            g.fp as f64,
            g.bp as f64,
            g.bp_fp_ratio(),
            (g.fp + g.bp) as f64
        );
    }
    let g = graph_ops_per_image(&net, &w);
    println!(
        "{:<22}{:>12.3e}{:>12.3e}{:>9.4}{:>12.3e}",
        "Total",
        g.fp as f64,
        g.bp as f64,
        g.bp_fp_ratio(),
        (g.fp + g.bp) as f64
    );
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            print!("{USAGE}");
            return Ok(());
        }
    };
    match cmd {
        "run" => cmd_run(&Flags::parse(rest)?),
        "sweep" => cmd_sweep(&Flags::parse(rest)?),
        "scenarios" => {
            Flags::parse(rest)?.reject_unknown(&[])?;
            cmd_scenarios();
            Ok(())
        }
        "live" => cmd_live(&Flags::parse(rest)?),
        "cluster" => cmd_cluster(&Flags::parse(rest)?),
        // Takes a positional file path, not `--key value` flags.
        "report" => cmd_report(rest),
        "flops" => {
            cmd_flops();
            Ok(())
        }
        "config" => {
            print!("{}", BenchmarkConfig::default().to_text());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}
