//! Random search (Bergstra & Bengio 2012) — Fig 7b baseline.

use crate::util::rng::Rng;

use super::space::{Config, Observation, SearchSpace};
use super::Optimizer;

#[derive(Debug, Clone)]
pub struct RandomSearch {
    space: SearchSpace,
    history: Vec<Observation>,
}

impl RandomSearch {
    pub(crate) fn new(space: SearchSpace) -> Self {
        RandomSearch {
            space,
            history: Vec::new(),
        }
    }
}

impl Optimizer for RandomSearch {
    fn suggest(&mut self, rng: &mut Rng) -> Config {
        self.space.sample(rng)
    }

    fn observe(&mut self, config: Config, loss: f64) {
        self.history.push(Observation { config, loss });
    }

    fn best(&self) -> Option<&Observation> {
        self.history
            .iter()
            .min_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap_or(std::cmp::Ordering::Equal))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpo::aiperf_space;
    use crate::util::rng::derive;

    #[test]
    fn covers_the_space() {
        let mut rs = RandomSearch::new(aiperf_space());
        let mut rng = derive(0, "rs", 0);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..300 {
            let c = rs.suggest(&mut rng);
            lo = lo.min(c[0]);
            hi = hi.max(c[0]);
            rs.observe(c, 1.0);
        }
        assert!(lo < 0.25 && hi > 0.75, "poor coverage: [{lo},{hi}]");
    }

    #[test]
    fn best_is_min() {
        let mut rs = RandomSearch::new(aiperf_space());
        rs.observe(vec![0.5, 3.0], 0.9);
        rs.observe(vec![0.6, 2.0], 0.1);
        assert_eq!(rs.best().unwrap().loss, 0.1);
    }

    #[test]
    fn empty_best_is_none() {
        let rs = RandomSearch::new(aiperf_space());
        assert!(rs.best().is_none());
    }
}
