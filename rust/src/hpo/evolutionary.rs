//! Evolutionary search (Real et al. 2017) — Fig 7b baseline.
//!
//! Regularized-evolution style: keep a sliding population; each suggestion
//! is either a random sample (until the population fills) or a Gaussian
//! mutation of a tournament winner; the oldest member dies on overflow.

use crate::util::rng::Rng;

use super::space::{Config, Observation, SearchSpace};
use super::Optimizer;

#[derive(Debug, Clone)]
pub struct Evolutionary {
    space: SearchSpace,
    history: Vec<Observation>,
    population: Vec<Observation>,
    pub population_size: usize,
    pub tournament_size: usize,
    /// Mutation stddev as a fraction of each parameter's span.
    pub sigma_frac: f64,
}

impl Evolutionary {
    pub(crate) fn new(space: SearchSpace) -> Self {
        Evolutionary {
            space,
            history: Vec::new(),
            population: Vec::new(),
            population_size: 12,
            tournament_size: 3,
            sigma_frac: 0.15,
        }
    }

    fn tournament(&self, rng: &mut Rng) -> &Observation {
        let mut best: Option<&Observation> = None;
        for _ in 0..self.tournament_size {
            let cand = &self.population[rng.gen_range_usize(0, self.population.len())];
            if best.map_or(true, |b| cand.loss < b.loss) {
                best = Some(cand);
            }
        }
        best.unwrap()
    }
}

impl Optimizer for Evolutionary {
    fn suggest(&mut self, rng: &mut Rng) -> Config {
        if self.population.len() < self.population_size {
            return self.space.sample(rng);
        }
        let parent = self.tournament(rng).config.clone();
        self.space
            .params
            .iter()
            .zip(&parent)
            .map(|(p, &x)| {
                let sigma = (p.hi - p.lo) * self.sigma_frac;
                p.project(rng.gen_normal_with(x, sigma))
            })
            .collect()
    }

    fn observe(&mut self, config: Config, loss: f64) {
        let obs = Observation { config, loss };
        self.history.push(obs.clone());
        self.population.push(obs);
        if self.population.len() > self.population_size {
            self.population.remove(0); // regularized: oldest dies
        }
    }

    fn best(&self) -> Option<&Observation> {
        self.history
            .iter()
            .min_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap_or(std::cmp::Ordering::Equal))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpo::aiperf_space;
    use crate::util::rng::derive;

    fn objective(c: &[f64]) -> f64 {
        (c[0] - 0.45).powi(2) * 4.0 + (c[1] - 3.0).powi(2) * 0.05
    }

    #[test]
    fn improves_over_budget() {
        let mut ev = Evolutionary::new(aiperf_space());
        let mut rng = derive(5, "evo", 0);
        let mut first10 = f64::MAX;
        for i in 0..80 {
            let c = ev.suggest(&mut rng);
            let l = objective(&c);
            if i < 10 {
                first10 = first10.min(l);
            }
            ev.observe(c, l);
        }
        assert!(ev.best().unwrap().loss <= first10);
        assert!(ev.best().unwrap().loss < 0.05);
    }

    #[test]
    fn population_is_bounded() {
        let mut ev = Evolutionary::new(aiperf_space());
        let mut rng = derive(6, "evo", 1);
        for _ in 0..100 {
            let c = ev.suggest(&mut rng);
            ev.observe(c, 1.0);
        }
        assert_eq!(ev.population.len(), ev.population_size);
        assert_eq!(ev.history.len(), 100);
    }

    #[test]
    fn mutations_stay_in_space() {
        let space = aiperf_space();
        let mut ev = Evolutionary::new(space.clone());
        let mut rng = derive(7, "evo", 2);
        for _ in 0..60 {
            let c = ev.suggest(&mut rng);
            assert!(space.contains(&c), "{c:?}");
            let l = objective(&c);
            ev.observe(c, l);
        }
    }
}
