//! Hyperparameter optimization (paper §4.2, Appendix A).
//!
//! AIPerf fixes HPO to Bayesian optimization with the tree-structured
//! Parzen estimator (TPE, Bergstra et al. 2011) after comparing it against
//! grid search, random search and an evolutionary method on CIFAR10
//! (Fig 7b — TPE wins). All four are implemented here behind a common
//! [`Optimizer`] trait so the comparison bench can rerun the selection
//! experiment.
//!
//! The benchmark's search space (Appendix A): dropout rate ∈ [0.2, 0.8]
//! and kernel size ∈ [2, 5]; batch size is fixed at the suggested 448
//! after the separate Fig 7a study.

pub mod evolutionary;
pub mod grid;
pub mod random;
pub mod space;
pub mod tpe;

pub use evolutionary::Evolutionary;
pub use grid::GridSearch;
pub use random::RandomSearch;
pub use space::{Config, Observation, ParamSpec, SearchSpace};
pub use tpe::Tpe;

use crate::util::rng::Rng;

/// Common interface: ask for a configuration, tell the observed loss
/// (validation error — lower is better).
pub trait Optimizer {
    /// Propose the next configuration to evaluate.
    fn suggest(&mut self, rng: &mut Rng) -> Config;
    /// Report the loss of a previously suggested configuration.
    fn observe(&mut self, config: Config, loss: f64);
    /// Best (config, loss) seen so far.
    fn best(&self) -> Option<&Observation>;
}

/// AIPerf's fixed HPO space: dropout ∈ [0.2,0.8], kernel ∈ {2..5}.
pub fn aiperf_space() -> SearchSpace {
    SearchSpace {
        params: vec![
            ParamSpec {
                name: "dropout".into(),
                lo: 0.2,
                hi: 0.8,
                integer: false,
            },
            ParamSpec {
                name: "kernel".into(),
                lo: 2.0,
                hi: 5.0,
                integer: true,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aiperf_space_shape() {
        let s = aiperf_space();
        assert_eq!(s.params.len(), 2);
        assert_eq!(s.params[0].name, "dropout");
        assert!(s.params[1].integer);
    }
}
