//! Hyperparameter optimization (paper §4.2, Appendix A).
//!
//! AIPerf fixes HPO to Bayesian optimization with the tree-structured
//! Parzen estimator (TPE, Bergstra et al. 2011) after comparing it against
//! grid search, random search and an evolutionary method on CIFAR10
//! (Fig 7b — TPE wins). All four are implemented here behind a common
//! [`Optimizer`] trait so the comparison bench can rerun the selection
//! experiment.
//!
//! The benchmark's search space (Appendix A): dropout rate ∈ [0.2, 0.8]
//! and kernel size ∈ [2, 5]; batch size is fixed at the suggested 448
//! after the separate Fig 7a study.
//!
//! The one public construction path is [`build`]: a [`Backend`] kind
//! (the `hpo = tpe|evolutionary|random|grid` config knob) plus the
//! search space and the seed yield a boxed [`Optimizer`]. The concrete
//! constructors are `pub(crate)` so the trait object is the only way
//! out of this module — benches, examples, and the engine all go
//! through the same factory.

pub mod evolutionary;
pub mod grid;
pub mod random;
pub mod space;
pub mod tpe;

pub use evolutionary::Evolutionary;
pub use grid::GridSearch;
pub use random::RandomSearch;
pub use space::{Config, Observation, ParamSpec, SearchSpace};
pub use tpe::Tpe;

use crate::util::rng::Rng;

/// Common interface: ask for a configuration, tell the observed loss
/// (validation error — lower is better).
pub trait Optimizer {
    /// Propose the next configuration to evaluate.
    fn suggest(&mut self, rng: &mut Rng) -> Config;
    /// Report the loss of a previously suggested configuration.
    fn observe(&mut self, config: Config, loss: f64);
    /// Best (config, loss) seen so far.
    fn best(&self) -> Option<&Observation>;
}

/// The selectable HPO backend — the value space of the `hpo` config key
/// (global or per-`[group.NAME]`) and the `--hpo` CLI flag. The paper
/// fixes TPE (Fig 7b); the others are the comparison baselines promoted
/// to first-class citizens.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    #[default]
    Tpe,
    Evolutionary,
    Random,
    Grid,
}

impl Backend {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "tpe" => Ok(Backend::Tpe),
            "evolutionary" => Ok(Backend::Evolutionary),
            "random" => Ok(Backend::Random),
            "grid" => Ok(Backend::Grid),
            other => Err(format!(
                "unknown hpo backend `{other}` (expected tpe|evolutionary|random|grid)"
            )),
        }
    }

    /// The canonical spelling (what `to_text` emits).
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Tpe => "tpe",
            Backend::Evolutionary => "evolutionary",
            Backend::Random => "random",
            Backend::Grid => "grid",
        }
    }
}

/// Grid resolution used by [`build`] for continuous dimensions: 5
/// levels per parameter (integer parameters enumerate every integral
/// level regardless).
pub const GRID_POINTS_PER_DIM: usize = 5;

/// The factory: the only public construction path for an optimizer.
///
/// TPE, evolutionary, and random draw every random number from the
/// caller's RNG stream at `suggest` time, so they carry no seed of
/// their own — `seed` only de-phases deterministic backends. Grid
/// search starts its lattice walk at `seed % lattice_size`, so lanes
/// with different seeds cover different lattice prefixes instead of
/// all re-evaluating the same corner.
pub fn build(kind: Backend, space: SearchSpace, seed: u64) -> Box<dyn Optimizer> {
    match kind {
        Backend::Tpe => Box::new(Tpe::new(space)),
        Backend::Evolutionary => Box::new(Evolutionary::new(space)),
        Backend::Random => Box::new(RandomSearch::new(space)),
        Backend::Grid => {
            let g = GridSearch::new(space, GRID_POINTS_PER_DIM);
            let offset = (seed % g.lattice_size() as u64) as usize;
            Box::new(g.with_cursor(offset))
        }
    }
}

/// AIPerf's fixed HPO space: dropout ∈ [0.2,0.8], kernel ∈ {2..5}.
pub fn aiperf_space() -> SearchSpace {
    SearchSpace {
        params: vec![
            ParamSpec {
                name: "dropout".into(),
                lo: 0.2,
                hi: 0.8,
                integer: false,
            },
            ParamSpec {
                name: "kernel".into(),
                lo: 2.0,
                hi: 5.0,
                integer: true,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aiperf_space_shape() {
        let s = aiperf_space();
        assert_eq!(s.params.len(), 2);
        assert_eq!(s.params[0].name, "dropout");
        assert!(s.params[1].integer);
    }

    #[test]
    fn backend_spellings_round_trip() {
        for b in [
            Backend::Tpe,
            Backend::Evolutionary,
            Backend::Random,
            Backend::Grid,
        ] {
            assert_eq!(Backend::parse(b.as_str()), Ok(b));
        }
        assert_eq!(Backend::default(), Backend::Tpe);
        assert!(Backend::parse("bayes").is_err());
        assert!(Backend::parse("TPE").is_err(), "spellings are lowercase");
    }

    #[test]
    fn built_tpe_draws_the_same_stream_as_a_direct_tpe() {
        // The factory must be a pure repackaging: a boxed TPE from
        // `build` and a directly-constructed `Tpe` consume identical
        // RNG streams and emit identical suggestions — the regression
        // guarantee behind swapping `SubShard`'s concrete field for the
        // trait object.
        use crate::util::rng::derive;
        let mut boxed = build(Backend::Tpe, aiperf_space(), 12345);
        let mut direct = Tpe::new(aiperf_space());
        let mut r1 = derive(9, "factory", 0);
        let mut r2 = derive(9, "factory", 0);
        for i in 0..20 {
            let a = boxed.suggest(&mut r1);
            let b = direct.suggest(&mut r2);
            assert_eq!(a, b, "suggestion {i} diverged");
            let loss = 0.5 + (i as f64) * 0.01;
            boxed.observe(a, loss);
            direct.observe(b, loss);
        }
        assert_eq!(
            r1.gen_f64().to_bits(),
            r2.gen_f64().to_bits(),
            "RNG streams diverged"
        );
    }

    #[test]
    fn built_grid_offsets_its_cursor_by_seed() {
        use crate::util::rng::derive;
        let mut rng = derive(0, "grid-seeded", 0);
        let mut zero = build(Backend::Grid, aiperf_space(), 0);
        let mut shifted = build(Backend::Grid, aiperf_space(), 3);
        let first_zero = zero.suggest(&mut rng);
        let first_shifted = shifted.suggest(&mut rng);
        assert_ne!(first_zero, first_shifted, "seed must de-phase the walk");
        // 20-point lattice: seed 20 wraps back to the seed-0 start.
        let mut wrapped = build(Backend::Grid, aiperf_space(), 20);
        assert_eq!(wrapped.suggest(&mut rng), first_zero);
    }

    #[test]
    fn every_backend_builds_and_respects_the_space() {
        use crate::util::rng::derive;
        let space = aiperf_space();
        for kind in [
            Backend::Tpe,
            Backend::Evolutionary,
            Backend::Random,
            Backend::Grid,
        ] {
            let mut opt = build(kind, space.clone(), 7);
            let mut rng = derive(3, "all-backends", 0);
            for i in 0..30 {
                let c = opt.suggest(&mut rng);
                assert!(space.contains(&c), "{kind:?} iter {i}: {c:?}");
                opt.observe(c, 1.0 - 0.001 * i as f64);
            }
            assert!(opt.best().is_some());
        }
    }
}
