//! Search-space definition shared by all HPO methods.

use crate::util::rng::Rng;

/// One tunable hyperparameter: a bounded scalar, optionally integral
/// (grid search quantizes integral params; continuous methods round).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub integer: bool,
}

impl ParamSpec {
    /// Clamp + round a raw value into the legal domain.
    pub fn project(&self, x: f64) -> f64 {
        let v = x.clamp(self.lo, self.hi);
        if self.integer {
            v.round().clamp(self.lo, self.hi)
        } else {
            v
        }
    }

    /// Uniform sample from the domain.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.project(rng.gen_range_f64(self.lo, self.hi))
    }
}

/// Product space of independent scalar parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    pub params: Vec<ParamSpec>,
}

impl SearchSpace {
    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        self.params.iter().map(|p| p.sample(rng)).collect()
    }

    pub fn project(&self, config: &[f64]) -> Config {
        assert_eq!(config.len(), self.dim());
        self.params
            .iter()
            .zip(config)
            .map(|(p, &x)| p.project(x))
            .collect()
    }

    /// True when the config lies inside every parameter's domain.
    pub fn contains(&self, config: &[f64]) -> bool {
        config.len() == self.dim()
            && self
                .params
                .iter()
                .zip(config)
                .all(|(p, &x)| x >= p.lo && x <= p.hi && (!p.integer || x.fract() == 0.0))
    }
}

/// A flat configuration vector, ordered like `SearchSpace::params`.
pub type Config = Vec<f64>;

/// A completed evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct Observation {
    pub config: Config,
    pub loss: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::derive;

    fn space() -> SearchSpace {
        SearchSpace {
            params: vec![
                ParamSpec {
                    name: "x".into(),
                    lo: 0.0,
                    hi: 1.0,
                    integer: false,
                },
                ParamSpec {
                    name: "k".into(),
                    lo: 2.0,
                    hi: 5.0,
                    integer: true,
                },
            ],
        }
    }

    #[test]
    fn project_clamps_and_rounds() {
        let s = space();
        assert_eq!(s.project(&[1.5, 3.4]), vec![1.0, 3.0]);
        assert_eq!(s.project(&[-0.2, 9.0]), vec![0.0, 5.0]);
    }

    #[test]
    fn samples_in_domain() {
        let s = space();
        let mut rng = derive(0, "space", 0);
        for _ in 0..200 {
            let c = s.sample(&mut rng);
            assert!(s.contains(&c), "{c:?}");
        }
    }

    #[test]
    fn contains_rejects_bad() {
        let s = space();
        assert!(!s.contains(&[0.5]));
        assert!(!s.contains(&[0.5, 3.5])); // non-integer kernel
        assert!(!s.contains(&[2.0, 3.0])); // x out of range
    }
}
