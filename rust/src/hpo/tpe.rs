//! Tree-structured Parzen estimator (Bergstra et al. 2011) — the paper's
//! fixed HPO method (Table 5).
//!
//! Per dimension: observations are split at the γ-quantile of loss into
//! "good" (l) and "bad" (g) sets; each set is modelled by a Parzen window
//! (Gaussian KDE with data-driven bandwidth); `n_candidates` samples are
//! drawn from l and the candidate maximizing the expected-improvement
//! surrogate l(x)/g(x) is suggested. Dimensions are treated independently
//! (the classic "tree" with no conditional structure — AIPerf's space has
//! none).

use crate::util::rng::Rng;

use super::space::{Config, Observation, SearchSpace};
use super::Optimizer;

#[derive(Debug, Clone)]
pub struct Tpe {
    space: SearchSpace,
    history: Vec<Observation>,
    /// Quantile split between good and bad sets.
    pub gamma: f64,
    /// Random-search warm start before the estimator kicks in.
    pub n_startup: usize,
    /// Candidates drawn from l(x) per suggestion.
    pub n_candidates: usize,
}

impl Tpe {
    pub(crate) fn new(space: SearchSpace) -> Self {
        Tpe {
            space,
            history: Vec::new(),
            gamma: 0.25,
            n_startup: 8,
            n_candidates: 24,
        }
    }

    /// Split history into (good, bad) by the γ-quantile of loss.
    fn split(&self) -> (Vec<&Observation>, Vec<&Observation>) {
        let mut sorted: Vec<&Observation> = self.history.iter().collect();
        sorted.sort_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap_or(std::cmp::Ordering::Equal));
        let n_good = ((self.gamma * sorted.len() as f64).ceil() as usize)
            .clamp(1, sorted.len().saturating_sub(1).max(1));
        let (good, bad) = sorted.split_at(n_good.min(sorted.len()));
        (good.to_vec(), bad.to_vec())
    }

    /// Parzen bandwidth for a 1-D sample set over [lo, hi]: max of the
    /// neighbour spacing heuristic and 1/20 of the domain.
    fn bandwidth(values: &[f64], lo: f64, hi: f64) -> f64 {
        let span = (hi - lo).max(1e-12);
        if values.len() < 2 {
            return span / 4.0;
        }
        (span / values.len() as f64).max(span / 20.0)
    }

    /// KDE log-density of `x` under the Parzen mixture.
    fn log_density(x: f64, centers: &[f64], bw: f64) -> f64 {
        let inv = 1.0 / (bw * (2.0 * std::f64::consts::PI).sqrt());
        let mut acc = 0.0;
        for &c in centers {
            let z = (x - c) / bw;
            acc += inv * (-0.5 * z * z).exp();
        }
        (acc / centers.len() as f64).max(1e-300).ln()
    }
}

impl Optimizer for Tpe {
    fn suggest(&mut self, rng: &mut Rng) -> Config {
        if self.history.len() < self.n_startup {
            return self.space.sample(rng);
        }
        let (good, bad) = self.split();
        let mut config = Vec::with_capacity(self.space.dim());
        for (d, p) in self.space.params.iter().enumerate() {
            let gvals: Vec<f64> = good.iter().map(|o| o.config[d]).collect();
            let bvals: Vec<f64> = bad.iter().map(|o| o.config[d]).collect();
            let gbw = Self::bandwidth(&gvals, p.lo, p.hi);
            let bbw = Self::bandwidth(&bvals, p.lo, p.hi);
            // Draw candidates from l(x): pick a good center, jitter by bw.
            let mut best_x = p.sample(rng);
            let mut best_score = f64::NEG_INFINITY;
            for _ in 0..self.n_candidates {
                let center = gvals[rng.gen_range_usize(0, gvals.len())];
                let x = p.project(rng.gen_normal_with(center, gbw));
                let score = Self::log_density(x, &gvals, gbw)
                    - if bvals.is_empty() {
                        0.0
                    } else {
                        Self::log_density(x, &bvals, bbw)
                    };
                if score > best_score {
                    best_score = score;
                    best_x = x;
                }
            }
            config.push(best_x);
        }
        config
    }

    fn observe(&mut self, config: Config, loss: f64) {
        debug_assert!(self.space.contains(&config), "observe outside space");
        self.history.push(Observation { config, loss });
    }

    fn best(&self) -> Option<&Observation> {
        self.history
            .iter()
            .min_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap_or(std::cmp::Ordering::Equal))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpo::aiperf_space;
    use crate::util::rng::derive;

    /// Smooth test objective with optimum at (0.45, 3): quadratic bowl.
    fn objective(c: &[f64]) -> f64 {
        (c[0] - 0.45).powi(2) * 4.0 + (c[1] - 3.0).powi(2) * 0.05
    }

    fn run(n: usize, seed: u64) -> f64 {
        let mut tpe = Tpe::new(aiperf_space());
        let mut rng = derive(seed, "tpe-test", 0);
        for _ in 0..n {
            let c = tpe.suggest(&mut rng);
            let l = objective(&c);
            tpe.observe(c, l);
        }
        tpe.best().unwrap().loss
    }

    #[test]
    fn converges_near_optimum() {
        let best = run(60, 3);
        assert!(best < 0.01, "best={best}");
    }

    #[test]
    fn beats_pure_random_on_average() {
        use crate::hpo::RandomSearch;
        let mut tpe_wins = 0;
        for seed in 0..10u64 {
            let t = run(40, seed);
            let mut rs = RandomSearch::new(aiperf_space());
            let mut rng = derive(seed, "rs-test", 0);
            for _ in 0..40 {
                let c = rs.suggest(&mut rng);
                let l = objective(&c);
                rs.observe(c, l);
            }
            if t <= rs.best().unwrap().loss {
                tpe_wins += 1;
            }
        }
        assert!(tpe_wins >= 6, "tpe won only {tpe_wins}/10");
    }

    #[test]
    fn suggestions_stay_in_space() {
        let space = aiperf_space();
        let mut tpe = Tpe::new(space.clone());
        let mut rng = derive(1, "tpe-dom", 0);
        for i in 0..50 {
            let c = tpe.suggest(&mut rng);
            assert!(space.contains(&c), "iter {i}: {c:?}");
            let l = objective(&c);
            tpe.observe(c, l);
        }
    }

    #[test]
    fn startup_phase_is_random() {
        let mut tpe = Tpe::new(aiperf_space());
        tpe.n_startup = 5;
        let mut rng = derive(2, "tpe-start", 0);
        // No history: suggestions must still be valid samples.
        for _ in 0..5 {
            let c = tpe.suggest(&mut rng);
            assert!(tpe.space.contains(&c));
            tpe.observe(c, 1.0);
        }
    }

    #[test]
    fn best_tracks_minimum() {
        let mut tpe = Tpe::new(aiperf_space());
        tpe.observe(vec![0.3, 3.0], 0.5);
        tpe.observe(vec![0.4, 4.0], 0.2);
        tpe.observe(vec![0.5, 2.0], 0.9);
        assert_eq!(tpe.best().unwrap().loss, 0.2);
    }

    #[test]
    fn split_never_empty_sides() {
        let mut tpe = Tpe::new(aiperf_space());
        tpe.observe(vec![0.3, 3.0], 0.5);
        tpe.observe(vec![0.4, 4.0], 0.2);
        let (g, b) = tpe.split();
        assert!(!g.is_empty());
        assert!(!b.is_empty());
    }
}
