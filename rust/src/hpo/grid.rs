//! Grid search (Larochelle et al. 2007) — Fig 7b baseline.
//!
//! The paper notes grid search uses *discrete* search values while the
//! other methods are continuous. The lattice has `points_per_dim` levels
//! per continuous parameter and every integral level for integer
//! parameters; suggestions enumerate the lattice row-major and wrap around
//! when exhausted.

use crate::util::rng::Rng;

use super::space::{Config, Observation, SearchSpace};
use super::Optimizer;

#[derive(Debug, Clone)]
pub struct GridSearch {
    space: SearchSpace,
    levels: Vec<Vec<f64>>,
    cursor: usize,
    total: usize,
    history: Vec<Observation>,
}

impl GridSearch {
    pub(crate) fn new(space: SearchSpace, points_per_dim: usize) -> Self {
        assert!(points_per_dim >= 2);
        let levels: Vec<Vec<f64>> = space
            .params
            .iter()
            .map(|p| {
                if p.integer {
                    let lo = p.lo.ceil() as i64;
                    let hi = p.hi.floor() as i64;
                    (lo..=hi).map(|v| v as f64).collect()
                } else {
                    (0..points_per_dim)
                        .map(|i| {
                            p.lo + (p.hi - p.lo) * i as f64 / (points_per_dim - 1) as f64
                        })
                        .collect()
                }
            })
            .collect();
        let total = levels.iter().map(Vec::len).product();
        GridSearch {
            space,
            levels,
            cursor: 0,
            total,
            history: Vec::new(),
        }
    }

    /// Number of lattice points.
    pub fn lattice_size(&self) -> usize {
        self.total
    }

    /// Start the lattice walk at `i` instead of the origin (suggestions
    /// already wrap modulo the lattice size). `hpo::build` uses this to
    /// de-phase seed-differentiated grid walkers.
    pub(crate) fn with_cursor(mut self, i: usize) -> Self {
        self.cursor = i;
        self
    }

    fn point(&self, mut idx: usize) -> Config {
        let mut c = Vec::with_capacity(self.levels.len());
        for lv in &self.levels {
            c.push(lv[idx % lv.len()]);
            idx /= lv.len();
        }
        c
    }
}

impl Optimizer for GridSearch {
    fn suggest(&mut self, _rng: &mut Rng) -> Config {
        let c = self.point(self.cursor % self.total);
        self.cursor += 1;
        self.space.project(&c)
    }

    fn observe(&mut self, config: Config, loss: f64) {
        self.history.push(Observation { config, loss });
    }

    fn best(&self) -> Option<&Observation> {
        self.history
            .iter()
            .min_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap_or(std::cmp::Ordering::Equal))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpo::aiperf_space;
    use crate::util::rng::derive;

    #[test]
    fn lattice_size_and_uniqueness() {
        let mut gs = GridSearch::new(aiperf_space(), 5);
        // dropout: 5 levels; kernel (integer): 2,3,4,5 → 4 levels.
        assert_eq!(gs.lattice_size(), 20);
        let mut rng = derive(0, "grid", 0);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..20 {
            let c = gs.suggest(&mut rng);
            seen.insert(format!("{c:?}"));
        }
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn wraps_after_exhaustion() {
        let mut gs = GridSearch::new(aiperf_space(), 2);
        let mut rng = derive(0, "grid", 1);
        let n = gs.lattice_size();
        let first = gs.suggest(&mut rng);
        for _ in 1..n {
            gs.suggest(&mut rng);
        }
        assert_eq!(gs.suggest(&mut rng), first);
    }

    #[test]
    fn points_lie_in_space() {
        let space = aiperf_space();
        let mut gs = GridSearch::new(space.clone(), 7);
        let mut rng = derive(0, "grid", 2);
        for _ in 0..gs.lattice_size() {
            let c = gs.suggest(&mut rng);
            assert!(space.contains(&c), "{c:?}");
        }
    }
}
