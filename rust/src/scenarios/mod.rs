//! Named scenario presets reproducing the paper's evaluated systems.
//!
//! AIPerf's weak-scalability claim (§5, Table 1 of the scalability
//! evaluation) spans 4 nodes / 32 NVIDIA T4s (56.1 Tera-OPS) through the
//! 16-node / 128-V100 testbed up to 512 nodes / 4096 Ascend 910s
//! (194.53 Peta-OPS). Each preset packages the cluster shape, accelerator
//! model, and run length of one evaluated system as a ready-to-run
//! [`BenchmarkConfig`], selectable with `aiperf run --scenario NAME`.
//!
//! Accelerator calibration follows the GPU model's convention
//! (sustained *analytical* ops/second — see [`crate::cluster::gpu`]):
//! the sustained rate × utilization reproduces the paper's reported
//! per-device score at each scale.
//!
//! The extra `smoke` preset is a down-scaled run for CI: small cluster,
//! short modelled duration, dense sampling intervals — the workload the
//! engine-parity and wall-clock-budget tests exercise.

use crate::cluster::GpuModel;
use crate::config::BenchmarkConfig;

/// A named, ready-to-run benchmark configuration.
pub struct ScenarioPreset {
    pub name: &'static str,
    pub description: &'static str,
    pub config: BenchmarkConfig,
    /// Wall-clock budget for *simulating* this scenario on a laptop-class
    /// CI host, seconds (enforced for `smoke` in the integration suite).
    pub wall_clock_budget_s: f64,
}

/// NVIDIA T4 (16 GB): ~56.1 Tera-OPS across 32 cards in the paper ⇒
/// ≈ 1.75e12 sustained analytical ops/s/device at benchmark utilization.
fn t4() -> GpuModel {
    GpuModel {
        sustained_flops: 2.0e12,
        memory_bytes: 16 * (1 << 30),
        util_half_batch: 32.0,
        util_max: 0.95,
        step_overhead_s: 2.5e-3,
    }
}

/// Huawei Ascend 910 (32 GB): 194.53 Peta-OPS across 4096 devices in the
/// paper ⇒ ≈ 4.75e13 sustained analytical ops/s/device.
fn ascend910() -> GpuModel {
    GpuModel {
        sustained_flops: 5.4e13,
        memory_bytes: 32 * (1 << 30),
        util_half_batch: 64.0,
        util_max: 0.97,
        step_overhead_s: 1.5e-3,
    }
}

fn smoke() -> ScenarioPreset {
    let mut config = BenchmarkConfig {
        nodes: 2,
        duration_s: 2.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    // Dense sampling so short runs still produce rich series for the
    // parity and integration tests.
    config.telemetry_interval_s = 600.0;
    config.score_interval_s = 900.0;
    ScenarioPreset {
        name: "smoke",
        description: "CI smoke run: 2 nodes x 8 V100, 2 modelled hours, dense sampling",
        config,
        wall_clock_budget_s: 120.0,
    }
}

fn t4_32() -> ScenarioPreset {
    let mut config = BenchmarkConfig {
        nodes: 4,
        duration_s: 12.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    config.node.gpu = t4();
    config.batch_per_gpu = 256; // 16 GB card: headroom for morphed models
    ScenarioPreset {
        name: "t4-32",
        description: "Paper system 1: 4 nodes x 8 NVIDIA T4 (56.1 Tera-OPS)",
        config,
        wall_clock_budget_s: 300.0,
    }
}

fn v100_128() -> ScenarioPreset {
    let config = BenchmarkConfig {
        nodes: 16,
        duration_s: 12.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "v100-128",
        description: "Paper testbed: 16 nodes x 8 V100 NVLink 32 GB (Figs 4-6, 9-12)",
        config,
        wall_clock_budget_s: 300.0,
    }
}

fn ascend_4096() -> ScenarioPreset {
    let mut config = BenchmarkConfig {
        nodes: 512,
        duration_s: 12.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    config.node.gpu = ascend910();
    ScenarioPreset {
        name: "ascend-4096",
        description: "Paper system 3: 512 nodes x 8 Ascend 910 (194.53 Peta-OPS)",
        config,
        wall_clock_budget_s: 1800.0,
    }
}

/// All presets, CI-cheapest first.
pub fn all() -> Vec<ScenarioPreset> {
    vec![smoke(), t4_32(), v100_128(), ascend_4096()]
}

/// Look up a preset by name.
pub fn get(name: &str) -> Option<ScenarioPreset> {
    all().into_iter().find(|p| p.name == name)
}

/// Preset names, for CLI help and error messages.
pub fn names() -> Vec<&'static str> {
    all().into_iter().map(|p| p.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        for name in ["smoke", "t4-32", "v100-128", "ascend-4096"] {
            let p = get(name).unwrap_or_else(|| panic!("missing preset {name}"));
            assert_eq!(p.name, name);
            assert!(!p.description.is_empty());
            assert!(p.wall_clock_budget_s > 0.0);
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn names_unique() {
        let mut n = names();
        n.sort_unstable();
        n.dedup();
        assert_eq!(n.len(), all().len());
    }

    #[test]
    fn cluster_shapes_match_paper() {
        assert_eq!(get("t4-32").unwrap().config.total_gpus(), 32);
        assert_eq!(get("v100-128").unwrap().config.total_gpus(), 128);
        assert_eq!(get("ascend-4096").unwrap().config.total_gpus(), 4096);
    }

    #[test]
    fn accelerator_scale_ordering() {
        // Ascend 910 >> V100 >> T4 in sustained analytical throughput.
        let t4 = get("t4-32").unwrap().config.node.gpu.sustained_flops;
        let v100 = get("v100-128").unwrap().config.node.gpu.sustained_flops;
        let ascend = get("ascend-4096").unwrap().config.node.gpu.sustained_flops;
        assert!(t4 < v100 && v100 < ascend);
    }

    #[test]
    fn t4_batch_fits_memory() {
        let cfg = get("t4-32").unwrap().config;
        // ResNet-50-class model must fit at the preset batch size.
        assert!(cfg.node.gpu.fits(25_600_000, 11_000_000, cfg.batch_per_gpu));
    }
}
