//! Named scenario presets reproducing the paper's evaluated systems.
//!
//! AIPerf's weak-scalability claim (§5, Table 1 of the scalability
//! evaluation) spans 4 nodes / 32 NVIDIA T4s (56.1 Tera-OPS) through the
//! 16-node / 128-V100 testbed up to 512 nodes / 4096 Ascend 910s
//! (194.53 Peta-OPS). Each preset packages one evaluated system — its
//! [`crate::cluster::ClusterTopology`], accelerator models, and run
//! length — as a ready-to-run [`BenchmarkConfig`], selectable with
//! `aiperf run --scenario NAME` and sweepable with `aiperf sweep`.
//!
//! Accelerator calibration lives in the named [`GpuModel`] constructors
//! ([`GpuModel::t4`], [`GpuModel::v100`], [`GpuModel::ascend910`] — see
//! [`crate::cluster::gpu`]): the sustained rate × utilization reproduces
//! the paper's reported per-device score at each scale, enforced by
//! `rust/tests/calibration.rs`.
//!
//! The extra `smoke` preset is a down-scaled run for CI, and
//! `t4v100-mixed` is a heterogeneous two-group topology (the paper's two
//! NVIDIA systems sharing one cluster) exercising the per-group device
//! models, per-group batch sizing (`batch_per_gpu` override on the T4
//! group), the sub-shard trial lanes with deterministic work stealing,
//! and the mixed-GPU engine-parity test.

use crate::cluster::{ClusterTopology, GpuModel, NodeGroup};
use crate::config::{BenchmarkConfig, WarmupSchedule};

/// A named, ready-to-run benchmark configuration.
pub struct ScenarioPreset {
    pub name: &'static str,
    pub description: &'static str,
    pub config: BenchmarkConfig,
    /// Wall-clock budget for *simulating* this scenario on a laptop-class
    /// CI host, seconds (enforced for `smoke` in the integration suite).
    pub wall_clock_budget_s: f64,
}

impl ScenarioPreset {
    /// Per-group cluster shape, e.g. `4x8 t4 (32 GPUs)`.
    pub fn topology_summary(&self) -> String {
        self.config.topology.summary()
    }
}

/// A single-group topology labelled after its accelerator; every paper
/// system runs 8 devices per slave node (Tables 6/7).
fn uniform(label: &'static str, nodes: u64, gpu: GpuModel) -> ClusterTopology {
    ClusterTopology::single(NodeGroup::new(label, nodes, 8, gpu))
}

fn smoke() -> ScenarioPreset {
    let config = BenchmarkConfig {
        topology: uniform("v100", 2, GpuModel::v100()),
        duration_s: 2.0 * 3600.0,
        // Dense sampling so short runs still produce rich series for the
        // parity and integration tests.
        telemetry_interval_s: 600.0,
        score_interval_s: 900.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "smoke",
        description: "CI smoke run: 2 nodes x 8 V100, 2 modelled hours, dense sampling",
        config,
        wall_clock_budget_s: 120.0,
    }
}

fn t4_32() -> ScenarioPreset {
    let config = BenchmarkConfig {
        topology: uniform("t4", 4, GpuModel::t4()),
        duration_s: 12.0 * 3600.0,
        batch_per_gpu: 256, // 16 GB card: headroom for morphed models
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "t4-32",
        description: "Paper system 1: 4 nodes x 8 NVIDIA T4 (56.1 Tera-OPS)",
        config,
        wall_clock_budget_s: 300.0,
    }
}

fn v100_128() -> ScenarioPreset {
    let config = BenchmarkConfig {
        topology: uniform("v100", 16, GpuModel::v100()),
        duration_s: 12.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "v100-128",
        description: "Paper testbed: 16 nodes x 8 V100 NVLink 32 GB (Figs 4-6, 9-12)",
        config,
        wall_clock_budget_s: 300.0,
    }
}

fn ascend_4096() -> ScenarioPreset {
    let config = BenchmarkConfig {
        topology: uniform("ascend910", 512, GpuModel::ascend910()),
        duration_s: 12.0 * 3600.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "ascend-4096",
        description: "Paper system 3: 512 nodes x 8 Ascend 910 (194.53 Peta-OPS)",
        config,
        wall_clock_budget_s: 1800.0,
    }
}

fn t4v100_mixed() -> ScenarioPreset {
    // Each group trains at its memory-appropriate batch: the 16 GB T4
    // overrides down to 256 while the 32 GB V100 keeps the Table-5
    // default of 448 (a single flat batch understated V100 utilization).
    let mut t4 = NodeGroup::new("t4", 2, 8, GpuModel::t4());
    t4.batch_per_gpu = Some(256);
    let config = BenchmarkConfig {
        topology: ClusterTopology {
            groups: vec![t4, NodeGroup::new("v100", 2, 8, GpuModel::v100())],
        },
        duration_s: 6.0 * 3600.0,
        // Two trial lanes per node with deterministic work stealing and
        // cross-group migration: the preset exercising the full elastic
        // scheduler (and the mixed-topology engine-parity seeds with
        // stealing + migration enabled).
        subshards_per_node: 2,
        work_stealing: true,
        migration: true,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "t4v100-mixed",
        description: "Heterogeneous site: 2 nodes x 8 T4 + 2 nodes x 8 V100, sub-sharded",
        config,
        wall_clock_budget_s: 300.0,
    }
}

fn elastic_mixed() -> ScenarioPreset {
    // The cross-group migration showcase. The deadline is deliberately
    // imbalanced against the T4 group: with the short warm-up ladder, a
    // T4 lane's first trial (2 epochs of ~4500 modelled seconds at 4
    // devices / batch 256) completes around t ≈ 9100 s, and one more T4
    // epoch no longer fits the 10800 s budget — so all six T4 lanes run
    // out of runway with ~28 modelled minutes still on the clock, stage
    // their round-2 candidates to NFS, and park. The V100 lanes (~8x
    // faster per device) keep turning trials over until much closer to
    // the deadline and adopt those candidates as they drain, recovering
    // tail ops no intra-node steal can reach. Tight barriers (120 s)
    // keep placement latency small relative to the recovered window.
    //
    // HPO starts at round 2 — the round the stranded T4 lanes stage out
    // in — so migrated candidates carry TPE-suggested hyperparameters
    // and their finalize observations route back to the source lanes'
    // optimizers (`feedback_routing`, on by default): the preset
    // exercises all three closed-loop paths (observation routing,
    // group-scoped penalties, steal-into-migrant).
    let mut t4 = NodeGroup::new("t4", 3, 8, GpuModel::t4());
    t4.batch_per_gpu = Some(256);
    let config = BenchmarkConfig {
        topology: ClusterTopology {
            groups: vec![t4, NodeGroup::new("v100", 2, 8, GpuModel::v100())],
        },
        duration_s: 10_800.0,
        warmup: WarmupSchedule {
            first_epochs: 2,
            step_epochs: 2,
            max_epochs: 6,
            hpo_start_round: 2,
        },
        subshards_per_node: 2,
        work_stealing: true,
        migration: true,
        sync_interval_s: 120.0,
        telemetry_interval_s: 600.0,
        score_interval_s: 900.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "elastic-mixed",
        description: "Migration showcase: imbalanced deadline strands the T4 group's tail",
        config,
        wall_clock_budget_s: 120.0,
    }
}

fn exa_100k() -> ScenarioPreset {
    // Aspirational exascale — a machine the paper could never book time
    // on: 12,800 nodes of 8 Ascend 910s, one trial lane per device, for
    // 102,400 concurrent lanes (25x the paper's largest system). This is
    // the preset the hot-path engine work is sized against: incremental
    // history snapshots, the arena event queue, the closed-form
    // rank-softmax draw, and dynamic shard batching all earn their keep
    // here. Simulated end to end it completes in minutes on one host;
    // the truncated-duration engine-parity seed and the checked-in bench
    // trajectory (BENCH_7.json) keep it honest. At this lane count the
    // buffered report itself is the memory bottleneck — pair the preset
    // with `--stream-report out.ndjson` to write every record as it
    // occurs and keep report memory O(groups + open windows).
    let config = BenchmarkConfig {
        topology: uniform("ascend910", 12_800, GpuModel::ascend910()),
        duration_s: 12.0 * 3600.0,
        // One lane per device: 8 lanes per node, 1 GPU each.
        subshards_per_node: 8,
        // Coarse cadences: every barrier merges ~100k lane outputs and
        // every telemetry tick records ~100k readings, so hourly-class
        // intervals keep the run fast and the report compact while still
        // producing full score/telemetry series.
        sync_interval_s: 1800.0,
        telemetry_interval_s: 3600.0,
        score_interval_s: 3600.0,
        ..BenchmarkConfig::default()
    };
    ScenarioPreset {
        name: "exa-100k",
        description: "Aspirational exascale: 12800 nodes x 8 Ascend 910, 102400 trial lanes",
        config,
        wall_clock_budget_s: 3600.0,
    }
}

/// All presets, CI-cheapest first.
pub fn all() -> Vec<ScenarioPreset> {
    vec![
        smoke(),
        elastic_mixed(),
        t4v100_mixed(),
        t4_32(),
        v100_128(),
        ascend_4096(),
        exa_100k(),
    ]
}

/// Look up a preset by name.
pub fn get(name: &str) -> Option<ScenarioPreset> {
    all().into_iter().find(|p| p.name == name)
}

/// Preset names, for CLI help and error messages.
pub fn names() -> Vec<&'static str> {
    all().into_iter().map(|p| p.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        for name in [
            "smoke",
            "t4-32",
            "v100-128",
            "ascend-4096",
            "t4v100-mixed",
            "elastic-mixed",
            "exa-100k",
        ] {
            let p = get(name).unwrap_or_else(|| panic!("missing preset {name}"));
            assert_eq!(p.name, name);
            assert!(!p.description.is_empty());
            assert!(p.wall_clock_budget_s > 0.0);
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn names_unique() {
        let mut n = names();
        n.sort_unstable();
        n.dedup();
        assert_eq!(n.len(), all().len());
    }

    #[test]
    fn cluster_shapes_match_paper() {
        assert_eq!(get("t4-32").unwrap().config.total_gpus(), 32);
        assert_eq!(get("v100-128").unwrap().config.total_gpus(), 128);
        assert_eq!(get("ascend-4096").unwrap().config.total_gpus(), 4096);
        assert_eq!(get("t4v100-mixed").unwrap().config.total_gpus(), 32);
    }

    #[test]
    fn exa_preset_shape_and_lane_count() {
        let cfg = get("exa-100k").unwrap().config;
        cfg.validate().unwrap();
        assert_eq!(cfg.total_gpus(), 102_400);
        // One lane per device: 12,800 nodes x 8 sub-shards.
        assert_eq!(cfg.subshards_per_node, 8);
        assert_eq!(cfg.total_subshards(), 102_400);
        // Coarse cadences keep the barrier/telemetry volume tractable at
        // this lane count.
        assert!(cfg.sync_interval_s >= 1800.0);
        assert!(cfg.telemetry_interval_s >= 3600.0);
    }

    #[test]
    fn mixed_preset_is_heterogeneous() {
        let cfg = get("t4v100-mixed").unwrap().config;
        assert_eq!(cfg.topology.groups.len(), 2);
        assert_eq!(cfg.topology.groups[0].gpu, GpuModel::t4());
        assert_eq!(cfg.topology.groups[1].gpu, GpuModel::v100());
        let s = get("t4v100-mixed").unwrap().topology_summary();
        assert!(s.contains("2x8 t4") && s.contains("2x8 v100"), "{s}");
    }

    #[test]
    fn mixed_preset_uses_per_group_batch_subshards_and_stealing() {
        let cfg = get("t4v100-mixed").unwrap().config;
        // The 16 GB T4 group overrides down; the V100 group trains at the
        // Table-5 default.
        assert_eq!(cfg.topology.groups[0].batch_per_gpu, Some(256));
        assert_eq!(cfg.topology.groups[1].batch_per_gpu, None);
        assert_eq!(cfg.group_batch(0), 256);
        assert_eq!(cfg.group_batch(1), 448);
        assert_eq!(cfg.subshards_per_node, 2);
        assert!(cfg.work_stealing);
        assert!(cfg.migration);
        // Both groups' batches fit a ResNet-50-class model in memory.
        for (i, g) in cfg.topology.groups.iter().enumerate() {
            assert!(
                g.gpu.fits(25_600_000, 11_000_000, cfg.group_batch(i)),
                "group {} batch {} must fit",
                g.label,
                cfg.group_batch(i)
            );
        }
        cfg.validate().unwrap();
    }

    #[test]
    fn elastic_preset_enables_the_full_elastic_scheduler() {
        let cfg = get("elastic-mixed").unwrap().config;
        cfg.validate().unwrap();
        assert_eq!(cfg.topology.groups.len(), 2);
        assert!(cfg.work_stealing && cfg.migration);
        assert_eq!(cfg.subshards_per_node, 2);
        assert!(cfg.topology.groups.iter().all(|g| g.accepts_migrants));
        // The imbalanced deadline: two warm-up epochs on a 4-device T4
        // lane must consume most (but not all) of the budget, so the T4
        // group strands a tail it can only recover by migrating.
        assert_eq!(cfg.warmup.first_epochs, 2);
        assert!(cfg.duration_s < 4.0 * 3600.0);
        // HPO is live by the stage-out round, so migrated trials carry
        // TPE suggestions and the feedback router has observations to
        // deliver (the routing knob defaults on).
        assert!(cfg.warmup.hpo_active(2));
        assert!(cfg.feedback_routing);
        // Barriers are tight so placements land quickly.
        assert!(cfg.sync_interval_s <= 300.0);
    }

    #[test]
    fn accelerator_scale_ordering() {
        // Ascend 910 >> V100 >> T4 in sustained analytical throughput.
        let flops = |name: &str| {
            get(name).unwrap().config.topology.groups[0]
                .gpu
                .sustained_flops
        };
        assert!(flops("t4-32") < flops("v100-128"));
        assert!(flops("v100-128") < flops("ascend-4096"));
    }

    #[test]
    fn t4_batch_fits_memory() {
        let cfg = get("t4-32").unwrap().config;
        // ResNet-50-class model must fit at the preset batch size.
        let gpu = &cfg.topology.groups[0].gpu;
        assert!(gpu.fits(25_600_000, 11_000_000, cfg.batch_per_gpu));
    }
}
