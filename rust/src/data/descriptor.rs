//! Dataset shape descriptor (Table 5: the dataset is *fixed* to ImageNet).


#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatasetDescriptor {
    pub train_images: u64,
    pub val_images: u64,
    pub image: u64,
    pub channels: u64,
    pub num_classes: u64,
}

impl DatasetDescriptor {
    /// ImageNet-1k, the paper's fixed benchmark dataset (§4.5).
    pub fn imagenet() -> Self {
        DatasetDescriptor {
            train_images: 1_281_167,
            val_images: 50_000,
            image: 224,
            channels: 3,
            num_classes: 1000,
        }
    }

    /// CIFAR10-shaped descriptor (the paper's preliminary/HPO-selection
    /// experiments, Appendix A).
    pub fn cifar10() -> Self {
        DatasetDescriptor {
            train_images: 50_000,
            val_images: 10_000,
            image: 32,
            channels: 3,
            num_classes: 10,
        }
    }

    /// Tiny synthetic corpus for the real-training example.
    pub fn synthetic_tiny() -> Self {
        DatasetDescriptor {
            train_images: 4_096,
            val_images: 512,
            image: 16,
            channels: 3,
            num_classes: 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_sizes_match_paper() {
        let d = DatasetDescriptor::imagenet();
        assert_eq!(d.train_images, 1_281_167);
        assert_eq!(d.val_images, 50_000);
        assert_eq!(d.image, 224);
    }
}
