//! Procedural image corpus — bit-identical twin of
//! `python/compile/dataset.py` (see the golden-value tests on both sides).
//!
//! Each class is a smooth template (four low-frequency plane waves per
//! channel); each sample is its class template plus splitmix64-counter
//! noise. The generator is pure: (seed, index) → (image, label), so the
//! rust trainer and the python oracle see exactly the same data.

use crate::util::rng::splitmix64;

/// Map a 64-bit hash to [0, 1) — mirrors `dataset._unit`.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Deterministic synthetic dataset.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub seed: u64,
    pub image: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub noise: f32,
    templates: Vec<Vec<f32>>, // [class][h*w*c]
}

impl SyntheticDataset {
    pub fn new(seed: u64, image: usize, channels: usize, num_classes: usize) -> Self {
        let templates = (0..num_classes)
            .map(|cls| Self::class_template(seed, cls as u64, image, channels))
            .collect();
        SyntheticDataset {
            seed,
            image,
            channels,
            num_classes,
            noise: 0.35,
            templates,
        }
    }

    /// Smooth per-class template — mirrors `dataset.class_template`.
    fn class_template(seed: u64, cls: u64, image: usize, channels: usize) -> Vec<f32> {
        let n = image * image * channels;
        let mut tpl = vec![0f32; n];
        for c in 0..channels {
            for k in 0..4u64 {
                let h = splitmix64(
                    seed.wrapping_mul(1_000_003)
                        .wrapping_add(cls.wrapping_mul(10_007))
                        .wrapping_add((c as u64).wrapping_mul(101))
                        .wrapping_add(k),
                );
                let fx = 1 + (h & 3);
                let fy = 1 + ((h >> 2) & 3);
                let phase = unit(splitmix64(h)) * 2.0 * std::f64::consts::PI;
                let amp = 0.5 + unit(splitmix64(h ^ 0xABCDEF)) * 0.5;
                for y in 0..image {
                    for x in 0..image {
                        let yy = y as f64 / image as f64;
                        let xx = x as f64 / image as f64;
                        let v = amp
                            * (2.0 * std::f64::consts::PI * (fx as f64 * xx + fy as f64 * yy)
                                + phase)
                                .sin();
                        tpl[(y * image + x) * channels + c] += v as f32;
                    }
                }
            }
        }
        for v in &mut tpl {
            *v /= 4.0;
        }
        tpl
    }

    /// Label of virtual sample `idx` — mirrors the python draw.
    pub fn label(&self, idx: u64) -> u32 {
        (splitmix64(self.seed ^ (idx * 2 + 1)) % self.num_classes as u64) as u32
    }

    /// One sample: (pixels h·w·c row-major channel-last, label).
    pub fn sample(&self, idx: u64) -> (Vec<f32>, u32) {
        let cls = self.label(idx);
        let n = self.image * self.image * self.channels;
        let base = splitmix64(self.seed.wrapping_mul(31).wrapping_add(idx));
        let tpl = &self.templates[cls as usize];
        let mut px = Vec::with_capacity(n);
        for j in 0..n {
            let noise = unit(splitmix64(base.wrapping_add(j as u64))) * 2.0 - 1.0;
            px.push(tpl[j] + self.noise * noise as f32);
        }
        (px, cls)
    }

    /// A batch starting at `start_index`: (x: [batch, h, w, c] flattened,
    /// y: [batch]) — mirrors `dataset.make_batch`.
    pub fn batch(&self, start_index: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let n = self.image * self.image * self.channels;
        let mut xs = Vec::with_capacity(batch * n);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let (px, cls) = self.sample(start_index + i as u64);
            xs.extend_from_slice(&px);
            ys.push(cls as i32);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d = SyntheticDataset::new(3, 8, 3, 10);
        let (a, la) = d.batch(100, 4);
        let (b, lb) = d.batch(100, 4);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn labels_balanced() {
        let d = SyntheticDataset::new(0, 4, 1, 4);
        let mut counts = [0usize; 4];
        for i in 0..512 {
            counts[d.label(i) as usize] += 1;
        }
        for c in counts {
            assert!(c > 512 / 4 / 2, "{counts:?}");
        }
    }

    #[test]
    fn matches_python_golden() {
        // Golden values produced by python/compile/dataset.py:
        //   make_batch(seed=3, start_index=100, batch=2, image=4,
        //              channels=1, num_classes=4)
        // → first pixel of each sample and both labels, pinned in
        //   python/tests via the same call (see test_dataset.py).
        let d = SyntheticDataset::new(3, 4, 1, 4);
        let (xs, ys) = d.batch(100, 2);
        // Structural checks that must agree with python exactly:
        assert_eq!(xs.len(), 2 * 4 * 4);
        assert_eq!(ys.len(), 2);
        for &y in &ys {
            assert!((0..4).contains(&y));
        }
        // Cross-language bit equality is asserted by the integration test
        // rust/tests/python_parity.rs which shells out to python.
        for &v in &xs {
            assert!(v.is_finite());
            assert!(v.abs() < 3.0);
        }
    }

    #[test]
    fn distinct_samples() {
        let d = SyntheticDataset::new(1, 8, 3, 10);
        let (a, _) = d.sample(0);
        let (b, _) = d.sample(1);
        assert_ne!(a, b);
    }

    #[test]
    fn template_bounded() {
        let d = SyntheticDataset::new(5, 16, 3, 10);
        for t in &d.templates {
            for &v in t {
                assert!(v.abs() < 2.0);
            }
        }
    }
}
