//! Dataset substrate.
//!
//! [`synthetic`] generates the procedural classification corpus used by
//! the real-training path — bit-identical to `python/compile/dataset.py`
//! so both sides materialize the same batches without shipping arrays.
//! [`descriptor`] carries the *shape* of the paper's fixed dataset
//! (ImageNet) for the analytical-FLOPs math in simulate mode.

pub mod descriptor;
pub mod shard;
pub mod synthetic;

pub use descriptor::DatasetDescriptor;
pub use shard::{ShardReader, ShardWriter};
pub use synthetic::SyntheticDataset;
