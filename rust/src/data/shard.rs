//! Binary record shards — the TFRecord-style data path (paper §4.5: "the
//! data can be formatted in an optimal way corresponding to the framework,
//! e.g. … TFRecord").
//!
//! Format (little-endian):
//!
//!   shard   := magic "AIPS" | version u32 | record*
//!   record  := payload_len u32 | crc32 u32 | payload
//!   payload := label i32 | h u16 | w u16 | c u16 | pad u16 | f32[h·w·c]
//!
//! The CRC32 (IEEE 802.3, table-driven) guards against torn writes on the
//! shared filesystem — the paper's slaves stream training data over NFS,
//! where partial reads are a real failure mode. The reader verifies every
//! record and surfaces corruption as an error instead of silent garbage.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::synthetic::SyntheticDataset;

const MAGIC: &[u8; 4] = b"AIPS";
const VERSION: u32 = 1;

/// IEEE CRC32, table-driven (no crate available offline).
fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    // Build once; the table is tiny and the build is const-foldable.
    thread_local! {
        static TABLE: [u32; 256] = crc32_table();
    }
    TABLE.with(|t| {
        let mut c = 0xFFFF_FFFFu32;
        for &b in data {
            c = t[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
        }
        c ^ 0xFFFF_FFFF
    })
}

/// One decoded record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub label: i32,
    pub h: u16,
    pub w: u16,
    pub c: u16,
    pub pixels: Vec<f32>,
}

impl Record {
    fn payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.pixels.len() * 4);
        out.extend_from_slice(&self.label.to_le_bytes());
        out.extend_from_slice(&self.h.to_le_bytes());
        out.extend_from_slice(&self.w.to_le_bytes());
        out.extend_from_slice(&self.c.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        for p in &self.pixels {
            out.extend_from_slice(&p.to_le_bytes());
        }
        out
    }

    fn from_payload(payload: &[u8]) -> Result<Record> {
        if payload.len() < 12 {
            bail!("payload too short: {}", payload.len());
        }
        let label = i32::from_le_bytes(payload[0..4].try_into().unwrap());
        let h = u16::from_le_bytes(payload[4..6].try_into().unwrap());
        let w = u16::from_le_bytes(payload[6..8].try_into().unwrap());
        let c = u16::from_le_bytes(payload[8..10].try_into().unwrap());
        let n = h as usize * w as usize * c as usize;
        if payload.len() != 12 + n * 4 {
            bail!("payload size mismatch: {} vs {}", payload.len(), 12 + n * 4);
        }
        let pixels = payload[12..]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Record {
            label,
            h,
            w,
            c,
            pixels,
        })
    }
}

/// Streaming shard writer.
pub struct ShardWriter<W: Write> {
    out: BufWriter<W>,
    pub records: u64,
}

impl ShardWriter<std::fs::File> {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating shard {:?}", path.as_ref()))?;
        Self::new(f)
    }
}

impl<W: Write> ShardWriter<W> {
    pub fn new(inner: W) -> Result<Self> {
        let mut out = BufWriter::new(inner);
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        Ok(ShardWriter { out, records: 0 })
    }

    pub fn write(&mut self, rec: &Record) -> Result<()> {
        let payload = rec.payload();
        self.out
            .write_all(&(payload.len() as u32).to_le_bytes())?;
        self.out.write_all(&crc32(&payload).to_le_bytes())?;
        self.out.write_all(&payload)?;
        self.records += 1;
        Ok(())
    }

    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        Ok(self.records)
    }
}

/// Streaming shard reader (validates CRC per record).
pub struct ShardReader<R: Read> {
    input: BufReader<R>,
}

impl ShardReader<std::fs::File> {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening shard {:?}", path.as_ref()))?;
        Self::new(f)
    }
}

impl<R: Read> ShardReader<R> {
    pub fn new(inner: R) -> Result<Self> {
        let mut input = BufReader::new(inner);
        let mut magic = [0u8; 4];
        input.read_exact(&mut magic).context("reading magic")?;
        if &magic != MAGIC {
            bail!("not an AIPerf shard (bad magic)");
        }
        let mut ver = [0u8; 4];
        input.read_exact(&mut ver)?;
        let version = u32::from_le_bytes(ver);
        if version != VERSION {
            bail!("unsupported shard version {version}");
        }
        Ok(ShardReader { input })
    }

    /// Next record; None at clean EOF; error on corruption.
    pub fn next(&mut self) -> Result<Option<Record>> {
        let mut len_buf = [0u8; 4];
        match self.input.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e).context("reading record length"),
        }
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > 64 << 20 {
            bail!("record length {len} implausible (corrupt shard?)");
        }
        let mut crc_buf = [0u8; 4];
        self.input.read_exact(&mut crc_buf).context("reading crc")?;
        let want = u32::from_le_bytes(crc_buf);
        let mut payload = vec![0u8; len];
        self.input
            .read_exact(&mut payload)
            .context("reading payload (torn record?)")?;
        let got = crc32(&payload);
        if got != want {
            bail!("CRC mismatch: {got:08x} != {want:08x}");
        }
        Ok(Some(Record::from_payload(&payload)?))
    }
}

/// Materialize `count` synthetic samples into a shard file.
pub fn write_synthetic_shard(
    path: impl AsRef<Path>,
    data: &SyntheticDataset,
    start_index: u64,
    count: u64,
) -> Result<u64> {
    let mut w = ShardWriter::create(path)?;
    for i in 0..count {
        let (pixels, label) = data.sample(start_index + i);
        w.write(&Record {
            label: label as i32,
            h: data.image as u16,
            w: data.image as u16,
            c: data.channels as u16,
            pixels,
        })?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn rec(label: i32, n: usize) -> Record {
        Record {
            label,
            h: n as u16,
            w: 1,
            c: 1,
            pixels: (0..n).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn crc32_golden() {
        // Standard IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_records() {
        let mut buf = Vec::new();
        {
            let mut w = ShardWriter::new(&mut buf).unwrap();
            for i in 0..5 {
                w.write(&rec(i, 8)).unwrap();
            }
            assert_eq!(w.finish().unwrap(), 5);
        }
        let mut r = ShardReader::new(&buf[..]).unwrap();
        for i in 0..5 {
            let got = r.next().unwrap().unwrap();
            assert_eq!(got, rec(i, 8));
        }
        assert!(r.next().unwrap().is_none());
    }

    #[test]
    fn detects_corruption() {
        let mut buf = Vec::new();
        {
            let mut w = ShardWriter::new(&mut buf).unwrap();
            w.write(&rec(1, 16)).unwrap();
            w.finish().unwrap();
        }
        // Flip one payload byte.
        let n = buf.len();
        buf[n - 3] ^= 0x40;
        let mut r = ShardReader::new(&buf[..]).unwrap();
        let err = r.next().unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn detects_torn_write() {
        let mut buf = Vec::new();
        {
            let mut w = ShardWriter::new(&mut buf).unwrap();
            w.write(&rec(1, 16)).unwrap();
            w.finish().unwrap();
        }
        buf.truncate(buf.len() - 5); // torn tail
        let mut r = ShardReader::new(&buf[..]).unwrap();
        assert!(r.next().is_err());
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        assert!(ShardReader::new(&b"NOPE\x01\x00\x00\x00"[..]).is_err());
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&99u32.to_le_bytes());
        assert!(ShardReader::new(&buf[..]).is_err());
    }

    #[test]
    fn synthetic_shard_file_roundtrip() {
        let dir = TempDir::new("shard").unwrap();
        let path = dir.path().join("train-00000.aips");
        let data = SyntheticDataset::new(0, 8, 3, 10);
        let n = write_synthetic_shard(&path, &data, 100, 32).unwrap();
        assert_eq!(n, 32);
        let mut r = ShardReader::open(&path).unwrap();
        let mut count = 0;
        while let Some(recd) = r.next().unwrap() {
            let (pixels, label) = data.sample(100 + count);
            assert_eq!(recd.label, label as i32);
            assert_eq!(recd.pixels, pixels);
            count += 1;
        }
        assert_eq!(count, 32);
    }
}
