//! PJRT client wrapper with a compile cache.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute` (the /opt/xla-example/load_hlo pattern).
//! Compilation is the expensive step, so executables are cached per path:
//! one compiled executable per model variant, reused across the whole run
//! (the paper's slaves likewise build each candidate's graph once).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled HLO program.
pub struct Executable {
    inner: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .inner
            .execute::<xla::Literal>(inputs)
            .context("PJRT execution failed")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("device→host transfer failed")?;
        lit.to_tuple().context("output is not a tuple")
    }
}

/// CPU PJRT runtime with per-path executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, std::rc::Rc<Executable>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached).
    pub fn load(&mut self, path: impl AsRef<Path>) -> Result<std::rc::Rc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.get(&path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let exe = std::rc::Rc::new(Executable { inner: exe });
        self.cache.insert(path, exe.clone());
        Ok(exe)
    }

    /// Number of cached executables (perf accounting).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/runtime_e2e.rs
    // (they require `make artifacts` to have run). Here: path hygiene only.
    use super::*;

    #[test]
    fn load_missing_file_errors() {
        let mut rt = Runtime::cpu().unwrap();
        let err = rt.load("/nonexistent/foo.hlo.txt");
        assert!(err.is_err());
        assert_eq!(rt.cache_len(), 0);
    }

    #[test]
    fn platform_is_cpu() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }
}
