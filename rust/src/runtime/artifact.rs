//! Artifact manifest — the ABI between `python/compile/aot.py` and rust.
//!
//! Parsed with the in-tree JSON codec (util::json); field-by-field
//! extraction keeps schema errors precise ("variant 2: missing `files`").

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One parameter slot (ordered).
#[derive(Debug, Clone)]
pub struct ParamSlot {
    pub name: String,
    pub shape: Vec<i64>,
}

impl ParamSlot {
    pub fn elems(&self) -> i64 {
        self.shape.iter().product::<i64>().max(1)
    }
}

/// File names per function kind.
#[derive(Debug, Clone)]
pub struct VariantFiles {
    pub init: String,
    pub train: String,
    pub eval: String,
}

/// One compiled architecture variant.
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub depth: u64,
    pub width: u64,
    pub kernel: u64,
    pub image: u64,
    pub channels: u64,
    pub num_classes: u64,
    pub batch: u64,
    pub seed: u64,
    pub params: Vec<ParamSlot>,
    pub files: VariantFiles,
}

impl Variant {
    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> i64 {
        self.params.iter().map(ParamSlot::elems).sum()
    }
}

/// artifacts/manifest.json root.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub schema: u64,
    pub default_variant: String,
    pub variants: Vec<Variant>,
    pub dir: PathBuf,
}

fn req<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    j.get(key)
        .with_context(|| format!("{ctx}: missing `{key}`"))
}

fn req_str(j: &Json, key: &str, ctx: &str) -> Result<String> {
    Ok(req(j, key, ctx)?
        .as_str()
        .with_context(|| format!("{ctx}: `{key}` is not a string"))?
        .to_string())
}

fn req_u64(j: &Json, key: &str, ctx: &str) -> Result<u64> {
    req(j, key, ctx)?
        .as_u64()
        .with_context(|| format!("{ctx}: `{key}` is not an integer"))
}

fn parse_variant(j: &Json, idx: usize) -> Result<Variant> {
    let ctx = format!("variant {idx}");
    let params = req(j, "params", &ctx)?
        .as_arr()
        .with_context(|| format!("{ctx}: `params` is not an array"))?
        .iter()
        .enumerate()
        .map(|(pi, p)| {
            let pctx = format!("{ctx} param {pi}");
            let shape = req(p, "shape", &pctx)?
                .as_arr()
                .with_context(|| format!("{pctx}: `shape` not an array"))?
                .iter()
                .map(|d| {
                    d.as_i64()
                        .with_context(|| format!("{pctx}: non-integer dim"))
                })
                .collect::<Result<Vec<i64>>>()?;
            Ok(ParamSlot {
                name: req_str(p, "name", &pctx)?,
                shape,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let files = req(j, "files", &ctx)?;
    Ok(Variant {
        name: req_str(j, "name", &ctx)?,
        depth: req_u64(j, "depth", &ctx)?,
        width: req_u64(j, "width", &ctx)?,
        kernel: req_u64(j, "kernel", &ctx)?,
        image: req_u64(j, "image", &ctx)?,
        channels: req_u64(j, "channels", &ctx)?,
        num_classes: req_u64(j, "num_classes", &ctx)?,
        batch: req_u64(j, "batch", &ctx)?,
        seed: req_u64(j, "seed", &ctx)?,
        params,
        files: VariantFiles {
            init: req_str(files, "init", &ctx)?,
            train: req_str(files, "train", &ctx)?,
            eval: req_str(files, "eval", &ctx)?,
        },
    })
}

impl Manifest {
    /// Load from `artifacts/manifest.json` under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let schema = req_u64(&j, "schema", "manifest")?;
        anyhow::ensure!(schema == 1, "unsupported manifest schema {schema}");
        let variants = req(&j, "variants", "manifest")?
            .as_arr()
            .context("manifest: `variants` is not an array")?
            .iter()
            .enumerate()
            .map(|(i, v)| parse_variant(v, i))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!variants.is_empty(), "manifest has no variants");
        let default_variant = req_str(&j, "default_variant", "manifest")?;
        anyhow::ensure!(
            variants.iter().any(|v| v.name == default_variant),
            "default variant {default_variant} not among variants"
        );
        Ok(Manifest {
            schema,
            default_variant,
            variants,
            dir,
        })
    }

    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    pub fn default_variant(&self) -> &Variant {
        self.variant(&self.default_variant)
            .expect("default variant present")
    }

    /// Pick the variant closest in capacity to (depth, width) — the
    /// projection used when mapping a morphed architecture onto the
    /// compiled grid (DESIGN.md §3).
    pub fn nearest_variant(&self, depth: u64, width: u64) -> &Variant {
        self.variants
            .iter()
            .min_by_key(|v| {
                let dd = v.depth.abs_diff(depth);
                let dw = v.width.abs_diff(width);
                dd * 100 + dw
            })
            .expect("non-empty variants")
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn manifest_json() -> &'static str {
        r#"{
          "schema": 1,
          "default_variant": "d2w8k3i16b32",
          "variants": [
            {"name":"d2w8k3i16b32","depth":2,"width":8,"kernel":3,"image":16,
             "channels":3,"num_classes":10,"batch":32,"seed":0,
             "params":[{"name":"stem/conv","shape":[3,3,3,8]},
                        {"name":"stem/bn_scale","shape":[8]}],
             "files":{"init":"i.hlo.txt","train":"t.hlo.txt","eval":"e.hlo.txt"}},
            {"name":"d4w16k3i16b32","depth":4,"width":16,"kernel":3,"image":16,
             "channels":3,"num_classes":10,"batch":32,"seed":0,
             "params":[{"name":"stem/conv","shape":[3,3,3,16]}],
             "files":{"init":"i2.hlo.txt","train":"t2.hlo.txt","eval":"e2.hlo.txt"}}
          ]
        }"#
    }

    #[test]
    fn parse_and_lookup() {
        let dir = TempDir::new("manifest").unwrap();
        std::fs::write(dir.path().join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.default_variant().name, "d2w8k3i16b32");
        assert!(m.variant("nope").is_none());
        assert_eq!(m.variant("d4w16k3i16b32").unwrap().width, 16);
        assert_eq!(m.variants[0].params[0].shape, vec![3, 3, 3, 8]);
        assert_eq!(m.variants[0].total_param_elems(), 216 + 8);
    }

    #[test]
    fn param_slot_math() {
        let s = ParamSlot {
            name: "w".into(),
            shape: vec![3, 3, 3, 8],
        };
        assert_eq!(s.elems(), 216);
        let scalar = ParamSlot {
            name: "s".into(),
            shape: vec![],
        };
        assert_eq!(scalar.elems(), 1);
    }

    #[test]
    fn nearest_variant_projection() {
        let dir = TempDir::new("manifest").unwrap();
        std::fs::write(dir.path().join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.nearest_variant(2, 8).name, "d2w8k3i16b32");
        assert_eq!(m.nearest_variant(5, 20).name, "d4w16k3i16b32");
        assert_eq!(m.nearest_variant(3, 8).name, "d2w8k3i16b32");
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = TempDir::new("manifest").unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn schema_and_field_errors() {
        let dir = TempDir::new("manifest").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"schema": 2, "default_variant": "x", "variants": []}"#,
        )
        .unwrap();
        assert!(Manifest::load(dir.path())
            .unwrap_err()
            .to_string()
            .contains("schema"));

        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"schema": 1, "default_variant": "x",
                "variants": [{"name": "x", "depth": 1}]}"#,
        )
        .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("variant 0"), "{err}");
    }
}
