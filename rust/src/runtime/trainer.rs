//! Stateful trainer over one compiled variant.
//!
//! Holds parameter + momentum literals and threads them through repeated
//! executions of the AOT train step:
//!
//!   train(*params, *moms, x, y, lr) → (*params', *moms', loss)
//!
//! matching python/compile/aot.py's flat ABI (manifest records the slot
//! order). All tensors are f32; labels are i32.

use anyhow::{Context, Result};

use super::artifact::{Manifest, Variant};
use super::client::Runtime;
use crate::data::synthetic::SyntheticDataset;

/// One variant's trainer.
pub struct Trainer {
    pub variant: Variant,
    train_exe: std::rc::Rc<super::client::Executable>,
    eval_exe: std::rc::Rc<super::client::Executable>,
    params: Vec<xla::Literal>,
    moms: Vec<xla::Literal>,
    pub steps_done: u64,
}

impl Trainer {
    /// Build from the manifest: compiles init/train/eval and runs init to
    /// materialize the He-initialized parameters.
    pub fn new(rt: &mut Runtime, manifest: &Manifest, variant_name: &str) -> Result<Self> {
        let variant = manifest
            .variant(variant_name)
            .with_context(|| format!("unknown variant {variant_name}"))?
            .clone();
        let init_exe = rt.load(manifest.hlo_path(&variant.files.init))?;
        let train_exe = rt.load(manifest.hlo_path(&variant.files.train))?;
        let eval_exe = rt.load(manifest.hlo_path(&variant.files.eval))?;

        let params = init_exe.run(&[])?;
        anyhow::ensure!(
            params.len() == variant.num_params(),
            "init returned {} params, manifest says {}",
            params.len(),
            variant.num_params()
        );
        let moms = variant
            .params
            .iter()
            .map(|slot| {
                let zeros = vec![0f32; slot.elems() as usize];
                xla::Literal::vec1(&zeros)
                    .reshape(&slot.shape)
                    .context("zero momentum literal")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Trainer {
            variant,
            train_exe,
            eval_exe,
            params,
            moms,
            steps_done: 0,
        })
    }

    fn batch_literals(&self, xs: &[f32], ys: &[i32]) -> Result<(xla::Literal, xla::Literal)> {
        let v = &self.variant;
        let b = v.batch as i64;
        anyhow::ensure!(
            xs.len() as i64 == b * v.image as i64 * v.image as i64 * v.channels as i64,
            "bad batch pixel count"
        );
        anyhow::ensure!(ys.len() as i64 == b, "bad label count");
        let x = xla::Literal::vec1(xs).reshape(&[
            b,
            v.image as i64,
            v.image as i64,
            v.channels as i64,
        ])?;
        let y = xla::Literal::vec1(ys).reshape(&[b])?;
        Ok((x, y))
    }

    /// One SGD-momentum step; returns the training loss.
    pub fn train_step(&mut self, xs: &[f32], ys: &[i32], lr: f32) -> Result<f32> {
        let (x, y) = self.batch_literals(xs, ys)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * self.params.len() + 3);
        // Flat ABI: params…, moms…, x, y, lr. Literals move into the call;
        // the outputs become the new state.
        inputs.extend(self.params.drain(..));
        inputs.extend(self.moms.drain(..));
        inputs.push(x);
        inputs.push(y);
        inputs.push(xla::Literal::scalar(lr));

        let mut out = self.train_exe.run(&inputs)?;
        let n = self.variant.num_params();
        anyhow::ensure!(out.len() == 2 * n + 1, "train step arity mismatch");
        let loss_lit = out.pop().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0];
        self.moms = out.split_off(n);
        self.params = out;
        self.steps_done += 1;
        Ok(loss)
    }

    /// (loss, accuracy) on one validation batch.
    pub fn eval_step(&self, xs: &[f32], ys: &[i32]) -> Result<(f32, f32)> {
        let (x, y) = self.batch_literals(xs, ys)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            // Literal has no Clone; round-trip through host data.
            inputs.push(clone_literal(p)?);
        }
        inputs.push(x);
        inputs.push(y);
        let out = self.eval_exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 2, "eval step arity mismatch");
        Ok((out[0].to_vec::<f32>()?[0], out[1].to_vec::<f32>()?[0]))
    }

    /// Evaluate over `batches` consecutive validation batches.
    pub fn evaluate(
        &self,
        data: &SyntheticDataset,
        start_index: u64,
        batches: u64,
    ) -> Result<(f32, f32)> {
        let mut loss = 0f32;
        let mut acc = 0f32;
        let b = self.variant.batch as usize;
        for i in 0..batches {
            let (xs, ys) = data.batch(start_index + i * b as u64, b);
            let (l, a) = self.eval_step(&xs, &ys)?;
            loss += l;
            acc += a;
        }
        Ok((loss / batches as f32, acc / batches as f32))
    }
}

/// Clone a literal via host round-trip (f32 tensors only).
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let data = l.to_vec::<f32>()?;
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}
