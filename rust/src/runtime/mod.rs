//! PJRT runtime — executes the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs ONCE at build time (`make artifacts`); this module makes the
//! rust binary self-contained afterwards: it loads `artifacts/*.hlo.txt`
//! (HLO **text** — the jax≥0.5 / xla_extension-0.5.1-safe interchange, see
//! python/compile/aot.py), compiles each on the PJRT CPU client, and runs
//! train/eval steps from the coordinator's hot path with no Python in
//! sight.
//!
//! * [`artifact`] — manifest parsing + artifact registry;
//! * [`client`] — the `xla` crate wrapper: text → executable, with a
//!   compile cache (one compiled executable per model variant);
//! * [`trainer`] — stateful trainer: parameter/momentum literals threaded
//!   through repeated train-step executions.

pub mod artifact;
pub mod client;
pub mod trainer;

pub use artifact::{Manifest, Variant};
pub use client::Runtime;
pub use trainer::Trainer;
