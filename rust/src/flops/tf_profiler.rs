//! Model of TensorFlow's profiler (Appendix B, Table 8 column 1).
//!
//! `tf.profiler` "can only count operations in the FP" — it walks the graph
//! and sums declared per-op FLOPs, seeing neither the backward pass nor
//! hardware-level batching effects. We reproduce that behaviour exactly:
//! the tf.profiler column of Table 8 is the analytical FP count with a
//! small graph-annotation deficit (ops TensorFlow does not annotate, e.g.
//! comparisons in ReLU/pooling, which tf.profiler reports as 0 FLOPs —
//! hence the paper's 9.97e15 vs the analytical 1.00e16).

use super::count::LoweredLayer;
use super::layers::{forward_ops, LayerKind, OpWeights};

/// Per-image FP ops as tf.profiler would report them: conv/dense/BN-style
/// arithmetic is annotated; comparison-only ops (ReLU, max-pool) are not.
pub fn profile_fp_per_image(layers: &[LoweredLayer], w: &OpWeights) -> u64 {
    layers
        .iter()
        .filter(|l| {
            !matches!(l.kind, LayerKind::Relu | LayerKind::MaxPool)
        })
        .map(|l| forward_ops(l.kind, &l.shape).weighted(w))
        .sum()
}

/// Table-8 style per-epoch totals (training FP / validation FP only).
pub fn profile_epoch(
    layers: &[LoweredLayer],
    w: &OpWeights,
    train_images: u64,
    val_images: u64,
) -> (f64, f64) {
    let fp = profile_fp_per_image(layers, w) as f64;
    (fp * train_images as f64, fp * val_images as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flops::resnet50::resnet50_imagenet;

    #[test]
    fn tf_profiler_undercounts_fp() {
        // Paper Table 8: tf.profiler 9.97e15 vs analytical 1.00e16 per epoch.
        let w = OpWeights::default();
        let net = resnet50_imagenet();
        let (train_fp, val_fp) = profile_epoch(&net, &w, 1_281_167, 50_000);
        let analytical_fp = crate::flops::graph_ops_per_image(&net, &w).fp as f64
            * 1_281_167.0;
        assert!(train_fp < analytical_fp);
        let err = (train_fp - 9.97e15).abs() / 9.97e15;
        assert!(err < 0.02, "train_fp={train_fp:.3e}");
        let verr = (val_fp - 3.89e14).abs() / 3.89e14;
        assert!(verr < 0.02, "val_fp={val_fp:.3e}");
    }

    #[test]
    fn ignores_comparison_only_layers() {
        use crate::flops::layers::LayerShape;
        let w = OpWeights::default();
        let relu = LoweredLayer::new(
            LayerKind::Relu,
            LayerShape {
                ho: 10,
                wo: 10,
                co: 10,
                ..Default::default()
            },
        );
        assert_eq!(profile_fp_per_image(&[relu], &w), 0);
    }
}
