//! Model of nvprof kernel-replay measurement (Appendix B, Tables 8/9).
//!
//! The paper's second measurement path profiles actually-executed GPU
//! operations with nvprof. Two effects distinguish it from the analytical
//! count, and both are modelled here (the real tool is a hardware gate —
//! DESIGN.md §2 substitution):
//!
//! 1. **Library overhead** — cuDNN executes slightly more ops than the
//!    mathematical minimum (im2col copies, workspace transforms). Table 8
//!    measures FP 1.02e16 vs analytical 1.00e16 (×1.021) and BP 2.10e16 vs
//!    1.95e16 (×1.077) at batch 1.
//! 2. **Batching optimization** — executed ops grow *sub-linearly* with
//!    batch size: cuDNN amortizes transforms across the batch, so the
//!    acceleration ratio `b·ops(1)/ops(b)` rises from 1 and plateaus at
//!    ≈1.52 past batch 32 (Table 9). We model it as a saturating geometric
//!    approach in log2(batch), anchored exactly at accel(1)=1.
//!
//! §4.4: "if the hardware or software has any special optimization, the
//! operation count is reduced … therefore higher FLOPS eventually" — the
//! analytical score deliberately ignores these effects; this module exists
//! so the benches can regenerate the comparison tables.

use super::count::LoweredLayer;
use super::layers::OpWeights;

/// Calibration constants (fit to the paper's measurements).
#[derive(Debug, Clone, Copy)]
pub struct NvprofModel {
    /// FP overhead factor at batch 1 (Table 8: 1.02e16 / 1.00e16).
    pub fp_overhead: f64,
    /// BP overhead factor at batch 1 (Table 8: 2.10e16 / 1.95e16).
    pub bp_overhead: f64,
    /// Acceleration-ratio plateau (Table 9: ≈1.52).
    pub accel_max: f64,
    /// Geometric approach rate per log2(batch) step.
    pub accel_rate: f64,
}

impl Default for NvprofModel {
    fn default() -> Self {
        NvprofModel {
            fp_overhead: 1.021,
            bp_overhead: 1.077,
            accel_max: 1.52,
            accel_rate: 0.66,
        }
    }
}

/// Paper Table 9 measured values, for side-by-side reporting in the bench:
/// (batch, op_ratio_fp, op_ratio_bp, accel_fp, accel_bp).
pub const PAPER_TABLE9: [(u64, f64, f64, f64, f64); 9] = [
    (1, 1.0, 1.0, 1.0, 1.0),
    (2, 1.838, 1.938, 1.088, 1.032),
    (4, 3.343, 3.394, 1.196, 1.178),
    (8, 6.682, 6.631, 1.197, 1.207),
    (16, 11.123, 11.492, 1.438, 1.392),
    (32, 20.985, 21.313, 1.525, 1.501),
    (64, 41.821, 43.082, 1.530, 1.486),
    (128, 84.368, 83.951, 1.517, 1.525),
    (256, 168.726, 169.026, 1.517, 1.515),
];

impl NvprofModel {
    /// Acceleration ratio `batch·ops(1)/ops(batch)` (Table 9 definition).
    /// accel(1) = 1 exactly; approaches `accel_max` geometrically.
    pub fn acceleration_ratio(&self, batch: u64) -> f64 {
        assert!(batch >= 1);
        let lg = (batch as f64).log2();
        self.accel_max - (self.accel_max - 1.0) * self.accel_rate.powf(lg)
    }

    /// Operation ratio `ops(batch)/ops(1)` (sub-linear in batch).
    pub fn operation_ratio(&self, batch: u64) -> f64 {
        batch as f64 / self.acceleration_ratio(batch)
    }

    /// Executed (measured) per-image FP ops for an architecture at a batch
    /// size, relative to the analytical count.
    pub fn measured_fp_per_image(&self, analytical_fp: u64, batch: u64) -> f64 {
        analytical_fp as f64 * self.fp_overhead / self.acceleration_ratio(batch)
    }

    /// Executed (measured) per-image BP ops.
    pub fn measured_bp_per_image(&self, analytical_bp: u64, batch: u64) -> f64 {
        analytical_bp as f64 * self.bp_overhead / self.acceleration_ratio(batch)
    }

    /// Table 8 row generator: per-epoch (fp_train, bp_train, fp_val) as
    /// nvprof would measure at batch 1 via the Appendix-B partition method.
    pub fn table8_epoch(
        &self,
        layers: &[LoweredLayer],
        w: &OpWeights,
        train_images: u64,
        val_images: u64,
    ) -> (f64, f64, f64) {
        let g = crate::flops::graph_ops_per_image(layers, w);
        (
            self.measured_fp_per_image(g.fp, 1) * train_images as f64,
            self.measured_bp_per_image(g.bp, 1) * train_images as f64,
            self.measured_fp_per_image(g.fp, 1) * val_images as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flops::resnet50::resnet50_imagenet;

    #[test]
    fn accel_anchored_at_one() {
        let m = NvprofModel::default();
        assert!((m.acceleration_ratio(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accel_monotone_and_plateaus() {
        let m = NvprofModel::default();
        let mut prev = 0.0;
        for b in [1u64, 2, 4, 8, 16, 32, 64, 128, 256] {
            let a = m.acceleration_ratio(b);
            assert!(a >= prev, "not monotone at {b}");
            assert!(a < m.accel_max + 1e-9);
            prev = a;
        }
        // Plateau: past batch 32 the curve is within 5 % of the max.
        assert!(m.acceleration_ratio(32) > 0.95 * m.accel_max);
        assert!((m.acceleration_ratio(256) - m.accel_max).abs() < 0.02);
    }

    #[test]
    fn operation_ratio_sublinear() {
        let m = NvprofModel::default();
        for b in [2u64, 4, 8, 16, 32, 64, 128, 256] {
            let r = m.operation_ratio(b);
            assert!(r < b as f64, "op ratio must be sub-linear at {b}");
            assert!(r > b as f64 / m.accel_max - 1e-9);
        }
    }

    #[test]
    fn matches_paper_shape_within_band() {
        // Not the authors' testbed: require the SHAPE (who wins, plateau
        // level), not point-exact values — ±15 % per row on acceleration.
        let m = NvprofModel::default();
        for (b, _, _, accel_fp, _) in PAPER_TABLE9 {
            let got = m.acceleration_ratio(b);
            assert!(
                (got - accel_fp).abs() / accel_fp < 0.15,
                "batch {b}: got {got:.3} want {accel_fp:.3}"
            );
        }
    }

    #[test]
    fn table8_row_matches_paper() {
        let m = NvprofModel::default();
        let w = OpWeights::default();
        let (fp, bp, val) = m.table8_epoch(&resnet50_imagenet(), &w, 1_281_167, 50_000);
        // Paper: nvprof FP(train) 1.02e16, BP(train) 2.10e16, FP(val) 3.98e14.
        assert!((fp - 1.02e16).abs() / 1.02e16 < 0.03, "fp={fp:.3e}");
        assert!((bp - 2.10e16).abs() / 2.10e16 < 0.03, "bp={bp:.3e}");
        assert!((val - 3.98e14).abs() / 3.98e14 < 0.03, "val={val:.3e}");
        // BP/FP ≈ 2.0603.
        assert!((bp / fp - 2.0603).abs() < 0.06);
    }
}
