//! Per-layer analytical operation counts — paper Tables 2 (FP) and 3 (BP).
//!
//! The paper treats op counting "as a mathematical problem": for each layer
//! kind, the forward-pass and backward-pass operation mix is a closed-form
//! function of the layer's shape. Operation weights follow Huss & Pennline
//! (1987): MACC = 2, add/sub/mul/comparison = 1, divide/sqrt = 4,
//! exponential (and other special functions) = 8.


/// Huss–Pennline operation weights (Table 2 caption).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpWeights {
    pub macc: u64,
    pub add: u64,
    pub mul: u64,
    pub comparison: u64,
    pub div: u64,
    pub sqrt: u64,
    pub exp: u64,
}

impl Default for OpWeights {
    fn default() -> Self {
        OpWeights {
            macc: 2,
            add: 1,
            mul: 1,
            comparison: 1,
            div: 4,
            sqrt: 4,
            exp: 8,
        }
    }
}

/// Raw (unweighted) operation mix for one layer, per image.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub macc: u64,
    pub add: u64,
    pub mul: u64,
    pub comparison: u64,
    pub div: u64,
    pub sqrt: u64,
    pub exp: u64,
}

impl OpCounts {
    /// Weighted operation count (what Tables 4/8 report as "operations").
    pub fn weighted(&self, w: &OpWeights) -> u64 {
        self.macc * w.macc
            + self.add * w.add
            + self.mul * w.mul
            + self.comparison * w.comparison
            + self.div * w.div
            + self.sqrt * w.sqrt
            + self.exp * w.exp
    }

    pub fn zero() -> Self {
        Self::default()
    }

    pub fn saturating_sum(&self, o: &OpCounts) -> OpCounts {
        OpCounts {
            macc: self.macc + o.macc,
            add: self.add + o.add,
            mul: self.mul + o.mul,
            comparison: self.comparison + o.comparison,
            div: self.div + o.div,
            sqrt: self.sqrt + o.sqrt,
            exp: self.exp + o.exp,
        }
    }
}

impl std::ops::Add for OpCounts {
    type Output = OpCounts;
    fn add(self, o: OpCounts) -> OpCounts {
        self.saturating_sum(&o)
    }
}

impl std::iter::Sum for OpCounts {
    fn sum<I: Iterator<Item = OpCounts>>(iter: I) -> OpCounts {
        iter.fold(OpCounts::zero(), |a, b| a + b)
    }
}

/// Layer kinds of the AIPerf model family plus everything ResNet-50 needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// K×K convolution (any stride; shapes carry the output dims).
    Conv,
    /// Fully connected Ci→Co (with bias).
    Dense,
    /// Batch normalization over Hi×Wi×Ci.
    BatchNorm,
    /// ReLU activation over the output volume.
    Relu,
    /// Element-wise residual add over the output volume.
    Add,
    /// K×K max-pooling.
    MaxPool,
    /// Global average pooling over Hi×Wi×Ci.
    GlobalPool,
    /// Softmax over Co classes.
    Softmax,
}

/// Shape record consumed by the formulas. Unused fields are zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerShape {
    /// Input spatial dims and channels.
    pub hi: u64,
    pub wi: u64,
    pub ci: u64,
    /// Output spatial dims and channels.
    pub ho: u64,
    pub wo: u64,
    pub co: u64,
    /// Kernel edge (conv / pooling).
    pub k: u64,
}

/// Forward-pass operation counts per image — paper Table 2, verbatim.
pub fn forward_ops(kind: LayerKind, s: &LayerShape) -> OpCounts {
    let mut c = OpCounts::zero();
    match kind {
        LayerKind::Conv => {
            // MACC = K·K·Ci·Ho·Wo·Co
            c.macc = s.k * s.k * s.ci * s.ho * s.wo * s.co;
        }
        LayerKind::Dense => {
            // MACC = Ci·Co
            c.macc = s.ci * s.co;
        }
        LayerKind::BatchNorm => {
            // MACC = Add = Div = Hi·Wi·Ci
            let v = s.hi * s.wi * s.ci;
            c.macc = v;
            c.add = v;
            c.div = v;
        }
        LayerKind::Relu => {
            // Comparison = Ho·Wo·Co
            c.comparison = s.ho * s.wo * s.co;
        }
        LayerKind::Add => {
            // Add = Ho·Wo·Co
            c.add = s.ho * s.wo * s.co;
        }
        LayerKind::MaxPool => {
            // Comparison = K·K·Ho·Wo·Co
            c.comparison = s.k * s.k * s.ho * s.wo * s.co;
        }
        LayerKind::GlobalPool => {
            // Add = Hi·Wi·Ci ; Div = Ci
            c.add = s.hi * s.wi * s.ci;
            c.div = s.ci;
        }
        LayerKind::Softmax => {
            // Exp = Add = Div = Co
            c.exp = s.co;
            c.add = s.co;
            c.div = s.co;
        }
    }
    c
}

/// Backward-pass operation counts per image — paper Table 3, verbatim.
///
/// Conv:  MACC = 2·(K·K·Ci·Ho·Wo·Co) + K·K·Ci·Co   (gradients + update)
/// Dense: MACC = 2·Ci·Co + (Ci+1)·Co
/// Everything else: "ignorable for practical purposes" → 0.
pub fn backward_ops(kind: LayerKind, s: &LayerShape) -> OpCounts {
    let mut c = OpCounts::zero();
    match kind {
        LayerKind::Conv => {
            c.macc = 2 * (s.k * s.k * s.ci * s.ho * s.wo * s.co) + s.k * s.k * s.ci * s.co;
        }
        LayerKind::Dense => {
            c.macc = 2 * s.ci * s.co + (s.ci + 1) * s.co;
        }
        _ => {}
    }
    c
}

/// Trainable parameter count of a layer (for the gradient-descent update
/// accounting in §4.4 and for model-capacity estimates in the surrogate).
pub fn param_count(kind: LayerKind, s: &LayerShape) -> u64 {
    match kind {
        LayerKind::Conv => s.k * s.k * s.ci * s.co, // no bias (paper §4.4)
        LayerKind::Dense => (s.ci + 1) * s.co,      // with bias
        LayerKind::BatchNorm => 2 * s.ci,           // scale + offset
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_shape() -> LayerShape {
        LayerShape {
            hi: 56,
            wi: 56,
            ci: 64,
            ho: 56,
            wo: 56,
            co: 64,
            k: 3,
        }
    }

    #[test]
    fn conv_fp_formula() {
        let s = conv_shape();
        let c = forward_ops(LayerKind::Conv, &s);
        assert_eq!(c.macc, 3 * 3 * 64 * 56 * 56 * 64);
        assert_eq!(c.add, 0);
    }

    #[test]
    fn conv_bp_is_double_plus_update() {
        let s = conv_shape();
        let fp = forward_ops(LayerKind::Conv, &s);
        let bp = backward_ops(LayerKind::Conv, &s);
        assert_eq!(bp.macc, 2 * fp.macc + 3 * 3 * 64 * 64);
    }

    #[test]
    fn dense_bp_ratio_matches_table4() {
        // ResNet-50 head: 2048 → 1000. Paper Table 4: BP/FP = 3.0005.
        let s = LayerShape {
            ci: 2048,
            co: 1000,
            ..Default::default()
        };
        let w = OpWeights::default();
        let fp = forward_ops(LayerKind::Dense, &s).weighted(&w);
        let bp = backward_ops(LayerKind::Dense, &s).weighted(&w);
        assert_eq!(fp, 2 * 2048 * 1000);
        let ratio = bp as f64 / fp as f64;
        assert!((ratio - 3.0005).abs() < 1e-3, "ratio={ratio}");
    }

    #[test]
    fn batchnorm_weighted_is_7x_volume() {
        // MACC(2) + Add(1) + Div(4) per element = 7 weighted ops.
        let s = LayerShape {
            hi: 10,
            wi: 10,
            ci: 4,
            ..Default::default()
        };
        let w = OpWeights::default();
        assert_eq!(forward_ops(LayerKind::BatchNorm, &s).weighted(&w), 7 * 400);
    }

    #[test]
    fn softmax_weighted_is_13x_classes() {
        // Exp(8) + Add(1) + Div(4) per class = 13 weighted ops.
        let s = LayerShape {
            co: 1000,
            ..Default::default()
        };
        let w = OpWeights::default();
        assert_eq!(forward_ops(LayerKind::Softmax, &s).weighted(&w), 13 * 1000);
    }

    #[test]
    fn pooling_and_relu_and_add() {
        let s = LayerShape {
            hi: 8,
            wi: 8,
            ci: 16,
            ho: 4,
            wo: 4,
            co: 16,
            k: 2,
        };
        assert_eq!(forward_ops(LayerKind::MaxPool, &s).comparison, 4 * 16 * 16);
        assert_eq!(forward_ops(LayerKind::Relu, &s).comparison, 4 * 4 * 16);
        assert_eq!(forward_ops(LayerKind::Add, &s).add, 4 * 4 * 16);
        let gp = forward_ops(LayerKind::GlobalPool, &s);
        assert_eq!(gp.add, 8 * 8 * 16);
        assert_eq!(gp.div, 16);
    }

    #[test]
    fn non_conv_dense_bp_is_zero() {
        let s = conv_shape();
        for kind in [
            LayerKind::BatchNorm,
            LayerKind::Relu,
            LayerKind::Add,
            LayerKind::MaxPool,
            LayerKind::GlobalPool,
            LayerKind::Softmax,
        ] {
            assert_eq!(backward_ops(kind, &s), OpCounts::zero());
        }
    }

    #[test]
    fn param_counts() {
        let s = conv_shape();
        assert_eq!(param_count(LayerKind::Conv, &s), 9 * 64 * 64);
        let d = LayerShape {
            ci: 2048,
            co: 1000,
            ..Default::default()
        };
        assert_eq!(param_count(LayerKind::Dense, &d), 2049 * 1000);
        assert_eq!(param_count(LayerKind::Relu, &s), 0);
    }

    #[test]
    fn opcounts_sum() {
        let a = OpCounts {
            macc: 1,
            add: 2,
            ..Default::default()
        };
        let b = OpCounts {
            macc: 10,
            exp: 1,
            ..Default::default()
        };
        let s: OpCounts = [a, b].into_iter().sum();
        assert_eq!(s.macc, 11);
        assert_eq!(s.add, 2);
        assert_eq!(s.exp, 1);
        assert_eq!(s.weighted(&OpWeights::default()), 22 + 2 + 8);
    }
}
