//! ResNet-50 layer inventory — the paper's Table 4 validation target.
//!
//! AIPerf validates its analytical op-counting against ResNet-50 on
//! ImageNet (224×224): Table 4 reports per-image weighted ops of
//! 7.81e9 (FP), 1.52e10 (BP), BP/FP ≈ 1.9531, total 2.31e10 — dominated by
//! convolution (7.71e9 / 1.52e10). This module builds the exact He et al.
//! (2016) v1 inventory (stride-2 on the first 1×1 of each downsampling
//! bottleneck) so `benches/table4_flops_breakdown` can regenerate the table
//! and the unit tests can pin the numbers.

use super::count::LoweredLayer;
use super::layers::{LayerKind, LayerShape};

/// Convenience constructors.
fn conv(hi: u64, ci: u64, ho: u64, co: u64, k: u64) -> LoweredLayer {
    LoweredLayer::new(
        LayerKind::Conv,
        LayerShape {
            hi,
            wi: hi,
            ci,
            ho,
            wo: ho,
            co,
            k,
        },
    )
}

fn bn(h: u64, c: u64) -> LoweredLayer {
    LoweredLayer::new(
        LayerKind::BatchNorm,
        LayerShape {
            hi: h,
            wi: h,
            ci: c,
            ..Default::default()
        },
    )
}

fn relu(h: u64, c: u64) -> LoweredLayer {
    LoweredLayer::new(
        LayerKind::Relu,
        LayerShape {
            ho: h,
            wo: h,
            co: c,
            ..Default::default()
        },
    )
}

fn add(h: u64, c: u64) -> LoweredLayer {
    LoweredLayer::new(
        LayerKind::Add,
        LayerShape {
            ho: h,
            wo: h,
            co: c,
            ..Default::default()
        },
    )
}

/// One bottleneck: 1×1 (stride s) → 3×3 → 1×1, BN+ReLU per conv,
/// projection shortcut when shapes change, residual add + final ReLU.
fn bottleneck(
    layers: &mut Vec<LoweredLayer>,
    hin: u64,
    cin: u64,
    cmid: u64,
    cout: u64,
    stride: u64,
) {
    let hout = hin / stride;
    // conv a: 1×1, stride s (ResNet v1 places the stride here).
    layers.push(conv(hin, cin, hout, cmid, 1));
    layers.push(bn(hout, cmid));
    layers.push(relu(hout, cmid));
    // conv b: 3×3.
    layers.push(conv(hout, cmid, hout, cmid, 3));
    layers.push(bn(hout, cmid));
    layers.push(relu(hout, cmid));
    // conv c: 1×1 expand.
    layers.push(conv(hout, cmid, hout, cout, 1));
    layers.push(bn(hout, cout));
    // projection shortcut.
    if cin != cout || stride != 1 {
        layers.push(conv(hin, cin, hout, cout, 1));
        layers.push(bn(hout, cout));
    }
    layers.push(add(hout, cout));
    layers.push(relu(hout, cout));
}

/// Full ResNet-50 (v1) on `image`×`image` inputs with `classes` outputs.
pub fn resnet50(image: u64, classes: u64) -> Vec<LoweredLayer> {
    let mut l = Vec::with_capacity(200);
    let h1 = image / 2; // stem conv stride 2
    let h2 = h1 / 2; // maxpool stride 2

    // Stem: 7×7/2 conv, BN, ReLU, 3×3/2 maxpool.
    l.push(conv(image, 3, h1, 64, 7));
    l.push(bn(h1, 64));
    l.push(relu(h1, 64));
    l.push(LoweredLayer::new(
        LayerKind::MaxPool,
        LayerShape {
            hi: h1,
            wi: h1,
            ci: 64,
            ho: h2,
            wo: h2,
            co: 64,
            k: 3,
        },
    ));

    // Stage configuration: (blocks, cmid, cout, stride of first block).
    let stages: [(u64, u64, u64, u64); 4] = [
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ];
    let mut h = h2;
    let mut cin = 64;
    for (blocks, cmid, cout, stride) in stages {
        for b in 0..blocks {
            let s = if b == 0 { stride } else { 1 };
            bottleneck(&mut l, h, cin, cmid, cout, s);
            h /= s;
            cin = cout;
        }
    }

    // Head: global average pool, dense, softmax.
    l.push(LoweredLayer::new(
        LayerKind::GlobalPool,
        LayerShape {
            hi: h,
            wi: h,
            ci: 2048,
            ..Default::default()
        },
    ));
    l.push(LoweredLayer::new(
        LayerKind::Dense,
        LayerShape {
            ci: 2048,
            co: classes,
            ..Default::default()
        },
    ));
    l.push(LoweredLayer::new(
        LayerKind::Softmax,
        LayerShape {
            co: classes,
            ..Default::default()
        },
    ));
    l
}

/// ImageNet configuration (224×224, 1000 classes) used throughout §4.4.
pub fn resnet50_imagenet() -> Vec<LoweredLayer> {
    resnet50(224, 1000)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flops::count::graph_ops_per_image;
    use crate::flops::layers::{forward_ops, OpWeights};

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn layer_census() {
        let net = resnet50_imagenet();
        let convs = net.iter().filter(|l| l.kind == LayerKind::Conv).count();
        // 1 stem + 16 blocks × 3 + 4 projections = 53 convolutions.
        assert_eq!(convs, 53);
        let denses = net.iter().filter(|l| l.kind == LayerKind::Dense).count();
        assert_eq!(denses, 1);
        let adds = net.iter().filter(|l| l.kind == LayerKind::Add).count();
        assert_eq!(adds, 16);
    }

    #[test]
    fn param_count_matches_published() {
        // ResNet-50 has ≈25.6 M parameters (weights; our conv has no bias).
        let w = OpWeights::default();
        let g = graph_ops_per_image(&resnet50_imagenet(), &w);
        assert!(
            rel_err(g.params as f64, 25.55e6) < 0.01,
            "params={}",
            g.params
        );
    }

    #[test]
    fn table4_conv_fp() {
        // Paper: convolutional FP = 7.71e9 weighted ops per image.
        let w = OpWeights::default();
        let fp: u64 = resnet50_imagenet()
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .map(|l| forward_ops(l.kind, &l.shape).weighted(&w))
            .sum();
        assert!(rel_err(fp as f64, 7.71e9) < 0.02, "conv fp={fp:.3e}", fp = fp as f64);
    }

    #[test]
    fn table4_bn_relu_pool_add_softmax() {
        let w = OpWeights::default();
        let sum_kind = |kind: LayerKind| -> u64 {
            resnet50_imagenet()
                .iter()
                .filter(|l| l.kind == kind)
                .map(|l| forward_ops(l.kind, &l.shape).weighted(&w))
                .sum()
        };
        // Paper Table 4 (per image, weighted).
        assert!(rel_err(sum_kind(LayerKind::BatchNorm) as f64, 7.41e7) < 0.02);
        assert!(rel_err(sum_kind(LayerKind::Relu) as f64, 9.08e6) < 0.03);
        assert!(rel_err(sum_kind(LayerKind::MaxPool) as f64, 1.81e6) < 0.02);
        assert!(rel_err(sum_kind(LayerKind::Add) as f64, 5.52e6) < 0.02);
        // Dense FP = 4.10e6; softmax 2.10e4 (paper rounds; we use 13·1000).
        assert!(rel_err(sum_kind(LayerKind::Dense) as f64, 4.10e6) < 0.01);
        assert!(rel_err(sum_kind(LayerKind::GlobalPool) as f64, 1.00e5) < 0.10);
        assert!(rel_err(sum_kind(LayerKind::Softmax) as f64, 2.10e4) < 0.40);
    }

    #[test]
    fn table4_totals_and_ratio() {
        let w = OpWeights::default();
        let g = graph_ops_per_image(&resnet50_imagenet(), &w);
        assert!(rel_err(g.fp as f64, 7.81e9) < 0.02, "fp={:.3e}", g.fp as f64);
        assert!(rel_err(g.bp as f64, 1.52e10) < 0.02, "bp={:.3e}", g.bp as f64);
        assert!(
            (g.bp_fp_ratio() - 1.9531).abs() < 0.05,
            "ratio={}",
            g.bp_fp_ratio()
        );
        let total = (g.fp + g.bp) as f64;
        assert!(rel_err(total, 2.31e10) < 0.02, "total={total:.3e}");
    }

    #[test]
    fn table8_epoch_totals() {
        // FP (training, per epoch) = 1.00e16; FP (validation) = 3.90e14;
        // total (training) = 2.95e16; grand total = 2.99e16.
        let w = OpWeights::default();
        let g = graph_ops_per_image(&resnet50_imagenet(), &w);
        let fp_train = g.fp as f64 * 1_281_167.0;
        let bp_train = g.bp as f64 * 1_281_167.0;
        let fp_val = g.fp as f64 * 50_000.0;
        assert!(rel_err(fp_train, 1.00e16) < 0.02, "{fp_train:.3e}");
        assert!(rel_err(fp_train + bp_train, 2.95e16) < 0.02);
        assert!(rel_err(fp_val, 3.90e14) < 0.02);
        assert!(rel_err(fp_train + bp_train + fp_val, 2.99e16) < 0.02);
    }

    #[test]
    fn smaller_images_scale_down() {
        let w = OpWeights::default();
        let big = graph_ops_per_image(&resnet50_imagenet(), &w);
        let small = graph_ops_per_image(&resnet50(112, 1000), &w);
        assert!(small.fp < big.fp / 3);
        assert_eq!(small.params, big.params); // params don't depend on H×W
    }
}
