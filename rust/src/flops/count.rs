//! Whole-run operation accounting (paper §4.4 + Appendix B).
//!
//! Given a lowered layer inventory (from [`crate::nas::graph`] or
//! [`super::resnet50`]) this module computes per-image FP/BP operation
//! counts and scales them over a training run:
//!
//! `Total = init + [train_ops·train_images + val_ops·val_images] · epochs`
//!
//! The score is then `FLOPS = Total ops / wall time` (Equation 4). All
//! counts use the Huss–Pennline weights of [`super::layers`].


use super::layers::{
    backward_ops, forward_ops, param_count, LayerKind, LayerShape, OpWeights,
};

/// One layer instance with concrete shapes — the unit of counting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoweredLayer {
    pub kind: LayerKind,
    pub shape: LayerShape,
}

impl LoweredLayer {
    pub fn new(kind: LayerKind, shape: LayerShape) -> Self {
        LoweredLayer { kind, shape }
    }
}

/// Per-image operation totals of one architecture.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GraphOps {
    /// Weighted forward-pass ops per image.
    pub fp: u64,
    /// Weighted backward-pass ops per image (gradients + parameter update).
    pub bp: u64,
    /// Trainable parameters.
    pub params: u64,
}

impl GraphOps {
    /// Weighted training ops per image (FP + BP).
    pub fn train_per_image(&self) -> u64 {
        self.fp + self.bp
    }

    /// Weighted validation ops per image (FP only).
    pub fn val_per_image(&self) -> u64 {
        self.fp
    }

    /// BP/FP ratio (paper Table 4 reports ≈1.95 for ResNet-50).
    pub fn bp_fp_ratio(&self) -> f64 {
        if self.fp == 0 {
            0.0
        } else {
            self.bp as f64 / self.fp as f64
        }
    }
}

/// Count weighted FP/BP ops per image over a layer inventory.
pub fn graph_ops_per_image(layers: &[LoweredLayer], w: &OpWeights) -> GraphOps {
    let mut fp = 0u64;
    let mut bp = 0u64;
    let mut params = 0u64;
    for l in layers {
        fp += forward_ops(l.kind, &l.shape).weighted(w);
        bp += backward_ops(l.kind, &l.shape).weighted(w);
        params += param_count(l.kind, &l.shape);
    }
    GraphOps { fp, bp, params }
}

/// Data volume of a training run (ImageNet defaults per Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainingVolume {
    pub train_images: u64,
    pub val_images: u64,
    pub epochs: u64,
}

impl TrainingVolume {
    /// ImageNet-1k sizes fixed by the paper (§4.5).
    pub fn imagenet(epochs: u64) -> Self {
        TrainingVolume {
            train_images: 1_281_167,
            val_images: 50_000,
            epochs,
        }
    }
}

/// Operation totals for a whole run — Appendix B bullet list.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunFlops {
    /// One-time initialization ops (does NOT scale with data; Appendix B
    /// calls this `init.(FLOPs)`). We charge one FP+BP over a single batch
    /// worth of images as the graph-build/weight-init cost.
    pub init: u64,
    /// Training ops over all epochs.
    pub train: u64,
    /// Validation ops over all epochs.
    pub val: u64,
}

impl RunFlops {
    pub fn total(&self) -> u64 {
        self.init + self.train + self.val
    }
}

/// Total weighted ops for training + validating one architecture.
pub fn training_flops(ops: &GraphOps, vol: &TrainingVolume, init_batch: u64) -> RunFlops {
    RunFlops {
        init: ops.train_per_image() * init_batch,
        train: ops.train_per_image() * vol.train_images * vol.epochs,
        val: ops.val_per_image() * vol.val_images * vol.epochs,
    }
}

/// Equation 4: FLOPS = total ops / total seconds.
pub fn flops_per_second(total_ops: u64, seconds: f64) -> f64 {
    assert!(seconds > 0.0, "wall time must be positive");
    total_ops as f64 / seconds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Vec<LoweredLayer> {
        vec![
            LoweredLayer::new(
                LayerKind::Conv,
                LayerShape {
                    hi: 8,
                    wi: 8,
                    ci: 3,
                    ho: 8,
                    wo: 8,
                    co: 4,
                    k: 3,
                },
            ),
            LoweredLayer::new(
                LayerKind::Relu,
                LayerShape {
                    ho: 8,
                    wo: 8,
                    co: 4,
                    ..Default::default()
                },
            ),
            LoweredLayer::new(
                LayerKind::Dense,
                LayerShape {
                    ci: 4,
                    co: 10,
                    ..Default::default()
                },
            ),
        ]
    }

    #[test]
    fn graph_ops_sum_layers() {
        let w = OpWeights::default();
        let g = graph_ops_per_image(&tiny_graph(), &w);
        let conv_macc = 3 * 3 * 3 * 8 * 8 * 4u64;
        let fp = conv_macc * 2 + 8 * 8 * 4 + 4 * 10 * 2;
        assert_eq!(g.fp, fp);
        let bp = (2 * conv_macc + 3 * 3 * 3 * 4) * 2 + (2 * 4 * 10 + 5 * 10) * 2;
        assert_eq!(g.bp, bp);
        assert_eq!(g.params, 3 * 3 * 3 * 4 + 5 * 10);
    }

    #[test]
    fn run_flops_scaling() {
        let ops = GraphOps {
            fp: 100,
            bp: 200,
            params: 7,
        };
        let vol = TrainingVolume {
            train_images: 10,
            val_images: 4,
            epochs: 3,
        };
        let r = training_flops(&ops, &vol, 2);
        assert_eq!(r.init, 300 * 2);
        assert_eq!(r.train, 300 * 10 * 3);
        assert_eq!(r.val, 100 * 4 * 3);
        assert_eq!(r.total(), 600 + 9000 + 1200);
    }

    #[test]
    fn imagenet_volume_fixed_sizes() {
        let v = TrainingVolume::imagenet(90);
        assert_eq!(v.train_images, 1_281_167);
        assert_eq!(v.val_images, 50_000);
    }

    #[test]
    fn flops_per_second_divides() {
        assert_eq!(flops_per_second(1_000, 2.0), 500.0);
    }

    #[test]
    #[should_panic]
    fn flops_rejects_zero_time() {
        flops_per_second(1, 0.0);
    }
}
