//! Analytical FLOPS measurement (paper §4.4, Tables 2/3/4, Appendix B).
//!
//! AIPerf's major score is FLOPS computed *analytically*: for a given
//! architecture, hyperparameters, and data, the operation count needed to
//! train and validate is predetermined — independent of any hardware or
//! software optimization. This module implements:
//!
//! * [`layers`] — per-layer forward/backward op-count formulas (Tables 2/3)
//!   with the Huss–Pennline operation weights;
//! * [`count`] — op counting over a lowered layer graph and over whole
//!   training runs (Equation 4 / Appendix B bullets);
//! * [`resnet50`] — the exact ResNet-50 layer inventory used to validate
//!   the method against the paper's Table 4 numbers;
//! * [`tf_profiler`] — a model of TensorFlow's profiler (FP only);
//! * [`nvprof_model`] — a model of nvprof kernel-replay measurement,
//!   including the cuDNN batching optimization of Table 9.

pub mod count;
pub mod layers;
pub mod nvprof_model;
pub mod resnet50;
pub mod tf_profiler;

pub use count::{graph_ops_per_image, training_flops, RunFlops, TrainingVolume};
pub use layers::{LayerKind, LayerShape, OpCounts, OpWeights};
