//! Unique temp directories for tests (tempfile is not vendored).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::SeqCst);
        // detlint: allow(env_read) — test scaffolding: the OS temp root is
        // the one ambient input a vendored-free TempDir needs.
        let path = std::env::temp_dir().join(format!(
            "aiperf-{label}-{}-{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), "1").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
