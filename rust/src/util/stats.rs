//! Statistics helpers used by telemetry, the log-fit predictor, and the
//! scaling-linearity checks in the benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples
/// (the paper notes "there is no standard deviation of just 1 node").
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Ordinary least squares y = a + b·x. Returns (a, b).
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "OLS needs at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (my - b * mx, b)
}

/// Coefficient of determination of the OLS fit of ys on xs.
pub fn r_squared(xs: &[f64], ys: &[f64]) -> f64 {
    let (a, b) = ols(xs, ys);
    let my = mean(ys);
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Root-mean-square error of predictions vs observations.
pub fn rmse(pred: &[f64], obs: &[f64]) -> f64 {
    assert_eq!(pred.len(), obs.len());
    if pred.is_empty() {
        return 0.0;
    }
    (pred
        .iter()
        .zip(obs)
        .map(|(p, o)| (p - o) * (p - o))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(stddev(&[5.0]), 0.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_recovers_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = ols(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r_squared(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r_squared_low_for_noise() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let ys = [5.0, -3.0, 4.0, -1.0, 2.0, 0.5];
        assert!(r_squared(&xs, &ys) < 0.6);
    }

    #[test]
    fn rmse_zero_for_perfect() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}
