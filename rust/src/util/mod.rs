//! Small shared utilities. The build is offline (crates restricted to the
//! vendored set), so the RNG, JSON codec, and temp-dir helper live in-tree.

pub mod json;
pub mod ndjson;
pub mod rng;
pub mod stats;
pub mod tmp;
