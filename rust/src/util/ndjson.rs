//! Newline-delimited JSON (NDJSON) reader.
//!
//! The streaming report pipeline writes one small JSON record per line
//! ([`crate::util::json::NdjsonWriter`]); this module is the consuming
//! side. It never builds a whole-document tree: callers either iterate
//! [`NdjsonReader`] line by line or hand a callback to
//! [`for_each_record`], so post-processing a multi-gigabyte stream
//! holds one record in memory at a time.
//!
//! Errors are positional — [`NdjsonError`] carries the 1-based line
//! number — and every malformed input is reported as an `Err`, never a
//! panic (the fuzz harness in `tests/fuzz.rs` pins that contract).

use crate::util::json::Json;

/// A parse failure at a specific line of an NDJSON stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NdjsonError {
    /// 1-based line number of the offending record.
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for NdjsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ndjson line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for NdjsonError {}

/// Iterator over the records of an NDJSON text.
///
/// Yields `(line_number, record)` for every non-empty line; blank lines
/// (including the trailing newline's empty remainder) are skipped so a
/// well-formed writer output and a hand-edited file both read cleanly.
pub struct NdjsonReader<'a> {
    lines: std::iter::Enumerate<std::str::Lines<'a>>,
}

impl<'a> NdjsonReader<'a> {
    pub fn new(text: &'a str) -> Self {
        NdjsonReader { lines: text.lines().enumerate() }
    }
}

impl Iterator for NdjsonReader<'_> {
    type Item = Result<(usize, Json), NdjsonError>;

    fn next(&mut self) -> Option<Self::Item> {
        for (idx, raw) in self.lines.by_ref() {
            let line = idx + 1;
            let trimmed = raw.trim();
            if trimmed.is_empty() {
                continue;
            }
            return Some(match Json::parse(trimmed) {
                Ok(v) => Ok((line, v)),
                Err(e) => Err(NdjsonError { line, msg: e.to_string() }),
            });
        }
        None
    }
}

/// Run `f` over every record of `text` in order, stopping at the first
/// malformed line. Returns the number of records visited.
pub fn for_each_record<F>(text: &str, mut f: F) -> Result<u64, NdjsonError>
where
    F: FnMut(usize, &Json) -> Result<(), NdjsonError>,
{
    let mut n = 0u64;
    for item in NdjsonReader::new(text) {
        let (line, value) = item?;
        f(line, &value)?;
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn reads_records_with_line_numbers() {
        let text = "{\"a\":1}\n\n{\"b\":2}\n";
        let records: Vec<_> = NdjsonReader::new(text).collect::<Result<_, _>>().unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0], (1, obj(vec![("a", num(1.0))])));
        assert_eq!(records[1], (3, obj(vec![("b", num(2.0))])));
    }

    #[test]
    fn malformed_line_reports_position() {
        let text = "{\"a\":1}\n{oops\n{\"b\":2}\n";
        let mut reader = NdjsonReader::new(text);
        assert!(reader.next().unwrap().is_ok());
        let err = reader.next().unwrap().unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn for_each_record_counts_and_stops_on_error() {
        let ok = for_each_record("1\n2\n3\n", |_, _| Ok(())).unwrap();
        assert_eq!(ok, 3);
        let err = for_each_record("1\n]\n3\n", |_, _| Ok(())).unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn truncated_record_is_an_error_not_a_panic() {
        // A stream cut mid-record leaves an unterminated final line.
        let text = "{\"a\":1}\n{\"b\":";
        let results: Vec<_> = NdjsonReader::new(text).collect();
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
    }
}
