//! Deterministic in-tree RNG (the build is offline; `rand` is not
//! vendored, so the generator lives here).
//!
//! Every stochastic component (NAS, HPO, accuracy surrogate, telemetry
//! noise) derives an independent xoshiro256** stream from (benchmark seed,
//! component label, counter). Runs are bit-reproducible for a fixed seed —
//! the paper's "reproducible measurement, based on open rules" requirement.

/// splitmix64 — also the python/rust shared dataset hash (see data module)
/// and the seeding function of the xoshiro state.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** (Blackman & Vigna) with convenience sampling methods.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, as recommended by the xoshiro authors.
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in &mut s {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *v = splitmix64(x);
        }
        Rng {
            s,
            spare_normal: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(hi >= lo, "empty range [{lo}, {hi})");
        lo + (hi - lo) * self.gen_f64()
    }

    /// Uniform integer in [lo, hi) (Lemire-style rejection-free for our
    /// non-cryptographic purposes: 128-bit multiply reduction).
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        let x = self.next_u64();
        lo + (((x as u128 * span as u128) >> 64) as u64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        let span = hi - lo;
        let x = self.next_u64();
        lo + ((x as u128 * span as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gen_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.gen_f64();
        let u2 = self.gen_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/stddev.
    pub fn gen_normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gen_normal()
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

/// Derive a named substream from a root seed.
pub fn derive(seed: u64, label: &str, counter: u64) -> Rng {
    let mut h = seed;
    for b in label.bytes() {
        h = splitmix64(h ^ u64::from(b));
    }
    h = splitmix64(h ^ counter);
    Rng::seed_from_u64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden_matches_python() {
        // Same golden values pinned in python/tests/test_dataset.py.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(1), 0x910A_2DEC_8902_5CC1);
        assert_eq!(splitmix64(0xDEAD_BEEF), 0x4ADF_B90F_68C9_EB9B);
    }

    #[test]
    fn derive_is_deterministic() {
        assert_eq!(derive(7, "nas", 3).next_u64(), derive(7, "nas", 3).next_u64());
    }

    #[test]
    fn derive_streams_independent() {
        let a = derive(7, "nas", 3).next_u64();
        let b = derive(7, "hpo", 3).next_u64();
        let c = derive(7, "nas", 4).next_u64();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_bounds_and_covers() {
        let mut r = derive(0, "t", 0);
        let (mut lo, mut hi) = (1.0f64, 0.0f64);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            lo = lo.min(x);
            hi = hi.max(x);
        }
        assert!(lo < 0.01 && hi > 0.99);
    }

    #[test]
    fn range_usize_uniformish() {
        let mut r = derive(1, "t", 0);
        let mut counts = [0usize; 8];
        for _ in 0..16_000 {
            counts[r.gen_range_usize(0, 8)] += 1;
        }
        for c in counts {
            assert!((1600..2400).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = derive(2, "t", 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    #[should_panic]
    fn empty_int_range_panics() {
        derive(0, "t", 0).gen_range_usize(3, 3);
    }
}
