//! Minimal JSON parser + writer (serde_json is not vendored offline).
//!
//! Covers the full JSON grammar the project touches: the artifact manifest
//! (python/compile/aot.py output) on the read side and benchmark reports
//! on the write side. Numbers are f64 (i64-exact integers round-trip via
//! `as_u64`/`as_i64`). Whole documents here are ≤ a few MB; outputs that
//! would not be (the 100k-lane streaming report) go through
//! [`NdjsonWriter`], which serializes one small record at a time instead
//! of building a whole tree.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= i64::MAX as f64 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    // --- writer -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no NaN/Infinity literals; Rust's `{}` would
                // emit them and corrupt the document, so non-finite
                // values serialize as null (the conventional lossy
                // mapping). The finite i64-exact fast path is unchanged.
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for the writer side.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(sv: &str, out: &mut String) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            // Remaining C0 controls have no short escape in JSON.
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Streaming newline-delimited-JSON writer: one value per line, written
/// to the sink as it is produced. Memory is bounded by the largest
/// single record (an internal line buffer is reused across records), so
/// a 100k-lane benchmark can emit millions of records in constant
/// memory — the scale-mode alternative to building the whole report
/// tree through [`Json::to_string`].
pub struct NdjsonWriter<W: std::io::Write> {
    out: W,
    buf: String,
    records: u64,
}

impl<W: std::io::Write> NdjsonWriter<W> {
    pub fn new(out: W) -> Self {
        NdjsonWriter {
            out,
            buf: String::new(),
            records: 0,
        }
    }

    /// Serialize one record and write it as a single `\n`-terminated
    /// line. Records must be objects or scalars without raw newlines by
    /// construction (the writer escapes newlines inside strings), so the
    /// line framing is unambiguous.
    pub fn record(&mut self, value: &Json) -> std::io::Result<()> {
        self.buf.clear();
        value.write(&mut self.buf);
        self.out.write_all(self.buf.as_bytes())?;
        self.out.write_all(b"\n")?;
        self.records += 1;
        Ok(())
    }

    /// Records written so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }

    /// Consume the writer, returning the underlying output.
    pub fn into_inner(self) -> W {
        self.out
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not produced by
                            // our writers); map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "schema": 1,
          "default_variant": "d2w8",
          "variants": [
            {"name": "d2w8", "depth": 2, "params": [{"shape": [3, 3, 3, 8]}],
             "files": {"init": "i.hlo.txt"}}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("schema").unwrap().as_u64(), Some(1));
        let v = &j.get("variants").unwrap().as_arr().unwrap()[0];
        assert_eq!(v.get("name").unwrap().as_str(), Some("d2w8"));
        assert_eq!(
            v.get("params").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            4
        );
    }

    #[test]
    fn scalars_and_numbers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("2.5").unwrap().as_u64(), None);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn writer_roundtrips() {
        let v = obj(vec![
            ("name", s("x")),
            ("xs", arr(vec![num(1.0), num(2.5), Json::Bool(false), Json::Null])),
            ("nested", obj(vec![("k", num(-7.0))])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // `{}` on f64 would print `NaN` / `inf` — not JSON. Exact bytes:
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let doc = arr(vec![num(1.0), num(f64::NAN), num(-2.5)]);
        assert_eq!(doc.to_string(), "[1,null,-2.5]");
        // The document stays parseable.
        assert!(Json::parse(&doc.to_string()).is_ok());
        // Finite values are untouched by the guard.
        assert_eq!(num(-0.0).to_string(), "0");
        assert_eq!(num(2.5).to_string(), "2.5");
        // Huge magnitudes print positionally (Rust's `{}` never uses
        // exponent form) and still round-trip exactly.
        assert_eq!(Json::parse(&num(1e300).to_string()).unwrap(), num(1e300));
    }

    #[test]
    fn control_characters_escape_to_exact_bytes() {
        let j = s("a\u{0000}\u{0001}\u{0008}\u{000C}\u{001f}\n\r\t\"\\z");
        assert_eq!(
            j.to_string(),
            "\"a\\u0000\\u0001\\b\\f\\u001f\\n\\r\\t\\\"\\\\z\""
        );
        // And every escape round-trips through the parser.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // DEL (0x7f) needs no escape per RFC 8259.
        assert_eq!(s("\u{007f}").to_string(), "\"\u{007f}\"");
    }

    #[test]
    fn ndjson_writer_frames_one_record_per_line() {
        let mut w = NdjsonWriter::new(Vec::new());
        w.record(&obj(vec![("a", num(1.0))])).unwrap();
        w.record(&obj(vec![("b", s("x\ny"))])).unwrap();
        assert_eq!(w.records(), 2);
        w.flush().unwrap();
        let text = String::from_utf8(w.into_inner()).unwrap();
        // Newlines inside strings are escaped, so framing stays 1/line.
        assert_eq!(text, "{\"a\":1}\n{\"b\":\"x\\ny\"}\n");
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(Json::parse(line).is_ok());
        }
    }
}
