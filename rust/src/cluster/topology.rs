//! Heterogeneous cluster topology.
//!
//! AIPerf ranks *diverse* systems with a single OPS metric — the paper
//! evaluates NVIDIA T4 and V100 fleets and a 4096-device Ascend 910
//! system side by side (Fig 4 / Table 1). A [`ClusterTopology`] is an
//! ordered list of [`NodeGroup`]s, each a homogeneous slice of the
//! cluster (`count` nodes × `gpus_per_node` accelerators of one
//! [`GpuModel`]); mixing groups models real mixed-accelerator sites.
//!
//! The ordering is load-bearing: slave nodes are numbered globally in
//! group order (group 0's nodes first, then group 1's, …), which fixes
//! shard RNG streams and the coordinator's deterministic merge order —
//! the reason heterogeneous runs stay bit-identical between the
//! sequential and parallel engines.

use super::gpu::GpuModel;
use super::node::{HostModel, NodeModel};

/// A homogeneous slice of the cluster: `count` identical slave nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeGroup {
    /// Section name in the config text (`[group.LABEL]`) and report rows.
    pub label: String,
    /// Number of slave nodes in this group.
    pub count: u64,
    /// Accelerators per node in this group.
    pub gpus_per_node: u64,
    /// The group's accelerator model.
    pub gpu: GpuModel,
    /// Per-group training batch override. `None` falls back to the global
    /// `BenchmarkConfig::batch_per_gpu`, so a mixed T4/V100 cluster can
    /// train each group at its memory-appropriate batch instead of the
    /// smallest card's.
    pub batch_per_gpu: Option<u64>,
    /// Per-group sub-shard override: how many independent trial lanes a
    /// node's GPUs split into. `None` falls back to the global
    /// `BenchmarkConfig::subshards_per_node`; must divide `gpus_per_node`.
    pub subshards_per_node: Option<u64>,
    /// Whether this group's idle lanes may adopt trials migrated from
    /// other groups (`[group.NAME] accepts_migrants`). Defaults to true;
    /// only consulted when `BenchmarkConfig::migration` is enabled. A
    /// group can opt out (e.g. a production partition that must not run
    /// foreign checkpoints) without disabling migration cluster-wide.
    pub accepts_migrants: bool,
    /// Per-group HPO backend override (`[group.NAME] hpo`). `None` falls
    /// back to the global `BenchmarkConfig::hpo`, so a mixed cluster can
    /// e.g. run grid search on a small partition while the bulk of the
    /// fleet runs TPE.
    pub hpo: Option<crate::hpo::Backend>,
}

impl NodeGroup {
    pub fn new(label: &str, count: u64, gpus_per_node: u64, gpu: GpuModel) -> Self {
        NodeGroup {
            label: label.to_string(),
            count,
            gpus_per_node,
            gpu,
            batch_per_gpu: None,
            subshards_per_node: None,
            accepts_migrants: true,
            hpo: None,
        }
    }

    /// Whether `label` can name a `[group.LABEL]` config section — the
    /// single source of the charset rule shared by topology validation
    /// and the config parser, so everything `validate` accepts survives
    /// a `to_text`/`from_text` round trip.
    pub fn is_valid_label(label: &str) -> bool {
        !label.is_empty()
            && label
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    }

    /// Total accelerators in this group.
    pub fn gpus(&self) -> u64 {
        self.count * self.gpus_per_node
    }

    /// The fully-specified node model for this group's nodes, sharing the
    /// cluster-wide host (slave container) shape.
    pub fn node_model(&self, host: HostModel) -> NodeModel {
        NodeModel {
            gpus_per_node: self.gpus_per_node,
            gpu: self.gpu,
            host,
        }
    }
}

/// Ordered node groups describing the whole cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTopology {
    pub groups: Vec<NodeGroup>,
}

impl Default for ClusterTopology {
    /// The historical flat default: 2 nodes × 8 V100.
    fn default() -> Self {
        ClusterTopology::homogeneous(2, 8, GpuModel::default())
    }
}

impl ClusterTopology {
    /// A cluster of exactly one node group.
    pub fn single(group: NodeGroup) -> Self {
        ClusterTopology {
            groups: vec![group],
        }
    }

    /// A single-group cluster — what the legacy flat `nodes` /
    /// `gpus_per_node` configuration keys describe.
    pub fn homogeneous(count: u64, gpus_per_node: u64, gpu: GpuModel) -> Self {
        Self::single(NodeGroup::new("default", count, gpus_per_node, gpu))
    }

    /// Total slave nodes across all groups.
    pub fn total_nodes(&self) -> u64 {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Total accelerators across all groups.
    pub fn total_gpus(&self) -> u64 {
        self.groups.iter().map(|g| g.gpus()).sum()
    }

    /// Group index of a global node index (nodes are numbered in group
    /// order). `None` when `node` is out of range.
    pub fn group_of_node(&self, node: u64) -> Option<usize> {
        let mut first = 0;
        for (i, g) in self.groups.iter().enumerate() {
            if node < first + g.count {
                return Some(i);
            }
            first += g.count;
        }
        None
    }

    /// Global node index of the first node of `group` (nodes are numbered
    /// in group order).
    pub fn first_node(&self, group: usize) -> u64 {
        self.groups[..group].iter().map(|g| g.count).sum()
    }

    /// `(group index, global node index)` for every node, in merge order.
    pub fn nodes(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.groups
            .iter()
            .enumerate()
            .flat_map(|(g, grp)| std::iter::repeat_n(g, grp.count as usize))
            .enumerate()
            .map(|(node, g)| (g, node))
    }

    /// Rescale a *single-group* topology to `count` nodes (the CLI
    /// `--nodes` override). Multi-group topologies are ambiguous here.
    pub fn scale_to_nodes(&mut self, count: u64) -> Result<(), String> {
        match self.groups.as_mut_slice() {
            [only] => {
                only.count = count;
                Ok(())
            }
            _ => Err(format!(
                "--nodes applies to single-group topologies only (this one has {} groups)",
                self.groups.len()
            )),
        }
    }

    /// Human-readable shape, e.g. `2x8 t4 + 2x8 v100 (32 GPUs)`.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .groups
            .iter()
            .map(|g| format!("{}x{} {}", g.count, g.gpus_per_node, g.label))
            .collect();
        format!("{} ({} GPUs)", parts.join(" + "), self.total_gpus())
    }

    /// Structural validity: at least one group, no empty groups, unique
    /// labels drawn from the config-section charset (labels name
    /// `[group.NAME]` sections, so anything `validate` accepts must
    /// survive a `to_text`/`from_text` round trip).
    pub fn validate(&self) -> Result<(), String> {
        if self.groups.is_empty() {
            return Err("at least one node group required".into());
        }
        for g in &self.groups {
            if !NodeGroup::is_valid_label(&g.label) {
                return Err(format!(
                    "bad node group label `{}` (alphanumeric, `-`, `_`)",
                    g.label
                ));
            }
            if g.count == 0 {
                return Err(format!("group `{}`: at least one node required", g.label));
            }
            if g.gpus_per_node == 0 {
                return Err(format!(
                    "group `{}`: at least one GPU per node required",
                    g.label
                ));
            }
        }
        for (i, g) in self.groups.iter().enumerate() {
            if self.groups[..i].iter().any(|h| h.label == g.label) {
                return Err(format!("duplicate node group label `{}`", g.label));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed() -> ClusterTopology {
        ClusterTopology {
            groups: vec![
                NodeGroup::new("t4", 2, 8, GpuModel::t4()),
                NodeGroup::new("v100", 3, 4, GpuModel::v100()),
            ],
        }
    }

    #[test]
    fn totals_sum_over_groups() {
        let t = mixed();
        assert_eq!(t.total_nodes(), 5);
        assert_eq!(t.total_gpus(), 2 * 8 + 3 * 4);
    }

    #[test]
    fn default_matches_legacy_flat_shape() {
        let t = ClusterTopology::default();
        assert_eq!(t.groups.len(), 1);
        assert_eq!(t.total_nodes(), 2);
        assert_eq!(t.total_gpus(), 16);
        assert_eq!(t.groups[0].gpu, GpuModel::default());
    }

    #[test]
    fn node_numbering_is_group_ordered() {
        let t = mixed();
        let nodes: Vec<(usize, usize)> = t.nodes().collect();
        assert_eq!(nodes, vec![(0, 0), (0, 1), (1, 2), (1, 3), (1, 4)]);
        assert_eq!(t.first_node(0), 0);
        assert_eq!(t.first_node(1), 2);
        assert_eq!(t.group_of_node(0), Some(0));
        assert_eq!(t.group_of_node(1), Some(0));
        assert_eq!(t.group_of_node(2), Some(1));
        assert_eq!(t.group_of_node(4), Some(1));
        assert_eq!(t.group_of_node(5), None);
    }

    #[test]
    fn validation_catches_bad_shapes() {
        assert!(ClusterTopology { groups: vec![] }.validate().is_err());
        let mut t = mixed();
        t.groups[0].count = 0;
        assert!(t.validate().is_err());
        let mut t = mixed();
        t.groups[1].gpus_per_node = 0;
        assert!(t.validate().is_err());
        let mut t = mixed();
        t.groups[1].label = "t4".into();
        assert!(t.validate().is_err(), "duplicate labels must be rejected");
        let mut t = mixed();
        t.groups[0].label = String::new();
        assert!(t.validate().is_err());
        // Labels outside the `[group.NAME]` section charset would break
        // the config round trip, so validation rejects them up front.
        let mut t = mixed();
        t.groups[0].label = "my gpu".into();
        assert!(t.validate().is_err());
        assert!(mixed().validate().is_ok());
    }

    #[test]
    fn scale_to_nodes_single_group_only() {
        let mut t = ClusterTopology::default();
        t.scale_to_nodes(7).unwrap();
        assert_eq!(t.total_nodes(), 7);
        let mut t = mixed();
        assert!(t.scale_to_nodes(7).is_err());
    }

    #[test]
    fn summary_names_every_group() {
        let s = mixed().summary();
        assert!(s.contains("2x8 t4"), "{s}");
        assert!(s.contains("3x4 v100"), "{s}");
        assert!(s.contains("28 GPUs"), "{s}");
    }

    #[test]
    fn groups_accept_migrants_by_default() {
        let t = mixed();
        assert!(t.groups.iter().all(|g| g.accepts_migrants));
        let mut t = mixed();
        t.groups[0].accepts_migrants = false;
        t.validate().unwrap();
    }

    #[test]
    fn node_model_inherits_host() {
        let host = HostModel {
            cpu_cores: 48,
            ..HostModel::default()
        };
        let n = mixed().groups[0].node_model(host);
        assert_eq!(n.gpus_per_node, 8);
        assert_eq!(n.gpu, GpuModel::t4());
        assert_eq!(n.host.cpu_cores, 48);
    }
}
