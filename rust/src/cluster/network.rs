//! NCCL-style collective cost model (paper §4.3 data parallelism).
//!
//! AIPerf trains each candidate with synchronous data parallelism: every
//! worker computes gradients on its batch partition and the gradients are
//! aggregated with NCCL allreduce each step. The standard ring-allreduce
//! cost on `n` workers moving `b` bytes is
//! `t = 2*(n-1)/n * b/bandwidth + 2*(n-1)*latency`.
//!
//! Intra-node (NVLink) and inter-node (100 Gb/s InfiniBand, Table 6) links
//! are distinguished; the slower link dominates a multi-node ring.


#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// NVLink effective bandwidth, bytes/s (V100 NVLink ≈ 150 GB/s eff.).
    pub nvlink_bw: f64,
    /// InfiniBand effective bandwidth, bytes/s (100 Gb/s ≈ 11 GB/s eff.).
    pub ib_bw: f64,
    /// Per-hop latency, seconds.
    pub nvlink_latency: f64,
    pub ib_latency: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            nvlink_bw: 1.5e11,
            ib_bw: 1.1e10,
            nvlink_latency: 5e-6,
            ib_latency: 2e-5,
        }
    }
}

impl NetworkModel {
    /// Ring allreduce over `workers` moving `bytes` per worker, using the
    /// bandwidth/latency of the weakest link in the ring.
    pub fn ring_allreduce_seconds(
        &self,
        workers: u64,
        bytes: u64,
        crosses_nodes: bool,
    ) -> f64 {
        assert!(workers >= 1);
        if workers == 1 {
            return 0.0;
        }
        let (bw, lat) = if crosses_nodes {
            (self.ib_bw, self.ib_latency)
        } else {
            (self.nvlink_bw, self.nvlink_latency)
        };
        let n = workers as f64;
        2.0 * (n - 1.0) / n * bytes as f64 / bw + 2.0 * (n - 1.0) * lat
    }

    /// Gradient allreduce per training step: one fp32 value per parameter.
    pub fn gradient_sync_seconds(&self, workers: u64, params: u64, crosses_nodes: bool) -> f64 {
        self.ring_allreduce_seconds(workers, params * 4, crosses_nodes)
    }

    /// Extra per-step gradient-sync cost a migrated trial pays because its
    /// allreduce ring leaves the NVLink domain and runs over InfiniBand
    /// instead — the network half of the cross-group migration overhead
    /// (the other half is NFS checkpoint staging).
    pub fn migration_sync_penalty_seconds(&self, workers: u64, params: u64) -> f64 {
        self.gradient_sync_seconds(workers, params, true)
            - self.gradient_sync_seconds(workers, params, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_free() {
        let n = NetworkModel::default();
        assert_eq!(n.ring_allreduce_seconds(1, 1 << 30, true), 0.0);
    }

    #[test]
    fn intra_node_faster_than_inter() {
        let n = NetworkModel::default();
        let intra = n.ring_allreduce_seconds(8, 100 << 20, false);
        let inter = n.ring_allreduce_seconds(8, 100 << 20, true);
        assert!(intra < inter / 5.0);
    }

    #[test]
    fn bandwidth_term_dominates_large_buffers() {
        let n = NetworkModel::default();
        let t = n.ring_allreduce_seconds(8, 1 << 30, true);
        // 2·(7/8)·1 GiB / 11 GB/s ≈ 0.17 s.
        assert!((0.1..0.3).contains(&t), "t={t}");
    }

    #[test]
    fn resnet_gradient_sync_sub_100ms_intra_node() {
        // 25.6 M params × 4 B ≈ 102 MB over 8 NVLink GPUs.
        let n = NetworkModel::default();
        let t = n.gradient_sync_seconds(8, 25_600_000, false);
        assert!(t < 0.1, "t={t}");
    }

    #[test]
    fn migration_penalty_positive_and_vanishes_for_one_worker() {
        let n = NetworkModel::default();
        // 25.6 M params over a 4-GPU lane: IB must cost strictly more
        // than NVLink, and the penalty is exactly the difference.
        let p = n.migration_sync_penalty_seconds(4, 25_600_000);
        assert!(p > 0.0, "penalty={p}");
        let direct = n.gradient_sync_seconds(4, 25_600_000, true)
            - n.gradient_sync_seconds(4, 25_600_000, false);
        assert_eq!(p.to_bits(), direct.to_bits());
        // A single worker has no ring at all, hence no penalty.
        assert_eq!(n.migration_sync_penalty_seconds(1, 25_600_000), 0.0);
    }

    #[test]
    fn cost_increases_with_workers_then_saturates() {
        let n = NetworkModel::default();
        let t2 = n.ring_allreduce_seconds(2, 100 << 20, false);
        let t8 = n.ring_allreduce_seconds(8, 100 << 20, false);
        assert!(t8 > t2);
        // (n−1)/n saturates: ×8 workers is < ×2 cost.
        assert!(t8 < 2.0 * t2);
    }
}
