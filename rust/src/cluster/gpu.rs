//! V100-like accelerator model.
//!
//! Calibration: an NVIDIA V100 trains ResNet-50/ImageNet at roughly
//! 600–800 images/s in mixed precision. AIPerf's score counts *analytical*
//! ops (2.31e10 per ResNet-50 image, Table 4), so the sustained
//! analytical-op throughput is ≈ 700 img/s × 2.31e10 ≈ 1.6e13 ops/s per
//! GPU — the `sustained_flops` default. Per-batch utilization follows the
//! amortization curve behind Fig 7a: kernel-launch and input overheads are
//! amortized as the batch grows, saturating near the memory limit.


/// Static accelerator description + throughput model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuModel {
    /// Sustained analytical ops/second at full utilization.
    pub sustained_flops: f64,
    /// Device memory in bytes (V100: 32 GB).
    pub memory_bytes: u64,
    /// Batch size at which utilization reaches 50 % (amortization knee).
    pub util_half_batch: f64,
    /// Utilization ceiling (input pipeline + launch gaps never vanish).
    pub util_max: f64,
    /// Fixed per-step host-side overhead in seconds.
    pub step_overhead_s: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            sustained_flops: 1.6e13,
            memory_bytes: 32 * (1 << 30),
            util_half_batch: 48.0,
            util_max: 0.97,
            step_overhead_s: 2.0e-3,
        }
    }
}

impl GpuModel {
    /// NVIDIA V100 NVLink 32 GB — the paper's testbed accelerator
    /// (Tables 6/7). Identical to [`GpuModel::default`].
    pub fn v100() -> Self {
        GpuModel::default()
    }

    /// NVIDIA T4 (16 GB): ~56.1 Tera-OPS across 32 cards in the paper ⇒
    /// ≈ 1.75e12 sustained analytical ops/s/device at benchmark
    /// utilization.
    pub fn t4() -> Self {
        GpuModel {
            sustained_flops: 2.0e12,
            memory_bytes: 16 * (1 << 30),
            util_half_batch: 32.0,
            util_max: 0.95,
            step_overhead_s: 2.5e-3,
        }
    }

    /// Huawei Ascend 910 (32 GB): 194.53 Peta-OPS across 4096 devices in
    /// the paper ⇒ ≈ 4.75e13 sustained analytical ops/s/device.
    pub fn ascend910() -> Self {
        GpuModel {
            sustained_flops: 5.4e13,
            memory_bytes: 32 * (1 << 30),
            util_half_batch: 64.0,
            util_max: 0.97,
            step_overhead_s: 1.5e-3,
        }
    }

    /// Look up a named accelerator model (the `gpu = NAME` config
    /// shorthand and scenario presets).
    pub fn named(name: &str) -> Option<Self> {
        match name {
            "v100" => Some(Self::v100()),
            "t4" => Some(Self::t4()),
            "ascend910" => Some(Self::ascend910()),
            _ => None,
        }
    }

    /// Utilization fraction at a per-GPU batch size (Fig 7a upper curve).
    pub fn utilization(&self, batch: u64) -> f64 {
        assert!(batch >= 1);
        self.util_max * batch as f64 / (batch as f64 + self.util_half_batch)
    }

    /// Memory demand of training one architecture at a per-GPU batch size.
    ///
    /// params + gradients + momentum (fp32) + activations (fp16, scales
    /// with batch × activation volume).
    pub fn memory_demand(&self, params: u64, activation_elems: u64, batch: u64) -> u64 {
        let states = params * 4 * 3;
        let activations = activation_elems * 2 * batch;
        // Framework overhead: CUDA context + workspace ≈ 1.5 GB.
        states + activations + 3 * (1 << 29)
    }

    /// Does the architecture fit at this batch size?
    pub fn fits(&self, params: u64, activation_elems: u64, batch: u64) -> bool {
        self.memory_demand(params, activation_elems, batch) <= self.memory_bytes
    }

    /// Largest per-GPU batch at which [`GpuModel::fits`] holds, or `None`
    /// when even batch 1 does not fit (the candidate cannot train on this
    /// device at all). Inverts the linear `memory_demand` formula, so the
    /// memory-adaption loop can clamp to the true fit boundary instead of
    /// stopping at an arbitrary floor.
    pub fn max_fitting_batch(&self, params: u64, activation_elems: u64) -> Option<u64> {
        // Batch-independent residents: optimizer states + framework
        // overhead (must mirror `memory_demand`).
        let fixed = params * 4 * 3 + 3 * (1 << 29);
        let avail = self.memory_bytes.checked_sub(fixed)?;
        let per_image = activation_elems * 2;
        if per_image == 0 {
            // Degenerate graph with no activations: any batch fits.
            return Some(u64::MAX);
        }
        let batch = avail / per_image;
        (batch >= 1).then_some(batch)
    }

    /// Seconds to process one training step of `batch` images needing
    /// `ops_per_image` analytical ops (compute only — allreduce is charged
    /// by the network model).
    pub fn step_seconds(&self, ops_per_image: u64, batch: u64) -> f64 {
        let eff = self.sustained_flops * self.utilization(batch);
        batch as f64 * ops_per_image as f64 / eff + self.step_overhead_s
    }

    /// Sustained images/second at a batch size.
    pub fn images_per_second(&self, ops_per_image: u64, batch: u64) -> f64 {
        batch as f64 / self.step_seconds(ops_per_image, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RESNET50_OPS: u64 = 23_100_000_000;

    #[test]
    fn utilization_monotone_saturating() {
        let g = GpuModel::default();
        let mut prev = 0.0;
        for b in [1u64, 8, 32, 64, 128, 256, 448, 512] {
            let u = g.utilization(b);
            assert!(u > prev);
            assert!(u < g.util_max);
            prev = u;
        }
        assert!(g.utilization(448) > 0.85);
    }

    #[test]
    fn v100_resnet_throughput_in_band() {
        // Sanity: 400–900 img/s at batch 64+ — the published V100 range.
        let g = GpuModel::default();
        let ips = g.images_per_second(RESNET50_OPS, 64);
        assert!((300.0..1000.0).contains(&ips), "ips={ips}");
    }

    #[test]
    fn memory_grows_with_batch_and_caps() {
        let g = GpuModel::default();
        let params = 25_600_000;
        let act = 11_000_000; // ResNet-50 activation elements per image
        assert!(g.fits(params, act, 64));
        let m64 = g.memory_demand(params, act, 64);
        let m448 = g.memory_demand(params, act, 448);
        assert!(m448 > m64);
        // At some batch the 32 GB must run out.
        assert!(!g.fits(params, act, 2048));
    }

    #[test]
    fn step_time_scales_with_ops() {
        let g = GpuModel::default();
        let t1 = g.step_seconds(RESNET50_OPS, 64);
        let t2 = g.step_seconds(2 * RESNET50_OPS, 64);
        assert!(t2 > 1.8 * t1);
    }

    #[test]
    fn named_models_resolve_and_order() {
        assert_eq!(GpuModel::named("v100"), Some(GpuModel::default()));
        assert!(GpuModel::named("nope").is_none());
        // Ascend 910 >> V100 >> T4 in sustained analytical throughput.
        assert!(GpuModel::t4().sustained_flops < GpuModel::v100().sustained_flops);
        assert!(GpuModel::v100().sustained_flops < GpuModel::ascend910().sustained_flops);
        // T4 is the 16 GB card; the others are 32 GB.
        assert_eq!(GpuModel::t4().memory_bytes, 16 * (1 << 30));
        assert_eq!(GpuModel::ascend910().memory_bytes, 32 * (1 << 30));
    }

    #[test]
    fn max_fitting_batch_is_the_fit_boundary() {
        let g = GpuModel::default();
        let params = 25_600_000;
        let act = 11_000_000;
        let b = g.max_fitting_batch(params, act).expect("resnet fits");
        assert!(g.fits(params, act, b), "boundary batch must fit");
        assert!(!g.fits(params, act, b + 1), "boundary + 1 must not fit");
        // A model whose fixed residents alone exceed device memory can
        // never fit, at any batch.
        let huge_params = g.memory_bytes; // 12 B/param of states ≫ memory
        assert_eq!(g.max_fitting_batch(huge_params, act), None);
        // Activation-heavy model on the 16 GB card: boundary is lower
        // than on the 32 GB card.
        let t4 = GpuModel::t4();
        let small = t4.max_fitting_batch(params, act).unwrap();
        assert!(small < b);
    }

    #[test]
    fn bigger_batch_better_throughput() {
        let g = GpuModel::default();
        let small = g.images_per_second(RESNET50_OPS, 8);
        let large = g.images_per_second(RESNET50_OPS, 256);
        assert!(large > small);
    }
}
