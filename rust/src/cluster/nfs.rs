//! Network-file-system model (paper §4.3).
//!
//! The framework stores the architecture buffer and the historical model
//! list on NFS; GPUs "load the candidate architecture and data from NFS".
//! The model charges latency + bandwidth per access, and tracks aggregate
//! bytes so the benchmark report can expose I/O pressure (the paper's §1
//! motivation: "I/O measurement … is often less considered").


#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NfsModel {
    /// Metadata round-trip, seconds.
    pub latency_s: f64,
    /// Sustained bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl Default for NfsModel {
    fn default() -> Self {
        NfsModel {
            latency_s: 1.0e-3,
            bandwidth: 1.2e9, // ~10 Gb/s effective NFS over IB
        }
    }
}

/// Aggregate I/O counters for one simulated run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NfsStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl NfsModel {
    /// Pure transfer cost of moving `bytes` through NFS, without touching
    /// any counters — the probe the migration scheduler uses to evaluate
    /// a candidate destination before committing to it.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth
    }

    /// Seconds to read `bytes` (also bumps the counters).
    pub fn read_seconds(&self, bytes: u64, stats: &mut NfsStats) -> f64 {
        stats.reads += 1;
        stats.bytes_read += bytes;
        self.transfer_seconds(bytes)
    }

    /// Seconds to write `bytes`.
    pub fn write_seconds(&self, bytes: u64, stats: &mut NfsStats) -> f64 {
        stats.writes += 1;
        stats.bytes_written += bytes;
        self.transfer_seconds(bytes)
    }

    /// Checkpoint stage-out of a migrating trial (source side): the
    /// proposing node serializes the candidate's initial state to NFS so
    /// any other node can pick it up. Cost model = one write.
    pub fn stage_out_seconds(&self, bytes: u64, stats: &mut NfsStats) -> f64 {
        self.write_seconds(bytes, stats)
    }

    /// Checkpoint stage-in of a migrating trial (destination side): the
    /// adopting node loads the staged state from NFS before training.
    /// Cost model = one read.
    pub fn stage_in_seconds(&self, bytes: u64, stats: &mut NfsStats) -> f64 {
        self.read_seconds(bytes, stats)
    }

    /// Per-epoch input-pipeline cost for streaming `images` of `bytes_per
    /// _image` across `prefetch_parallelism` streams. Pipelined with
    /// compute, so callers take max(compute, input).
    pub fn epoch_input_seconds(
        &self,
        images: u64,
        bytes_per_image: u64,
        prefetch_parallelism: u64,
    ) -> f64 {
        let total = images as f64 * bytes_per_image as f64;
        total / (self.bandwidth * prefetch_parallelism.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_charges_latency_plus_bw() {
        let n = NfsModel::default();
        let mut s = NfsStats::default();
        let t = n.read_seconds(1_200_000_000, &mut s);
        assert!((t - (1e-3 + 1.0)).abs() < 1e-6);
        assert_eq!(s.reads, 1);
        assert_eq!(s.bytes_read, 1_200_000_000);
    }

    #[test]
    fn stats_accumulate() {
        let n = NfsModel::default();
        let mut s = NfsStats::default();
        n.write_seconds(100, &mut s);
        n.write_seconds(200, &mut s);
        n.read_seconds(50, &mut s);
        assert_eq!(s.writes, 2);
        assert_eq!(s.bytes_written, 300);
        assert_eq!(s.reads, 1);
    }

    #[test]
    fn transfer_probe_matches_charged_cost_without_counters() {
        let n = NfsModel::default();
        let mut s = NfsStats::default();
        let probe = n.transfer_seconds(10_000_000);
        let charged = n.read_seconds(10_000_000, &mut s);
        assert_eq!(probe.to_bits(), charged.to_bits());
        // Probing never touches the counters.
        assert_eq!(s.reads, 1);
        let _ = n.transfer_seconds(1 << 30);
        assert_eq!(s.reads, 1);
        assert_eq!(s.bytes_read, 10_000_000);
    }

    #[test]
    fn checkpoint_staging_charges_both_sides() {
        let n = NfsModel::default();
        let mut src = NfsStats::default();
        let mut dst = NfsStats::default();
        let bytes = 8 * 25_600_000; // 8 B/param on a ResNet-50-class model
        let out = n.stage_out_seconds(bytes, &mut src);
        let inn = n.stage_in_seconds(bytes, &mut dst);
        assert_eq!(src.writes, 1);
        assert_eq!(src.bytes_written, bytes);
        assert_eq!(dst.reads, 1);
        assert_eq!(dst.bytes_read, bytes);
        // ~205 MB over 1.2 GB/s: fractions of a second, both directions.
        assert!(out > 0.0 && out < 1.0, "out={out}");
        assert_eq!(out.to_bits(), inn.to_bits());
    }

    #[test]
    fn imagenet_epoch_streaming_feasible() {
        // 1.28 M JPEG-decoded 224² images ≈ 150 KB each, 8 streams:
        // must be well under a compute-bound epoch (~4 min at 8 GPUs).
        let n = NfsModel::default();
        let t = n.epoch_input_seconds(1_281_167, 150_000, 8);
        assert!(t < 30.0, "t={t}");
    }
}
