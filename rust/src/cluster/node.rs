//! Slave-node model (Tables 6/7).
//!
//! A slave node is "one or multiple servers with AI accelerators": here
//! 2×Xeon-8268-class CPUs (40 cores), 8 GPUs, 1.5 TB memory, running the
//! containerised workload (24 cores / 280 GB / 8 GPUs per slave
//! container). The node model supplies per-component capacities and the
//! CPU-side costs of the search loop (architecture generation is run on
//! slave CPUs in AIPerf's modified NNI, §4.3).
//!
//! The host side ([`HostModel`]) is split from the accelerator side so a
//! heterogeneous [`crate::cluster::ClusterTopology`] can vary the GPU
//! complement per node group while every group shares the same slave
//! container shape.

use super::gpu::GpuModel;

/// CPU-side slave container: cores, memory, and the search-loop costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostModel {
    /// Container CPU cores (Table 7: 24).
    pub cpu_cores: u64,
    /// Container memory bytes (Table 7: 280 GB).
    pub memory_bytes: u64,
    /// Seconds of CPU time to generate one candidate architecture
    /// (morphism + bookkeeping on the historical list).
    pub search_seconds: f64,
    /// Seconds to build/compile the training graph for a new candidate
    /// (the utilization "dent between training stages" in Fig 9).
    pub setup_seconds: f64,
}

impl Default for HostModel {
    fn default() -> Self {
        HostModel {
            cpu_cores: 24,
            memory_bytes: 280 * (1 << 30),
            search_seconds: 1.5,
            setup_seconds: 45.0,
        }
    }
}

impl HostModel {
    /// CPU utilization fraction while training runs: the input pipeline and
    /// the search thread keep a few cores busy (paper Fig 11: < 5 % of the
    /// host, i.e. a couple of container cores).
    pub fn cpu_util_training(&self) -> f64 {
        // 1 core of search + ~0.5 core of input pipeline per 8 GPUs.
        (1.5 / self.cpu_cores as f64).min(1.0)
    }

    /// Main-memory fraction used while training (Fig 12: < 20 % — data is
    /// pre-loaded to GPU memory, host holds pipeline buffers + runtime).
    pub fn host_memory_util(&self, dataset_cache_bytes: u64) -> f64 {
        let runtime = 20u64 << 30; // framework + CUDA host allocations
        ((runtime + dataset_cache_bytes) as f64 / self.memory_bytes as f64).min(1.0)
    }
}

/// One fully-specified slave node: its accelerator complement plus host.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeModel {
    pub gpus_per_node: u64,
    pub gpu: GpuModel,
    pub host: HostModel,
}

impl Default for NodeModel {
    fn default() -> Self {
        NodeModel {
            gpus_per_node: 8,
            gpu: GpuModel::default(),
            host: HostModel::default(),
        }
    }
}

impl NodeModel {
    /// Aggregate per-node sustained analytical throughput at a batch size.
    pub fn node_flops(&self, batch_per_gpu: u64) -> f64 {
        self.gpus_per_node as f64
            * self.gpu.sustained_flops
            * self.gpu.utilization(batch_per_gpu)
    }

    /// CPU utilization fraction while training runs (see [`HostModel`]).
    pub fn cpu_util_training(&self) -> f64 {
        self.host.cpu_util_training()
    }

    /// Main-memory fraction used while training (see [`HostModel`]).
    pub fn host_memory_util(&self, dataset_cache_bytes: u64) -> f64 {
        self.host.host_memory_util(dataset_cache_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table7() {
        let n = NodeModel::default();
        assert_eq!(n.gpus_per_node, 8);
        assert_eq!(n.host.cpu_cores, 24);
        assert_eq!(n.host.memory_bytes, 280 * (1 << 30));
    }

    #[test]
    fn node_flops_scales_with_gpus() {
        let n = NodeModel::default();
        let one = NodeModel {
            gpus_per_node: 1,
            ..n
        };
        let f8 = n.node_flops(448);
        let f1 = one.node_flops(448);
        assert!((f8 / f1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn cpu_util_under_five_percent() {
        let n = NodeModel::default();
        assert!(n.cpu_util_training() < 0.10);
        assert!(n.cpu_util_training() > 0.0);
    }

    #[test]
    fn host_memory_under_twenty_percent() {
        let n = NodeModel::default();
        // 30 GB of pipeline cache (TFRecord shards).
        let u = n.host_memory_util(30 << 30);
        assert!(u < 0.20, "u={u}");
    }
}
