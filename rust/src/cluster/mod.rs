//! Simulated cluster substrate (DESIGN.md §2 substitution).
//!
//! The paper's testbed — up to 16 slave nodes, each 2×Xeon 8268 + 8×V100
//! NVLink 32 GB on 100 Gb/s InfiniBand, SLURM + Docker + NFS (Tables 6/7)
//! — is a hardware gate. This module models each component with enough
//! fidelity for the benchmark's claims to be exercised for real:
//!
//! * [`gpu`] — V100-like accelerator: sustained analytical-op throughput,
//!   32 GB memory, batch-amortized utilization;
//! * [`node`] — a slave node: 8 GPUs + CPU search capacity + memory;
//! * [`topology`] — the whole cluster as ordered [`NodeGroup`]s, so
//!   heterogeneous (mixed-accelerator) sites are first-class;
//! * [`network`] — NCCL-style ring allreduce cost on 100 Gb/s links;
//! * [`nfs`] — the shared filesystem holding the architecture buffer and
//!   the historical model list, with latency/bandwidth charges.

pub mod gpu;
pub mod network;
pub mod nfs;
pub mod node;
pub mod topology;

pub use gpu::GpuModel;
pub use network::NetworkModel;
pub use nfs::NfsModel;
pub use node::{HostModel, NodeModel};
pub use topology::{ClusterTopology, NodeGroup};
