//! A trial's learning curve in the error domain — the one shared
//! implementation behind the engine's early-stop decision and the Fig 8
//! accuracy-prediction bench.
//!
//! [`LearningCurve`] accumulates `(epoch, validation error)` points and
//! answers extrapolation questions through the paper's logarithmic OLS
//! fit ([`LogFit`], Appendix C). Keeping both consumers on this type
//! means the early-stop rule and the fig8 reproduction can never drift
//! apart on how a partial curve is turned into a convergence estimate.

use super::logfit::LogFit;

/// The epoch the paper treats as "converged" for ImageNet-class models
/// (Appendix C predicts achievable accuracy at epoch 60).
pub const CONVERGENCE_EPOCH: f64 = 60.0;

/// Observed partial learning curve of one trial, in validation-error
/// terms (lower is better — the optimizer-facing convention).
#[derive(Debug, Clone, Default)]
pub struct LearningCurve {
    epochs: Vec<f64>,
    errors: Vec<f64>,
}

impl LearningCurve {
    pub fn new() -> Self {
        LearningCurve::default()
    }

    /// Record one validation epoch's error. Epochs are 1-based (the log
    /// fit is undefined at 0) and must arrive in increasing order.
    pub fn observe(&mut self, epoch: u64, error: f64) {
        assert!(epoch >= 1, "epochs are 1-based");
        if let Some(&last) = self.epochs.last() {
            assert!((epoch as f64) > last, "epochs must increase");
        }
        self.epochs.push(epoch as f64);
        self.errors.push(error);
    }

    /// Points observed so far.
    pub fn len(&self) -> usize {
        self.epochs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.epochs.is_empty()
    }

    /// Whether enough of the curve exists to fit (the OLS needs ≥ 2
    /// points).
    pub fn can_fit(&self) -> bool {
        self.epochs.len() >= 2
    }

    /// The paper's logarithmic fit over the observed curve, in the
    /// *accuracy* domain (`acc(e) = a + b·ln(e)`): the fig8 bench reads
    /// `a`/`b`/`rmse` straight off it. Requires [`Self::can_fit`].
    pub fn fit(&self) -> LogFit {
        let accs: Vec<f64> = self.errors.iter().map(|e| 1.0 - e).collect();
        LogFit::fit(&self.epochs, &accs)
    }

    /// Fitted validation error at a future epoch, clamped to [0, 1].
    pub fn extrapolate(&self, to_epoch: f64) -> f64 {
        (1.0 - self.fit().at(to_epoch)).clamp(0.0, 1.0)
    }

    /// Optimistic error floor at the convergence horizon: the fitted
    /// error at [`CONVERGENCE_EPOCH`] *minus* two RMSE of accuracy
    /// headroom. This is the mirror image of the paper's conservative
    /// accuracy prediction — where ranking wants a floor on accuracy,
    /// termination wants a floor on error: a trial is only declared
    /// doomed when even this best plausible outcome cannot reach the
    /// incumbent.
    pub fn converged_floor(&self) -> f64 {
        let fit = self.fit();
        (1.0 - (fit.at(CONVERGENCE_EPOCH) + 2.0 * fit.rmse)).clamp(0.0, 1.0)
    }

    /// Conservative *accuracy* prediction at the convergence horizon
    /// (the paper's exact Appendix-C rule, `−2·RMSE`).
    pub fn conservative_accuracy(&self) -> f64 {
        self.fit().conservative(CONVERGENCE_EPOCH)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A noiseless logarithmic curve in error terms.
    fn curve(a: f64, b: f64, n: u64) -> LearningCurve {
        let mut lc = LearningCurve::new();
        for e in 1..=n {
            lc.observe(e, 1.0 - (a + b * (e as f64).ln()));
        }
        lc
    }

    #[test]
    fn extrapolation_matches_the_underlying_fit() {
        let lc = curve(0.3, 0.08, 20);
        assert!(lc.can_fit());
        let fit = lc.fit();
        assert!((fit.a - 0.3).abs() < 1e-10);
        assert!((fit.b - 0.08).abs() < 1e-10);
        let want = 1.0 - (0.3 + 0.08 * 60f64.ln());
        assert!((lc.extrapolate(60.0) - want).abs() < 1e-10);
    }

    #[test]
    fn floor_is_optimistic_under_noise() {
        // With RMSE > 0 the floor sits below the raw extrapolation: the
        // trial gets the benefit of the doubt before termination.
        let mut lc = LearningCurve::new();
        let mut rng = crate::util::rng::derive(0, "curve", 0);
        for e in 1..=30u64 {
            let acc = 0.3 + 0.08 * (e as f64).ln() + rng.gen_range_f64(-0.02, 0.02);
            lc.observe(e, 1.0 - acc);
        }
        assert!(lc.fit().rmse > 0.0);
        assert!(lc.converged_floor() < lc.extrapolate(CONVERGENCE_EPOCH));
    }

    #[test]
    fn floor_and_conservative_accuracy_are_mirror_bounds() {
        let lc = curve(0.25, 0.06, 15);
        // Noiseless curve: both collapse onto the raw fit.
        let at60 = lc.fit().at(CONVERGENCE_EPOCH);
        assert!((lc.converged_floor() - (1.0 - at60)).abs() < 1e-9);
        assert!((lc.conservative_accuracy() - at60).abs() < 1e-9);
    }

    #[test]
    fn flat_curve_floor_stays_put() {
        // A trial that stopped improving: b ≈ 0, so the floor equals
        // today's error — it can never look better than it is.
        let mut lc = LearningCurve::new();
        for e in 1..=10u64 {
            lc.observe(e, 0.7);
        }
        assert!((lc.converged_floor() - 0.7).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn single_point_cannot_fit() {
        let mut lc = LearningCurve::new();
        lc.observe(1, 0.5);
        assert!(!lc.can_fit());
        let _ = lc.fit();
    }

    #[test]
    #[should_panic]
    fn epochs_must_increase() {
        let mut lc = LearningCurve::new();
        lc.observe(3, 0.5);
        lc.observe(3, 0.4);
    }
}
