//! Logarithmic accuracy prediction (paper Appendix C / Fig 8).
//!
//! During warm-up, models train only 10–70 epochs while ImageNet typically
//! converges after ~60; the framework must rank them anyway. The paper
//! fits `acc(e) = a + b·ln(e)` by ordinary least squares over the partial
//! curve, estimates the goodness of fit with RMSE, and predicts the
//! achievable accuracy at the convergence epoch *minus twice the RMSE*
//! ("a conservative prediction").


use crate::util::stats::{ols, rmse};

/// The fitted curve with its fit quality.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogFit {
    /// acc(e) = a + b·ln(e)
    pub a: f64,
    pub b: f64,
    pub rmse: f64,
}

impl LogFit {
    /// Fit to (epoch, accuracy) pairs. Needs ≥ 2 points, epochs ≥ 1.
    pub fn fit(epochs: &[f64], accs: &[f64]) -> LogFit {
        assert_eq!(epochs.len(), accs.len());
        assert!(epochs.len() >= 2, "log fit needs at least two points");
        assert!(epochs.iter().all(|&e| e >= 1.0), "epochs must be >= 1");
        let xs: Vec<f64> = epochs.iter().map(|e| e.ln()).collect();
        let (a, b) = ols(&xs, accs);
        let pred: Vec<f64> = xs.iter().map(|x| a + b * x).collect();
        LogFit {
            a,
            b,
            rmse: rmse(&pred, accs),
        }
    }

    /// Curve value at an epoch.
    pub fn at(&self, epoch: f64) -> f64 {
        assert!(epoch >= 1.0);
        self.a + self.b * epoch.ln()
    }

    /// Conservative prediction: value at `target_epoch` − 2·RMSE, clamped
    /// to [0, 1].
    pub fn conservative(&self, target_epoch: f64) -> f64 {
        (self.at(target_epoch) - 2.0 * self.rmse).clamp(0.0, 1.0)
    }
}

/// One-shot helper: the paper's exact procedure (predict at epoch 60).
pub fn predict_accuracy(epochs: &[f64], accs: &[f64]) -> f64 {
    LogFit::fit(epochs, accs).conservative(60.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Generate a noiseless logarithmic curve.
    fn curve(a: f64, b: f64, n: usize) -> (Vec<f64>, Vec<f64>) {
        let epochs: Vec<f64> = (1..=n).map(|e| e as f64).collect();
        let accs = epochs.iter().map(|e| a + b * e.ln()).collect();
        (epochs, accs)
    }

    #[test]
    fn recovers_exact_log_curve() {
        let (e, acc) = curve(0.3, 0.08, 20);
        let fit = LogFit::fit(&e, &acc);
        assert!((fit.a - 0.3).abs() < 1e-10);
        assert!((fit.b - 0.08).abs() < 1e-10);
        assert!(fit.rmse < 1e-10);
        assert!((fit.at(60.0) - (0.3 + 0.08 * 60f64.ln())).abs() < 1e-10);
    }

    #[test]
    fn conservative_is_below_fit_under_noise() {
        let mut rng = crate::util::rng::derive(0, "logfit", 0);
        let (e, acc) = curve(0.3, 0.08, 30);
        let noisy: Vec<f64> = acc.iter().map(|a| a + rng.gen_range_f64(-0.02, 0.02)).collect();
        let fit = LogFit::fit(&e, &noisy);
        assert!(fit.rmse > 0.0);
        assert!(fit.conservative(60.0) < fit.at(60.0));
        // Still in the right ballpark (±0.08 of the true value).
        let truth = 0.3 + 0.08 * 60f64.ln();
        assert!((fit.conservative(60.0) - truth).abs() < 0.08);
    }

    #[test]
    fn prediction_clamped_to_unit_interval() {
        let fit = LogFit {
            a: 0.9,
            b: 0.2,
            rmse: 0.0,
        };
        assert_eq!(fit.conservative(60.0), 1.0);
        let low = LogFit {
            a: 0.0,
            b: 0.0,
            rmse: 0.5,
        };
        assert_eq!(low.conservative(60.0), 0.0);
    }

    #[test]
    fn helper_matches_manual() {
        let (e, acc) = curve(0.2, 0.1, 10);
        let p = predict_accuracy(&e, &acc);
        let fit = LogFit::fit(&e, &acc);
        assert_eq!(p, fit.conservative(60.0));
    }

    #[test]
    #[should_panic]
    fn rejects_single_point() {
        LogFit::fit(&[5.0], &[0.5]);
    }

    #[test]
    #[should_panic]
    fn rejects_epoch_zero() {
        LogFit::fit(&[0.0, 1.0], &[0.1, 0.2]);
    }
}
