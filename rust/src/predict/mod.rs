//! Accuracy prediction for insufficiently trained models (Appendix C).

pub mod curve;
pub mod logfit;

pub use curve::{LearningCurve, CONVERGENCE_EPOCH};
pub use logfit::{predict_accuracy, LogFit};
