//! Accuracy prediction for insufficiently trained models (Appendix C).

pub mod logfit;

pub use logfit::{predict_accuracy, LogFit};
