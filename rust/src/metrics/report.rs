//! Final benchmark report (paper §4.3 last bullet: "the final results
//! (score, achieved error, and regulated score) are automatically
//! calculated based on the recorded metrics and then reported").


use super::score::{ScoreSample, Validity};
use super::telemetry::TelemetrySample;
use crate::util::stats::mean;

/// Per-node-group slice of the report: how much of the cluster's
/// analytical work each topology group contributed (the paper ranks
/// heterogeneous systems — T4, V100, Ascend 910 — with one OPS metric,
/// and this row is a system's entry at sub-cluster granularity).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupBreakdown {
    pub label: String,
    pub nodes: u64,
    pub gpus_per_node: u64,
    /// Total analytical ops trained by this group's nodes.
    pub ops: f64,
    /// Mean analytical ops/second over the whole run.
    pub ops_per_second: f64,
    /// Work-steal events performed by this group's sub-shard lanes (a
    /// lane out of runway joining a sibling lane's trial).
    pub steals: u64,
    /// Candidates skipped because no batch size fit the accelerator
    /// (instead of silently simulating an OOM configuration).
    pub oom_skips: u64,
    /// Trials this group's lanes adopted from other groups (the elastic
    /// scheduler's inter-group migration pass).
    pub migrations_in: u64,
    /// Trials this group's lanes proposed that were dispatched to other
    /// groups.
    pub migrations_out: u64,
    /// Seconds of migration overhead charged in this group: NFS
    /// checkpoint staging (both directions) plus the InfiniBand
    /// gradient-sync penalty of adopted trials' completed epochs.
    pub migration_overhead_s: f64,
    /// Migrated-trial observations routed back into this group's lanes'
    /// TPE optimizers at epoch barriers (the source side of the
    /// search-feedback loop — `coordinator::sched::feedback`).
    pub feedback_routed: u64,
    /// Steal events whose victim was an adopted migrant: a sibling lane
    /// joined the migrant's InfiniBand gradient ring (subset of
    /// `steals`).
    pub migrant_ring_joins: u64,
    /// Mean barrier slack, seconds: how far a solo lane's in-flight
    /// epoch overshoots an epoch barrier, averaged over lanes × windows
    /// — the utilization headroom work stealing recovers.
    pub barrier_slack_s: f64,
    /// Trials this group's lanes terminated early because the LogFit
    /// learning-curve extrapolation declared them doomed
    /// (`BenchmarkConfig::early_stop`). Zero when the knob is off.
    pub early_stops: u64,
    /// Training epochs those early stops skipped (budgeted minus
    /// trained, summed over the group's early-stopped trials) — the
    /// search-time the predictor bought back for fresh candidates.
    pub epochs_saved: u64,
}

impl GroupBreakdown {
    /// Total devices in this group.
    pub fn gpus(&self) -> u64 {
        self.nodes * self.gpus_per_node
    }
}

/// Busy fraction of one sub-shard trial lane over the whole run — the
/// per-lane utilization view: node aggregates hide the truncated tail a
/// lane spends idle (parked, or waiting out the deadline), which is
/// exactly what the steal/migration passes recover.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneUtil {
    /// Topology group label of the lane's node.
    pub group: String,
    /// Global node index.
    pub node: u64,
    /// Lane index within its node.
    pub lane: u64,
    /// Fraction of the run the lane spent training, assisting a sibling,
    /// or running an adopted migrant.
    pub busy_fraction: f64,
}

#[derive(Debug, Clone)]
pub struct BenchmarkReport {
    /// Cluster shape: total slave nodes and devices across all groups.
    pub nodes: u64,
    pub total_gpus: u64,
    /// Per-group OPS contributions, in topology order.
    pub groups: Vec<GroupBreakdown>,
    /// Per-lane busy fractions, in global lane order (nodes in topology
    /// order, lanes within each node).
    pub lane_util: Vec<LaneUtil>,
    /// Run length, seconds.
    pub duration_s: f64,
    /// Hourly score samples (Figs 4–6 series).
    pub score_series: Vec<ScoreSample>,
    /// Reported score: mean FLOPS over the stable window (hours 6–12).
    pub score_flops: f64,
    /// Best achieved validation error.
    pub final_error: f64,
    /// Reported regulated score over the stable window.
    pub regulated_score: f64,
    /// Number of architectures evaluated (paper §5.2: 96 at 16 nodes/12 h).
    pub architectures_evaluated: u64,
    /// Utilization telemetry.
    pub telemetry: Vec<TelemetrySample>,
    /// Validity verdict per §4.5.
    pub validity: Validity,
    /// NFS aggregate I/O.
    pub nfs_bytes_read: u64,
    pub nfs_bytes_written: u64,
    /// Active-set window scheduling counters: shard visits executed vs
    /// skipped across all epoch-barrier windows. `shards_touched +
    /// shards_skipped == shards × windows`; a skipped visit is a shard
    /// whose next event lay past the window end, left untouched
    /// (bit-identical by construction — see `coordinator::active`).
    pub shards_touched: u64,
    pub shards_skipped: u64,
}

impl BenchmarkReport {
    /// Stable-window averages from the series; the paper reports averages
    /// over [6 h, 12 h] ("after the initial warm-up phase"), falling back
    /// to the second half for shorter runs.
    pub fn stable_window(duration_s: f64) -> (f64, f64) {
        if duration_s >= 12.0 * 3600.0 {
            (6.0 * 3600.0, 12.0 * 3600.0)
        } else {
            (duration_s / 2.0, duration_s)
        }
    }

    /// Compute the reported (score, regulated) from a sample series.
    pub fn stable_scores(series: &[ScoreSample], duration_s: f64) -> (f64, f64) {
        let (t0, t1) = Self::stable_window(duration_s);
        let in_window: Vec<&ScoreSample> =
            series.iter().filter(|s| s.t >= t0 && s.t <= t1).collect();
        let picked: Vec<&ScoreSample> = if in_window.is_empty() {
            series.iter().collect()
        } else {
            in_window
        };
        let f = mean(&picked.iter().map(|s| s.flops).collect::<Vec<_>>());
        let r = mean(&picked.iter().map(|s| s.regulated).collect::<Vec<_>>());
        (f, r)
    }

    /// Full report as JSON (the paper's toolkit emits a machine-readable
    /// report at termination; serde is not vendored, so this uses the
    /// in-tree codec).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, obj, s};
        obj(vec![
            ("nodes", num(self.nodes as f64)),
            ("total_gpus", num(self.total_gpus as f64)),
            (
                "groups",
                arr(self
                    .groups
                    .iter()
                    .map(|g| {
                        obj(vec![
                            ("label", s(g.label.clone())),
                            ("nodes", num(g.nodes as f64)),
                            ("gpus_per_node", num(g.gpus_per_node as f64)),
                            ("ops", num(g.ops)),
                            ("ops_per_second", num(g.ops_per_second)),
                            ("steals", num(g.steals as f64)),
                            ("oom_skips", num(g.oom_skips as f64)),
                            ("migrations_in", num(g.migrations_in as f64)),
                            ("migrations_out", num(g.migrations_out as f64)),
                            ("migration_overhead_s", num(g.migration_overhead_s)),
                            ("feedback_routed", num(g.feedback_routed as f64)),
                            ("migrant_ring_joins", num(g.migrant_ring_joins as f64)),
                            ("barrier_slack_s", num(g.barrier_slack_s)),
                            ("early_stops", num(g.early_stops as f64)),
                            ("epochs_saved", num(g.epochs_saved as f64)),
                        ])
                    })
                    .collect()),
            ),
            (
                "lanes",
                arr(self
                    .lane_util
                    .iter()
                    .map(|l| {
                        obj(vec![
                            ("group", s(l.group.clone())),
                            ("node", num(l.node as f64)),
                            ("lane", num(l.lane as f64)),
                            ("busy_fraction", num(l.busy_fraction)),
                        ])
                    })
                    .collect()),
            ),
            ("duration_s", num(self.duration_s)),
            ("score_flops", num(self.score_flops)),
            ("final_error", num(self.final_error)),
            ("regulated_score", num(self.regulated_score)),
            (
                "architectures_evaluated",
                num(self.architectures_evaluated as f64),
            ),
            ("validity", s(format!("{:?}", self.validity))),
            ("nfs_bytes_read", num(self.nfs_bytes_read as f64)),
            ("nfs_bytes_written", num(self.nfs_bytes_written as f64)),
            ("shards_touched", num(self.shards_touched as f64)),
            ("shards_skipped", num(self.shards_skipped as f64)),
            (
                "score_series",
                arr(self
                    .score_series
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("t", num(p.t)),
                            ("flops", num(p.flops)),
                            ("best_error", num(p.best_error)),
                            ("regulated", num(p.regulated)),
                        ])
                    })
                    .collect()),
            ),
            (
                "telemetry",
                arr(self
                    .telemetry
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("t", num(p.t)),
                            ("gpu_util_mean", num(p.gpu_util_mean)),
                            ("gpu_util_std", num(p.gpu_util_std)),
                            ("gpu_mem_mean", num(p.gpu_mem_mean)),
                            ("gpu_mem_std", num(p.gpu_mem_std)),
                            ("cpu_util_mean", num(p.cpu_util_mean)),
                            ("cpu_util_std", num(p.cpu_util_std)),
                            ("host_mem_mean", num(p.host_mem_mean)),
                            ("host_mem_std", num(p.host_mem_std)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Human-readable single-line summary.
    pub fn summary(&self) -> String {
        format!(
            "nodes={} gpus={} score={:.3} PFLOPS error={:.1}% regulated={:.3} PFLOPS archs={} validity={:?} shards_touched={} shards_skipped={}",
            self.nodes,
            self.total_gpus,
            self.score_flops / 1e15,
            self.final_error * 100.0,
            self.regulated_score / 1e15,
            self.architectures_evaluated,
            self.validity,
            self.shards_touched,
            self.shards_skipped,
        )
    }

    /// Per-group OPS breakdown as indented table lines (one per group),
    /// printed under the summary for heterogeneous runs. Migration
    /// columns appear whenever the run paid any migration cost —
    /// including stage-outs whose candidates were never placed — so the
    /// summary can never hide overhead the JSON/CSV artifacts report.
    pub fn group_table(&self) -> String {
        let migrated = self.groups.iter().any(|g| {
            g.migrations_in > 0 || g.migrations_out > 0 || g.migration_overhead_s > 0.0
        });
        let early_stopped = self.groups.iter().any(|g| g.early_stops > 0);
        let mut out = String::new();
        for g in &self.groups {
            out.push_str(&format!(
                "  group {:<12} {:>4} nodes x {:<2} GPUs  ops={:.3e}  mean {:.4} PFLOPS  ({:.1}% of total)  slack={:.0}s steals={} oom_skips={}",
                g.label,
                g.nodes,
                g.gpus_per_node,
                g.ops,
                g.ops_per_second / 1e15,
                if self.total_ops() > 0.0 {
                    g.ops / self.total_ops() * 100.0
                } else {
                    0.0
                },
                g.barrier_slack_s,
                g.steals,
                g.oom_skips,
            ));
            if migrated {
                out.push_str(&format!(
                    " migrations={}in/{}out overhead={:.1}s feedback_routed={} ring_joins={}",
                    g.migrations_in,
                    g.migrations_out,
                    g.migration_overhead_s,
                    g.feedback_routed,
                    g.migrant_ring_joins,
                ));
            }
            if early_stopped {
                out.push_str(&format!(
                    " early_stops={} epochs_saved={}",
                    g.early_stops, g.epochs_saved,
                ));
            }
            out.push('\n');
        }
        out
    }

    /// Total analytical ops across all groups.
    pub fn total_ops(&self) -> f64 {
        self.groups.iter().map(|g| g.ops).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_window_long_run() {
        assert_eq!(
            BenchmarkReport::stable_window(12.0 * 3600.0),
            (6.0 * 3600.0, 12.0 * 3600.0)
        );
        assert_eq!(BenchmarkReport::stable_window(4.0 * 3600.0), (2.0 * 3600.0, 4.0 * 3600.0));
    }

    #[test]
    fn stable_scores_average_window_only() {
        let series: Vec<ScoreSample> = (1..=12)
            .map(|h| ScoreSample::new(h as f64 * 3600.0, 1e18 * h as f64, 0.3))
            .collect();
        // flops constant at 1e18/3600 ≈ 2.78e14 for every sample.
        let (f, _) = BenchmarkReport::stable_scores(&series, 12.0 * 3600.0);
        assert!((f - 1e18 / 3600.0).abs() / f < 1e-9);
    }

    #[test]
    fn empty_window_falls_back() {
        let series = vec![ScoreSample::new(100.0, 1e12, 0.4)];
        let (f, r) = BenchmarkReport::stable_scores(&series, 12.0 * 3600.0);
        assert!(f > 0.0);
        assert!(r > 0.0);
    }
}
