//! Resource-utilization telemetry (paper Appendix D, Figs 9–12).
//!
//! The paper samples nvidia-smi/host counters on a user-defined interval
//! and reports, per timestamp, the mean across nodes and the corresponding
//! standard deviation (uniformity evidence). The simulated coordinator
//! pushes per-node readings here; the toolkit aggregates exactly like the
//! paper's.


use crate::util::stats::{mean, stddev};

/// One node's utilization reading at a sample instant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NodeReading {
    pub gpu_util: f64,
    pub gpu_mem_util: f64,
    pub cpu_util: f64,
    pub host_mem_util: f64,
}

/// Aggregated sample across nodes (what Figs 9–12 plot).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TelemetrySample {
    pub t: f64,
    pub gpu_util_mean: f64,
    pub gpu_util_std: f64,
    pub gpu_mem_mean: f64,
    pub gpu_mem_std: f64,
    pub cpu_util_mean: f64,
    pub cpu_util_std: f64,
    pub host_mem_mean: f64,
    pub host_mem_std: f64,
}

/// Collector with a fixed sampling interval.
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub interval_s: f64,
    samples: Vec<TelemetrySample>,
}

impl Telemetry {
    /// 18-minute default interval (Figs 9/10).
    pub fn new(interval_s: f64) -> Self {
        assert!(interval_s > 0.0);
        Telemetry {
            interval_s,
            samples: Vec::new(),
        }
    }

    /// Aggregate one instant's per-node readings.
    pub fn record(&mut self, t: f64, readings: &[NodeReading]) {
        self.samples.push(aggregate(t, readings));
    }

    /// Append an already-aggregated sample (streaming callers aggregate
    /// via [`aggregate`] themselves and may not buffer at all).
    pub fn push_sample(&mut self, sample: TelemetrySample) {
        self.samples.push(sample);
    }

    pub fn samples(&self) -> &[TelemetrySample] {
        &self.samples
    }

    /// Mean of a metric over a time window [t0, t1] — the paper reports
    /// averages "from 6 hours to 12 hours (after the initial warm-up)".
    pub fn window_mean(&self, t0: f64, t1: f64, f: fn(&TelemetrySample) -> f64) -> f64 {
        let v: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.t >= t0 && s.t <= t1)
            .map(f)
            .collect();
        mean(&v)
    }
}

/// Aggregate one instant's per-node readings into a cross-node sample.
///
/// Free function (not a `Telemetry` method) so the streaming report path
/// can compute the identical sample — same column order, same left-fold
/// mean, bit-for-bit — without buffering it.
pub fn aggregate(t: f64, readings: &[NodeReading]) -> TelemetrySample {
    assert!(!readings.is_empty());
    let col = |f: fn(&NodeReading) -> f64| -> Vec<f64> { readings.iter().map(f).collect() };
    let g = col(|r| r.gpu_util);
    let gm = col(|r| r.gpu_mem_util);
    let c = col(|r| r.cpu_util);
    let hm = col(|r| r.host_mem_util);
    TelemetrySample {
        t,
        gpu_util_mean: mean(&g),
        gpu_util_std: stddev(&g),
        gpu_mem_mean: mean(&gm),
        gpu_mem_std: stddev(&gm),
        cpu_util_mean: mean(&c),
        cpu_util_std: stddev(&c),
        host_mem_mean: mean(&hm),
        host_mem_std: stddev(&hm),
    }
}

/// Running summary of one metric: count, mean (exact left-fold order),
/// min, max, and last value — O(1) state per metric.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OnlineStat {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
}

impl OnlineStat {
    pub fn push(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            if x < self.min {
                self.min = x;
            }
            if x > self.max {
                self.max = x;
            }
        }
        self.sum += x;
        self.last = x;
        self.count += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Online per-group utilization aggregate for the streaming report path:
/// one [`OnlineStat`] per metric, so a 100k-lane run keeps O(groups)
/// telemetry state instead of O(ticks × lanes) buffered samples.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GroupTelemetry {
    pub gpu_util: OnlineStat,
    pub gpu_mem: OnlineStat,
    pub cpu_util: OnlineStat,
    pub host_mem: OnlineStat,
}

impl GroupTelemetry {
    pub fn push(&mut self, r: &NodeReading) {
        self.gpu_util.push(r.gpu_util);
        self.gpu_mem.push(r.gpu_mem_util);
        self.cpu_util.push(r.cpu_util);
        self.host_mem.push(r.host_mem_util);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reading(g: f64) -> NodeReading {
        NodeReading {
            gpu_util: g,
            gpu_mem_util: 0.8,
            cpu_util: 0.04,
            host_mem_util: 0.15,
        }
    }

    #[test]
    fn aggregates_mean_and_std() {
        let mut t = Telemetry::new(60.0);
        t.record(0.0, &[reading(0.9), reading(0.95), reading(1.0)]);
        let s = &t.samples()[0];
        assert!((s.gpu_util_mean - 0.95).abs() < 1e-9);
        assert!(s.gpu_util_std > 0.0);
        assert!(s.gpu_mem_std < 1e-12);
    }

    #[test]
    fn single_node_has_zero_std() {
        // Paper: "there is no standard deviation of just 1 node".
        let mut t = Telemetry::new(60.0);
        t.record(0.0, &[reading(0.9)]);
        assert_eq!(t.samples()[0].gpu_util_std, 0.0);
    }

    #[test]
    fn window_mean_filters() {
        let mut t = Telemetry::new(60.0);
        for i in 0..10 {
            t.record(i as f64 * 3600.0, &[reading(if i < 5 { 0.2 } else { 1.0 })]);
        }
        let m = t.window_mean(5.0 * 3600.0, 9.0 * 3600.0, |s| s.gpu_util_mean);
        assert!((m - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn record_requires_readings() {
        Telemetry::new(60.0).record(0.0, &[]);
    }

    #[test]
    fn aggregate_matches_record() {
        let readings = [reading(0.9), reading(0.95), reading(1.0)];
        let mut t = Telemetry::new(60.0);
        t.record(7.0, &readings);
        assert_eq!(t.samples()[0], aggregate(7.0, &readings));
    }

    #[test]
    fn push_sample_appends_verbatim() {
        let s = aggregate(3.0, &[reading(0.5)]);
        let mut t = Telemetry::new(60.0);
        t.push_sample(s);
        assert_eq!(t.samples(), &[s]);
    }

    #[test]
    fn online_stat_tracks_running_summary() {
        let mut st = OnlineStat::default();
        assert_eq!(st.mean(), 0.0);
        for x in [3.0, -1.0, 2.0, 2.0] {
            st.push(x);
        }
        assert_eq!(st.count, 4);
        assert_eq!(st.min, -1.0);
        assert_eq!(st.max, 3.0);
        assert_eq!(st.last, 2.0);
        // Exactly the left-fold sum/count of util::stats::mean.
        assert_eq!(st.mean().to_bits(), mean(&[3.0, -1.0, 2.0, 2.0]).to_bits());
    }

    #[test]
    fn group_telemetry_folds_all_four_metrics() {
        let mut g = GroupTelemetry::default();
        g.push(&reading(0.9));
        g.push(&reading(0.7));
        assert_eq!(g.gpu_util.count, 2);
        assert_eq!(g.gpu_util.min, 0.7);
        assert_eq!(g.gpu_util.last, 0.7);
        assert_eq!(g.host_mem.mean(), 0.15);
    }
}
