//! `aiperf sweep` scaling-table assembly (the paper's Fig 4 / Table 1
//! weak-scaling view) and its CSV exporter.
//!
//! A sweep runs several scenario presets and compares them with the
//! paper's methodology: the weak-scaling efficiency of a system is its
//! per-device score relative to the *smallest sweep entry with the same
//! accelerator mix* — a T4 fleet is never scored against a V100 baseline
//! (that would measure hardware speed, not scaling). When a mix appears
//! only once in the sweep, or its baseline score is zero, the ratio is
//! meaningless and renders as `—` (and as an empty CSV cell) rather than
//! a fake 100 %.

use std::collections::BTreeMap;

use super::report::BenchmarkReport;

/// One sweep entry: a named scenario and its finished report.
pub struct SweepRun {
    pub scenario: String,
    pub report: BenchmarkReport,
}

/// Format an ops/s quantity with the paper's unit ladder (Tera/Peta).
pub fn si_ops(x: f64) -> String {
    if x >= 1e15 {
        format!("{:.2} POPS", x / 1e15)
    } else if x >= 1e12 {
        format!("{:.2} TOPS", x / 1e12)
    } else if x >= 1e9 {
        format!("{:.2} GOPS", x / 1e9)
    } else {
        format!("{x:.3e} OPS")
    }
}

/// Accelerator-mix key of a report: sorted, deduplicated group labels.
pub fn accelerator_mix(r: &BenchmarkReport) -> String {
    let mut labels: Vec<&str> = r.groups.iter().map(|g| g.label.as_str()).collect();
    labels.sort_unstable();
    labels.dedup();
    labels.join("+")
}

/// The efficiency baseline of one accelerator mix.
pub struct Baseline {
    /// Device count of the smallest entry of this mix.
    pub devices: u64,
    /// Per-device score of that smallest entry.
    pub per_device: f64,
    /// How many sweep entries share this mix.
    pub entries: usize,
}

/// Baseline per accelerator mix: the fewest-device entry of each mix.
pub fn baselines(runs: &[SweepRun]) -> BTreeMap<String, Baseline> {
    let mut map: BTreeMap<String, Baseline> = BTreeMap::new();
    for run in runs {
        let r = &run.report;
        let per_device = r.score_flops / r.total_gpus.max(1) as f64;
        let e = map.entry(accelerator_mix(r)).or_insert(Baseline {
            devices: r.total_gpus,
            per_device,
            entries: 0,
        });
        e.entries += 1;
        if r.total_gpus < e.devices {
            e.devices = r.total_gpus;
            e.per_device = per_device;
        }
    }
    map
}

/// Weak-scaling efficiency (% of the same-mix baseline's per-device
/// score), or `None` when the ratio is meaningless: the mix appears only
/// once in the sweep, or the baseline score is zero / not positive.
pub fn efficiency_pct(run: &SweepRun, baselines: &BTreeMap<String, Baseline>) -> Option<f64> {
    let b = baselines.get(&accelerator_mix(&run.report))?;
    if b.entries < 2 || !b.per_device.is_finite() || b.per_device <= 0.0 {
        return None;
    }
    let per_device = run.report.score_flops / run.report.total_gpus.max(1) as f64;
    Some(per_device / b.per_device * 100.0)
}

/// One per-group breakdown row of a heterogeneous sweep entry.
pub struct GroupRow {
    pub label: String,
    pub nodes: u64,
    pub devices: u64,
    /// Slice of the scenario's stable-window score allocated to this
    /// group by its share of the run's analytical ops — the same
    /// estimator as (and summing to) the parent row.
    pub score: f64,
}

/// Per-group rows of a report (empty for homogeneous entries, which have
/// no breakdown to show). Both renderers draw from this single
/// allocation so the table and the CSV artifact cannot drift apart.
pub fn group_rows(r: &BenchmarkReport) -> Vec<GroupRow> {
    if r.groups.len() < 2 {
        return Vec::new();
    }
    let total_ops = r.total_ops();
    r.groups
        .iter()
        .map(|g| {
            let share = if total_ops > 0.0 { g.ops / total_ops } else { 0.0 };
            GroupRow {
                label: g.label.clone(),
                nodes: g.nodes,
                devices: g.gpus(),
                score: r.score_flops * share,
            }
        })
        .collect()
}

/// Render the human-readable scaling table (stable-window scores, with a
/// per-group breakdown row set under each heterogeneous entry).
pub fn render_table(runs: &[SweepRun]) -> String {
    let base = baselines(runs);
    let mut out = String::new();
    out.push_str(
        "\nscaling table (stable-window score; efficiency vs the smallest \
         sweep entry of the same accelerator mix, \u{2014} when that ratio \
         is meaningless):\n",
    );
    out.push_str(&format!(
        "{:<14} {:>6} {:>8} {:>16} {:>16} {:>11}\n",
        "scenario", "nodes", "devices", "score OPS", "OPS/device", "efficiency"
    ));
    for run in runs {
        let r = &run.report;
        let per_device = r.score_flops / r.total_gpus.max(1) as f64;
        let eff = match efficiency_pct(run, &base) {
            Some(e) => format!("{e:>10.1}%"),
            None => format!("{:>11}", "\u{2014}"),
        };
        out.push_str(&format!(
            "{:<14} {:>6} {:>8} {:>16} {:>16} {}\n",
            run.scenario,
            r.nodes,
            r.total_gpus,
            si_ops(r.score_flops),
            si_ops(per_device),
            eff,
        ));
        for g in group_rows(r) {
            out.push_str(&format!(
                "{:<14} {:>6} {:>8} {:>16} {:>16}\n",
                format!("  .{}", g.label),
                g.nodes,
                g.devices,
                si_ops(g.score),
                si_ops(g.score / g.devices.max(1) as f64),
            ));
        }
    }
    out
}

/// Render the sweep as CSV (one total row per scenario; heterogeneous
/// scenarios add one row per group with the `group` column set). The
/// efficiency cell is empty exactly when the table renders `—`. The
/// migration columns carry the elastic scheduler's per-group counters
/// (summed over groups on the total row): adopted trials, dispatched
/// trials, and the staging + IB-sync overhead seconds they paid. The
/// trailing early-stop columns carry the LogFit predictor's counters
/// (`early_stops` terminations, `epochs_saved` skipped epochs) with the
/// same totals-row summation.
pub fn render_csv(runs: &[SweepRun]) -> String {
    let base = baselines(runs);
    let mut out = String::from(
        "scenario,group,nodes,devices,score_ops,ops_per_device,efficiency_pct,\
         migrations_in,migrations_out,migration_overhead_s,early_stops,epochs_saved\n",
    );
    for run in runs {
        let r = &run.report;
        let per_device = r.score_flops / r.total_gpus.max(1) as f64;
        let eff = efficiency_pct(run, &base)
            .map(|e| format!("{e}"))
            .unwrap_or_default();
        let mig_in: u64 = r.groups.iter().map(|g| g.migrations_in).sum();
        let mig_out: u64 = r.groups.iter().map(|g| g.migrations_out).sum();
        let overhead: f64 = r.groups.iter().map(|g| g.migration_overhead_s).sum();
        let stops: u64 = r.groups.iter().map(|g| g.early_stops).sum();
        let saved: u64 = r.groups.iter().map(|g| g.epochs_saved).sum();
        out.push_str(&format!(
            "{},,{},{},{},{},{},{},{},{},{},{}\n",
            run.scenario, r.nodes, r.total_gpus, r.score_flops, per_device, eff, mig_in, mig_out,
            overhead, stops, saved,
        ));
        for (g, b) in group_rows(r).iter().zip(&r.groups) {
            out.push_str(&format!(
                "{},{},{},{},{},{},,{},{},{},{},{}\n",
                run.scenario,
                g.label,
                g.nodes,
                g.devices,
                g.score,
                g.score / g.devices.max(1) as f64,
                b.migrations_in,
                b.migrations_out,
                b.migration_overhead_s,
                b.early_stops,
                b.epochs_saved,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::report::GroupBreakdown;
    use super::super::score::Validity;
    use super::*;

    /// A minimal report with the given `(label, nodes, gpus_per_node)`
    /// groups and stable-window score.
    fn report(groups: &[(&str, u64, u64)], score: f64) -> BenchmarkReport {
        BenchmarkReport {
            nodes: groups.iter().map(|g| g.1).sum(),
            total_gpus: groups.iter().map(|g| g.1 * g.2).sum(),
            groups: groups
                .iter()
                .map(|&(label, nodes, gpus_per_node)| GroupBreakdown {
                    label: label.to_string(),
                    nodes,
                    gpus_per_node,
                    ops: 1.0,
                    ops_per_second: 1.0,
                    steals: 0,
                    oom_skips: 0,
                    migrations_in: 0,
                    migrations_out: 0,
                    migration_overhead_s: 0.0,
                    feedback_routed: 0,
                    migrant_ring_joins: 0,
                    barrier_slack_s: 0.0,
                    early_stops: 0,
                    epochs_saved: 0,
                })
                .collect(),
            lane_util: Vec::new(),
            duration_s: 3600.0,
            score_series: Vec::new(),
            score_flops: score,
            final_error: 0.3,
            regulated_score: score,
            architectures_evaluated: 1,
            telemetry: Vec::new(),
            validity: Validity::Valid,
            nfs_bytes_read: 0,
            nfs_bytes_written: 0,
            shards_touched: 0,
            shards_skipped: 0,
        }
    }

    fn run(name: &str, groups: &[(&str, u64, u64)], score: f64) -> SweepRun {
        SweepRun {
            scenario: name.to_string(),
            report: report(groups, score),
        }
    }

    #[test]
    fn same_mix_scales_get_a_real_efficiency() {
        let runs = vec![
            run("small", &[("v100", 2, 8)], 16.0e12),
            run("big", &[("v100", 16, 8)], 115.2e12),
        ];
        let base = baselines(&runs);
        // Baseline row: exactly 100 %.
        assert_eq!(efficiency_pct(&runs[0], &base), Some(100.0));
        // 115.2e12/128 per device vs 16e12/16 = 0.9e12 vs 1.0e12 → 90 %.
        let eff = efficiency_pct(&runs[1], &base).unwrap();
        assert!((eff - 90.0).abs() < 1e-9, "eff={eff}");
    }

    #[test]
    fn unique_mix_has_no_meaningful_efficiency() {
        let runs = vec![
            run("v100", &[("v100", 2, 8)], 16.0e12),
            run("t4", &[("t4", 4, 8)], 2.0e12),
        ];
        let base = baselines(&runs);
        assert_eq!(efficiency_pct(&runs[0], &base), None);
        assert_eq!(efficiency_pct(&runs[1], &base), None);
        let table = render_table(&runs);
        assert!(table.contains('\u{2014}'), "table must render —:\n{table}");
        assert!(!table.contains("100.0%"), "no fake 100% baselines:\n{table}");
    }

    #[test]
    fn zero_score_baseline_guarded() {
        let runs = vec![
            run("dead-small", &[("v100", 2, 8)], 0.0),
            run("dead-big", &[("v100", 4, 8)], 1.0e12),
        ];
        let base = baselines(&runs);
        // The smallest entry scored zero: any ratio against it is
        // meaningless for every entry of the mix.
        assert_eq!(efficiency_pct(&runs[0], &base), None);
        assert_eq!(efficiency_pct(&runs[1], &base), None);
    }

    #[test]
    fn mixed_topology_entries_key_on_the_full_mix() {
        // A heterogeneous entry is its own mix, distinct from its parts.
        let runs = vec![
            run("mixed", &[("t4", 2, 8), ("v100", 2, 8)], 10.0e12),
            run("t4-only", &[("t4", 4, 8)], 2.0e12),
        ];
        let base = baselines(&runs);
        assert!(base.contains_key("t4+v100"));
        assert!(base.contains_key("t4"));
        assert_eq!(efficiency_pct(&runs[0], &base), None);
    }

    #[test]
    fn csv_has_totals_and_group_rows() {
        let runs = vec![
            run("small", &[("v100", 2, 8)], 16.0e12),
            run("mixed", &[("t4", 2, 8), ("v100", 2, 8)], 10.0e12),
            run("big", &[("v100", 16, 8)], 115.2e12),
        ];
        let csv = render_csv(&runs);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines[0],
            "scenario,group,nodes,devices,score_ops,ops_per_device,efficiency_pct,\
             migrations_in,migrations_out,migration_overhead_s,early_stops,epochs_saved"
        );
        // 3 totals + 2 group rows under the heterogeneous entry.
        assert_eq!(lines.len(), 6);
        assert!(lines[1].starts_with("small,,2,16,"));
        assert!(lines[2].starts_with("mixed,,4,32,"));
        assert!(lines[3].starts_with("mixed,t4,2,16,"));
        assert!(lines[4].starts_with("mixed,v100,2,16,"));
        // The unique mix's efficiency cell is empty (`,,` before the
        // migration columns); same-mix entries get a number.
        assert!(
            lines[2].contains(",,0,0,0,0,0"),
            "unique mix keeps the cell empty"
        );
        assert!(lines[1].contains(",100,"), "baseline row reads 100");
        // Every row has the same column count.
        for l in &lines[1..] {
            assert_eq!(l.matches(',').count(), 11, "row {l}");
        }
    }

    #[test]
    fn csv_migration_columns_carry_group_counters() {
        let mut r = report(&[("t4", 2, 8), ("v100", 2, 8)], 10.0e12);
        r.groups[0].migrations_out = 3;
        r.groups[1].migrations_in = 2;
        r.groups[1].migration_overhead_s = 4.5;
        let runs = vec![SweepRun {
            scenario: "elastic".to_string(),
            report: r,
        }];
        let csv = render_csv(&runs);
        let lines: Vec<&str> = csv.lines().collect();
        // Totals row sums the group counters.
        assert!(lines[1].ends_with(",2,3,4.5,0,0"), "totals row: {}", lines[1]);
        // Group rows carry their own counters after the empty efficiency
        // cell.
        assert!(lines[2].ends_with(",,0,3,0,0,0"), "t4 row: {}", lines[2]);
        assert!(lines[3].ends_with(",,2,0,4.5,0,0"), "v100 row: {}", lines[3]);
    }

    #[test]
    fn csv_early_stop_columns_carry_group_counters() {
        let mut r = report(&[("t4", 2, 8), ("v100", 2, 8)], 10.0e12);
        r.groups[0].early_stops = 4;
        r.groups[0].epochs_saved = 31;
        r.groups[1].early_stops = 1;
        r.groups[1].epochs_saved = 6;
        let runs = vec![SweepRun {
            scenario: "predict".to_string(),
            report: r,
        }];
        let csv = render_csv(&runs);
        let lines: Vec<&str> = csv.lines().collect();
        // Totals row sums the predictor's counters across groups.
        assert!(lines[1].ends_with(",0,0,0,5,37"), "totals row: {}", lines[1]);
        // Group rows carry their own counters in the trailing columns.
        assert!(lines[2].ends_with(",4,31"), "t4 row: {}", lines[2]);
        assert!(lines[3].ends_with(",1,6"), "v100 row: {}", lines[3]);
    }
}
