//! Streaming NDJSON report pipeline (`--stream-report <path>`).
//!
//! The buffered [`BenchmarkReport`] holds every score sample, telemetry
//! tick, and lane row in RAM and then serializes the whole tree at once
//! — fine at 16 nodes, the memory bottleneck at 102,400 lanes. This
//! module is the constant-memory alternative: [`ReportStream`] writes
//! one small record per line *as events occur* (through
//! [`crate::util::json::NdjsonWriter`], no whole-tree construction),
//! and [`reconstruct_summary`] post-processes a stream one record at a
//! time via [`crate::util::ndjson`].
//!
//! Record schema (each line is one object tagged by its `record` key):
//!
//! | `record`          | fields                                                        |
//! |-------------------|---------------------------------------------------------------|
//! | `header`          | `schema` (1), cluster shape, seed, intervals, `duration_s`    |
//! | `trial`           | one merged completion: `t`, `id`, `node`, `group`, `round`, `epochs_trained`, `ops`, `accuracy`, `penalty` |
//! | `window`          | one epoch barrier: `idx`, `t`, `completions`                  |
//! | `score`           | one score tick: `t`, `cumulative_ops`, `flops`, `best_error`, `regulated` |
//! | `telemetry`       | one telemetry tick: `t` + cross-node mean/std per metric      |
//! | `telemetry_group` | end-of-run per-group online stats (count/mean/min/max/last)   |
//! | `lane`            | one lane's busy fraction: `group`, `node`, `lane`, `busy_fraction` |
//! | `summary`         | trailer: the report scalars + per-group breakdown + `records` (count of records before this line) |
//!
//! The `records` count in the trailer is the truncation detector: a
//! stream without a matching trailer was cut short and
//! [`reconstruct_summary`] says so instead of crashing.

use std::io;

use crate::config::BenchmarkConfig;
use crate::coordinator::history::ModelRecord;
use crate::metrics::report::BenchmarkReport;
use crate::metrics::score::ScoreSample;
use crate::metrics::telemetry::{GroupTelemetry, OnlineStat, TelemetrySample};
use crate::util::json::{arr, num, obj, s, Json, NdjsonWriter};
use crate::util::ndjson::NdjsonReader;
use crate::util::stats::mean;

/// Typed writer for the streaming report: one method per record kind,
/// each serializing a single small object and appending it as one
/// NDJSON line. State is the output handle and a record counter —
/// nothing scales with run length.
pub struct ReportStream<W: io::Write> {
    w: NdjsonWriter<W>,
}

impl<W: io::Write> ReportStream<W> {
    pub fn new(out: W) -> Self {
        ReportStream { w: NdjsonWriter::new(out) }
    }

    /// Records written so far.
    pub fn records(&self) -> u64 {
        self.w.records()
    }

    pub fn flush(&mut self) -> io::Result<()> {
        self.w.flush()
    }

    pub fn header(&mut self, cfg: &BenchmarkConfig) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("header")),
            ("schema", num(1.0)),
            ("nodes", num(cfg.topology.total_nodes() as f64)),
            ("total_gpus", num(cfg.topology.total_gpus() as f64)),
            (
                "groups",
                arr(cfg
                    .topology
                    .groups
                    .iter()
                    .map(|g| {
                        obj(vec![
                            ("label", s(g.label.clone())),
                            ("nodes", num(g.count as f64)),
                            ("gpus_per_node", num(g.gpus_per_node as f64)),
                        ])
                    })
                    .collect()),
            ),
            ("duration_s", num(cfg.duration_s)),
            ("seed", num(cfg.seed as f64)),
            ("sync_interval_s", num(cfg.sync_interval_s)),
            ("telemetry_interval_s", num(cfg.telemetry_interval_s)),
            ("score_interval_s", num(cfg.score_interval_s)),
        ]))
    }

    pub fn trial(&mut self, rec: &ModelRecord) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("trial")),
            ("t", num(rec.completed_at)),
            ("id", num(rec.id as f64)),
            ("node", num(rec.node as f64)),
            ("group", num(rec.group as f64)),
            ("round", num(rec.round as f64)),
            ("epochs_trained", num(rec.epochs_trained as f64)),
            ("ops", num(rec.ops)),
            ("accuracy", num(rec.measured_accuracy)),
            ("penalty", Json::Bool(rec.penalty)),
        ]))
    }

    pub fn window(&mut self, idx: u64, t: f64, completions: u64) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("window")),
            ("idx", num(idx as f64)),
            ("t", num(t)),
            ("completions", num(completions as f64)),
        ]))
    }

    pub fn score(&mut self, p: &ScoreSample) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("score")),
            ("t", num(p.t)),
            ("cumulative_ops", num(p.cumulative_ops)),
            ("flops", num(p.flops)),
            ("best_error", num(p.best_error)),
            ("regulated", num(p.regulated)),
        ]))
    }

    pub fn telemetry(&mut self, p: &TelemetrySample) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("telemetry")),
            ("t", num(p.t)),
            ("gpu_util_mean", num(p.gpu_util_mean)),
            ("gpu_util_std", num(p.gpu_util_std)),
            ("gpu_mem_mean", num(p.gpu_mem_mean)),
            ("gpu_mem_std", num(p.gpu_mem_std)),
            ("cpu_util_mean", num(p.cpu_util_mean)),
            ("cpu_util_std", num(p.cpu_util_std)),
            ("host_mem_mean", num(p.host_mem_mean)),
            ("host_mem_std", num(p.host_mem_std)),
        ]))
    }

    pub fn group_telemetry(
        &mut self,
        group: u64,
        label: &str,
        g: &GroupTelemetry,
    ) -> io::Result<()> {
        fn metric(prefix: &str, st: &OnlineStat) -> Vec<(String, Json)> {
            vec![
                (format!("{prefix}_count"), num(st.count as f64)),
                (format!("{prefix}_mean"), num(st.mean())),
                (format!("{prefix}_min"), num(st.min)),
                (format!("{prefix}_max"), num(st.max)),
                (format!("{prefix}_last"), num(st.last)),
            ]
        }
        let mut pairs = vec![
            ("record".to_string(), s("telemetry_group")),
            ("group".to_string(), num(group as f64)),
            ("label".to_string(), s(label)),
        ];
        pairs.extend(metric("gpu_util", &g.gpu_util));
        pairs.extend(metric("gpu_mem", &g.gpu_mem));
        pairs.extend(metric("cpu_util", &g.cpu_util));
        pairs.extend(metric("host_mem", &g.host_mem));
        let value = Json::Obj(pairs.into_iter().collect());
        self.w.record(&value)
    }

    pub fn lane(&mut self, group: &str, node: u64, lane: u64, busy_fraction: f64) -> io::Result<()> {
        self.w.record(&obj(vec![
            ("record", s("lane")),
            ("group", s(group)),
            ("node", num(node as f64)),
            ("lane", num(lane as f64)),
            ("busy_fraction", num(busy_fraction)),
        ]))
    }

    /// The trailer: report scalars, the per-group breakdown, and the
    /// count of records written before this line (the truncation
    /// detector).
    pub fn summary(&mut self, report: &BenchmarkReport) -> io::Result<()> {
        let records = self.w.records();
        self.w.record(&obj(vec![
            ("record", s("summary")),
            ("records", num(records as f64)),
            ("nodes", num(report.nodes as f64)),
            ("total_gpus", num(report.total_gpus as f64)),
            ("duration_s", num(report.duration_s)),
            ("score_flops", num(report.score_flops)),
            ("final_error", num(report.final_error)),
            ("regulated_score", num(report.regulated_score)),
            (
                "architectures_evaluated",
                num(report.architectures_evaluated as f64),
            ),
            ("validity", s(format!("{:?}", report.validity))),
            ("nfs_bytes_read", num(report.nfs_bytes_read as f64)),
            ("nfs_bytes_written", num(report.nfs_bytes_written as f64)),
            ("shards_touched", num(report.shards_touched as f64)),
            ("shards_skipped", num(report.shards_skipped as f64)),
            (
                "groups",
                arr(report
                    .groups
                    .iter()
                    .map(|g| {
                        obj(vec![
                            ("label", s(g.label.clone())),
                            ("nodes", num(g.nodes as f64)),
                            ("gpus_per_node", num(g.gpus_per_node as f64)),
                            ("ops", num(g.ops)),
                            ("ops_per_second", num(g.ops_per_second)),
                            ("steals", num(g.steals as f64)),
                            ("oom_skips", num(g.oom_skips as f64)),
                            ("migrations_in", num(g.migrations_in as f64)),
                            ("migrations_out", num(g.migrations_out as f64)),
                            ("migration_overhead_s", num(g.migration_overhead_s)),
                            ("feedback_routed", num(g.feedback_routed as f64)),
                            ("migrant_ring_joins", num(g.migrant_ring_joins as f64)),
                            ("barrier_slack_s", num(g.barrier_slack_s)),
                            ("early_stops", num(g.early_stops as f64)),
                            ("epochs_saved", num(g.epochs_saved as f64)),
                        ])
                    })
                    .collect()),
            ),
        ]))
    }
}

/// Serialize a buffered report as the equivalent NDJSON stream (score,
/// telemetry, and lane records, then the summary trailer). Used by the
/// hotpath bench to compare the allocation profile of record-at-a-time
/// serialization against the whole-tree `to_json()` path on identical
/// data. Returns the number of records written.
pub fn write_report<W: io::Write>(out: W, report: &BenchmarkReport) -> io::Result<u64> {
    let mut stream = ReportStream::new(out);
    for p in &report.score_series {
        stream.score(p)?;
    }
    for p in &report.telemetry {
        stream.telemetry(p)?;
    }
    for l in &report.lane_util {
        stream.lane(&l.group, l.node, l.lane, l.busy_fraction)?;
    }
    stream.summary(report)?;
    stream.flush()?;
    Ok(stream.records())
}

/// Online replacement for [`BenchmarkReport::stable_scores`]: folds
/// score samples as they occur, O(1) state, and returns bit-identical
/// (score, regulated) — same left-fold summation order as
/// `util::stats::mean` over the same window filter.
#[derive(Debug, Clone, Copy)]
pub struct OnlineScores {
    t0: f64,
    t1: f64,
    win_flops: f64,
    win_reg: f64,
    win_n: u64,
    all_flops: f64,
    all_reg: f64,
    all_n: u64,
}

impl OnlineScores {
    pub fn new(duration_s: f64) -> Self {
        let (t0, t1) = BenchmarkReport::stable_window(duration_s);
        OnlineScores {
            t0,
            t1,
            win_flops: 0.0,
            win_reg: 0.0,
            win_n: 0,
            all_flops: 0.0,
            all_reg: 0.0,
            all_n: 0,
        }
    }

    pub fn push(&mut self, p: &ScoreSample) {
        self.all_flops += p.flops;
        self.all_reg += p.regulated;
        self.all_n += 1;
        if p.t >= self.t0 && p.t <= self.t1 {
            self.win_flops += p.flops;
            self.win_reg += p.regulated;
            self.win_n += 1;
        }
    }

    /// (score_flops, regulated_score) with the buffered fallback: the
    /// stable window if it caught any samples, else the whole series,
    /// else zeros (`mean` of an empty slice).
    pub fn stable_scores(&self) -> (f64, f64) {
        if self.win_n > 0 {
            (
                self.win_flops / self.win_n as f64,
                self.win_reg / self.win_n as f64,
            )
        } else if self.all_n > 0 {
            (
                self.all_flops / self.all_n as f64,
                self.all_reg / self.all_n as f64,
            )
        } else {
            (0.0, 0.0)
        }
    }
}

/// A streaming-report read failure. Every malformed or cut-short input
/// maps to one of these — the reader never panics (`tests/fuzz.rs`).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamError {
    /// A line failed to parse as JSON (typically a stream cut
    /// mid-record).
    Parse { line: usize, msg: String },
    /// The stream ended without a summary trailer: the run was cut
    /// short after `records_seen` complete records.
    Truncated { records_seen: u64 },
    /// A structurally invalid record: missing/mistyped fields, an
    /// unknown record tag, data after the trailer, or a trailer whose
    /// counts or scores disagree with the records before it.
    Malformed { line: usize, msg: String },
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Parse { line, msg } => write!(f, "stream line {line}: {msg}"),
            StreamError::Truncated { records_seen } => write!(
                f,
                "stream truncated: no summary trailer after {records_seen} records"
            ),
            StreamError::Malformed { line, msg } => {
                write!(f, "malformed stream record at line {line}: {msg}")
            }
        }
    }
}

impl std::error::Error for StreamError {}

/// The summary reconstructed from a complete stream: the trailer's
/// scalars plus the record counts actually observed.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSummary {
    pub nodes: u64,
    pub total_gpus: u64,
    pub duration_s: f64,
    pub score_flops: f64,
    pub final_error: f64,
    pub regulated_score: f64,
    pub architectures_evaluated: u64,
    pub validity: String,
    pub nfs_bytes_read: u64,
    pub nfs_bytes_written: u64,
    /// Active-set window scheduling counters (see
    /// [`BenchmarkReport::shards_touched`]).
    pub shards_touched: u64,
    pub shards_skipped: u64,
    /// Records before the trailer, per the trailer (verified against
    /// the observed count).
    pub records: u64,
    pub trials: u64,
    pub windows: u64,
    pub score_samples: u64,
    pub telemetry_ticks: u64,
    pub lanes: u64,
}

fn req<'a>(v: &'a Json, key: &str, line: usize) -> Result<&'a Json, StreamError> {
    v.get(key).ok_or_else(|| StreamError::Malformed {
        line,
        msg: format!("missing field `{key}`"),
    })
}

fn req_f64(v: &Json, key: &str, line: usize) -> Result<f64, StreamError> {
    req(v, key, line)?
        .as_f64()
        .ok_or_else(|| StreamError::Malformed {
            line,
            msg: format!("field `{key}` is not a number"),
        })
}

fn req_u64(v: &Json, key: &str, line: usize) -> Result<u64, StreamError> {
    req(v, key, line)?
        .as_u64()
        .ok_or_else(|| StreamError::Malformed {
            line,
            msg: format!("field `{key}` is not a non-negative integer"),
        })
}

fn req_str(v: &Json, key: &str, line: usize) -> Result<String, StreamError> {
    Ok(req(v, key, line)?
        .as_str()
        .ok_or_else(|| StreamError::Malformed {
            line,
            msg: format!("field `{key}` is not a string"),
        })?
        .to_string())
}

/// Reconstruct the run summary from an NDJSON stream, one record at a
/// time (constant memory apart from the score series, which is re-
/// averaged to cross-check the trailer).
///
/// Verifies three integrity properties and reports — never panics on —
/// any violation: every line parses, the trailer is present and its
/// `records` count matches the records observed, and the stable-window
/// scores recomputed from the streamed `score` records equal the
/// trailer's bit for bit.
pub fn reconstruct_summary(text: &str) -> Result<StreamSummary, StreamError> {
    let mut records_seen = 0u64;
    let mut trials = 0u64;
    let mut windows = 0u64;
    let mut telemetry_ticks = 0u64;
    let mut lanes = 0u64;
    let mut scores: Vec<(f64, f64, f64)> = Vec::new();
    let mut summary: Option<(usize, StreamSummary)> = None;

    for item in NdjsonReader::new(text) {
        let (line, v) = item.map_err(|e| StreamError::Parse {
            line: e.line,
            msg: e.msg,
        })?;
        if summary.is_some() {
            return Err(StreamError::Malformed {
                line,
                msg: "record after the summary trailer".to_string(),
            });
        }
        let kind = req_str(&v, "record", line)?;
        match kind.as_str() {
            "header" => {
                let schema = req_u64(&v, "schema", line)?;
                if schema != 1 {
                    return Err(StreamError::Malformed {
                        line,
                        msg: format!("unsupported stream schema {schema}"),
                    });
                }
            }
            "trial" => {
                req_f64(&v, "t", line)?;
                trials += 1;
            }
            "window" => {
                req_f64(&v, "t", line)?;
                windows += 1;
            }
            "score" => {
                scores.push((
                    req_f64(&v, "t", line)?,
                    req_f64(&v, "flops", line)?,
                    req_f64(&v, "regulated", line)?,
                ));
            }
            "telemetry" => {
                req_f64(&v, "t", line)?;
                telemetry_ticks += 1;
            }
            "telemetry_group" => {
                req_u64(&v, "group", line)?;
            }
            "lane" => {
                req_f64(&v, "busy_fraction", line)?;
                lanes += 1;
            }
            "summary" => {
                let records = req_u64(&v, "records", line)?;
                if records != records_seen {
                    return Err(StreamError::Malformed {
                        line,
                        msg: format!(
                            "trailer claims {records} records, stream has {records_seen}"
                        ),
                    });
                }
                summary = Some((
                    line,
                    StreamSummary {
                        nodes: req_u64(&v, "nodes", line)?,
                        total_gpus: req_u64(&v, "total_gpus", line)?,
                        duration_s: req_f64(&v, "duration_s", line)?,
                        score_flops: req_f64(&v, "score_flops", line)?,
                        final_error: req_f64(&v, "final_error", line)?,
                        regulated_score: req_f64(&v, "regulated_score", line)?,
                        architectures_evaluated: req_u64(&v, "architectures_evaluated", line)?,
                        validity: req_str(&v, "validity", line)?,
                        nfs_bytes_read: req_u64(&v, "nfs_bytes_read", line)?,
                        nfs_bytes_written: req_u64(&v, "nfs_bytes_written", line)?,
                        shards_touched: req_u64(&v, "shards_touched", line)?,
                        shards_skipped: req_u64(&v, "shards_skipped", line)?,
                        records,
                        trials,
                        windows,
                        score_samples: scores.len() as u64,
                        telemetry_ticks,
                        lanes,
                    },
                ));
            }
            other => {
                return Err(StreamError::Malformed {
                    line,
                    msg: format!("unknown record tag `{other}`"),
                });
            }
        }
        records_seen += 1;
    }

    let (line, out) = summary.ok_or(StreamError::Truncated { records_seen })?;

    // Cross-check: the trailer's stable-window scores must equal the
    // ones recomputed from the streamed score records, bit for bit
    // (f64s survive the JSON round trip exactly).
    let (t0, t1) = BenchmarkReport::stable_window(out.duration_s);
    let in_window: Vec<&(f64, f64, f64)> =
        scores.iter().filter(|p| p.0 >= t0 && p.0 <= t1).collect();
    let picked: Vec<&(f64, f64, f64)> = if in_window.is_empty() {
        scores.iter().collect()
    } else {
        in_window
    };
    let f = mean(&picked.iter().map(|p| p.1).collect::<Vec<_>>());
    let r = mean(&picked.iter().map(|p| p.2).collect::<Vec<_>>());
    if f.to_bits() != out.score_flops.to_bits() || r.to_bits() != out.regulated_score.to_bits() {
        return Err(StreamError::Malformed {
            line,
            msg: format!(
                "trailer scores ({}, {}) disagree with recomputed ({f}, {r})",
                out.score_flops, out.regulated_score
            ),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::{GroupBreakdown, LaneUtil};
    use crate::metrics::score::Validity;
    use crate::metrics::telemetry::aggregate;
    use crate::metrics::telemetry::NodeReading;

    fn tiny_report() -> BenchmarkReport {
        let series: Vec<ScoreSample> = (1..=4)
            .map(|h| ScoreSample::new(h as f64 * 3600.0, 1e18 * h as f64, 0.3))
            .collect();
        let duration_s = 4.0 * 3600.0;
        let (score_flops, regulated_score) =
            BenchmarkReport::stable_scores(&series, duration_s);
        BenchmarkReport {
            nodes: 2,
            total_gpus: 16,
            groups: vec![GroupBreakdown {
                label: "v100".to_string(),
                nodes: 2,
                gpus_per_node: 8,
                ops: 1e18,
                ops_per_second: 1e18 / duration_s,
                steals: 0,
                oom_skips: 0,
                migrations_in: 0,
                migrations_out: 0,
                migration_overhead_s: 0.0,
                feedback_routed: 0,
                migrant_ring_joins: 0,
                barrier_slack_s: 0.0,
                early_stops: 0,
                epochs_saved: 0,
            }],
            lane_util: vec![LaneUtil {
                group: "v100".to_string(),
                node: 0,
                lane: 0,
                busy_fraction: 0.9,
            }],
            duration_s,
            score_series: series,
            score_flops,
            final_error: 0.3,
            regulated_score,
            architectures_evaluated: 7,
            telemetry: vec![aggregate(
                3600.0,
                &[NodeReading {
                    gpu_util: 0.9,
                    gpu_mem_util: 0.8,
                    cpu_util: 0.05,
                    host_mem_util: 0.2,
                }],
            )],
            validity: Validity::Valid,
            nfs_bytes_read: 1024,
            nfs_bytes_written: 2048,
            shards_touched: 6,
            shards_skipped: 2,
        }
    }

    #[test]
    fn round_trips_a_buffered_report() {
        let report = tiny_report();
        let mut buf = Vec::new();
        let records = write_report(&mut buf, &report).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(records, text.lines().count() as u64);
        let summary = reconstruct_summary(&text).unwrap();
        assert_eq!(summary.nodes, report.nodes);
        assert_eq!(summary.total_gpus, report.total_gpus);
        assert_eq!(summary.score_flops.to_bits(), report.score_flops.to_bits());
        assert_eq!(summary.final_error.to_bits(), report.final_error.to_bits());
        assert_eq!(
            summary.regulated_score.to_bits(),
            report.regulated_score.to_bits()
        );
        assert_eq!(summary.architectures_evaluated, 7);
        assert_eq!(summary.validity, "Valid");
        assert_eq!(summary.score_samples, 4);
        assert_eq!(summary.telemetry_ticks, 1);
        assert_eq!(summary.lanes, 1);
        assert_eq!(summary.records, records - 1);
    }

    #[test]
    fn truncated_stream_is_detected() {
        let report = tiny_report();
        let mut buf = Vec::new();
        write_report(&mut buf, &report).unwrap();
        let text = String::from_utf8(buf).unwrap();
        // Drop the trailer line entirely.
        let cut = &text[..text.rfind("{\"").unwrap()];
        match reconstruct_summary(cut) {
            Err(StreamError::Truncated { records_seen }) => {
                assert_eq!(records_seen, text.lines().count() as u64 - 1);
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
        // Cut mid-record: a parse error with the right line, not a panic.
        let mid = &text[..text.len() - 10];
        assert!(matches!(
            reconstruct_summary(mid),
            Err(StreamError::Parse { .. })
        ));
    }

    #[test]
    fn tampered_record_count_is_malformed() {
        let report = tiny_report();
        let mut buf = Vec::new();
        write_report(&mut buf, &report).unwrap();
        let text = String::from_utf8(buf).unwrap();
        // Remove one non-trailer line: the trailer count no longer matches.
        let mut lines: Vec<&str> = text.lines().collect();
        lines.remove(0);
        let tampered = lines.join("\n");
        assert!(matches!(
            reconstruct_summary(&tampered),
            Err(StreamError::Malformed { .. })
        ));
    }

    #[test]
    fn online_scores_match_buffered_stable_scores() {
        for duration_h in [4.0, 12.0, 24.0] {
            let duration_s = duration_h * 3600.0;
            let series: Vec<ScoreSample> = (1..=(duration_h as u64))
                .map(|h| {
                    ScoreSample::new(h as f64 * 3600.0, 3.7e17 * h as f64, 0.31 / h as f64)
                })
                .collect();
            let mut online = OnlineScores::new(duration_s);
            for p in &series {
                online.push(p);
            }
            let (bf, br) = BenchmarkReport::stable_scores(&series, duration_s);
            let (of, or) = online.stable_scores();
            assert_eq!(bf.to_bits(), of.to_bits());
            assert_eq!(br.to_bits(), or.to_bits());
        }
        // Empty series: both fall back to zeros.
        let empty = OnlineScores::new(3600.0);
        let (bf, br) = BenchmarkReport::stable_scores(&[], 3600.0);
        assert_eq!(empty.stable_scores(), (bf, br));
    }

    #[test]
    fn group_telemetry_record_serializes() {
        let mut g = GroupTelemetry::default();
        g.push(&NodeReading {
            gpu_util: 0.9,
            gpu_mem_util: 0.8,
            cpu_util: 0.05,
            host_mem_util: 0.2,
        });
        let mut stream = ReportStream::new(Vec::new());
        stream.group_telemetry(0, "v100", &g).unwrap();
        let mut w = stream.w;
        w.flush().unwrap();
        let text = String::from_utf8(w.into_inner()).unwrap();
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("record").and_then(Json::as_str), Some("telemetry_group"));
        assert_eq!(v.get("gpu_util_count").and_then(Json::as_u64), Some(1));
        assert_eq!(v.get("host_mem_last").and_then(Json::as_f64), Some(0.2));
    }
}
