//! Scoring and observability (paper §4.4, Appendix D).
//!
//! * [`score`] — the major score (FLOPS) and the regulated score
//!   (Equation 3: −ln(error)·FLOPS), with the paper's validity rules;
//! * [`telemetry`] — time-series sampling of GPU/CPU/memory utilization
//!   with per-node standard deviations (Figs 9–12);
//! * [`report`] — the final benchmark report the data-analysis toolkit
//!   produces at termination;
//! * [`stream`] — the constant-memory NDJSON streaming report pipeline
//!   (`--stream-report`): records written as they occur, summary
//!   reconstructed from the stream;
//! * [`sweep`] — the Fig-4 weak-scaling table over several scenario
//!   presets, with per-mix efficiency baselines and a CSV exporter.

pub mod chart;
pub mod report;
pub mod score;
pub mod stream;
pub mod sweep;
pub mod telemetry;

pub use chart::{ascii_chart, csv, lane_util_chart};
pub use report::{BenchmarkReport, GroupBreakdown, LaneUtil};
pub use score::{regulated_score, validate_result, ScoreSample, Validity};
pub use stream::{reconstruct_summary, ReportStream, StreamError, StreamSummary};
pub use telemetry::{Telemetry, TelemetrySample};
