//! The benchmark scores (paper §4.4).
//!
//! Major score: FLOPS = analytical ops / wall time (Equation 4).
//! Regulated score (Equation 3): `−ln(Error) × FLOPS`, Error ∈ (0,1) —
//! designed so ∂score/∂error grows as error shrinks (compensating the
//! plateauing accuracy curve) while ∂score/∂FLOPS is constant.
//!
//! Validity rules (§4.5): precision ≥ fp16 and final error ≤ 35 %.


/// One sampled point of the score time series (Fig 4/6 hourly samples).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreSample {
    /// Sample time, seconds since benchmark start.
    pub t: f64,
    /// Cumulative analytical ops at `t`.
    pub cumulative_ops: f64,
    /// FLOPS = cumulative_ops / t.
    pub flops: f64,
    /// Best achieved validation error at `t`.
    pub best_error: f64,
    /// Regulated score at `t`.
    pub regulated: f64,
}

impl ScoreSample {
    pub fn new(t: f64, cumulative_ops: f64, best_error: f64) -> Self {
        assert!(t > 0.0);
        let flops = cumulative_ops / t;
        ScoreSample {
            t,
            cumulative_ops,
            flops,
            best_error,
            regulated: regulated_score(best_error, flops),
        }
    }
}

/// Equation 3. `error` is clamped into (0,1) open interval before the log.
pub fn regulated_score(error: f64, flops: f64) -> f64 {
    let e = error.clamp(1e-9, 1.0 - 1e-9);
    -e.ln() * flops
}

/// Result-validity verdict (§4.5 fixed rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Validity {
    Valid,
    /// Final error above the 35 % requirement.
    ErrorTooHigh,
    /// Sub-fp16 precision used somewhere in training.
    PrecisionTooLow,
    /// Run shorter than the suggested minimum (warning-level).
    RunTooShort,
}

/// Apply the paper's validity rules.
pub fn validate_result(
    final_error: f64,
    min_precision_bits: u32,
    run_seconds: f64,
    min_run_seconds: f64,
) -> Validity {
    if min_precision_bits < 16 {
        Validity::PrecisionTooLow
    } else if final_error > 0.35 {
        Validity::ErrorTooHigh
    } else if run_seconds < min_run_seconds {
        Validity::RunTooShort
    } else {
        Validity::Valid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regulated_increases_with_lower_error() {
        let f = 1e15;
        assert!(regulated_score(0.25, f) > regulated_score(0.35, f));
    }

    #[test]
    fn regulated_linear_in_flops() {
        // ∂score/∂FLOPS independent of FLOPS (paper's design condition).
        let e = 0.3;
        let a = regulated_score(e, 1e15);
        let b = regulated_score(e, 2e15);
        assert!((b / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn regulated_derivative_grows_as_error_shrinks() {
        // |∂score/∂error| = FLOPS/error increases with decreasing error.
        let f = 1.0;
        let d_at = |e: f64| {
            let h = 1e-7;
            (regulated_score(e + h, f) - regulated_score(e - h, f)).abs() / (2.0 * h)
        };
        assert!(d_at(0.1) > d_at(0.3));
    }

    #[test]
    fn regulated_positive_in_domain() {
        assert!(regulated_score(0.5, 1e12) > 0.0);
        assert!(regulated_score(0.999_999, 1e12) > 0.0);
    }

    #[test]
    fn clamps_degenerate_error() {
        assert!(regulated_score(0.0, 1.0).is_finite());
        assert!(regulated_score(1.0, 1.0).is_finite());
        assert!(regulated_score(1.0, 1.0) > 0.0);
    }

    #[test]
    fn score_sample_math() {
        let s = ScoreSample::new(100.0, 5e17, 0.3);
        assert_eq!(s.flops, 5e15);
        assert!((s.regulated - regulated_score(0.3, 5e15)).abs() < 1.0);
    }

    #[test]
    fn validity_rules() {
        assert_eq!(validate_result(0.30, 16, 50_000.0, 21_600.0), Validity::Valid);
        assert_eq!(
            validate_result(0.40, 16, 50_000.0, 21_600.0),
            Validity::ErrorTooHigh
        );
        assert_eq!(
            validate_result(0.30, 8, 50_000.0, 21_600.0),
            Validity::PrecisionTooLow
        );
        assert_eq!(
            validate_result(0.30, 32, 3_600.0, 21_600.0),
            Validity::RunTooShort
        );
    }
}
