//! Terminal chart + CSV rendering for the report toolkit.
//!
//! The paper's analysis toolkit "runs automatically … and then creates a
//! report"; this module renders the Fig 4/5/6-style time series as ASCII
//! line charts for the CLI and as CSV for downstream plotting.

/// Render one or more named series sharing an x-axis as an ASCII chart.
///
/// `height` rows tall; x is compressed to the series length; values are
/// scaled to the global [min, max]. Each series draws with its own glyph.
pub fn ascii_chart(
    title: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
) -> String {
    assert!(height >= 2);
    assert!(!series.is_empty());
    for (_, ys) in series {
        assert_eq!(ys.len(), xs.len(), "series length mismatch");
    }
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    let all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.iter().copied()).collect();
    let lo = all.iter().cloned().fold(f64::MAX, f64::min);
    let hi = all.iter().cloned().fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-12);
    let width = xs.len();

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, &y) in ys.iter().enumerate() {
            let row = ((y - lo) / span * (height - 1) as f64).round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][x] = glyphs[si % glyphs.len()];
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{hi:>10.3}")
        } else if i == height - 1 {
            format!("{lo:>10.3}")
        } else {
            " ".repeat(10)
        };
        out.push_str(&label);
        out.push_str(" |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(10));
    out.push_str(" +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "{:>10}  x: {:.1} … {:.1}   ",
        "",
        xs.first().copied().unwrap_or(0.0),
        xs.last().copied().unwrap_or(0.0)
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", glyphs[si % glyphs.len()], name));
    }
    out.push('\n');
    out
}

/// Render the per-lane busy fractions (the JSON report's `lanes` array)
/// as horizontal ASCII bars — the lane-level complement of the Figs 9–12
/// node-aggregate utilization charts. Node aggregates hide the parked or
/// stranded tail a single lane spends idle; one bar per lane makes the
/// headroom the steal/migration passes recover directly visible in the
/// terminal.
pub fn lane_util_chart(title: &str, lanes: &[super::report::LaneUtil], width: usize) -> String {
    assert!(width >= 4);
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if lanes.is_empty() {
        out.push_str("  (no lanes)\n");
        return out;
    }
    let label_w = lanes.iter().map(|l| l.group.len()).max().unwrap_or(0).max(5);
    for l in lanes {
        let busy = l.busy_fraction.clamp(0.0, 1.0);
        let filled = (busy * width as f64).round() as usize;
        out.push_str(&format!(
            "  {:<label_w$} n{:<3} lane{:<2} |{}{}| {:>5.1}%\n",
            l.group,
            l.node,
            l.lane,
            "#".repeat(filled.min(width)),
            "-".repeat(width - filled.min(width)),
            busy * 100.0,
        ));
    }
    let mean = lanes.iter().map(|l| l.busy_fraction).sum::<f64>() / lanes.len() as f64;
    out.push_str(&format!(
        "  {:<label_w$} {} lanes, mean busy {:>5.1}%\n",
        "all",
        lanes.len(),
        mean * 100.0,
    ));
    out
}

/// Render aligned series as CSV with a header row.
pub fn csv(xs_name: &str, xs: &[f64], series: &[(&str, Vec<f64>)]) -> String {
    let mut out = String::new();
    out.push_str(xs_name);
    for (name, ys) in series {
        assert_eq!(ys.len(), xs.len());
        out.push(',');
        out.push_str(name);
    }
    out.push('\n');
    for (i, x) in xs.iter().enumerate() {
        out.push_str(&format!("{x}"));
        for (_, ys) in series {
            out.push_str(&format!(",{}", ys[i]));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_all_points() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x * x).collect();
        let chart = ascii_chart("t", &xs, &[("sq", ys)], 6);
        // 10 plotted points (count only grid rows — the legend adds one).
        let grid_stars: usize = chart
            .lines()
            .filter(|l| l.contains('|'))
            .map(|l| l.matches('*').count())
            .sum();
        assert_eq!(grid_stars, 10);
        assert!(chart.contains("sq"));
    }

    #[test]
    fn chart_two_series_two_glyphs() {
        let xs: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let a: Vec<f64> = xs.iter().map(|x| *x).collect();
        let b: Vec<f64> = xs.iter().map(|x| 4.0 - *x).collect();
        let chart = ascii_chart("t", &xs, &[("up", a), ("down", b)], 5);
        assert!(chart.contains('*'));
        assert!(chart.contains('o'));
    }

    #[test]
    fn chart_constant_series_no_panic() {
        let xs = [0.0, 1.0, 2.0];
        let ys = vec![5.0, 5.0, 5.0];
        let chart = ascii_chart("flat", &xs, &[("c", ys)], 3);
        assert!(chart.contains('*'));
    }

    #[test]
    fn lane_chart_one_bar_per_lane_scaled_to_busy_fraction() {
        use crate::metrics::report::LaneUtil;
        let lanes = vec![
            LaneUtil { group: "t4".into(), node: 0, lane: 0, busy_fraction: 1.0 },
            LaneUtil { group: "t4".into(), node: 0, lane: 1, busy_fraction: 0.5 },
            LaneUtil { group: "v100".into(), node: 1, lane: 0, busy_fraction: 0.0 },
        ];
        let chart = lane_util_chart("lanes", &lanes, 10);
        let rows: Vec<&str> = chart.lines().collect();
        assert_eq!(rows[0], "lanes");
        // One bar row per lane plus the mean footer.
        assert_eq!(rows.len(), 1 + lanes.len() + 1);
        assert!(rows[1].contains("##########") && rows[1].contains("100.0%"));
        assert!(rows[2].contains("#####-----") && rows[2].contains("50.0%"));
        assert!(rows[3].contains("----------") && rows[3].contains("0.0%"));
        assert!(rows[4].contains("3 lanes") && rows[4].contains("50.0%"));
        // Out-of-range fractions clamp instead of panicking.
        let odd = vec![LaneUtil { group: "g".into(), node: 0, lane: 0, busy_fraction: 1.7 }];
        assert!(lane_util_chart("t", &odd, 10).contains("##########"));
        // Empty lane lists render a placeholder.
        assert!(lane_util_chart("t", &[], 10).contains("no lanes"));
    }

    #[test]
    fn csv_format() {
        let xs = [1.0, 2.0];
        let out = csv("t", &xs, &[("a", vec![0.5, 0.6]), ("b", vec![7.0, 8.0])]);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines[0], "t,a,b");
        assert_eq!(lines[1], "1,0.5,7");
        assert_eq!(lines[2], "2,0.6,8");
    }

    #[test]
    #[should_panic]
    fn csv_rejects_mismatched_lengths() {
        csv("t", &[1.0], &[("a", vec![1.0, 2.0])]);
    }
}
