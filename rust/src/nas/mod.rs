//! Neural architecture search by network morphism (paper §4.1).
//!
//! AIPerf fixes the NAS method to network morphism (Wei et al. 2016): a
//! parent network is transformed into a child by function-preserving
//! operations — deepening (AIPerf's variant adds a whole conv+BN+ReLU
//! *block*, not a single layer), widening, kernel-size changes and skip
//! connections — and the child continues training from inherited knowledge.
//!
//! * [`graph`] — the architecture IR (stages of residual conv blocks) and
//!   its lowering to the flat layer inventory the FLOPs counter consumes;
//! * [`morphism`] — the morph operators with their legality rules;
//! * [`search`] — history-ranked parent selection driving the search, as
//!   run on slave-node CPUs in the paper's framework (§4.3).

pub mod graph;
pub mod morphism;
pub mod search;

pub use graph::{Architecture, Block, Stage};
pub use morphism::{morph, Morph};
pub use search::SearchPolicy;
