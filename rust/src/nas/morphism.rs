//! Network-morphism operators (Wei et al. 2016, as adapted by AIPerf §4.1).
//!
//! Each operator maps a parent architecture to a child that can inherit
//! the parent's knowledge (function-preserving at morph time):
//!
//! * **Deepen** — insert an identity-initialisable conv+BN+ReLU *block*
//!   (AIPerf's modification: a whole block per step, not one layer);
//! * **Widen** — grow a stage's channel width (weights padded/replicated);
//! * **Kernel** — grow/shrink a block's kernel (zero-pad the filter);
//! * **Skip** — add an identity skip across a block (subnet morph).
//!
//! Operators carry legality rules: a memory guard caps parameters (the
//! benchmark "automatically adapts … regarding AI accelerator's memory"),
//! widths stay powers-of-two-ish for MXU alignment, kernels stay in the
//! paper's [1,5] range.

use crate::util::rng::Rng;

use super::graph::{Architecture, Block};

/// A single morph step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Morph {
    /// Insert a block at `at` within stage `stage`.
    Deepen { stage: usize, at: usize, kernel: u64 },
    /// Multiply stage width by 2 (function-preserving widening).
    Widen { stage: usize },
    /// Set block kernel size.
    Kernel { stage: usize, block: usize, kernel: u64 },
    /// Make a block residual.
    Skip { stage: usize, block: usize },
}

/// Limits that keep morphed models trainable on the target accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MorphLimits {
    /// Parameter cap from accelerator memory (§4.5 memory adaption).
    pub max_params: u64,
    /// Total block cap (search-space bound).
    pub max_depth: usize,
    /// Channel cap per stage.
    pub max_width: u64,
}

impl Default for MorphLimits {
    fn default() -> Self {
        MorphLimits {
            // 32 GB V100: fits well beyond ResNet-50's 25.6 M params; the
            // cap reflects activation+optimizer-state headroom at batch 448.
            max_params: 60_000_000,
            max_depth: 48,
            max_width: 1024,
        }
    }
}

/// Error for illegal morphs.
#[derive(Debug, PartialEq, Eq)]
pub enum MorphError {
    BadStage(usize),
    BadBlock(usize),
    BadKernel(u64),
    LimitExceeded(String),
}

impl std::fmt::Display for MorphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MorphError::BadStage(stage) => write!(f, "stage index {stage} out of range"),
            MorphError::BadBlock(block) => write!(f, "block index {block} out of range"),
            MorphError::BadKernel(kernel) => write!(f, "kernel {kernel} outside [1,5]"),
            MorphError::LimitExceeded(why) => write!(f, "morph would exceed limits: {why}"),
        }
    }
}

impl std::error::Error for MorphError {}

/// Apply one morph, returning the child (parent is untouched).
pub fn morph(
    parent: &Architecture,
    m: Morph,
    limits: &MorphLimits,
) -> Result<Architecture, MorphError> {
    let mut child = parent.clone();
    match m {
        Morph::Deepen { stage, at, kernel } => {
            if !(1..=5).contains(&kernel) {
                return Err(MorphError::BadKernel(kernel));
            }
            let s = child.stages.get_mut(stage).ok_or(MorphError::BadStage(stage))?;
            if at > s.blocks.len() {
                return Err(MorphError::BadBlock(at));
            }
            // Identity-initialisable insert: residual so the new block can
            // start as a no-op (conv≈0 ⇒ output = input via the skip).
            s.blocks.insert(
                at,
                Block {
                    kernel,
                    residual: true,
                },
            );
            if child.depth() > limits.max_depth {
                return Err(MorphError::LimitExceeded(format!(
                    "depth {} > {}",
                    child.depth(),
                    limits.max_depth
                )));
            }
        }
        Morph::Widen { stage } => {
            let s = child.stages.get_mut(stage).ok_or(MorphError::BadStage(stage))?;
            let new_w = s.width * 2;
            if new_w > limits.max_width {
                return Err(MorphError::LimitExceeded(format!(
                    "width {new_w} > {}",
                    limits.max_width
                )));
            }
            s.width = new_w;
        }
        Morph::Kernel { stage, block, kernel } => {
            if !(1..=5).contains(&kernel) {
                return Err(MorphError::BadKernel(kernel));
            }
            let s = child.stages.get_mut(stage).ok_or(MorphError::BadStage(stage))?;
            let b = s.blocks.get_mut(block).ok_or(MorphError::BadBlock(block))?;
            b.kernel = kernel;
        }
        Morph::Skip { stage, block } => {
            let s = child.stages.get_mut(stage).ok_or(MorphError::BadStage(stage))?;
            let b = s.blocks.get_mut(block).ok_or(MorphError::BadBlock(block))?;
            b.residual = true;
        }
    }
    if child.params() > limits.max_params {
        return Err(MorphError::LimitExceeded(format!(
            "params {} > {}",
            child.params(),
            limits.max_params
        )));
    }
    debug_assert!(child.validate().is_ok());
    Ok(child)
}

/// Draw a random legal morph proposal (retry loop lives in the caller).
pub fn random_morph(parent: &Architecture, rng: &mut Rng) -> Morph {
    let stage = rng.gen_range_usize(0, parent.stages.len());
    let nblocks = parent.stages[stage].blocks.len();
    match rng.gen_range_usize(0, 100) {
        // Deepen dominates: the paper's morphism "adds a block" per step.
        0..=54 => Morph::Deepen {
            stage,
            at: rng.gen_range_usize(0, nblocks + 1),
            kernel: *[1u64, 3, 3, 5].get(rng.gen_range_usize(0, 4)).unwrap(),
        },
        55..=74 => Morph::Widen { stage },
        75..=89 => Morph::Kernel {
            stage,
            block: rng.gen_range_usize(0, nblocks),
            kernel: *[1u64, 2, 3, 4, 5].get(rng.gen_range_usize(0, 5)).unwrap(),
        },
        _ => Morph::Skip {
            stage,
            block: rng.gen_range_usize(0, nblocks),
        },
    }
}

/// Apply up to `tries` random proposals until one is legal; returns the
/// child and the morph used. Falls back to the parent clone if the space
/// is saturated (all proposals hit limits).
pub fn random_legal_morph(
    parent: &Architecture,
    limits: &MorphLimits,
    rng: &mut Rng,
    tries: usize,
) -> (Architecture, Option<Morph>) {
    for _ in 0..tries {
        let m = random_morph(parent, rng);
        if let Ok(child) = morph(parent, m, limits) {
            return (child, Some(m));
        }
    }
    (parent.clone(), None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::derive;

    fn arch() -> Architecture {
        Architecture::initial(32, 3, 10)
    }

    #[test]
    fn deepen_adds_block() {
        let a = arch();
        let c = morph(
            &a,
            Morph::Deepen {
                stage: 1,
                at: 1,
                kernel: 3,
            },
            &MorphLimits::default(),
        )
        .unwrap();
        assert_eq!(c.depth(), a.depth() + 1);
        assert!(c.stages[1].blocks[1].residual);
        c.validate().unwrap();
    }

    #[test]
    fn widen_doubles() {
        let a = arch();
        let c = morph(&a, Morph::Widen { stage: 0 }, &MorphLimits::default()).unwrap();
        assert_eq!(c.stages[0].width, a.stages[0].width * 2);
    }

    #[test]
    fn kernel_change_applies() {
        let a = arch();
        let c = morph(
            &a,
            Morph::Kernel {
                stage: 2,
                block: 0,
                kernel: 5,
            },
            &MorphLimits::default(),
        )
        .unwrap();
        assert_eq!(c.stages[2].blocks[0].kernel, 5);
    }

    #[test]
    fn limits_enforced() {
        let a = arch();
        let tight = MorphLimits {
            max_depth: 6,
            ..Default::default()
        };
        let err = morph(
            &a,
            Morph::Deepen {
                stage: 0,
                at: 0,
                kernel: 3,
            },
            &tight,
        )
        .unwrap_err();
        assert!(matches!(err, MorphError::LimitExceeded(_)));

        let narrow = MorphLimits {
            max_width: 16,
            ..Default::default()
        };
        assert!(morph(&a, Morph::Widen { stage: 0 }, &narrow).is_err());
    }

    #[test]
    fn bad_indices_rejected() {
        let a = arch();
        let l = MorphLimits::default();
        assert_eq!(
            morph(&a, Morph::Widen { stage: 9 }, &l).unwrap_err(),
            MorphError::BadStage(9)
        );
        assert_eq!(
            morph(
                &a,
                Morph::Kernel {
                    stage: 0,
                    block: 7,
                    kernel: 3
                },
                &l
            )
            .unwrap_err(),
            MorphError::BadBlock(7)
        );
        assert_eq!(
            morph(
                &a,
                Morph::Kernel {
                    stage: 0,
                    block: 0,
                    kernel: 6
                },
                &l
            )
            .unwrap_err(),
            MorphError::BadKernel(6)
        );
    }

    #[test]
    fn parent_untouched() {
        let a = arch();
        let sig = a.signature();
        let _ = morph(&a, Morph::Widen { stage: 0 }, &MorphLimits::default()).unwrap();
        assert_eq!(a.signature(), sig);
    }

    #[test]
    fn random_legal_morph_always_valid() {
        let mut rng = derive(42, "morph-test", 0);
        let limits = MorphLimits::default();
        let mut cur = arch();
        for _ in 0..200 {
            let (child, _) = random_legal_morph(&cur, &limits, &mut rng, 16);
            child.validate().unwrap();
            assert!(child.params() <= limits.max_params);
            cur = child;
        }
        assert!(cur.depth() <= limits.max_depth);
    }

    #[test]
    fn morph_increases_flops_on_deepen() {
        use crate::flops::{graph_ops_per_image, OpWeights};
        let a = arch();
        let w = OpWeights::default();
        let c = morph(
            &a,
            Morph::Deepen {
                stage: 0,
                at: 0,
                kernel: 3,
            },
            &MorphLimits::default(),
        )
        .unwrap();
        assert!(
            graph_ops_per_image(&c.lower(), &w).fp > graph_ops_per_image(&a.lower(), &w).fp
        );
    }
}
