//! Architecture IR for the morphism search space.
//!
//! The space mirrors the paper's: ResNet-style CNNs organised as a chain of
//! *stages*; each stage holds residual conv+BN+ReLU blocks of a uniform
//! width and may end in a 2×2 max-pool. The initial model is "pre-morphed
//! based on ResNet-50" (Table 5) — here a capacity-scaled residual network
//! with the same stage structure.
//!
//! `lower()` flattens an architecture to the `LoweredLayer` inventory used
//! by the analytical FLOPs counter; `params()` feeds the memory guard that
//! adapts the search to accelerator memory (§1, "automatic adaption …
//! regarding AI accelerator's memory").


use crate::flops::count::LoweredLayer;
use crate::flops::layers::{LayerKind, LayerShape};

/// One conv+BN+ReLU block (the paper's morphing unit), optionally residual.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    /// Conv kernel edge (K×K). The HPO search range is [2,5] (Appendix A).
    pub kernel: u64,
    /// Identity skip across the block (function-preserving when widths match).
    pub residual: bool,
}

/// A run of equal-width blocks, optionally followed by a 2×2/2 max-pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage {
    pub width: u64,
    pub blocks: Vec<Block>,
    pub pool_after: bool,
}

/// Single-pass architecture statistics (see [`Architecture::stats`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchStats {
    pub ops: crate::flops::count::GraphOps,
    pub params: u64,
    pub activation_elems: u64,
}

/// A complete candidate architecture.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Architecture {
    pub image: u64,
    pub channels: u64,
    pub num_classes: u64,
    /// Number of 2×2 stem max-pools before the first stage (the ResNet
    /// stem downsamples 224→56 before any residual block; morphing never
    /// touches this).
    pub stem_pool: u64,
    pub stages: Vec<Stage>,
}

impl Architecture {
    /// The fixed initial architecture (Table 5: "pre-morphed based on
    /// ResNet-50"): the ResNet-50 stage layout (3/4/6/3 blocks at widths
    /// 64/128/256/512 with a 4× stem downsample) for large images, and a
    /// CIFAR-scale residual net for small ones.
    pub fn initial(image: u64, channels: u64, num_classes: u64) -> Self {
        let block = |k| Block {
            kernel: k,
            residual: true,
        };
        if image >= 64 {
            Architecture {
                image,
                channels,
                num_classes,
                stem_pool: 2,
                stages: vec![
                    Stage {
                        width: 64,
                        blocks: vec![block(3); 3],
                        pool_after: true,
                    },
                    Stage {
                        width: 128,
                        blocks: vec![block(3); 4],
                        pool_after: true,
                    },
                    Stage {
                        width: 256,
                        blocks: vec![block(3); 6],
                        pool_after: true,
                    },
                    Stage {
                        width: 512,
                        blocks: vec![block(3); 3],
                        pool_after: false,
                    },
                ],
            }
        } else {
            Architecture {
                image,
                channels,
                num_classes,
                stem_pool: 0,
                stages: vec![
                    Stage {
                        width: 16,
                        blocks: vec![block(3); 2],
                        pool_after: true,
                    },
                    Stage {
                        width: 32,
                        blocks: vec![block(3); 2],
                        pool_after: true,
                    },
                    Stage {
                        width: 64,
                        blocks: vec![block(3); 2],
                        pool_after: false,
                    },
                ],
            }
        }
    }

    /// ImageNet-shaped initial model (224×224×3, 1000 classes).
    pub fn initial_imagenet() -> Self {
        Self::initial(224, 3, 1000)
    }

    pub fn depth(&self) -> usize {
        self.stages.iter().map(|s| s.blocks.len()).sum()
    }

    /// Lower to the flat layer inventory (shapes fully resolved).
    ///
    /// Per stage: a transition conv (prev_width → width, first block's
    /// kernel) then the remaining blocks at uniform width; residual adds
    /// only where in/out widths match (i.e. not on the transition block).
    pub fn lower(&self) -> Vec<LoweredLayer> {
        let mut layers = Vec::new();
        let mut h = self.image;
        let mut cin = self.channels;
        for _ in 0..self.stem_pool {
            if h < 2 {
                break;
            }
            layers.push(LoweredLayer::new(
                LayerKind::MaxPool,
                LayerShape {
                    hi: h,
                    wi: h,
                    ci: cin,
                    ho: h / 2,
                    wo: h / 2,
                    co: cin,
                    k: 2,
                },
            ));
            h /= 2;
        }
        for stage in &self.stages {
            for (i, block) in stage.blocks.iter().enumerate() {
                let ci = if i == 0 { cin } else { stage.width };
                let co = stage.width;
                layers.push(LoweredLayer::new(
                    LayerKind::Conv,
                    LayerShape {
                        hi: h,
                        wi: h,
                        ci,
                        ho: h,
                        wo: h,
                        co,
                        k: block.kernel,
                    },
                ));
                layers.push(LoweredLayer::new(
                    LayerKind::BatchNorm,
                    LayerShape {
                        hi: h,
                        wi: h,
                        ci: co,
                        ..Default::default()
                    },
                ));
                if block.residual && ci == co {
                    layers.push(LoweredLayer::new(
                        LayerKind::Add,
                        LayerShape {
                            ho: h,
                            wo: h,
                            co,
                            ..Default::default()
                        },
                    ));
                }
                layers.push(LoweredLayer::new(
                    LayerKind::Relu,
                    LayerShape {
                        ho: h,
                        wo: h,
                        co,
                        ..Default::default()
                    },
                ));
            }
            cin = stage.width;
            if stage.pool_after && h >= 2 {
                layers.push(LoweredLayer::new(
                    LayerKind::MaxPool,
                    LayerShape {
                        hi: h,
                        wi: h,
                        ci: cin,
                        ho: h / 2,
                        wo: h / 2,
                        co: cin,
                        k: 2,
                    },
                ));
                h /= 2;
            }
        }
        layers.push(LoweredLayer::new(
            LayerKind::GlobalPool,
            LayerShape {
                hi: h,
                wi: h,
                ci: cin,
                ..Default::default()
            },
        ));
        layers.push(LoweredLayer::new(
            LayerKind::Dense,
            LayerShape {
                ci: cin,
                co: self.num_classes,
                ..Default::default()
            },
        ));
        layers.push(LoweredLayer::new(
            LayerKind::Softmax,
            LayerShape {
                co: self.num_classes,
                ..Default::default()
            },
        ));
        layers
    }

    /// Trainable parameter count (memory-guard input).
    pub fn params(&self) -> u64 {
        self.lower()
            .iter()
            .map(|l| crate::flops::layers::param_count(l.kind, &l.shape))
            .sum()
    }

    /// Total activation elements per image across conv outputs (GPU-memory
    /// model input: activations are the batch-scaled term).
    pub fn activation_elems(&self) -> u64 {
        self.lower()
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::MaxPool))
            .map(|l| l.shape.ho * l.shape.wo * l.shape.co)
            .sum()
    }

    /// Everything the coordinator needs about an architecture, computed
    /// from a single lowering pass (perf: `lower()` allocates the layer
    /// inventory; the master previously called it three times per trial —
    /// ops, params, activations. EXPERIMENTS.md §Perf/L3).
    pub fn stats(&self, weights: &crate::flops::layers::OpWeights) -> ArchStats {
        let layers = self.lower();
        let ops = crate::flops::count::graph_ops_per_image(&layers, weights);
        let activation_elems = layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::MaxPool))
            .map(|l| l.shape.ho * l.shape.wo * l.shape.co)
            .sum();
        ArchStats {
            ops,
            params: ops.params,
            activation_elems,
        }
    }

    /// Structural well-formedness — the invariant proptest exercises after
    /// arbitrary morph sequences.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("architecture has no stages".into());
        }
        let pools =
            self.stages.iter().filter(|s| s.pool_after).count() as u32 + self.stem_pool as u32;
        if self.image >> pools == 0 {
            return Err(format!(
                "too many pools ({pools}) for image size {}",
                self.image
            ));
        }
        for (i, s) in self.stages.iter().enumerate() {
            if s.blocks.is_empty() {
                return Err(format!("stage {i} has no blocks"));
            }
            if s.width == 0 {
                return Err(format!("stage {i} has zero width"));
            }
            for (j, b) in s.blocks.iter().enumerate() {
                if !(1..=7).contains(&b.kernel) {
                    return Err(format!("stage {i} block {j}: kernel {}", b.kernel));
                }
            }
        }
        Ok(())
    }

    /// Stable short description, e.g. `16x2p-32x2p-64x2` — used as the
    /// model id in history/log records.
    pub fn signature(&self) -> String {
        self.stages
            .iter()
            .map(|s| {
                format!(
                    "{}x{}{}",
                    s.width,
                    s.blocks.len(),
                    if s.pool_after { "p" } else { "" }
                )
            })
            .collect::<Vec<_>>()
            .join("-")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flops::{graph_ops_per_image, OpWeights};

    #[test]
    fn initial_is_valid() {
        let a = Architecture::initial_imagenet();
        a.validate().unwrap();
        // ResNet-50 stage layout: 3/4/6/3 blocks at widths 64/128/256/512.
        assert_eq!(a.depth(), 16);
        assert_eq!(a.signature(), "64x3p-128x4p-256x6p-512x3");
        assert_eq!(a.stem_pool, 2);
        // Capacity in the ResNet-50 ballpark (paper: ~25.6 M; plain 3×3
        // blocks land lower but same order of magnitude).
        let p = a.params();
        assert!((5_000_000..40_000_000).contains(&p), "params={p}");

        let small = Architecture::initial(32, 3, 10);
        small.validate().unwrap();
        assert_eq!(small.signature(), "16x2p-32x2p-64x2");
        assert_eq!(small.stem_pool, 0);
    }

    #[test]
    fn initial_imagenet_ops_near_resnet50() {
        // Trial-cadence calibration: the initial model's per-image training
        // ops must be within ~3× of ResNet-50's 2.31e10 so the simulated
        // run reproduces the paper's ~96 architectures at 16 nodes / 12 h.
        let w = OpWeights::default();
        let a = Architecture::initial_imagenet();
        let g = graph_ops_per_image(&a.lower(), &w);
        let total = (g.fp + g.bp) as f64;
        assert!(
            (0.8e10..7.0e10).contains(&total),
            "train ops/image = {total:.3e}"
        );
    }

    #[test]
    fn activation_elems_positive_and_scale() {
        let a = Architecture::initial_imagenet();
        let small = Architecture::initial(32, 3, 10);
        assert!(a.activation_elems() > small.activation_elems());
        assert!(a.activation_elems() > 100_000);
    }

    #[test]
    fn lowering_shape_chain_consistent() {
        let a = Architecture::initial(32, 3, 10);
        let layers = a.lower();
        // Every conv's ci must equal the previous producing layer's co.
        let mut cur_c = a.channels;
        let mut cur_h = a.image;
        for l in &layers {
            match l.kind {
                LayerKind::Conv => {
                    assert_eq!(l.shape.ci, cur_c, "conv ci mismatch");
                    assert_eq!(l.shape.hi, cur_h);
                    cur_c = l.shape.co;
                }
                LayerKind::MaxPool => {
                    assert_eq!(l.shape.ci, cur_c);
                    cur_h = l.shape.ho;
                }
                LayerKind::Dense => assert_eq!(l.shape.ci, cur_c),
                _ => {}
            }
        }
    }

    #[test]
    fn residual_add_only_on_width_match() {
        let a = Architecture::initial(32, 3, 10);
        let layers = a.lower();
        let adds = layers.iter().filter(|l| l.kind == LayerKind::Add).count();
        // 2 blocks per stage, transition block has ci≠co → 1 add per stage.
        assert_eq!(adds, 3);
    }

    #[test]
    fn params_grow_with_width() {
        let mut a = Architecture::initial(32, 3, 10);
        let p0 = a.params();
        a.stages[0].width *= 2;
        assert!(a.params() > p0);
    }

    #[test]
    fn flops_grow_with_depth() {
        let w = OpWeights::default();
        let mut a = Architecture::initial(32, 3, 10);
        let f0 = graph_ops_per_image(&a.lower(), &w).fp;
        a.stages[1].blocks.push(Block {
            kernel: 3,
            residual: true,
        });
        assert!(graph_ops_per_image(&a.lower(), &w).fp > f0);
    }

    #[test]
    fn validate_rejects_broken() {
        let mut a = Architecture::initial(8, 3, 10);
        a.stages[0].pool_after = true;
        a.stages[1].pool_after = true;
        a.stages[2].pool_after = true;
        a.stages.push(Stage {
            width: 8,
            blocks: vec![Block {
                kernel: 3,
                residual: false,
            }],
            pool_after: true,
        });
        // 8 >> 4 pools = 0 → invalid.
        assert!(a.validate().is_err());

        let mut b = Architecture::initial(32, 3, 10);
        b.stages[0].blocks.clear();
        assert!(b.validate().is_err());

        let mut c = Architecture::initial(32, 3, 10);
        c.stages[0].blocks[0].kernel = 9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn signature_distinguishes() {
        let a = Architecture::initial(32, 3, 10);
        let mut b = a.clone();
        b.stages[2].blocks.push(Block {
            kernel: 3,
            residual: true,
        });
        assert_ne!(a.signature(), b.signature());
    }
}
