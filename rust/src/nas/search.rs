//! NAS search driver: history-ranked parent selection (paper §4.3).
//!
//! "The CPUs on slave nodes search for new neural architectures based on
//! the rank of models in the historical model list." The policy here is
//! rank-softmax parent selection: candidates are ranked by (predicted or
//! measured) accuracy and the parent is drawn with probability
//! exponentially tilted toward the best — exploration comes from the
//! random morph on top of the chosen parent.
//!
//! Two selection entry points coexist:
//!
//! * [`SearchPolicy::select_parent_on`] — the historic form over one
//!   contiguous slice, re-sorting per call. Still the reference
//!   semantics, and what small callers (the live runner, tests) use.
//! * [`SearchPolicy::select_parent_merged`] — the hot-path form over a
//!   frozen pre-sorted base (the barrier snapshot) plus a small unsorted
//!   tail of local completions. For histories up to
//!   [`EXACT_SOFTMAX_MAX`] entries, or whenever penalties are present,
//!   it performs the *identical* float operations in the identical
//!   order, so its draws are bit-equal to the historic form. Past that
//!   size with no penalties it switches to a closed-form inversion of
//!   the geometric rank CDF — same distribution, O(log n) instead of
//!   O(n log n) per proposal, which is what makes 100k-lane simulations
//!   tractable.

use std::sync::Arc;

use crate::hpo::{Config, Optimizer};
use crate::util::rng::Rng;

use super::graph::Architecture;
use super::morphism::{random_legal_morph, Morph, MorphLimits};

/// Largest history for which the merged selection replays the historic
/// per-call sort + subtract-scan bit for bit. Every pinned preset tops
/// out well below this (ascend-4096 records ~4k models), so their RNG
/// streams — and every determinism gate over them — are unchanged; only
/// aspirational exascale runs cross into the closed-form path.
pub const EXACT_SOFTMAX_MAX: usize = 8192;

/// Scored history entry the policy selects from.
#[derive(Debug, Clone)]
pub struct RankedModel {
    /// Shared with the history's `ModelRecord`: snapshots and proposals
    /// never deep-clone an architecture.
    pub arch: Arc<Architecture>,
    /// Accuracy in [0,1] (measured, or predicted during warm-up).
    pub accuracy: f64,
    /// OOM-penalty entry: the architecture fit no batch size on its
    /// group's accelerator. Penalty entries teach the search where the
    /// memory boundary lies by ranking (at accuracy zero) without ever
    /// being selected as morph parents while real entries exist — so a
    /// skipped candidate's neighborhood stops being re-proposed.
    pub penalty: bool,
    /// Topology node group of the node that recorded this entry. The
    /// memory boundary is per-accelerator, so a penalty only disqualifies
    /// parenthood for proposals that would run on this same group (when
    /// [`SearchPolicy::group_scoped_penalties`] is on).
    pub group: usize,
}

/// Stable accuracy-ascending order of `models` — the same comparator
/// (and therefore the same permutation) as the historic per-call sort in
/// [`SearchPolicy::select_parent_on`]. Ties keep input order.
pub fn sorted_order(models: &[RankedModel]) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..models.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        models[a as usize]
            .accuracy
            .partial_cmp(&models[b as usize].accuracy)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Two-pointer walk over a pre-sorted base order and a sorted extras
/// order, yielding `(is_extra, index)` in exactly the order a stable
/// sort of `base ++ extras` by accuracy visits them: ties resolve
/// base-first (lower position in the concatenation), then insertion
/// order within each side. This is the invariant that lets the frozen
/// snapshot path reproduce the historic per-call sort bit for bit.
struct MergeWalk<'a> {
    base: &'a [RankedModel],
    base_sorted: &'a [u32],
    extras: &'a [RankedModel],
    extras_sorted: &'a [u32],
    bi: usize,
    ei: usize,
}

impl<'a> MergeWalk<'a> {
    fn new(
        base: &'a [RankedModel],
        base_sorted: &'a [u32],
        extras: &'a [RankedModel],
        extras_sorted: &'a [u32],
    ) -> Self {
        MergeWalk {
            base,
            base_sorted,
            extras,
            extras_sorted,
            bi: 0,
            ei: 0,
        }
    }
}

impl Iterator for MergeWalk<'_> {
    type Item = (bool, u32);

    fn next(&mut self) -> Option<(bool, u32)> {
        let take_base = match (
            self.bi < self.base_sorted.len(),
            self.ei < self.extras_sorted.len(),
        ) {
            (true, true) => {
                let ba = self.base[self.base_sorted[self.bi] as usize].accuracy;
                let ea = self.extras[self.extras_sorted[self.ei] as usize].accuracy;
                // Tie → base: the base entry sits earlier in the
                // concatenation, so a stable sort keeps it first.
                !(ea < ba)
            }
            (true, false) => true,
            (false, true) => false,
            (false, false) => return None,
        };
        if take_base {
            let i = self.base_sorted[self.bi];
            self.bi += 1;
            Some((false, i))
        } else {
            let i = self.extras_sorted[self.ei];
            self.ei += 1;
            Some((true, i))
        }
    }
}

/// Rank-tilted parent selection + random morphism.
#[derive(Debug, Clone)]
pub struct SearchPolicy {
    pub limits: MorphLimits,
    /// Rank temperature: 0 → uniform, large → greedy-best.
    pub rank_beta: f64,
    /// Proposal retries before giving up on morphing a parent.
    pub morph_tries: usize,
    /// Scope OOM penalties to the node group where the candidate failed
    /// to fit (`BenchmarkConfig::feedback_routing`): a penalty recorded on
    /// a 16 GB T4 group stops disqualifying parenthood for proposals on a
    /// 32 GB V100 group. Off reproduces the global (pre-feedback) filter
    /// exactly, draw for draw.
    pub group_scoped_penalties: bool,
}

impl Default for SearchPolicy {
    fn default() -> Self {
        SearchPolicy {
            limits: MorphLimits::default(),
            rank_beta: 1.0,
            morph_tries: 16,
            group_scoped_penalties: false,
        }
    }
}

impl SearchPolicy {
    /// Select a parent index by rank-softmax over accuracies, without a
    /// proposing-group context (penalties filter globally).
    pub fn select_parent(&self, history: &[RankedModel], rng: &mut Rng) -> usize {
        self.select_parent_on(history, None, rng)
    }

    /// The per-entry eligibility filter of [`Self::select_parent_on`].
    fn eligible(&self, m: &RankedModel, on_group: Option<usize>) -> bool {
        !m.penalty || (self.group_scoped_penalties && on_group.is_some_and(|g| m.group != g))
    }

    /// Select a parent index by rank-softmax over accuracies, for a
    /// proposal that would run on topology group `on_group`.
    /// `history` may be unsorted; an empty history is a caller bug.
    /// Penalty entries (OOM-skipped candidates) are excluded from
    /// selection whenever at least one real entry exists — they inform
    /// the ranking's shape but must not seed new morphs past the memory
    /// boundary. With [`SearchPolicy::group_scoped_penalties`] on and a
    /// proposing group given, only penalties recorded on *that* group
    /// disqualify: the memory boundary is per-accelerator, so a candidate
    /// too big for one group's card stays a legal (bottom-ranked) parent
    /// on groups with more memory. With no penalties present the
    /// selection is identical to the historic rank-softmax, draw for
    /// draw.
    pub fn select_parent_on(
        &self,
        history: &[RankedModel],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> usize {
        assert!(!history.is_empty(), "select_parent on empty history");
        // Rank ascending by accuracy: best gets the largest weight.
        let mut idx: Vec<usize> = (0..history.len())
            .filter(|&i| self.eligible(&history[i], on_group))
            .collect();
        if idx.is_empty() {
            // Nothing but penalties: fall back to the full history (the
            // caller still needs some parent to morph).
            idx = (0..history.len()).collect();
        }
        idx.sort_by(|&a, &b| {
            history[a]
                .accuracy
                .partial_cmp(&history[b].accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let n = idx.len();
        let weights: Vec<f64> = (0..n)
            .map(|rank| (self.rank_beta * rank as f64 / n.max(1) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = rng.gen_range_f64(0.0, total);
        for (rank, &i) in idx.iter().enumerate() {
            u -= weights[rank];
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }

    /// Rank-softmax selection over a frozen pre-sorted `base` (the
    /// barrier snapshot, with `base_sorted` its stable accuracy order and
    /// `base_penalties` its penalty-entry count) merged with a small
    /// unsorted `extras` tail (a lane's local completions since the
    /// barrier). Returns `(is_extra, index)` into the respective slice.
    ///
    /// Semantically this selects from the concatenation
    /// `base ++ extras` exactly as [`Self::select_parent_on`] would —
    /// and for histories within [`EXACT_SOFTMAX_MAX`] entries (or with
    /// any penalties present) the draws are bit-equal, because the
    /// merged walk visits eligible entries in precisely the order the
    /// historic stable sort produces and the weight/total/scan float
    /// operations are identical. Beyond that size with no penalties, the
    /// geometric weight series is inverted in closed form: same
    /// distribution, one RNG draw either way, O(log n) per call.
    #[allow(clippy::too_many_arguments)]
    pub fn select_parent_merged(
        &self,
        base: &[RankedModel],
        base_sorted: &[u32],
        base_penalties: u64,
        extras: &[RankedModel],
        extras_sorted: &[u32],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> (bool, usize) {
        let total_len = base.len() + extras.len();
        assert!(total_len > 0, "select_parent on empty history");
        debug_assert_eq!(base_sorted.len(), base.len(), "stale snapshot sort order");
        debug_assert_eq!(extras_sorted.len(), extras.len(), "stale extras sort order");
        let no_penalties = base_penalties == 0 && extras.iter().all(|m| !m.penalty);
        if no_penalties && total_len > EXACT_SOFTMAX_MAX {
            let rank = self.closed_form_rank(total_len, rng);
            return merged_rank_to_item(base, base_sorted, extras, extras_sorted, rank);
        }

        // Historic path: the same filter, rank order, weights, and
        // subtract-scan as `select_parent_on` over the concatenation.
        let mut n = base.iter().filter(|m| self.eligible(m, on_group)).count()
            + extras.iter().filter(|m| self.eligible(m, on_group)).count();
        let all = n == 0;
        if all {
            n = total_len;
        }
        let weight = |rank: usize| (self.rank_beta * rank as f64 / n.max(1) as f64).exp();
        // Identical accumulation order to `weights.iter().sum()`.
        let mut total = 0.0f64;
        for rank in 0..n {
            total += weight(rank);
        }
        let mut u = rng.gen_range_f64(0.0, total);
        let mut rank = 0usize;
        let mut last = None;
        for (is_extra, i) in MergeWalk::new(base, base_sorted, extras, extras_sorted) {
            let m = if is_extra {
                &extras[i as usize]
            } else {
                &base[i as usize]
            };
            if !all && !self.eligible(m, on_group) {
                continue;
            }
            u -= weight(rank);
            if u <= 0.0 {
                return (is_extra, i as usize);
            }
            last = Some((is_extra, i as usize));
            rank += 1;
        }
        last.expect("eligible set cannot be empty here")
    }

    /// Closed-form draw of a rank in `0..n` (ascending accuracy, so rank
    /// 0 carries the smallest weight) from the geometric weight series
    /// `w(r) = e^{β·r/n}`: with `x = β/n`, the prefix sums are
    /// `S(k) = expm1(x·k) / expm1(x)` and the subtract-scan's stopping
    /// rule — the smallest `r` with `S(r+1) ≥ u` — inverts analytically.
    /// A short fix-up walk absorbs any FP residue of the inversion, so
    /// the result matches a literal scan of `S` exactly.
    fn closed_form_rank(&self, n: usize, rng: &mut Rng) -> usize {
        debug_assert!(n > 0);
        let x = self.rank_beta / n as f64;
        if x == 0.0 || !x.is_finite() {
            // β = 0 (or degenerate): every weight is 1, total is n.
            let u = rng.gen_range_f64(0.0, n as f64);
            return ((u.ceil() as i64) - 1).clamp(0, n as i64 - 1) as usize;
        }
        let denom = f64::exp_m1(x);
        let total = f64::exp_m1(self.rank_beta) / denom;
        let u = rng.gen_range_f64(0.0, total);
        let s = |k: usize| f64::exp_m1(x * k as f64) / denom;
        let mut r = ((f64::ln_1p(u * denom) / x).ceil() as i64 - 1).clamp(0, n as i64 - 1) as usize;
        while r > 0 && s(r) >= u {
            r -= 1;
        }
        while r + 1 < n && s(r + 1) < u {
            r += 1;
        }
        r
    }

    /// Draw the next hyperparameter configuration for a trial from the
    /// lane's optimizer — any [`crate::hpo::Backend`] behind the
    /// [`Optimizer`] trait object. `None` during warm-up rounds
    /// (`active` false): defaults apply and neither the optimizer nor
    /// the RNG stream is touched. This is the single path between the
    /// engine and an HPO backend, so every backend sees the identical
    /// call order regardless of which one the `hpo` knob selected —
    /// what keeps Sequential/Parallel bit-identical per backend.
    pub fn suggest_hp(
        &self,
        opt: &mut dyn Optimizer,
        active: bool,
        rng: &mut Rng,
    ) -> Option<Config> {
        if !active {
            return None;
        }
        Some(opt.suggest(rng))
    }

    /// Generate one child architecture from the history (the unit of work a
    /// slave-node CPU performs before pushing into the buffer).
    pub fn propose(
        &self,
        history: &[RankedModel],
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        self.propose_on(history, None, rng)
    }

    /// [`SearchPolicy::propose`] for a proposal that would run on
    /// topology group `on_group` — the group scopes the penalty filter of
    /// [`SearchPolicy::select_parent_on`].
    pub fn propose_on(
        &self,
        history: &[RankedModel],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        let parent = &history[self.select_parent_on(history, on_group, rng)].arch;
        random_legal_morph(parent, &self.limits, rng, self.morph_tries)
    }

    /// [`SearchPolicy::propose_on`] over a frozen snapshot plus local
    /// extras — see [`SearchPolicy::select_parent_merged`]. Sorting the
    /// handful of extras consumes no RNG, so the draw stream (one
    /// selection draw, then the morph draws) is identical to the
    /// historic concatenate-and-propose form.
    pub fn propose_merged(
        &self,
        base: &[RankedModel],
        base_sorted: &[u32],
        base_penalties: u64,
        extras: &[RankedModel],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        let extras_sorted = sorted_order(extras);
        let (is_extra, i) = self.select_parent_merged(
            base,
            base_sorted,
            base_penalties,
            extras,
            &extras_sorted,
            on_group,
            rng,
        );
        let parent: &Architecture = if is_extra {
            &extras[i].arch
        } else {
            &base[i].arch
        };
        random_legal_morph(parent, &self.limits, rng, self.morph_tries)
    }
}

/// Locate the element at merged-sorted position `rank` in the stable
/// accuracy order of `base ++ extras`. Each sorted extra lands at the
/// count of base entries ordered before it (ties base-first) plus the
/// extras already inserted; base entries fill the remaining positions in
/// `base_sorted` order.
fn merged_rank_to_item(
    base: &[RankedModel],
    base_sorted: &[u32],
    extras: &[RankedModel],
    extras_sorted: &[u32],
    rank: usize,
) -> (bool, usize) {
    let mut before = 0usize; // extras at merged positions < rank
    for (j, &e) in extras_sorted.iter().enumerate() {
        let acc = extras[e as usize].accuracy;
        let ub = base_sorted.partition_point(|&b| base[b as usize].accuracy <= acc);
        let pos = ub + j;
        if pos == rank {
            return (true, e as usize);
        }
        if pos < rank {
            before += 1;
        } else {
            break;
        }
    }
    (false, base_sorted[rank - before] as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::derive;

    fn history() -> Vec<RankedModel> {
        let base = Arc::new(Architecture::initial(32, 3, 10));
        (0..8)
            .map(|i| RankedModel {
                arch: Arc::clone(&base),
                accuracy: 0.1 * i as f64,
                penalty: false,
                group: 0,
            })
            .collect()
    }

    /// `n` penalty-free entries with distinct ascending accuracies, all
    /// sharing one architecture (selection only reads accuracy/penalty).
    fn big_history(n: usize) -> Vec<RankedModel> {
        let arch = Arc::new(Architecture::initial(32, 3, 10));
        (0..n)
            .map(|i| RankedModel {
                arch: Arc::clone(&arch),
                accuracy: i as f64 / n as f64,
                penalty: false,
                group: 0,
            })
            .collect()
    }

    #[test]
    fn suggest_hp_is_a_transparent_shim_over_the_optimizer() {
        // The policy hop must not perturb the stream: active suggestions
        // equal a direct `suggest` on the same optimizer state draw for
        // draw, and warm-up rounds consume nothing — the regression
        // guarantee that routing TPE through the trait object keeps the
        // engine's historic RNG stream.
        use crate::hpo::{aiperf_space, build, Backend};
        let policy = SearchPolicy::default();
        let mut through = build(Backend::Tpe, aiperf_space(), 0);
        let mut direct = build(Backend::Tpe, aiperf_space(), 0);
        let mut r1 = derive(5, "suggest-hp", 0);
        let mut r2 = derive(5, "suggest-hp", 0);
        assert!(policy.suggest_hp(through.as_mut(), false, &mut r1).is_none());
        for i in 0..12 {
            let a = policy
                .suggest_hp(through.as_mut(), true, &mut r1)
                .expect("active round must suggest");
            let b = direct.suggest(&mut r2);
            assert_eq!(a, b, "draw {i} diverged");
            through.observe(a, 0.4);
            direct.observe(b, 0.4);
        }
        assert_eq!(r1.gen_f64().to_bits(), r2.gen_f64().to_bits());
    }

    #[test]
    fn parent_selection_prefers_accurate() {
        let policy = SearchPolicy {
            rank_beta: 4.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(1, "search", 0);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..4000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        // Best model (idx 7, acc 0.7) must be chosen far more often than
        // the worst (idx 0, acc 0.0).
        assert!(counts[7] > counts[0] * 3, "{counts:?}");
    }

    #[test]
    fn uniform_at_zero_beta() {
        let policy = SearchPolicy {
            rank_beta: 0.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(2, "search", 1);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..8000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        let expect = 8000.0 / 8.0;
        for c in &counts {
            assert!((*c as f64 - expect).abs() < expect * 0.25, "{counts:?}");
        }
    }

    #[test]
    fn propose_yields_valid_children() {
        let policy = SearchPolicy::default();
        let h = history();
        let mut rng = derive(3, "search", 2);
        for _ in 0..100 {
            let (child, _) = policy.propose(&h, &mut rng);
            child.validate().unwrap();
        }
    }

    #[test]
    fn propose_is_deterministic_per_seed() {
        let policy = SearchPolicy::default();
        let h = history();
        let a = policy.propose(&h, &mut derive(9, "s", 0));
        let b = policy.propose(&h, &mut derive(9, "s", 0));
        assert_eq!(a.0.signature(), b.0.signature());
        assert_eq!(a.1, b.1);
    }

    #[test]
    #[should_panic]
    fn empty_history_panics() {
        let policy = SearchPolicy::default();
        policy.select_parent(&[], &mut derive(0, "s", 0));
    }

    #[test]
    fn penalty_entries_are_never_parents_while_real_ones_exist() {
        let policy = SearchPolicy::default();
        let mut h = history();
        // Mark every entry but index 3 as an OOM penalty: selection must
        // collapse onto the single real record, draw after draw.
        for (i, m) in h.iter_mut().enumerate() {
            if i != 3 {
                m.penalty = true;
                m.accuracy = 0.0;
            }
        }
        let mut rng = derive(4, "search", 3);
        for _ in 0..200 {
            assert_eq!(policy.select_parent(&h, &mut rng), 3);
        }
        // All-penalty history still yields a parent (fallback).
        for m in h.iter_mut() {
            m.penalty = true;
        }
        let pick = policy.select_parent(&h, &mut rng);
        assert!(pick < h.len());
        let (child, _) = policy.propose(&h, &mut rng);
        child.validate().unwrap();
    }

    #[test]
    fn group_scoped_penalty_is_a_parent_on_other_groups_only() {
        // The per-group memory boundary: an entry OOM-penalized on group
        // 0 (say a 16 GB T4) must stay a legal morph parent for group-1
        // proposals (a 32 GB V100) — and vice versa stays excluded.
        let policy = SearchPolicy {
            rank_beta: 0.0, // uniform over the eligible set
            group_scoped_penalties: true,
            ..Default::default()
        };
        let mut h = history();
        h[0].penalty = true;
        h[0].accuracy = 0.0;
        h[0].group = 0;
        let mut rng = derive(11, "search", 5);
        let mut on_own = vec![0usize; h.len()];
        let mut on_other = vec![0usize; h.len()];
        for _ in 0..2000 {
            on_own[policy.select_parent_on(&h, Some(0), &mut rng)] += 1;
            on_other[policy.select_parent_on(&h, Some(1), &mut rng)] += 1;
        }
        assert_eq!(on_own[0], 0, "penalty picked on its own group: {on_own:?}");
        assert!(
            on_other[0] > 0,
            "penalty never picked on the other group: {on_other:?}"
        );
    }

    #[test]
    fn group_scoping_off_keeps_the_global_filter() {
        // With the knob off (feedback_routing disabled), a group context
        // changes nothing: penalties are excluded everywhere, and the
        // draws match the context-free selection stream exactly.
        let policy = SearchPolicy::default();
        assert!(!policy.group_scoped_penalties);
        let mut h = history();
        h[0].penalty = true;
        h[0].accuracy = 0.0;
        h[0].group = 0;
        let scoped: Vec<usize> = {
            let mut rng = derive(12, "search", 6);
            (0..256)
                .map(|_| policy.select_parent_on(&h, Some(1), &mut rng))
                .collect()
        };
        let global: Vec<usize> = {
            let mut rng = derive(12, "search", 6);
            (0..256).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        assert_eq!(scoped, global);
        assert!(scoped.iter().all(|&i| i != 0), "penalty must stay excluded");
    }

    #[test]
    fn penalty_free_selection_matches_historic_stream() {
        // The penalty filter must be a no-op when no penalties exist:
        // same picks for the same RNG stream as an unfiltered softmax.
        let policy = SearchPolicy::default();
        let h = history();
        let picks: Vec<usize> = {
            let mut rng = derive(7, "search", 9);
            (0..64).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        let again: Vec<usize> = {
            let mut rng = derive(7, "search", 9);
            (0..64).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        assert_eq!(picks, again);
    }

    /// Map a merged pick back to its index in the concatenation
    /// `base ++ extras`, for comparison against the historic form.
    fn concat_index(pick: (bool, usize), base_len: usize) -> usize {
        if pick.0 {
            base_len + pick.1
        } else {
            pick.1
        }
    }

    #[test]
    fn merged_selection_is_bit_equal_to_concat_on_the_exact_path() {
        // The frozen-snapshot form must replay the historic sort +
        // subtract-scan draw for draw: interleaved accuracies, ties
        // across the base/extras boundary, penalties on and off, group
        // scoping on and off.
        for (scoped, on_group) in [(false, None), (false, Some(1)), (true, Some(1))] {
            let policy = SearchPolicy {
                group_scoped_penalties: scoped,
                ..Default::default()
            };
            let arch = Arc::new(Architecture::initial(32, 3, 10));
            let rm = |accuracy: f64, penalty: bool, group: usize| RankedModel {
                arch: Arc::clone(&arch),
                accuracy,
                penalty,
                group,
            };
            let base = vec![
                rm(0.5, false, 0),
                rm(0.2, false, 1),
                rm(0.2, true, 0), // ties with base[1] and extras[0]
                rm(0.9, false, 0),
                rm(0.4, false, 1),
            ];
            let extras = vec![rm(0.2, false, 0), rm(0.9, true, 1), rm(0.05, false, 0)];
            let concat: Vec<RankedModel> = base.iter().chain(&extras).cloned().collect();
            let base_sorted = sorted_order(&base);
            let extras_sorted = sorted_order(&extras);
            let penalties = base.iter().filter(|m| m.penalty).count() as u64;

            let merged: Vec<usize> = {
                let mut rng = derive(21, "merged", 0);
                (0..400)
                    .map(|_| {
                        concat_index(
                            policy.select_parent_merged(
                                &base,
                                &base_sorted,
                                penalties,
                                &extras,
                                &extras_sorted,
                                on_group,
                                &mut rng,
                            ),
                            base.len(),
                        )
                    })
                    .collect()
            };
            let historic: Vec<usize> = {
                let mut rng = derive(21, "merged", 0);
                (0..400)
                    .map(|_| policy.select_parent_on(&concat, on_group, &mut rng))
                    .collect()
            };
            assert_eq!(merged, historic, "scoped={scoped} on_group={on_group:?}");
        }
    }

    #[test]
    fn merged_with_empty_base_matches_plain_selection() {
        // A lane's very first window: no snapshot yet, only local
        // completions. The merged form must equal selection over the
        // extras alone.
        let policy = SearchPolicy::default();
        let extras = history();
        let extras_sorted = sorted_order(&extras);
        let merged: Vec<usize> = {
            let mut rng = derive(22, "merged", 1);
            (0..128)
                .map(|_| {
                    let (is_extra, i) = policy.select_parent_merged(
                        &[],
                        &[],
                        0,
                        &extras,
                        &extras_sorted,
                        None,
                        &mut rng,
                    );
                    assert!(is_extra);
                    i
                })
                .collect()
        };
        let plain: Vec<usize> = {
            let mut rng = derive(22, "merged", 1);
            (0..128)
                .map(|_| policy.select_parent(&extras, &mut rng))
                .collect()
        };
        assert_eq!(merged, plain);
    }

    #[test]
    fn propose_merged_matches_concat_propose_stream() {
        // End to end through the morph: same children, same morph ops as
        // concatenating and calling the historic propose.
        let policy = SearchPolicy::default();
        let base = history();
        let extras: Vec<RankedModel> = history()
            .into_iter()
            .map(|mut m| {
                m.accuracy += 0.05;
                m
            })
            .take(3)
            .collect();
        let concat: Vec<RankedModel> = base.iter().chain(&extras).cloned().collect();
        let base_sorted = sorted_order(&base);
        let mut rng_a = derive(23, "merged", 2);
        let mut rng_b = derive(23, "merged", 2);
        for _ in 0..64 {
            let a = policy.propose_merged(&base, &base_sorted, 0, &extras, None, &mut rng_a);
            let b = policy.propose_on(&concat, None, &mut rng_b);
            assert_eq!(a.0.signature(), b.0.signature());
            assert_eq!(a.1, b.1);
        }
    }

    #[test]
    fn exact_path_holds_at_the_threshold_boundary() {
        // n == EXACT_SOFTMAX_MAX must still take the bit-exact path.
        let policy = SearchPolicy::default();
        let h = big_history(EXACT_SOFTMAX_MAX);
        let sorted = sorted_order(&h);
        let merged = {
            let mut rng = derive(24, "merged", 3);
            concat_index(
                policy.select_parent_merged(&h, &sorted, 0, &[], &[], None, &mut rng),
                h.len(),
            )
        };
        let historic = {
            let mut rng = derive(24, "merged", 3);
            policy.select_parent_on(&h, None, &mut rng)
        };
        assert_eq!(merged, historic);
    }

    #[test]
    fn closed_form_rank_matches_a_literal_prefix_scan() {
        // Past the threshold the inversion must land on exactly the rank
        // a literal scan of the prefix sums S(k) stops at — the fix-up
        // walk absorbs all FP residue.
        let n = EXACT_SOFTMAX_MAX + 1808; // 10_000
        for (case, beta) in [(0u64, 1.0f64), (1, 4.0), (2, 0.25), (3, -1.5)] {
            let policy = SearchPolicy {
                rank_beta: beta,
                ..Default::default()
            };
            for draw in 0..300u64 {
                let mut rng = derive(case, "closed-form", draw);
                let got = policy.closed_form_rank(n, &mut rng);
                // Replay the identical draw and scan literally.
                let mut replay = derive(case, "closed-form", draw);
                let x = beta / n as f64;
                let denom = f64::exp_m1(x);
                let total = f64::exp_m1(beta) / denom;
                let u = replay.gen_range_f64(0.0, total);
                let s = |k: usize| f64::exp_m1(x * k as f64) / denom;
                let mut want = n - 1;
                for r in 0..n {
                    if s(r + 1) >= u {
                        want = r;
                        break;
                    }
                }
                assert_eq!(got, want, "beta {beta} draw {draw}");
            }
        }
    }

    #[test]
    fn closed_form_zero_beta_is_roughly_uniform() {
        let policy = SearchPolicy {
            rank_beta: 0.0,
            ..Default::default()
        };
        let n = EXACT_SOFTMAX_MAX * 2;
        let mut rng = derive(31, "closed-form", 0);
        let mut below = 0usize;
        let draws = 4000;
        for _ in 0..draws {
            let r = policy.closed_form_rank(n, &mut rng);
            assert!(r < n);
            if r < n / 2 {
                below += 1;
            }
        }
        let frac = below as f64 / draws as f64;
        assert!((frac - 0.5).abs() < 0.05, "bottom-half fraction {frac}");
    }

    #[test]
    fn closed_form_prefers_high_ranks_at_positive_beta() {
        // β = 1 tilts toward the top of the ranking, exactly like the
        // literal softmax does at small n.
        let policy = SearchPolicy::default();
        let h = big_history(EXACT_SOFTMAX_MAX * 2);
        let sorted = sorted_order(&h);
        let mut rng = derive(32, "closed-form", 1);
        let mut top = 0usize;
        let draws = 4000;
        for _ in 0..draws {
            let (is_extra, i) =
                policy.select_parent_merged(&h, &sorted, 0, &[], &[], None, &mut rng);
            assert!(!is_extra);
            // Distinct ascending accuracies: index == rank.
            if i >= h.len() / 2 {
                top += 1;
            }
        }
        let frac = top as f64 / draws as f64;
        // Top half holds e/(1+e) ≈ 73% of the geometric mass at β = 1.
        assert!(frac > 0.6, "top-half fraction {frac}");
    }

    #[test]
    fn merged_rank_maps_extras_into_their_sorted_slots() {
        // Walk every rank of a small merged set and check the mapping
        // agrees with MergeWalk's order (the ground truth).
        let arch = Arc::new(Architecture::initial(32, 3, 10));
        let rm = |accuracy: f64| RankedModel {
            arch: Arc::clone(&arch),
            accuracy,
            penalty: false,
            group: 0,
        };
        let base = vec![rm(0.1), rm(0.5), rm(0.5), rm(0.8)];
        let extras = vec![rm(0.5), rm(0.05), rm(0.9)];
        let base_sorted = sorted_order(&base);
        let extras_sorted = sorted_order(&extras);
        let walked: Vec<(bool, usize)> =
            MergeWalk::new(&base, &base_sorted, &extras, &extras_sorted)
                .map(|(e, i)| (e, i as usize))
                .collect();
        assert_eq!(walked.len(), base.len() + extras.len());
        for (rank, want) in walked.iter().enumerate() {
            let got = merged_rank_to_item(&base, &base_sorted, &extras, &extras_sorted, rank);
            assert_eq!(got, *want, "rank {rank}");
        }
    }
}
