//! NAS search driver: history-ranked parent selection (paper §4.3).
//!
//! "The CPUs on slave nodes search for new neural architectures based on
//! the rank of models in the historical model list." The policy here is
//! rank-softmax parent selection: candidates are ranked by (predicted or
//! measured) accuracy and the parent is drawn with probability
//! exponentially tilted toward the best — exploration comes from the
//! random morph on top of the chosen parent.

use crate::util::rng::Rng;

use super::graph::Architecture;
use super::morphism::{random_legal_morph, Morph, MorphLimits};

/// Scored history entry the policy selects from.
#[derive(Debug, Clone)]
pub struct RankedModel {
    pub arch: Architecture,
    /// Accuracy in [0,1] (measured, or predicted during warm-up).
    pub accuracy: f64,
}

/// Rank-tilted parent selection + random morphism.
#[derive(Debug, Clone)]
pub struct SearchPolicy {
    pub limits: MorphLimits,
    /// Rank temperature: 0 → uniform, large → greedy-best.
    pub rank_beta: f64,
    /// Proposal retries before giving up on morphing a parent.
    pub morph_tries: usize,
}

impl Default for SearchPolicy {
    fn default() -> Self {
        SearchPolicy {
            limits: MorphLimits::default(),
            rank_beta: 1.0,
            morph_tries: 16,
        }
    }
}

impl SearchPolicy {
    /// Select a parent index by rank-softmax over accuracies.
    /// `history` may be unsorted; an empty history is a caller bug.
    pub fn select_parent(&self, history: &[RankedModel], rng: &mut Rng) -> usize {
        assert!(!history.is_empty(), "select_parent on empty history");
        // Rank ascending by accuracy: best gets the largest weight.
        let mut idx: Vec<usize> = (0..history.len()).collect();
        idx.sort_by(|&a, &b| {
            history[a]
                .accuracy
                .partial_cmp(&history[b].accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let n = history.len();
        let weights: Vec<f64> = (0..n)
            .map(|rank| (self.rank_beta * rank as f64 / n.max(1) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = rng.gen_range_f64(0.0, total);
        for (rank, &i) in idx.iter().enumerate() {
            u -= weights[rank];
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }

    /// Generate one child architecture from the history (the unit of work a
    /// slave-node CPU performs before pushing into the buffer).
    pub fn propose(
        &self,
        history: &[RankedModel],
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        let parent = &history[self.select_parent(history, rng)].arch;
        random_legal_morph(parent, &self.limits, rng, self.morph_tries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::derive;

    fn history() -> Vec<RankedModel> {
        let base = Architecture::initial(32, 3, 10);
        (0..8)
            .map(|i| RankedModel {
                arch: base.clone(),
                accuracy: 0.1 * i as f64,
            })
            .collect()
    }

    #[test]
    fn parent_selection_prefers_accurate() {
        let policy = SearchPolicy {
            rank_beta: 4.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(1, "search", 0);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..4000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        // Best model (idx 7, acc 0.7) must be chosen far more often than
        // the worst (idx 0, acc 0.0).
        assert!(counts[7] > counts[0] * 3, "{counts:?}");
    }

    #[test]
    fn uniform_at_zero_beta() {
        let policy = SearchPolicy {
            rank_beta: 0.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(2, "search", 1);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..8000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        let expect = 8000.0 / 8.0;
        for c in &counts {
            assert!((*c as f64 - expect).abs() < expect * 0.25, "{counts:?}");
        }
    }

    #[test]
    fn propose_yields_valid_children() {
        let policy = SearchPolicy::default();
        let h = history();
        let mut rng = derive(3, "search", 2);
        for _ in 0..100 {
            let (child, _) = policy.propose(&h, &mut rng);
            child.validate().unwrap();
        }
    }

    #[test]
    fn propose_is_deterministic_per_seed() {
        let policy = SearchPolicy::default();
        let h = history();
        let a = policy.propose(&h, &mut derive(9, "s", 0));
        let b = policy.propose(&h, &mut derive(9, "s", 0));
        assert_eq!(a.0.signature(), b.0.signature());
        assert_eq!(a.1, b.1);
    }

    #[test]
    #[should_panic]
    fn empty_history_panics() {
        let policy = SearchPolicy::default();
        policy.select_parent(&[], &mut derive(0, "s", 0));
    }
}
