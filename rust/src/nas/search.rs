//! NAS search driver: history-ranked parent selection (paper §4.3).
//!
//! "The CPUs on slave nodes search for new neural architectures based on
//! the rank of models in the historical model list." The policy here is
//! rank-softmax parent selection: candidates are ranked by (predicted or
//! measured) accuracy and the parent is drawn with probability
//! exponentially tilted toward the best — exploration comes from the
//! random morph on top of the chosen parent.

use crate::util::rng::Rng;

use super::graph::Architecture;
use super::morphism::{random_legal_morph, Morph, MorphLimits};

/// Scored history entry the policy selects from.
#[derive(Debug, Clone)]
pub struct RankedModel {
    pub arch: Architecture,
    /// Accuracy in [0,1] (measured, or predicted during warm-up).
    pub accuracy: f64,
    /// OOM-penalty entry: the architecture fit no batch size on its
    /// group's accelerator. Penalty entries teach the search where the
    /// memory boundary lies by ranking (at accuracy zero) without ever
    /// being selected as morph parents while real entries exist — so a
    /// skipped candidate's neighborhood stops being re-proposed.
    pub penalty: bool,
    /// Topology node group of the node that recorded this entry. The
    /// memory boundary is per-accelerator, so a penalty only disqualifies
    /// parenthood for proposals that would run on this same group (when
    /// [`SearchPolicy::group_scoped_penalties`] is on).
    pub group: usize,
}

/// Rank-tilted parent selection + random morphism.
#[derive(Debug, Clone)]
pub struct SearchPolicy {
    pub limits: MorphLimits,
    /// Rank temperature: 0 → uniform, large → greedy-best.
    pub rank_beta: f64,
    /// Proposal retries before giving up on morphing a parent.
    pub morph_tries: usize,
    /// Scope OOM penalties to the node group where the candidate failed
    /// to fit (`BenchmarkConfig::feedback_routing`): a penalty recorded on
    /// a 16 GB T4 group stops disqualifying parenthood for proposals on a
    /// 32 GB V100 group. Off reproduces the global (pre-feedback) filter
    /// exactly, draw for draw.
    pub group_scoped_penalties: bool,
}

impl Default for SearchPolicy {
    fn default() -> Self {
        SearchPolicy {
            limits: MorphLimits::default(),
            rank_beta: 1.0,
            morph_tries: 16,
            group_scoped_penalties: false,
        }
    }
}

impl SearchPolicy {
    /// Select a parent index by rank-softmax over accuracies, without a
    /// proposing-group context (penalties filter globally).
    pub fn select_parent(&self, history: &[RankedModel], rng: &mut Rng) -> usize {
        self.select_parent_on(history, None, rng)
    }

    /// Select a parent index by rank-softmax over accuracies, for a
    /// proposal that would run on topology group `on_group`.
    /// `history` may be unsorted; an empty history is a caller bug.
    /// Penalty entries (OOM-skipped candidates) are excluded from
    /// selection whenever at least one real entry exists — they inform
    /// the ranking's shape but must not seed new morphs past the memory
    /// boundary. With [`SearchPolicy::group_scoped_penalties`] on and a
    /// proposing group given, only penalties recorded on *that* group
    /// disqualify: the memory boundary is per-accelerator, so a candidate
    /// too big for one group's card stays a legal (bottom-ranked) parent
    /// on groups with more memory. With no penalties present the
    /// selection is identical to the historic rank-softmax, draw for
    /// draw.
    pub fn select_parent_on(
        &self,
        history: &[RankedModel],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> usize {
        assert!(!history.is_empty(), "select_parent on empty history");
        // Rank ascending by accuracy: best gets the largest weight.
        let mut idx: Vec<usize> = (0..history.len())
            .filter(|&i| {
                let m = &history[i];
                !m.penalty
                    || (self.group_scoped_penalties && on_group.is_some_and(|g| m.group != g))
            })
            .collect();
        if idx.is_empty() {
            // Nothing but penalties: fall back to the full history (the
            // caller still needs some parent to morph).
            idx = (0..history.len()).collect();
        }
        idx.sort_by(|&a, &b| {
            history[a]
                .accuracy
                .partial_cmp(&history[b].accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let n = idx.len();
        let weights: Vec<f64> = (0..n)
            .map(|rank| (self.rank_beta * rank as f64 / n.max(1) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = rng.gen_range_f64(0.0, total);
        for (rank, &i) in idx.iter().enumerate() {
            u -= weights[rank];
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }

    /// Generate one child architecture from the history (the unit of work a
    /// slave-node CPU performs before pushing into the buffer).
    pub fn propose(
        &self,
        history: &[RankedModel],
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        self.propose_on(history, None, rng)
    }

    /// [`SearchPolicy::propose`] for a proposal that would run on
    /// topology group `on_group` — the group scopes the penalty filter of
    /// [`SearchPolicy::select_parent_on`].
    pub fn propose_on(
        &self,
        history: &[RankedModel],
        on_group: Option<usize>,
        rng: &mut Rng,
    ) -> (Architecture, Option<Morph>) {
        let parent = &history[self.select_parent_on(history, on_group, rng)].arch;
        random_legal_morph(parent, &self.limits, rng, self.morph_tries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::derive;

    fn history() -> Vec<RankedModel> {
        let base = Architecture::initial(32, 3, 10);
        (0..8)
            .map(|i| RankedModel {
                arch: base.clone(),
                accuracy: 0.1 * i as f64,
                penalty: false,
                group: 0,
            })
            .collect()
    }

    #[test]
    fn parent_selection_prefers_accurate() {
        let policy = SearchPolicy {
            rank_beta: 4.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(1, "search", 0);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..4000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        // Best model (idx 7, acc 0.7) must be chosen far more often than
        // the worst (idx 0, acc 0.0).
        assert!(counts[7] > counts[0] * 3, "{counts:?}");
    }

    #[test]
    fn uniform_at_zero_beta() {
        let policy = SearchPolicy {
            rank_beta: 0.0,
            ..Default::default()
        };
        let h = history();
        let mut rng = derive(2, "search", 1);
        let mut counts = vec![0usize; h.len()];
        for _ in 0..8000 {
            counts[policy.select_parent(&h, &mut rng)] += 1;
        }
        let expect = 8000.0 / 8.0;
        for c in &counts {
            assert!((*c as f64 - expect).abs() < expect * 0.25, "{counts:?}");
        }
    }

    #[test]
    fn propose_yields_valid_children() {
        let policy = SearchPolicy::default();
        let h = history();
        let mut rng = derive(3, "search", 2);
        for _ in 0..100 {
            let (child, _) = policy.propose(&h, &mut rng);
            child.validate().unwrap();
        }
    }

    #[test]
    fn propose_is_deterministic_per_seed() {
        let policy = SearchPolicy::default();
        let h = history();
        let a = policy.propose(&h, &mut derive(9, "s", 0));
        let b = policy.propose(&h, &mut derive(9, "s", 0));
        assert_eq!(a.0.signature(), b.0.signature());
        assert_eq!(a.1, b.1);
    }

    #[test]
    #[should_panic]
    fn empty_history_panics() {
        let policy = SearchPolicy::default();
        policy.select_parent(&[], &mut derive(0, "s", 0));
    }

    #[test]
    fn penalty_entries_are_never_parents_while_real_ones_exist() {
        let policy = SearchPolicy::default();
        let mut h = history();
        // Mark every entry but index 3 as an OOM penalty: selection must
        // collapse onto the single real record, draw after draw.
        for (i, m) in h.iter_mut().enumerate() {
            if i != 3 {
                m.penalty = true;
                m.accuracy = 0.0;
            }
        }
        let mut rng = derive(4, "search", 3);
        for _ in 0..200 {
            assert_eq!(policy.select_parent(&h, &mut rng), 3);
        }
        // All-penalty history still yields a parent (fallback).
        for m in h.iter_mut() {
            m.penalty = true;
        }
        let pick = policy.select_parent(&h, &mut rng);
        assert!(pick < h.len());
        let (child, _) = policy.propose(&h, &mut rng);
        child.validate().unwrap();
    }

    #[test]
    fn group_scoped_penalty_is_a_parent_on_other_groups_only() {
        // The per-group memory boundary: an entry OOM-penalized on group
        // 0 (say a 16 GB T4) must stay a legal morph parent for group-1
        // proposals (a 32 GB V100) — and vice versa stays excluded.
        let policy = SearchPolicy {
            rank_beta: 0.0, // uniform over the eligible set
            group_scoped_penalties: true,
            ..Default::default()
        };
        let mut h = history();
        h[0].penalty = true;
        h[0].accuracy = 0.0;
        h[0].group = 0;
        let mut rng = derive(11, "search", 5);
        let mut on_own = vec![0usize; h.len()];
        let mut on_other = vec![0usize; h.len()];
        for _ in 0..2000 {
            on_own[policy.select_parent_on(&h, Some(0), &mut rng)] += 1;
            on_other[policy.select_parent_on(&h, Some(1), &mut rng)] += 1;
        }
        assert_eq!(on_own[0], 0, "penalty picked on its own group: {on_own:?}");
        assert!(
            on_other[0] > 0,
            "penalty never picked on the other group: {on_other:?}"
        );
    }

    #[test]
    fn group_scoping_off_keeps_the_global_filter() {
        // With the knob off (feedback_routing disabled), a group context
        // changes nothing: penalties are excluded everywhere, and the
        // draws match the context-free selection stream exactly.
        let policy = SearchPolicy::default();
        assert!(!policy.group_scoped_penalties);
        let mut h = history();
        h[0].penalty = true;
        h[0].accuracy = 0.0;
        h[0].group = 0;
        let scoped: Vec<usize> = {
            let mut rng = derive(12, "search", 6);
            (0..256)
                .map(|_| policy.select_parent_on(&h, Some(1), &mut rng))
                .collect()
        };
        let global: Vec<usize> = {
            let mut rng = derive(12, "search", 6);
            (0..256).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        assert_eq!(scoped, global);
        assert!(scoped.iter().all(|&i| i != 0), "penalty must stay excluded");
    }

    #[test]
    fn penalty_free_selection_matches_historic_stream() {
        // The penalty filter must be a no-op when no penalties exist:
        // same picks for the same RNG stream as an unfiltered softmax.
        let policy = SearchPolicy::default();
        let h = history();
        let picks: Vec<usize> = {
            let mut rng = derive(7, "search", 9);
            (0..64).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        let again: Vec<usize> = {
            let mut rng = derive(7, "search", 9);
            (0..64).map(|_| policy.select_parent(&h, &mut rng)).collect()
        };
        assert_eq!(picks, again);
    }
}
