//! Benchmark configuration (paper §4.5, Table 5).
//!
//! The paper fixes the rules (NAS method, HPO method, dataset, initial
//! architecture, precision, error requirement) and keeps the rest
//! "pencil-and-paper" customizable (framework, batch size, optimizer,
//! learning rate, termination). This module is the single source of those
//! knobs: TOML-serializable, CLI-overridable, validated before a run.
//!
//! # Configuration text format
//!
//! The file is a TOML subset: global `key = value` lines followed by one
//! `[group.NAME]` section per node group of the cluster topology
//! (heterogeneous clusters list several). `#` starts a comment. Example:
//!
//! ```text
//! batch_per_gpu = 256
//! duration_s = 43200
//!
//! [group.t4]
//! count = 2
//! gpus_per_node = 8
//! gpu = t4                 # named model: t4 | v100 | ascend910
//!
//! [group.v100]
//! count = 2
//! gpus_per_node = 8
//! gpu = v100
//! gpu_util_max = 0.96      # per-field overrides after `gpu = NAME`
//! ```
//!
//! Group keys: `count` (required per section), `gpus_per_node`, `gpu`
//! (named accelerator), the per-field accelerator overrides
//! `gpu_sustained_flops`, `gpu_memory_bytes` (or `gpu_memory_gb`),
//! `gpu_util_half_batch`, `gpu_util_max`, `gpu_step_overhead_s`, and the
//! per-group scheduling overrides `batch_per_gpu` (this group trains at
//! its own batch instead of the global one — a mixed T4/V100 site keeps
//! the V100 group at its memory-appropriate batch),
//! `subshards_per_node` (how many independent trial lanes a node's GPUs
//! split into; must divide `gpus_per_node`), and `accepts_migrants`
//! (whether this group's idle lanes may adopt trials migrated from other
//! groups; defaults to true).
//!
//! The global `subshards_per_node` key is the all-groups default (1 = one
//! lane per node spanning all its GPUs, the classic layout),
//! `work_stealing = true|false` enables the deterministic intra-node
//! steal scheduler (a lane without runway for another full epoch joins
//! the most-loaded sibling lane's trial as extra data-parallel devices),
//! and `migration = true|false` enables the cluster-wide elastic pass on
//! top: a candidate proposed on a lane with no runway and no sibling to
//! steal from is staged to NFS (`migration_nfs_bytes_per_param` bytes
//! per model parameter) and adopted at the next epoch barrier by the
//! least-loaded idle lane of another accepting group (see
//! `coordinator::sched`). `feedback_routing = true|false` (default on)
//! closes the search-feedback loop on top of migration: migrated-trial
//! observations are routed back to the source lane's TPE at a barrier,
//! OOM penalties are scoped per node group, and a parked sibling lane
//! may join an adopted migrant's InfiniBand gradient ring.
//!
//! **Legacy flat shorthand:** the pre-topology keys `nodes`,
//! `gpus_per_node`, and the `gpu_*` family may still appear at the top
//! level *instead of* `[group.*]` sections; they describe a single
//! homogeneous group labelled `default`. Mixing the flat shorthand with
//! explicit sections is an error. Global keys must precede the first
//! section header.
//!
//! [`BenchmarkConfig::to_text`] always emits the canonical sectioned
//! form, and for any configuration that passes
//! [`BenchmarkConfig::validate`] (in particular, group labels restricted
//! to the `[group.NAME]` charset), `BenchmarkConfig::from_text(cfg.to_text())`
//! is the identity (enforced by a property test in
//! `rust/tests/properties.rs`).

use crate::cluster::{ClusterTopology, GpuModel, HostModel, NodeGroup};
use crate::data::DatasetDescriptor;
use crate::hpo::Backend;
use crate::nas::morphism::MorphLimits;

/// Simulation execution engine.
///
/// Both engines run the identical sharded coordinator and are
/// bit-identical for the same seed (enforced by
/// `rust/tests/engine_parity.rs`); `Parallel` executes the per-slave
/// shards on a scoped thread pool between deterministic epoch barriers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Shards run one after another on the calling thread.
    Sequential,
    /// Shards run on a scoped `std::thread` pool.
    #[default]
    Parallel,
}

impl Engine {
    /// Parse from the configuration-file / CLI spelling.
    pub fn parse(s: &str) -> Result<Engine, String> {
        match s {
            "sequential" => Ok(Engine::Sequential),
            "parallel" => Ok(Engine::Parallel),
            other => Err(format!(
                "unknown engine `{other}` (expected `sequential` or `parallel`)"
            )),
        }
    }

    /// The configuration-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Engine::Sequential => "sequential",
            Engine::Parallel => "parallel",
        }
    }
}

/// Warm-up schedule (§4.5): round r trains `first + step·(r−1)` epochs,
/// capped at `max_epochs`; HPO starts at round `hpo_start_round`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WarmupSchedule {
    pub first_epochs: u64,
    pub step_epochs: u64,
    pub max_epochs: u64,
    pub hpo_start_round: u64,
}

impl Default for WarmupSchedule {
    fn default() -> Self {
        // "10 epochs for the first round, then an additional 20 epochs for
        // each one more round until 90 epochs in the fifth round."
        WarmupSchedule {
            first_epochs: 10,
            step_epochs: 20,
            max_epochs: 90,
            hpo_start_round: 5,
        }
    }
}

impl WarmupSchedule {
    /// Epoch budget for a node's `round` (1-based).
    pub fn epochs_for_round(&self, round: u64) -> u64 {
        assert!(round >= 1);
        (self.first_epochs + self.step_epochs * (round - 1)).min(self.max_epochs)
    }

    /// Whether HPO is active for `round`.
    pub fn hpo_active(&self, round: u64) -> bool {
        round >= self.hpo_start_round
    }
}

/// Full benchmark configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchmarkConfig {
    /// Cluster shape: ordered node groups (heterogeneous clusters list
    /// several; the legacy flat keys describe a single group).
    pub topology: ClusterTopology,
    /// Slave container (host) shape, shared by every group.
    pub host: HostModel,
    /// Dataset (fixed to ImageNet shape for official runs).
    pub dataset: DatasetDescriptor,
    /// Suggested per-GPU batch size (Table 5: 448).
    pub batch_per_gpu: u64,
    /// Learning rate (Table 5: 0.1 with decay 0.1/90 per epoch).
    pub learning_rate: f64,
    pub lr_decay_per_epoch: f64,
    /// Warm-up + HPO schedule.
    pub warmup: WarmupSchedule,
    /// Early stopping patience, epochs without validation improvement.
    pub patience: u64,
    /// Minimum improvement counting as progress.
    pub min_delta: f64,
    /// Termination: user-defined wall-clock budget, seconds (§4.5
    /// suggests > 6 h on V100; the evaluation runs 12 h).
    pub duration_s: f64,
    /// Telemetry sampling interval, seconds (Appendix D: 18 min).
    pub telemetry_interval_s: f64,
    /// Score sampling interval, seconds (Figs 4–6: hourly).
    pub score_interval_s: f64,
    /// Morph limits (accelerator-memory adaption).
    pub morph_limits: MorphLimits,
    /// Root seed: fixed seed ⇒ bit-reproducible run.
    pub seed: u64,
    /// Training numeric precision in bits (validity requires ≥ 16).
    pub precision_bits: u32,
    /// Execution engine for the sharded simulation.
    pub engine: Engine,
    /// Epoch-barrier interval, seconds: shards run independently within a
    /// window and merge into the shared history at each barrier. Both
    /// engines use the same windows, so results are engine-independent.
    pub sync_interval_s: f64,
    /// How many independent trial lanes (sub-shards) a node's GPUs split
    /// into, for every group without its own override. 1 = the classic
    /// layout (one trial at a time spanning all of a node's GPUs); must
    /// divide each group's `gpus_per_node`.
    pub subshards_per_node: u64,
    /// Deterministic intra-node work stealing: a sub-shard lane that
    /// lacks runway for another full epoch before the benchmark deadline
    /// joins the most-loaded sibling lane's trial as extra data-parallel
    /// devices (seed-derived scan order; engine-independent).
    pub work_stealing: bool,
    /// Deterministic inter-group trial migration: a candidate proposed on
    /// a lane with no runway left in its own group (and no sibling to
    /// steal from) is staged to NFS and, at the next epoch barrier,
    /// adopted by the least-loaded idle lane of another node group that
    /// `accepts_migrants` — re-timed under the destination group's device
    /// model and batch, with its gradient ring over InfiniBand (see
    /// `coordinator::sched`). Off by default; with it off the elastic
    /// scheduler reproduces the pure steal schedules exactly.
    pub migration: bool,
    /// Checkpoint bytes staged through NFS per model parameter when a
    /// trial migrates (fp32 weights + optimizer state ≈ 8 B/param).
    pub migration_nfs_bytes_per_param: u64,
    /// Close the elastic search-feedback loop (on by default): a migrated
    /// trial's `(hyperparameters, accuracy)` observation is routed back
    /// through the shard outbox to the *source* lane's TPE at the next
    /// epoch barrier instead of being dropped; OOM penalty entries are
    /// scoped to the node group whose accelerator the candidate failed to
    /// fit (a model too big for a 16 GB T4 stays a valid morph parent for
    /// 32 GB V100 lanes); and a parked sibling lane may join an adopted
    /// migrant's gradient ring (steal-into-migrant, re-timed over
    /// InfiniBand). With this off the scheduler reproduces the
    /// pre-feedback schedules exactly (see `coordinator::sched::feedback`).
    pub feedback_routing: bool,
    /// Stream the report to this NDJSON file as the run executes
    /// (`--stream-report` / `stream_report`): records are written the
    /// moment they merge, and the in-RAM report keeps only O(groups)
    /// state — the constant-memory output mode for 100k-lane runs (see
    /// `metrics::stream`). `None` (the default) is the classic buffered
    /// report, byte-identical to before this knob existed.
    pub stream_report: Option<String>,
    /// The HPO backend every lane's optimizer is built from (`hpo =
    /// tpe|evolutionary|random|grid`, `--hpo`). Per-`[group.NAME]`
    /// sections may override it, so a heterogeneous site can run the
    /// paper's TPE on one group and a comparison baseline on another.
    /// Default TPE — the paper's fixed method — reproduces the historic
    /// schedules exactly.
    pub hpo: Backend,
    /// LogFit-based early stopping (`early_stop`, `--early-stop`): after
    /// each validation epoch, extrapolate the trial's learning curve to
    /// the convergence horizon and terminate it when even the optimistic
    /// error floor cannot beat the cluster's best known error by
    /// `early_stop_margin`. The freed lane immediately becomes a steal
    /// victim / migrant-adoption opportunity. Off by default; with it
    /// off the schedules are byte-identical to before the knob existed.
    pub early_stop: bool,
    /// Epochs a trial must complete before it can be early-stopped (the
    /// log fit is meaningless on the first point or two).
    pub early_stop_min_epochs: u64,
    /// Error margin the extrapolated floor must fail to close before a
    /// trial is terminated: larger margins kill fewer trials.
    pub early_stop_margin: f64,
}

impl Default for BenchmarkConfig {
    fn default() -> Self {
        BenchmarkConfig {
            topology: ClusterTopology::default(),
            host: HostModel::default(),
            dataset: DatasetDescriptor::imagenet(),
            batch_per_gpu: 448,
            learning_rate: 0.1,
            lr_decay_per_epoch: 0.1 / 90.0,
            warmup: WarmupSchedule::default(),
            patience: 5,
            min_delta: 1e-3,
            duration_s: 12.0 * 3600.0,
            telemetry_interval_s: 18.0 * 60.0,
            score_interval_s: 3600.0,
            morph_limits: MorphLimits::default(),
            seed: 0,
            precision_bits: 16,
            engine: Engine::default(),
            sync_interval_s: 300.0,
            subshards_per_node: 1,
            work_stealing: false,
            migration: false,
            migration_nfs_bytes_per_param: 8,
            feedback_routing: true,
            stream_report: None,
            hpo: Backend::Tpe,
            early_stop: false,
            early_stop_min_epochs: 3,
            early_stop_margin: 0.02,
        }
    }
}

impl BenchmarkConfig {
    /// The default configuration rescaled to a homogeneous cluster of
    /// `nodes` V100 slave nodes (the pre-topology constructor shape).
    pub fn homogeneous(nodes: u64) -> Self {
        let mut cfg = BenchmarkConfig::default();
        cfg.topology.groups[0].count = nodes;
        cfg
    }

    /// Total slave node count.
    pub fn total_nodes(&self) -> u64 {
        self.topology.total_nodes()
    }

    /// Total GPU count.
    pub fn total_gpus(&self) -> u64 {
        self.topology.total_gpus()
    }

    /// Effective training batch of a topology group: the group override
    /// when set, the global `batch_per_gpu` otherwise.
    pub fn group_batch(&self, group: usize) -> u64 {
        self.topology.groups[group]
            .batch_per_gpu
            .unwrap_or(self.batch_per_gpu)
    }

    /// Effective sub-shards per node of a topology group: the group
    /// override when set, the global `subshards_per_node` otherwise.
    pub fn group_subshards(&self, group: usize) -> u64 {
        self.topology.groups[group]
            .subshards_per_node
            .unwrap_or(self.subshards_per_node)
    }

    /// Effective HPO backend of a topology group: the group override
    /// when set, the global `hpo` key otherwise.
    pub fn group_hpo(&self, group: usize) -> Backend {
        self.topology.groups[group].hpo.unwrap_or(self.hpo)
    }

    /// Total sub-shard lanes across the cluster (the execution-unit count
    /// that strides globally unique trial ids).
    pub fn total_subshards(&self) -> u64 {
        (0..self.topology.groups.len())
            .map(|i| self.topology.groups[i].count * self.group_subshards(i))
            .sum()
    }

    /// Global index of the first sub-shard lane of global node `node`
    /// (which lives in topology group `group`). Lanes are numbered like
    /// nodes: group 0's nodes' lanes first, then group 1's, … — with one
    /// lane per node this is exactly the node index, preserving the
    /// pre-sub-shard RNG streams.
    pub fn subshard_base(&self, group: usize, node: usize) -> u64 {
        let first = self.topology.first_node(group);
        debug_assert!(
            node as u64 >= first,
            "node {node} is not in group {group} (first node {first})"
        );
        let before: u64 = (0..group)
            .map(|i| self.topology.groups[i].count * self.group_subshards(i))
            .sum();
        before + (node as u64 - first) * self.group_subshards(group)
    }

    /// Validate the configuration against the paper's fixed rules.
    pub fn validate(&self) -> Result<(), String> {
        self.topology.validate()?;
        if self.precision_bits < 16 {
            return Err("precision must be FP16 or higher (Table 5)".into());
        }
        if self.batch_per_gpu == 0 {
            return Err("batch size must be positive".into());
        }
        // Written as `!(x > 0.0)` so NaN fails validation too.
        if !(self.duration_s > 0.0) {
            return Err("duration must be positive".into());
        }
        if !(0.0..1.0).contains(&self.min_delta) {
            return Err("min_delta must be in [0,1)".into());
        }
        if !(self.sync_interval_s > 0.0) {
            return Err("sync_interval_s must be positive".into());
        }
        if !(self.score_interval_s > 0.0) {
            return Err("score_interval_s must be positive".into());
        }
        if !(self.telemetry_interval_s > 0.0) {
            return Err("telemetry_interval_s must be positive".into());
        }
        if self.subshards_per_node == 0 {
            return Err("subshards_per_node must be at least 1".into());
        }
        if self.early_stop_min_epochs == 0 {
            return Err("early_stop_min_epochs must be at least 1".into());
        }
        if !(0.0..1.0).contains(&self.early_stop_margin) {
            return Err("early_stop_margin must be in [0,1)".into());
        }
        for (i, g) in self.topology.groups.iter().enumerate() {
            let k = self.group_subshards(i);
            if k == 0 {
                return Err(format!(
                    "group `{}`: subshards_per_node must be at least 1",
                    g.label
                ));
            }
            if g.gpus_per_node % k != 0 {
                return Err(format!(
                    "group `{}`: subshards_per_node ({k}) must divide gpus_per_node ({})",
                    g.label, g.gpus_per_node
                ));
            }
            if g.batch_per_gpu == Some(0) {
                return Err(format!("group `{}`: batch_per_gpu must be positive", g.label));
            }
        }
        Ok(())
    }

    /// Parse from the configuration text format (see the module doc):
    /// global `key = value` lines, then `[group.NAME]` sections — or the
    /// legacy flat cluster keys as a single-group shorthand. Unknown keys
    /// are an error — configuration typos must not silently fall back to
    /// defaults. Unlisted keys keep their default.
    pub fn from_text(s: &str) -> Result<Self, String> {
        /// Parse a boolean knob value (`true/on/1`, `false/off/0`) —
        /// shared by every boolean key so the accepted spellings cannot
        /// drift between them.
        fn parse_flag(key: &str, value: &str) -> Result<bool, String> {
            match value {
                // detlint: allow(knob_key) — boolean value spellings, not
                // config keys.
                "true" | "on" | "1" => Ok(true),
                // detlint: allow(knob_key) — boolean value spellings, not
                // config keys.
                "false" | "off" | "0" => Ok(false),
                other => Err(format!(
                    "bad boolean `{other}` for {key} (expected true/false)"
                )),
            }
        }

        /// Apply one cluster-group key to `g`; `Ok(false)` means the key
        /// is not a group key. Shared by the `[group.*]` branch and the
        /// legacy flat branch so the two dialects cannot drift.
        fn apply_group_key(g: &mut NodeGroup, key: &str, value: &str) -> Result<bool, String> {
            let parse_u64 = |v: &str| -> Result<u64, String> {
                v.parse().map_err(|_| format!("bad integer `{v}`"))
            };
            let parse_f64 = |v: &str| -> Result<f64, String> {
                v.parse().map_err(|_| format!("bad number `{v}`"))
            };
            match key {
                "count" => g.count = parse_u64(value)?,
                "gpus_per_node" => g.gpus_per_node = parse_u64(value)?,
                // detlint: allow(knob_to_text) — parse-only sugar: `gpu`
                // names a preset whose expansion to_text emits as the
                // explicit gpu_* fields.
                "gpu" => {
                    g.gpu = GpuModel::named(value).ok_or_else(|| {
                        format!(
                            "unknown accelerator `{value}` (expected t4, v100, or ascend910)"
                        )
                    })?
                }
                "gpu_sustained_flops" => g.gpu.sustained_flops = parse_f64(value)?,
                "gpu_memory_bytes" => g.gpu.memory_bytes = parse_u64(value)?,
                // detlint: allow(knob_to_text) — parse-only alias:
                // to_text canonicalizes to gpu_memory_bytes.
                "gpu_memory_gb" => {
                    g.gpu.memory_bytes = (parse_f64(value)? * (1u64 << 30) as f64) as u64
                }
                "gpu_util_half_batch" => g.gpu.util_half_batch = parse_f64(value)?,
                "gpu_util_max" => g.gpu.util_max = parse_f64(value)?,
                "gpu_step_overhead_s" => g.gpu.step_overhead_s = parse_f64(value)?,
                // Per-group scheduling overrides (inside `[group.*]`
                // sections only: the same spellings at the top level stay
                // the global defaults).
                "batch_per_gpu" => g.batch_per_gpu = Some(parse_u64(value)?),
                "subshards_per_node" => g.subshards_per_node = Some(parse_u64(value)?),
                "accepts_migrants" => g.accepts_migrants = parse_flag(key, value)?,
                "hpo" => g.hpo = Some(crate::hpo::Backend::parse(value)?),
                _ => return Ok(false),
            }
            Ok(true)
        }

        let mut cfg = BenchmarkConfig::default();
        // Explicit `[group.NAME]` sections, in file order; each section
        // must set `count` explicitly (no silent one-node default).
        let mut groups: Vec<NodeGroup> = Vec::new();
        let mut count_seen: Vec<bool> = Vec::new();
        // Single group accumulated from the legacy flat keys, starting
        // from the default topology's group so partial flat configs stay
        // consistent with the no-keys default.
        let mut flat: Option<NodeGroup> = None;
        fn flat_group() -> NodeGroup {
            ClusterTopology::default().groups.swap_remove(0)
        }

        for (lineno, raw) in s.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: String| format!("line {}: {msg}", lineno + 1);

            // Section header?
            if let Some(inner) = line.strip_prefix('[') {
                let inner = inner
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header".into()))?
                    .trim();
                let label = inner.strip_prefix("group.").ok_or_else(|| {
                    err(format!("unknown section `[{inner}]` (expected `[group.NAME]`)"))
                })?;
                if !NodeGroup::is_valid_label(label) {
                    return Err(err(format!(
                        "bad group label `{label}` (alphanumeric, `-`, `_`)"
                    )));
                }
                if groups.iter().any(|g| g.label == label) {
                    return Err(err(format!("duplicate group `[group.{label}]`")));
                }
                groups.push(NodeGroup::new(label, 1, 8, GpuModel::default()));
                count_seen.push(false);
                continue;
            }

            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err("expected `key = value`".into()))?;
            let key = key.trim();
            let value = value.trim();
            let parse_u64 = |v: &str| -> Result<u64, String> {
                v.parse().map_err(|_| err(format!("bad integer `{v}`")))
            };
            let parse_f64 = |v: &str| -> Result<f64, String> {
                v.parse().map_err(|_| err(format!("bad number `{v}`")))
            };

            // Inside a section: keys configure the newest group.
            if let Some(g) = groups.last_mut() {
                if apply_group_key(g, key, value).map_err(&err)? {
                    if key == "count" {
                        *count_seen.last_mut().expect("group just pushed") = true;
                    }
                    continue;
                }
                return Err(err(format!(
                    "unknown key `{key}` in [group.{}] (global keys go before \
                     the first section)",
                    g.label
                )));
            }

            // Legacy flat cluster keys: a single-group shorthand
            // (`nodes` is the flat spelling of a group's `count`; the
            // section-only `count` key stays invalid at the top level).
            let flat_key = match key {
                // detlint: allow(knob_to_text) — parse-only alias: the
                // flat spelling of a group's `count`, which to_text emits.
                "nodes" => Some("count"),
                "gpus_per_node" | "gpu" | "gpu_sustained_flops" | "gpu_memory_bytes"
                | "gpu_memory_gb" | "gpu_util_half_batch" | "gpu_util_max"
                | "gpu_step_overhead_s" => Some(key),
                _ => None,
            };
            if let Some(flat_key) = flat_key {
                let g = flat.get_or_insert_with(flat_group);
                apply_group_key(g, flat_key, value).map_err(&err)?;
                continue;
            }

            match key {
                // Host (slave container) keys.
                "cpu_cores" => cfg.host.cpu_cores = parse_u64(value)?,
                "host_memory_bytes" => cfg.host.memory_bytes = parse_u64(value)?,
                "search_seconds" => cfg.host.search_seconds = parse_f64(value)?,
                "setup_seconds" => cfg.host.setup_seconds = parse_f64(value)?,
                // Global benchmark keys.
                "batch_per_gpu" => cfg.batch_per_gpu = parse_u64(value)?,
                "learning_rate" => cfg.learning_rate = parse_f64(value)?,
                "lr_decay_per_epoch" => cfg.lr_decay_per_epoch = parse_f64(value)?,
                "patience" => cfg.patience = parse_u64(value)?,
                "min_delta" => cfg.min_delta = parse_f64(value)?,
                // detlint: allow(knob_to_text) — parse-only alias:
                // to_text canonicalizes to duration_s.
                "duration_hours" => cfg.duration_s = parse_f64(value)? * 3600.0,
                "duration_s" => cfg.duration_s = parse_f64(value)?,
                "telemetry_interval_s" => cfg.telemetry_interval_s = parse_f64(value)?,
                "score_interval_s" => cfg.score_interval_s = parse_f64(value)?,
                "seed" => cfg.seed = parse_u64(value)?,
                "precision_bits" => cfg.precision_bits = parse_u64(value)? as u32,
                "engine" => cfg.engine = Engine::parse(value).map_err(err)?,
                "sync_interval_s" => cfg.sync_interval_s = parse_f64(value)?,
                "subshards_per_node" => cfg.subshards_per_node = parse_u64(value)?,
                "work_stealing" => cfg.work_stealing = parse_flag(key, value).map_err(&err)?,
                "migration" => cfg.migration = parse_flag(key, value).map_err(&err)?,
                "migration_nfs_bytes_per_param" => {
                    cfg.migration_nfs_bytes_per_param = parse_u64(value)?
                }
                "feedback_routing" => {
                    cfg.feedback_routing = parse_flag(key, value).map_err(&err)?
                }
                "hpo" => cfg.hpo = Backend::parse(value).map_err(&err)?,
                "early_stop" => cfg.early_stop = parse_flag(key, value).map_err(&err)?,
                "early_stop_min_epochs" => cfg.early_stop_min_epochs = parse_u64(value)?,
                "early_stop_margin" => cfg.early_stop_margin = parse_f64(value)?,
                "stream_report" => {
                    if value.is_empty() {
                        return Err(err("stream_report needs a file path".into()));
                    }
                    cfg.stream_report = Some(value.to_string());
                }
                "max_params" => cfg.morph_limits.max_params = parse_u64(value)?,
                "max_depth" => cfg.morph_limits.max_depth = parse_u64(value)? as usize,
                "max_width" => cfg.morph_limits.max_width = parse_u64(value)?,
                "warmup_first_epochs" => cfg.warmup.first_epochs = parse_u64(value)?,
                "warmup_step_epochs" => cfg.warmup.step_epochs = parse_u64(value)?,
                "max_epochs" => cfg.warmup.max_epochs = parse_u64(value)?,
                "hpo_start_round" => cfg.warmup.hpo_start_round = parse_u64(value)?,
                other => return Err(err(format!("unknown key `{other}`"))),
            }
        }

        // A section that never set `count` would silently simulate a
        // one-node group; require it explicitly (typos must not shrink
        // the cluster).
        if let Some(i) = count_seen.iter().position(|&seen| !seen) {
            return Err(format!(
                "[group.{}] is missing the required `count` key",
                groups[i].label
            ));
        }
        match (groups.is_empty(), flat) {
            (false, Some(_)) => {
                return Err(
                    "flat cluster keys (nodes/gpus_per_node/gpu_*) cannot be mixed with \
                     [group.*] sections"
                        .into(),
                )
            }
            (false, None) => cfg.topology = ClusterTopology { groups },
            (true, Some(g)) => cfg.topology = ClusterTopology { groups: vec![g] },
            (true, None) => {} // default topology stands
        }
        Ok(cfg)
    }

    /// Render as the canonical sectioned text `from_text` accepts;
    /// for any configuration that passes [`BenchmarkConfig::validate`],
    /// `from_text(self.to_text())` reproduces `self` exactly.
    pub fn to_text(&self) -> String {
        debug_assert!(
            self.topology
                .groups
                .iter()
                .all(|g| NodeGroup::is_valid_label(&g.label)),
            "group labels must use the [group.NAME] charset to round-trip"
        );
        let mut out = format!(
            "# AIPerf benchmark configuration (Table 5 defaults)\n\
             batch_per_gpu = {}\n\
             learning_rate = {}\n\
             lr_decay_per_epoch = {}\n\
             patience = {}\n\
             min_delta = {}\n\
             duration_s = {}\n\
             telemetry_interval_s = {}\n\
             score_interval_s = {}\n\
             seed = {}\n\
             precision_bits = {}\n\
             max_params = {}\n\
             max_depth = {}\n\
             max_width = {}\n\
             warmup_first_epochs = {}\n\
             warmup_step_epochs = {}\n\
             max_epochs = {}\n\
             hpo_start_round = {}\n\
             cpu_cores = {}\n\
             host_memory_bytes = {}\n\
             search_seconds = {}\n\
             setup_seconds = {}\n\
             engine = {}\n\
             sync_interval_s = {}\n\
             subshards_per_node = {}\n\
             work_stealing = {}\n\
             migration = {}\n\
             migration_nfs_bytes_per_param = {}\n\
             feedback_routing = {}\n\
             hpo = {}\n\
             early_stop = {}\n\
             early_stop_min_epochs = {}\n\
             early_stop_margin = {}\n",
            self.batch_per_gpu,
            self.learning_rate,
            self.lr_decay_per_epoch,
            self.patience,
            self.min_delta,
            self.duration_s,
            self.telemetry_interval_s,
            self.score_interval_s,
            self.seed,
            self.precision_bits,
            self.morph_limits.max_params,
            self.morph_limits.max_depth,
            self.morph_limits.max_width,
            self.warmup.first_epochs,
            self.warmup.step_epochs,
            self.warmup.max_epochs,
            self.warmup.hpo_start_round,
            self.host.cpu_cores,
            self.host.memory_bytes,
            self.host.search_seconds,
            self.host.setup_seconds,
            self.engine.as_str(),
            self.sync_interval_s,
            self.subshards_per_node,
            self.work_stealing,
            self.migration,
            self.migration_nfs_bytes_per_param,
            self.feedback_routing,
            self.hpo.as_str(),
            self.early_stop,
            self.early_stop_min_epochs,
            self.early_stop_margin,
        );
        // Emitted only when set, so configs from before the knob existed
        // round-trip byte-identically.
        if let Some(path) = &self.stream_report {
            out.push_str(&format!("stream_report = {path}\n"));
        }
        for g in &self.topology.groups {
            out.push_str(&format!(
                "\n[group.{}]\n\
                 count = {}\n\
                 gpus_per_node = {}\n\
                 gpu_sustained_flops = {}\n\
                 gpu_memory_bytes = {}\n\
                 gpu_util_half_batch = {}\n\
                 gpu_util_max = {}\n\
                 gpu_step_overhead_s = {}\n",
                g.label,
                g.count,
                g.gpus_per_node,
                g.gpu.sustained_flops,
                g.gpu.memory_bytes,
                g.gpu.util_half_batch,
                g.gpu.util_max,
                g.gpu.step_overhead_s,
            ));
            // Optional per-group overrides: emitted only when set, so the
            // round trip preserves `None` exactly.
            if let Some(b) = g.batch_per_gpu {
                out.push_str(&format!("batch_per_gpu = {b}\n"));
            }
            if let Some(k) = g.subshards_per_node {
                out.push_str(&format!("subshards_per_node = {k}\n"));
            }
            // Per-group HPO override: emitted only when set, like the
            // other optional overrides.
            if let Some(b) = g.hpo {
                out.push_str(&format!("hpo = {}\n", b.as_str()));
            }
            // `accepts_migrants` defaults to true; emitting it only when
            // false keeps old configs byte-stable and still round-trips.
            if !g.accepts_migrants {
                out.push_str("accepts_migrants = false\n");
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_schedule_matches_paper() {
        let w = WarmupSchedule::default();
        assert_eq!(w.epochs_for_round(1), 10);
        assert_eq!(w.epochs_for_round(2), 30);
        assert_eq!(w.epochs_for_round(3), 50);
        assert_eq!(w.epochs_for_round(4), 70);
        assert_eq!(w.epochs_for_round(5), 90);
        assert_eq!(w.epochs_for_round(9), 90); // capped
        assert!(!w.hpo_active(4));
        assert!(w.hpo_active(5));
    }

    #[test]
    fn default_config_valid_and_matches_table5() {
        let c = BenchmarkConfig::default();
        c.validate().unwrap();
        assert_eq!(c.batch_per_gpu, 448);
        assert_eq!(c.learning_rate, 0.1);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.total_nodes(), 2);
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = BenchmarkConfig::default();
        c.topology.groups[0].count = 0;
        assert!(c.validate().is_err());

        let mut c = BenchmarkConfig::default();
        c.topology.groups.clear();
        assert!(c.validate().is_err());

        let c = BenchmarkConfig {
            precision_bits: 8,
            ..BenchmarkConfig::default()
        };
        assert!(c.validate().is_err());

        let c = BenchmarkConfig {
            duration_s: -1.0,
            ..BenchmarkConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn text_roundtrip_is_identity() {
        let mut c = BenchmarkConfig::homogeneous(7);
        c.seed = 99;
        c.duration_s = 4.5 * 3600.0;
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn multi_group_roundtrip_is_identity() {
        let c = BenchmarkConfig {
            topology: ClusterTopology {
                groups: vec![
                    NodeGroup::new("t4", 2, 8, GpuModel::t4()),
                    NodeGroup::new("v100", 3, 4, GpuModel::v100()),
                    NodeGroup::new("ascend", 1, 16, GpuModel::ascend910()),
                ],
            },
            host: HostModel {
                cpu_cores: 48,
                ..HostModel::default()
            },
            ..BenchmarkConfig::default()
        };
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn legacy_flat_keys_parse_to_one_group() {
        let c = BenchmarkConfig::from_text(
            "nodes = 4\ngpus_per_node = 2\ngpu_sustained_flops = 2e12\ngpu_memory_gb = 16\n",
        )
        .unwrap();
        assert_eq!(c.topology.groups.len(), 1);
        let g = &c.topology.groups[0];
        assert_eq!(g.label, "default");
        assert_eq!((g.count, g.gpus_per_node), (4, 2));
        assert_eq!(g.gpu.sustained_flops, 2e12);
        assert_eq!(g.gpu.memory_bytes, 16 * (1 << 30));
    }

    #[test]
    fn group_sections_parse_with_named_gpu_and_overrides() {
        let text = "batch_per_gpu = 256\n\
                    [group.t4]\ncount = 2\ngpus_per_node = 8\ngpu = t4\n\
                    [group.v100]\ncount = 3\ngpus_per_node = 4\ngpu = v100\ngpu_util_max = 0.9\n";
        let c = BenchmarkConfig::from_text(text).unwrap();
        assert_eq!(c.batch_per_gpu, 256);
        assert_eq!(c.topology.groups.len(), 2);
        assert_eq!(c.topology.groups[0].gpu, GpuModel::t4());
        assert_eq!(c.topology.groups[1].gpu.util_max, 0.9);
        assert_eq!(c.total_nodes(), 5);
        assert_eq!(c.total_gpus(), 28);
    }

    #[test]
    fn flat_and_sections_do_not_mix() {
        let text = "nodes = 2\n[group.t4]\ncount = 1\n";
        assert!(BenchmarkConfig::from_text(text).is_err());
    }

    #[test]
    fn section_errors_are_reported() {
        assert!(BenchmarkConfig::from_text("[group.t4]\nseed = 1\n").is_err(),
            "global key inside a section must error");
        assert!(BenchmarkConfig::from_text("[group.]\ncount = 1\n").is_err());
        assert!(BenchmarkConfig::from_text("[group.a b]\ncount = 1\n").is_err());
        assert!(BenchmarkConfig::from_text("[nodes]\n").is_err());
        assert!(BenchmarkConfig::from_text("[group.x\ncount = 1\n").is_err());
        assert!(
            BenchmarkConfig::from_text("[group.x]\ncount = 1\n[group.x]\ncount = 2\n").is_err(),
            "duplicate group labels must error"
        );
        assert!(BenchmarkConfig::from_text("[group.x]\ngpu = hal9000\n").is_err());
        assert!(
            BenchmarkConfig::from_text("[group.x]\ngpus_per_node = 4\n").is_err(),
            "a section without `count` must not silently default"
        );
    }

    #[test]
    fn text_parse_errors_are_reported() {
        assert!(BenchmarkConfig::from_text("nodes = two").is_err());
        assert!(BenchmarkConfig::from_text("bogus_key = 1").is_err());
        assert!(BenchmarkConfig::from_text("no equals sign").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ok() {
        let c = BenchmarkConfig::from_text("# comment\n\nnodes = 4 # inline\n").unwrap();
        assert_eq!(c.total_nodes(), 4);
    }

    #[test]
    fn engine_parses_and_roundtrips() {
        let c = BenchmarkConfig::from_text("engine = sequential\nsync_interval_s = 120\n")
            .unwrap();
        assert_eq!(c.engine, Engine::Sequential);
        assert_eq!(c.sync_interval_s, 120.0);
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        assert!(BenchmarkConfig::from_text("engine = turbo\n").is_err());
    }

    #[test]
    fn per_group_batch_and_subshards_parse_and_roundtrip() {
        let text = "batch_per_gpu = 448\nsubshards_per_node = 1\nwork_stealing = on\n\
                    [group.t4]\ncount = 2\ngpus_per_node = 8\ngpu = t4\nbatch_per_gpu = 256\n\
                    [group.v100]\ncount = 2\ngpus_per_node = 8\ngpu = v100\nsubshards_per_node = 2\n";
        let c = BenchmarkConfig::from_text(text).unwrap();
        assert!(c.work_stealing);
        assert_eq!(c.batch_per_gpu, 448);
        assert_eq!(c.topology.groups[0].batch_per_gpu, Some(256));
        assert_eq!(c.topology.groups[1].batch_per_gpu, None);
        assert_eq!(c.topology.groups[1].subshards_per_node, Some(2));
        // Effective values: group override wins, global is the fallback.
        assert_eq!(c.group_batch(0), 256);
        assert_eq!(c.group_batch(1), 448);
        assert_eq!(c.group_subshards(0), 1);
        assert_eq!(c.group_subshards(1), 2);
        assert_eq!(c.total_subshards(), 2 * 1 + 2 * 2);
        // Lane numbering strides nodes in group order.
        assert_eq!(c.subshard_base(0, 0), 0);
        assert_eq!(c.subshard_base(0, 1), 1);
        assert_eq!(c.subshard_base(1, 2), 2);
        assert_eq!(c.subshard_base(1, 3), 4);
        c.validate().unwrap();
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn work_stealing_key_rejects_garbage() {
        assert!(BenchmarkConfig::from_text("work_stealing = maybe\n").is_err());
        let c = BenchmarkConfig::from_text("work_stealing = off\n").unwrap();
        assert!(!c.work_stealing);
    }

    #[test]
    fn migration_keys_parse_and_roundtrip() {
        let text = "work_stealing = on\nmigration = on\nmigration_nfs_bytes_per_param = 12\n\
                    [group.t4]\ncount = 2\ngpus_per_node = 8\ngpu = t4\n\
                    [group.v100]\ncount = 2\ngpus_per_node = 8\ngpu = v100\naccepts_migrants = false\n";
        let c = BenchmarkConfig::from_text(text).unwrap();
        assert!(c.migration);
        assert_eq!(c.migration_nfs_bytes_per_param, 12);
        assert!(c.topology.groups[0].accepts_migrants);
        assert!(!c.topology.groups[1].accepts_migrants);
        c.validate().unwrap();
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        // Bad values error instead of silently defaulting.
        assert!(BenchmarkConfig::from_text("migration = maybe\n").is_err());
        assert!(
            BenchmarkConfig::from_text("[group.x]\ncount = 1\naccepts_migrants = sure\n")
                .is_err()
        );
        // `accepts_migrants` is a group key, not a global one.
        assert!(BenchmarkConfig::from_text("accepts_migrants = true\n").is_err());
        // Migration is off by default and absent keys keep defaults.
        let d = BenchmarkConfig::from_text("seed = 1\n").unwrap();
        assert!(!d.migration);
        assert_eq!(d.migration_nfs_bytes_per_param, 8);
    }

    #[test]
    fn feedback_routing_parses_and_roundtrips() {
        // On by default; both spellings parse; `off` survives the round
        // trip (the knob must be explicit in the canonical text so a
        // disabled loop stays disabled on reparse).
        let d = BenchmarkConfig::from_text("seed = 1\n").unwrap();
        assert!(d.feedback_routing);
        let c = BenchmarkConfig::from_text("feedback_routing = off\n").unwrap();
        assert!(!c.feedback_routing);
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        assert!(!c2.feedback_routing);
        assert!(BenchmarkConfig::from_text("feedback_routing = maybe\n").is_err());
    }

    #[test]
    fn stream_report_parses_and_roundtrips() {
        // Off (None) by default, and absent from the canonical text so
        // pre-knob configs stay byte-identical.
        let d = BenchmarkConfig::from_text("seed = 1\n").unwrap();
        assert_eq!(d.stream_report, None);
        assert!(!d.to_text().contains("stream_report"));
        let c = BenchmarkConfig::from_text("stream_report = out/run.ndjson\n").unwrap();
        assert_eq!(c.stream_report.as_deref(), Some("out/run.ndjson"));
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        // An empty path is a config error, not a silent no-op.
        assert!(BenchmarkConfig::from_text("stream_report =\n").is_err());
        assert!(BenchmarkConfig::from_text("stream_report = \n").is_err());
    }

    #[test]
    fn hpo_key_parses_and_roundtrips() {
        // Default TPE; every spelling parses; per-group overrides win
        // and survive the round trip.
        let d = BenchmarkConfig::from_text("seed = 1\n").unwrap();
        assert_eq!(d.hpo, Backend::Tpe);
        let text = "hpo = evolutionary\n\
                    [group.t4]\ncount = 2\ngpus_per_node = 8\ngpu = t4\nhpo = grid\n\
                    [group.v100]\ncount = 2\ngpus_per_node = 8\ngpu = v100\n";
        let c = BenchmarkConfig::from_text(text).unwrap();
        assert_eq!(c.hpo, Backend::Evolutionary);
        assert_eq!(c.topology.groups[0].hpo, Some(Backend::Grid));
        assert_eq!(c.topology.groups[1].hpo, None);
        assert_eq!(c.group_hpo(0), Backend::Grid);
        assert_eq!(c.group_hpo(1), Backend::Evolutionary);
        c.validate().unwrap();
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        // Bad values error, globally and per group.
        assert!(BenchmarkConfig::from_text("hpo = bayes\n").is_err());
        assert!(BenchmarkConfig::from_text("[group.x]\ncount = 1\nhpo = bayes\n").is_err());
    }

    #[test]
    fn early_stop_keys_parse_and_roundtrip() {
        let d = BenchmarkConfig::from_text("seed = 1\n").unwrap();
        assert!(!d.early_stop);
        assert_eq!(d.early_stop_min_epochs, 3);
        assert_eq!(d.early_stop_margin, 0.02);
        let c = BenchmarkConfig::from_text(
            "early_stop = on\nearly_stop_min_epochs = 5\nearly_stop_margin = 0.05\n",
        )
        .unwrap();
        assert!(c.early_stop);
        assert_eq!(c.early_stop_min_epochs, 5);
        assert_eq!(c.early_stop_margin, 0.05);
        c.validate().unwrap();
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2, c);
        assert!(BenchmarkConfig::from_text("early_stop = maybe\n").is_err());
        assert!(BenchmarkConfig::from_text("early_stop_min_epochs = few\n").is_err());
        assert!(BenchmarkConfig::from_text("early_stop_margin = wide\n").is_err());
        // Validation bounds: min_epochs >= 1, margin in [0,1), NaN fails.
        let mut bad = BenchmarkConfig::default();
        bad.early_stop_min_epochs = 0;
        assert!(bad.validate().is_err());
        let mut bad = BenchmarkConfig::default();
        bad.early_stop_margin = 1.0;
        assert!(bad.validate().is_err());
        let mut bad = BenchmarkConfig::default();
        bad.early_stop_margin = f64::NAN;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn subshards_must_divide_gpus_per_node() {
        let mut c = BenchmarkConfig::default();
        c.subshards_per_node = 3; // default group has 8 GPUs per node
        assert!(c.validate().is_err());
        c.subshards_per_node = 2;
        c.validate().unwrap();
        c.topology.groups[0].subshards_per_node = Some(0);
        assert!(c.validate().is_err());
        let mut c = BenchmarkConfig::default();
        c.subshards_per_node = 0;
        assert!(c.validate().is_err());
        let mut c = BenchmarkConfig::default();
        c.topology.groups[0].batch_per_gpu = Some(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn sync_interval_validated() {
        let c = BenchmarkConfig {
            sync_interval_s: 0.0,
            ..BenchmarkConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn nan_intervals_rejected() {
        for field in 0..4 {
            let mut c = BenchmarkConfig::default();
            match field {
                0 => c.sync_interval_s = f64::NAN,
                1 => c.score_interval_s = f64::NAN,
                2 => c.telemetry_interval_s = f64::NAN,
                _ => c.duration_s = f64::NAN,
            }
            assert!(c.validate().is_err(), "field {field}: NaN passed validation");
        }
    }
}
