//! Benchmark configuration (paper §4.5, Table 5).
//!
//! The paper fixes the rules (NAS method, HPO method, dataset, initial
//! architecture, precision, error requirement) and keeps the rest
//! "pencil-and-paper" customizable (framework, batch size, optimizer,
//! learning rate, termination). This module is the single source of those
//! knobs: TOML-serializable, CLI-overridable, validated before a run.


use crate::cluster::NodeModel;
use crate::data::DatasetDescriptor;
use crate::nas::morphism::MorphLimits;

/// Simulation execution engine.
///
/// Both engines run the identical sharded coordinator and are
/// bit-identical for the same seed (enforced by
/// `rust/tests/engine_parity.rs`); `Parallel` executes the per-slave
/// shards on a scoped thread pool between deterministic epoch barriers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Shards run one after another on the calling thread.
    Sequential,
    /// Shards run on a scoped `std::thread` pool.
    #[default]
    Parallel,
}

impl Engine {
    /// Parse from the configuration-file / CLI spelling.
    pub fn parse(s: &str) -> Result<Engine, String> {
        match s {
            "sequential" => Ok(Engine::Sequential),
            "parallel" => Ok(Engine::Parallel),
            other => Err(format!(
                "unknown engine `{other}` (expected `sequential` or `parallel`)"
            )),
        }
    }

    /// The configuration-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Engine::Sequential => "sequential",
            Engine::Parallel => "parallel",
        }
    }
}

/// Warm-up schedule (§4.5): round r trains `first + step·(r−1)` epochs,
/// capped at `max_epochs`; HPO starts at round `hpo_start_round`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WarmupSchedule {
    pub first_epochs: u64,
    pub step_epochs: u64,
    pub max_epochs: u64,
    pub hpo_start_round: u64,
}

impl Default for WarmupSchedule {
    fn default() -> Self {
        // "10 epochs for the first round, then an additional 20 epochs for
        // each one more round until 90 epochs in the fifth round."
        WarmupSchedule {
            first_epochs: 10,
            step_epochs: 20,
            max_epochs: 90,
            hpo_start_round: 5,
        }
    }
}

impl WarmupSchedule {
    /// Epoch budget for a node's `round` (1-based).
    pub fn epochs_for_round(&self, round: u64) -> u64 {
        assert!(round >= 1);
        (self.first_epochs + self.step_epochs * (round - 1)).min(self.max_epochs)
    }

    /// Whether HPO is active for `round`.
    pub fn hpo_active(&self, round: u64) -> bool {
        round >= self.hpo_start_round
    }
}

/// Full benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchmarkConfig {
    /// Cluster scale.
    pub nodes: u64,
    pub node: NodeModel,
    /// Dataset (fixed to ImageNet shape for official runs).
    pub dataset: DatasetDescriptor,
    /// Suggested per-GPU batch size (Table 5: 448).
    pub batch_per_gpu: u64,
    /// Learning rate (Table 5: 0.1 with decay 0.1/90 per epoch).
    pub learning_rate: f64,
    pub lr_decay_per_epoch: f64,
    /// Warm-up + HPO schedule.
    pub warmup: WarmupSchedule,
    /// Early stopping patience, epochs without validation improvement.
    pub patience: u64,
    /// Minimum improvement counting as progress.
    pub min_delta: f64,
    /// Termination: user-defined wall-clock budget, seconds (§4.5
    /// suggests > 6 h on V100; the evaluation runs 12 h).
    pub duration_s: f64,
    /// Telemetry sampling interval, seconds (Appendix D: 18 min).
    pub telemetry_interval_s: f64,
    /// Score sampling interval, seconds (Figs 4–6: hourly).
    pub score_interval_s: f64,
    /// Morph limits (accelerator-memory adaption).
    pub morph_limits: MorphLimits,
    /// Root seed: fixed seed ⇒ bit-reproducible run.
    pub seed: u64,
    /// Training numeric precision in bits (validity requires ≥ 16).
    pub precision_bits: u32,
    /// Execution engine for the sharded simulation.
    pub engine: Engine,
    /// Epoch-barrier interval, seconds: shards run independently within a
    /// window and merge into the shared history at each barrier. Both
    /// engines use the same windows, so results are engine-independent.
    pub sync_interval_s: f64,
}

impl Default for BenchmarkConfig {
    fn default() -> Self {
        BenchmarkConfig {
            nodes: 2,
            node: NodeModel::default(),
            dataset: DatasetDescriptor::imagenet(),
            batch_per_gpu: 448,
            learning_rate: 0.1,
            lr_decay_per_epoch: 0.1 / 90.0,
            warmup: WarmupSchedule::default(),
            patience: 5,
            min_delta: 1e-3,
            duration_s: 12.0 * 3600.0,
            telemetry_interval_s: 18.0 * 60.0,
            score_interval_s: 3600.0,
            morph_limits: MorphLimits::default(),
            seed: 0,
            precision_bits: 16,
            engine: Engine::default(),
            sync_interval_s: 300.0,
        }
    }
}

impl BenchmarkConfig {
    /// Total GPU count.
    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.node.gpus_per_node
    }

    /// Validate the configuration against the paper's fixed rules.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes == 0 {
            return Err("at least one slave node required".into());
        }
        if self.node.gpus_per_node == 0 {
            return Err("at least one GPU per node required".into());
        }
        if self.precision_bits < 16 {
            return Err("precision must be FP16 or higher (Table 5)".into());
        }
        if self.batch_per_gpu == 0 {
            return Err("batch size must be positive".into());
        }
        // Written as `!(x > 0.0)` so NaN fails validation too.
        if !(self.duration_s > 0.0) {
            return Err("duration must be positive".into());
        }
        if !(0.0..1.0).contains(&self.min_delta) {
            return Err("min_delta must be in [0,1)".into());
        }
        if !(self.sync_interval_s > 0.0) {
            return Err("sync_interval_s must be positive".into());
        }
        if !(self.score_interval_s > 0.0) {
            return Err("score_interval_s must be positive".into());
        }
        if !(self.telemetry_interval_s > 0.0) {
            return Err("telemetry_interval_s must be positive".into());
        }
        Ok(())
    }

    /// Parse from a flat `key = value` text (a TOML subset; `#` comments).
    /// Unknown keys are an error — configuration typos must not silently
    /// fall back to defaults. Unlisted keys keep their default.
    pub fn from_text(s: &str) -> Result<Self, String> {
        let mut cfg = BenchmarkConfig::default();
        for (lineno, raw) in s.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = key.trim();
            let value = value.trim();
            let parse_u64 = |v: &str| -> Result<u64, String> {
                v.parse().map_err(|_| format!("line {}: bad integer `{v}`", lineno + 1))
            };
            let parse_f64 = |v: &str| -> Result<f64, String> {
                v.parse().map_err(|_| format!("line {}: bad number `{v}`", lineno + 1))
            };
            match key {
                "nodes" => cfg.nodes = parse_u64(value)?,
                "gpus_per_node" => cfg.node.gpus_per_node = parse_u64(value)?,
                "batch_per_gpu" => cfg.batch_per_gpu = parse_u64(value)?,
                "learning_rate" => cfg.learning_rate = parse_f64(value)?,
                "lr_decay_per_epoch" => cfg.lr_decay_per_epoch = parse_f64(value)?,
                "patience" => cfg.patience = parse_u64(value)?,
                "min_delta" => cfg.min_delta = parse_f64(value)?,
                "duration_hours" => cfg.duration_s = parse_f64(value)? * 3600.0,
                "duration_s" => cfg.duration_s = parse_f64(value)?,
                "telemetry_interval_s" => cfg.telemetry_interval_s = parse_f64(value)?,
                "score_interval_s" => cfg.score_interval_s = parse_f64(value)?,
                "seed" => cfg.seed = parse_u64(value)?,
                "precision_bits" => cfg.precision_bits = parse_u64(value)? as u32,
                "engine" => {
                    cfg.engine = Engine::parse(value)
                        .map_err(|e| format!("line {}: {e}", lineno + 1))?
                }
                "sync_interval_s" => cfg.sync_interval_s = parse_f64(value)?,
                "max_params" => cfg.morph_limits.max_params = parse_u64(value)?,
                "max_depth" => cfg.morph_limits.max_depth = parse_u64(value)? as usize,
                "max_width" => cfg.morph_limits.max_width = parse_u64(value)?,
                "warmup_first_epochs" => cfg.warmup.first_epochs = parse_u64(value)?,
                "warmup_step_epochs" => cfg.warmup.step_epochs = parse_u64(value)?,
                "max_epochs" => cfg.warmup.max_epochs = parse_u64(value)?,
                "hpo_start_round" => cfg.warmup.hpo_start_round = parse_u64(value)?,
                "gpu_sustained_flops" => cfg.node.gpu.sustained_flops = parse_f64(value)?,
                "gpu_memory_gb" => {
                    cfg.node.gpu.memory_bytes = (parse_f64(value)? * (1u64 << 30) as f64) as u64
                }
                "gpu_util_half_batch" => cfg.node.gpu.util_half_batch = parse_f64(value)?,
                "gpu_util_max" => cfg.node.gpu.util_max = parse_f64(value)?,
                "gpu_step_overhead_s" => cfg.node.gpu.step_overhead_s = parse_f64(value)?,
                other => return Err(format!("line {}: unknown key `{other}`", lineno + 1)),
            }
        }
        Ok(cfg)
    }

    /// Render as the same flat `key = value` text `from_text` accepts.
    pub fn to_text(&self) -> String {
        format!(
            "# AIPerf benchmark configuration (Table 5 defaults)\n\
             nodes = {}\n\
             gpus_per_node = {}\n\
             batch_per_gpu = {}\n\
             learning_rate = {}\n\
             lr_decay_per_epoch = {}\n\
             patience = {}\n\
             min_delta = {}\n\
             duration_hours = {}\n\
             telemetry_interval_s = {}\n\
             score_interval_s = {}\n\
             seed = {}\n\
             precision_bits = {}\n\
             max_params = {}\n\
             max_depth = {}\n\
             max_width = {}\n\
             warmup_first_epochs = {}\n\
             warmup_step_epochs = {}\n\
             max_epochs = {}\n\
             hpo_start_round = {}\n\
             gpu_sustained_flops = {:e}\n\
             gpu_memory_gb = {}\n\
             gpu_util_half_batch = {}\n\
             gpu_util_max = {}\n\
             gpu_step_overhead_s = {}\n\
             engine = {}\n\
             sync_interval_s = {}\n",
            self.nodes,
            self.node.gpus_per_node,
            self.batch_per_gpu,
            self.learning_rate,
            self.lr_decay_per_epoch,
            self.patience,
            self.min_delta,
            self.duration_s / 3600.0,
            self.telemetry_interval_s,
            self.score_interval_s,
            self.seed,
            self.precision_bits,
            self.morph_limits.max_params,
            self.morph_limits.max_depth,
            self.morph_limits.max_width,
            self.warmup.first_epochs,
            self.warmup.step_epochs,
            self.warmup.max_epochs,
            self.warmup.hpo_start_round,
            self.node.gpu.sustained_flops,
            self.node.gpu.memory_bytes / (1 << 30),
            self.node.gpu.util_half_batch,
            self.node.gpu.util_max,
            self.node.gpu.step_overhead_s,
            self.engine.as_str(),
            self.sync_interval_s,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_schedule_matches_paper() {
        let w = WarmupSchedule::default();
        assert_eq!(w.epochs_for_round(1), 10);
        assert_eq!(w.epochs_for_round(2), 30);
        assert_eq!(w.epochs_for_round(3), 50);
        assert_eq!(w.epochs_for_round(4), 70);
        assert_eq!(w.epochs_for_round(5), 90);
        assert_eq!(w.epochs_for_round(9), 90); // capped
        assert!(!w.hpo_active(4));
        assert!(w.hpo_active(5));
    }

    #[test]
    fn default_config_valid_and_matches_table5() {
        let c = BenchmarkConfig::default();
        c.validate().unwrap();
        assert_eq!(c.batch_per_gpu, 448);
        assert_eq!(c.learning_rate, 0.1);
        assert_eq!(c.total_gpus(), 16);
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = BenchmarkConfig::default();
        c.nodes = 0;
        assert!(c.validate().is_err());

        let mut c = BenchmarkConfig::default();
        c.precision_bits = 8;
        assert!(c.validate().is_err());

        let mut c = BenchmarkConfig::default();
        c.duration_s = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn text_roundtrip() {
        let mut c = BenchmarkConfig::default();
        c.nodes = 7;
        c.seed = 99;
        c.duration_s = 4.5 * 3600.0;
        let s = c.to_text();
        let c2 = BenchmarkConfig::from_text(&s).unwrap();
        assert_eq!(c2.nodes, 7);
        assert_eq!(c2.seed, 99);
        assert!((c2.duration_s - c.duration_s).abs() < 1.0);
        assert_eq!(c2.batch_per_gpu, c.batch_per_gpu);
        assert_eq!(c2.warmup, c.warmup);
    }

    #[test]
    fn text_parse_errors_are_reported() {
        assert!(BenchmarkConfig::from_text("nodes = two").is_err());
        assert!(BenchmarkConfig::from_text("bogus_key = 1").is_err());
        assert!(BenchmarkConfig::from_text("no equals sign").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ok() {
        let c = BenchmarkConfig::from_text("# comment\n\nnodes = 4 # inline\n").unwrap();
        assert_eq!(c.nodes, 4);
    }

    #[test]
    fn engine_parses_and_roundtrips() {
        let c = BenchmarkConfig::from_text("engine = sequential\nsync_interval_s = 120\n")
            .unwrap();
        assert_eq!(c.engine, Engine::Sequential);
        assert_eq!(c.sync_interval_s, 120.0);
        let c2 = BenchmarkConfig::from_text(&c.to_text()).unwrap();
        assert_eq!(c2.engine, Engine::Sequential);
        assert_eq!(c2.sync_interval_s, 120.0);
        assert!(BenchmarkConfig::from_text("engine = turbo\n").is_err());
    }

    #[test]
    fn sync_interval_validated() {
        let mut c = BenchmarkConfig::default();
        c.sync_interval_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn nan_intervals_rejected() {
        for field in 0..4 {
            let mut c = BenchmarkConfig::default();
            match field {
                0 => c.sync_interval_s = f64::NAN,
                1 => c.score_interval_s = f64::NAN,
                2 => c.telemetry_interval_s = f64::NAN,
                _ => c.duration_s = f64::NAN,
            }
            assert!(c.validate().is_err(), "field {field}: NaN passed validation");
        }
    }
}
