//! End-to-end real-training driver — proves all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e
//! ```
//!
//! Loads the AOT artifacts (L2 JAX model calling the L1 Pallas conv2d
//! kernel, lowered to HLO text), compiles them on the PJRT CPU client from
//! rust (L3), trains the default variant for a few hundred steps on the
//! synthetic corpus, logs the loss curve, evaluates held-out accuracy, and
//! reports the AIPerf scores for the work performed. Python is never
//! touched at runtime. The run is recorded in EXPERIMENTS.md §E2E.

use aiperf::coordinator::live::variant_layers;
use aiperf::data::SyntheticDataset;
use aiperf::flops::{graph_ops_per_image, OpWeights};
use aiperf::metrics::score::regulated_score;
use aiperf::runtime::{Manifest, Runtime, Trainer};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let mut rt = Runtime::cpu()?;
    println!(
        "runtime: platform={} variants={} default={}",
        rt.platform(),
        manifest.variants.len(),
        manifest.default_variant
    );

    let variant = manifest.default_variant().clone();
    let mut trainer = Trainer::new(&mut rt, &manifest, &variant.name)?;
    println!(
        "variant {}: {} params in {} slots, batch {}",
        variant.name,
        variant.total_param_elems(),
        variant.num_params(),
        variant.batch
    );

    let data = SyntheticDataset::new(
        0,
        variant.image as usize,
        variant.channels as usize,
        variant.num_classes as usize,
    );

    // A few hundred steps with the paper's decaying learning-rate schedule
    // (Table 5: lr 0.1, decay per epoch).
    let steps: u64 = 300;
    let steps_per_epoch: u64 = 25;
    let b = variant.batch as usize;
    let started = std::time::Instant::now();
    let mut curve = Vec::new();
    for step in 0..steps {
        let epoch = step / steps_per_epoch;
        let lr = 0.08 * (1.0 - 0.1 * epoch as f32 / 12.0).max(0.2);
        let (xs, ys) = data.batch(step * b as u64, b);
        let loss = trainer.train_step(&xs, &ys, lr)?;
        curve.push(loss);
        if step % 25 == 0 || step == steps - 1 {
            println!("step {step:>4}  epoch {epoch:>2}  loss {loss:.4}");
        }
    }
    let train_s = started.elapsed().as_secs_f64();

    // Held-out evaluation (indices far beyond the training range).
    let (val_loss, val_acc) = trainer.evaluate(&data, 10_000_000, 8)?;
    println!("\nheld-out: loss={val_loss:.4} accuracy={val_acc:.4} (chance=0.1)");

    // Loss-curve and generalization checks: the E2E claim is that the
    // compiled three-layer stack actually LEARNS.
    let first: f32 = curve[..10].iter().sum::<f32>() / 10.0;
    let last: f32 = curve[curve.len() - 10..].iter().sum::<f32>() / 10.0;
    println!("loss curve: first10={first:.3} last10={last:.3}");
    assert!(last < first * 0.5, "loss did not halve — training broken");
    assert!(val_acc > 0.5, "held-out accuracy {val_acc} not above 0.5");

    // AIPerf accounting for the work performed (Equation 4).
    let ops_per_image = graph_ops_per_image(&variant_layers(&variant), &OpWeights::default());
    let images = steps as f64 * variant.batch as f64;
    let total_ops = ops_per_image.train_per_image() as f64 * images;
    let flops = total_ops / train_s;
    println!(
        "\nAIPerf accounting: {:.3e} analytical ops in {:.1}s → {:.3} GFLOPS",
        total_ops,
        train_s,
        flops / 1e9
    );
    println!(
        "regulated score: {:.3} GFLOPS",
        regulated_score(1.0 - val_acc as f64, flops) / 1e9
    );
    println!("\ntrain_e2e OK — L1 (Pallas) + L2 (JAX) + L3 (rust/PJRT) compose");
    Ok(())
}
