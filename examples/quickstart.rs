//! Quickstart: run a small simulated AIPerf benchmark and read the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the two-node, two-hour version of the paper's evaluation
//! protocol (§5): slave nodes search architectures by network morphism,
//! train them (modelled V100 cluster), and the toolkit reports the FLOPS
//! score, the achieved error, and the regulated score.

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;

fn main() {
    let mut cfg = BenchmarkConfig::homogeneous(2);
    cfg.duration_s = 2.0 * 3600.0;
    cfg.seed = 42;
    println!(
        "AIPerf quickstart: {} ({:.0} h budget)",
        cfg.topology.summary(),
        cfg.duration_s / 3600.0
    );

    let report = run_benchmark(&cfg);

    println!("\n== result ==\n{}", report.summary());
    println!("\nhourly samples:");
    for s in &report.score_series {
        println!(
            "  t={:>4.1}h  score={:.4} PFLOPS  best_error={:.3}  regulated={:.4} PFLOPS",
            s.t / 3600.0,
            s.flops / 1e15,
            s.best_error,
            s.regulated / 1e15
        );
    }
    println!("\ntelemetry (last sample):");
    if let Some(t) = report.telemetry.last() {
        println!(
            "  gpu {:.1}%±{:.1}  gpu-mem {:.1}%  cpu {:.1}%  host-mem {:.1}%",
            t.gpu_util_mean * 100.0,
            t.gpu_util_std * 100.0,
            t.gpu_mem_mean * 100.0,
            t.cpu_util_mean * 100.0,
            t.host_mem_mean * 100.0
        );
    }
    println!(
        "\nNFS traffic: {:.1} MB read, {:.1} MB written",
        report.nfs_bytes_read as f64 / 1e6,
        report.nfs_bytes_written as f64 / 1e6
    );
}
