//! Scalability sweep — the paper's §5.2 evaluation (Figs 4/5/6).
//!
//! Runs the full 12-hour benchmark at 2/4/8/16 slave nodes (8 GPUs each)
//! and reports, per scale: the stable-window score, the achieved error,
//! the regulated score, and the architectures-searched count. Asserts the
//! paper's headline shape claims:
//!
//! * score scales linearly with nodes (R² > 0.99);
//! * regulated score scales linearly;
//! * every scale meets the 35 % error-validity requirement;
//! * architectures searched ≈ paper's cadence (96 at 16 nodes / 12 h).

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::util::stats::r_squared;

fn main() {
    let scales = [2u64, 4, 8, 16];
    println!("AIPerf scalability sweep: 12 h at {scales:?} nodes × 8 GPUs\n");
    println!(
        "{:>6} {:>6} {:>14} {:>12} {:>16} {:>8}",
        "nodes", "gpus", "score PFLOPS", "error %", "regulated PFLOPS", "archs"
    );

    let mut xs = Vec::new();
    let mut scores = Vec::new();
    let mut regulated = Vec::new();
    let mut archs_at_16 = 0;
    for &nodes in &scales {
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = 12.0 * 3600.0;
        let r = run_benchmark(&cfg);
        println!(
            "{:>6} {:>6} {:>14.4} {:>12.1} {:>16.4} {:>8}",
            nodes,
            nodes * 8,
            r.score_flops / 1e15,
            r.final_error * 100.0,
            r.regulated_score / 1e15,
            r.architectures_evaluated
        );
        assert!(
            r.final_error < 0.35,
            "validity: error {:.3} exceeds 35 % at {nodes} nodes",
            r.final_error
        );
        xs.push(nodes as f64);
        scores.push(r.score_flops);
        regulated.push(r.regulated_score);
        if nodes == 16 {
            archs_at_16 = r.architectures_evaluated;
        }
    }

    let r2_score = r_squared(&xs, &scores);
    let r2_reg = r_squared(&xs, &regulated);
    println!("\nlinearity: score R²={r2_score:.5}  regulated R²={r2_reg:.5}");
    assert!(r2_score > 0.99, "score not linear in nodes (R²={r2_score})");
    assert!(r2_reg > 0.95, "regulated score not linear (R²={r2_reg})");

    println!("architectures at 16 nodes / 12 h: {archs_at_16} (paper: 96)");
    assert!(
        (48..=192).contains(&archs_at_16),
        "search cadence far from the paper's 96"
    );
    println!("\nscalability sweep OK — Fig 4/5/6 shape claims hold");
}
