//! HPO method comparison — the paper's Appendix A selection study (Fig 7b).
//!
//! Reruns the experiment that made AIPerf fix TPE as its HPO method: four
//! optimizers (TPE, random, grid, evolutionary) each tune (dropout, kernel)
//! against the accuracy surrogate's CIFAR10-scale objective under the same
//! trial budget; the best validation accuracy per method is reported. The
//! paper finds "the TPE method results in slightly better accuracy".

use aiperf::hpo::{aiperf_space, build, Backend, Optimizer};
use aiperf::sim::accuracy::{AccuracySurrogate, HpPoint};
use aiperf::util::rng::derive;

/// The paper's toy setup: one GPU, 48 h, CIFAR10 — here the surrogate's
/// converged accuracy of a fixed CIFAR-scale architecture (≈1 M params)
/// under the candidate hyperparameters.
fn objective(sur: &AccuracySurrogate, cfg: &[f64]) -> f64 {
    let hp = HpPoint {
        dropout: cfg[0],
        kernel: cfg[1],
    };
    // 60-epoch training (Appendix A's warm-up cap), fixed architecture.
    1.0 - sur.accuracy(1, 1_000_000, &hp, 60)
}

fn run(name: &str, opt: &mut dyn Optimizer, trials: usize, seed: u64) -> f64 {
    let sur = AccuracySurrogate {
        seed: 7,
        ..AccuracySurrogate::default()
    };
    let mut rng = derive(seed, name, 0);
    for _ in 0..trials {
        let cfg = opt.suggest(&mut rng);
        let loss = objective(&sur, &cfg);
        opt.observe(cfg, loss);
    }
    1.0 - opt.best().map(|o| o.loss).unwrap_or(1.0)
}

fn main() {
    let trials = 32; // ≈ one 48-hour single-GPU budget at 90 min/trial
    let repeats = 8;
    println!("HPO method comparison (Fig 7b): {trials} trials × {repeats} seeds\n");

    let mut means = Vec::new();
    for (name, kind) in [
        ("TPE", Backend::Tpe),
        ("random", Backend::Random),
        ("grid", Backend::Grid),
        ("evolutionary", Backend::Evolutionary),
    ] {
        let mut accs = Vec::new();
        for seed in 0..repeats {
            // Built through the engine's own `hpo::build` factory, so the
            // study compares exactly what an `hpo = ...` run would use.
            let mut opt = build(kind, aiperf_space(), seed);
            accs.push(run(name, opt.as_mut(), trials, seed));
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let best = accs.iter().cloned().fold(f64::MIN, f64::max);
        println!("{name:>14}: mean best-accuracy {mean:.4}  (max {best:.4})");
        means.push((name, mean));
    }

    let tpe = means.iter().find(|(n, _)| *n == "TPE").unwrap().1;
    let others_max = means
        .iter()
        .filter(|(n, _)| *n != "TPE")
        .map(|(_, m)| *m)
        .fold(f64::MIN, f64::max);
    println!("\nTPE {tpe:.4} vs best-other {others_max:.4}");
    assert!(
        tpe >= others_max - 0.002,
        "TPE not competitive — Fig 7b shape violated"
    );
    println!("hpo_compare OK — TPE wins (or ties), as the paper reports");
}
