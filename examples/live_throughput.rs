//! Live train-step throughput probe (EXPERIMENTS.md §Perf/L2).
//!
//! Measures steps/s of the real PJRT training loop per compiled variant —
//! the number the §Perf log tracks across L2 lowering changes (e.g. the
//! reverted donate_argnums experiment).
//!
//! ```bash
//! make artifacts && cargo run --release --example live_throughput
//! ```

fn main() -> anyhow::Result<()> {
    let m = aiperf::runtime::Manifest::load("artifacts")?;
    let mut rt = aiperf::runtime::Runtime::cpu()?;
    for name in ["d2w8k3i16b32", "d4w16k3i16b32"] {
        if m.variant(name).is_none() {
            eprintln!("variant {name} not in manifest; skipping");
            continue;
        }
        let mut t = aiperf::runtime::Trainer::new(&mut rt, &m, name)?;
        let v = t.variant.clone();
        let d = aiperf::data::SyntheticDataset::new(
            0,
            v.image as usize,
            v.channels as usize,
            v.num_classes as usize,
        );
        let (xs, ys) = d.batch(0, v.batch as usize);
        // Warm-up (first steps include compile/alloc effects).
        for _ in 0..5 {
            t.train_step(&xs, &ys, 0.05)?;
        }
        let n = 60;
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            t.train_step(&xs, &ys, 0.05)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{name}: {:.2} steps/s ({:.2} ms/step, batch {})",
            n as f64 / dt,
            dt / n as f64 * 1e3,
            v.batch
        );
    }
    Ok(())
}
