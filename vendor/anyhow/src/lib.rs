//! Minimal offline-vendored subset of the `anyhow` API.
//!
//! The build runs without network access to crates.io, so the small slice
//! of `anyhow` this project uses is vendored in-tree: `Error`, `Result`,
//! the `Context` extension trait for `Result`/`Option`, and the
//! `anyhow!`/`bail!`/`ensure!` macros. Semantics follow upstream where the
//! project relies on them: `Display` shows the outermost context, `Debug`
//! shows the whole cause chain, and `?` converts any
//! `std::error::Error + Send + Sync + 'static` into `Error`.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a stack of human-readable context messages.
pub struct Error {
    /// Context layers, outermost first.
    context: Vec<String>,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error {
            context: Vec::new(),
            source: Box::new(MessageError(message.to_string())),
        }
    }

    /// Wrap the error in an additional layer of context.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.context.insert(0, context.to_string());
        self
    }

    /// The lowest-level (root) cause.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = self.source.as_ref();
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.first() {
            Some(outer) => f.write_str(outer),
            None => write!(f, "{}", self.source),
        }
    }
}

fn print_cause(
    f: &mut fmt::Formatter<'_>,
    printed_header: &mut bool,
    cause: &dyn Display,
) -> fmt::Result {
    if !*printed_header {
        write!(f, "\n\nCaused by:")?;
        *printed_header = true;
    }
    write!(f, "\n    {cause}")
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)?;
        let mut printed_header = false;
        for layer in self.context.iter().skip(1) {
            print_cause(f, &mut printed_header, layer)?;
        }
        if !self.context.is_empty() {
            print_cause(f, &mut printed_header, &self.source)?;
        }
        let mut cause: &(dyn StdError + 'static) = self.source.as_ref();
        while let Some(next) = cause.source() {
            print_cause(f, &mut printed_header, &next)?;
            cause = next;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error {
            context: Vec::new(),
            source: Box::new(e),
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.root_cause().to_string(), "missing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("layer1")
            .map_err(|e| e.context("layer0"))
            .unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("layer0"));
        assert!(dbg.contains("layer1"));
        assert!(dbg.contains("missing"));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("ok").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag {fail} was set");
            if fail {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(inner(true).unwrap_err().to_string(), "flag true was set");
        let s = String::from("stringy");
        assert_eq!(anyhow!(s).to_string(), "stringy");
        assert_eq!(anyhow!("x={}", 3).to_string(), "x=3");
    }

    #[test]
    fn question_mark_converts() {
        fn run() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(run().is_err());
    }
}
