"""AOT pipeline: artifacts are emitted, parseable, and ABI-consistent."""

import json
import os

import pytest

from compile.aot import QUICK_GRID, lower_variant, main, to_hlo_text
from compile.model import ModelSpec, param_layout


def test_lower_variant_emits_all_files(tmp_path):
    spec = QUICK_GRID[0]
    entry = lower_variant(spec, str(tmp_path), seed=0)
    for kind in ("init", "train", "eval"):
        path = tmp_path / entry["files"][kind]
        assert path.exists()
        text = path.read_text()
        assert text.startswith("HloModule"), f"{kind} is not HLO text"
        # The 0.5.1 text parser requires ids to fit in 32 bits after
        # reassignment; plain text has no explicit id fields to reject.
        assert "ENTRY" in text


def test_manifest_entry_matches_param_layout(tmp_path):
    spec = ModelSpec(depth=2, width=8)
    entry = lower_variant(spec, str(tmp_path), seed=1)
    layout = param_layout(spec)
    assert len(entry["params"]) == len(layout)
    for rec, (name, shape) in zip(entry["params"], layout):
        assert rec["name"] == name
        assert tuple(rec["shape"]) == tuple(shape)


def test_main_quick_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    main(["--out", out, "--quick"])
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["schema"] == 1
    assert manifest["default_variant"] == manifest["variants"][0]["name"]
    for v in manifest["variants"]:
        for kind in ("init", "train", "eval"):
            assert os.path.exists(os.path.join(out, v["files"][kind]))


def test_hlo_text_mentions_entry_tuple(tmp_path):
    """Lowering uses return_tuple=True — the rust loader unwraps a tuple."""
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((2,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "tuple" in text
