"""L2 correctness: model family shapes, training dynamics, ABI invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.dataset import make_batch
from compile.model import (
    ModelSpec,
    accuracy,
    eval_step,
    forward,
    init_params,
    loss_fn,
    param_layout,
    train_step,
)


def _batch(spec: ModelSpec, seed=0, start=0):
    xs, ys = make_batch(seed, start, spec.batch, spec.image, spec.channels,
                        spec.num_classes)
    return jnp.asarray(xs), jnp.asarray(ys)


@settings(max_examples=8, deadline=None)
@given(
    depth=st.integers(1, 4),
    width=st.sampled_from([4, 8, 16]),
    kernel=st.sampled_from([1, 3, 5]),
    image=st.sampled_from([8, 16]),
)
def test_forward_shape(depth, width, kernel, image):
    spec = ModelSpec(depth=depth, width=width, kernel=kernel, image=image,
                     batch=2)
    params = init_params(spec)
    x = jnp.zeros((2, image, image, 3), jnp.float32)
    logits = forward(spec, params, x)
    assert logits.shape == (2, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_layout_matches_init():
    spec = ModelSpec(depth=3, width=8)
    layout = param_layout(spec)
    params = init_params(spec)
    assert len(layout) == len(params)
    for (name, shape), p in zip(layout, params):
        assert tuple(shape) == p.shape, name
        assert p.dtype == jnp.float32


def test_param_layout_counts():
    """Slots: 3 stem + 3/block + 2 head — the rust ABI depends on this."""
    for depth in (1, 2, 5):
        spec = ModelSpec(depth=depth)
        assert len(param_layout(spec)) == 3 + 3 * depth + 2


def test_init_deterministic_per_seed():
    spec = ModelSpec(depth=2, width=8)
    a = init_params(spec, seed=7)
    b = init_params(spec, seed=7)
    c = init_params(spec, seed=8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_loss_positive_and_near_lnc_at_init():
    """At init, CE loss should be near ln(num_classes) (uninformed model)."""
    spec = ModelSpec(depth=2, width=8, image=8, batch=16)
    params = init_params(spec)
    x, y = _batch(spec)
    loss = float(loss_fn(spec, params, x, y))
    assert 0.5 * np.log(10) < loss < 5 * np.log(10)


def test_train_step_decreases_loss():
    spec = ModelSpec(depth=2, width=8, image=8, batch=16)
    params = init_params(spec)
    moms = [jnp.zeros_like(p) for p in params]
    x, y = _batch(spec)
    lr = jnp.float32(0.05)
    l0 = float(loss_fn(spec, params, x, y))
    for _ in range(20):
        params, moms, loss = train_step(spec, params, moms, x, y, lr)
    l1 = float(loss_fn(spec, params, x, y))
    assert l1 < l0 * 0.8, (l0, l1)


def test_train_improves_accuracy_on_heldout():
    """A few epochs on the synthetic corpus must beat chance on fresh data —
    the end-to-end learnability guarantee train_e2e.rs relies on."""
    spec = ModelSpec(depth=2, width=8, image=8, batch=32, num_classes=4)
    params = init_params(spec)
    moms = [jnp.zeros_like(p) for p in params]
    step = jax.jit(lambda p, m, x, y: train_step(spec, p, m, x, y, jnp.float32(0.05)))
    for i in range(30):
        x, y = _batch(spec, seed=0, start=i * spec.batch)
        params, moms, _ = step(params, moms, x, y)
    xh, yh = _batch(spec, seed=0, start=10_000)
    acc = float(accuracy(spec, params, xh, yh))
    assert acc > 0.5, acc  # chance = 0.25


def test_eval_step_bounds():
    spec = ModelSpec(depth=1, width=4, image=8, batch=8)
    params = init_params(spec)
    x, y = _batch(spec)
    loss, acc = eval_step(spec, params, x, y)
    assert float(loss) > 0
    assert 0.0 <= float(acc) <= 1.0


def test_momentum_update_matches_manual():
    """One train_step equals the hand-computed SGD+momentum update."""
    from compile.model import MOMENTUM, WEIGHT_DECAY

    spec = ModelSpec(depth=1, width=4, image=8, batch=4)
    params = init_params(spec)
    moms = [jnp.ones_like(p) * 0.01 for p in params]
    x, y = _batch(spec)
    lr = jnp.float32(0.1)
    grads = jax.grad(lambda p: loss_fn(spec, p, x, y))(params)
    got_p, got_m, _ = train_step(spec, params, moms, x, y, lr)
    for p, v, g, gp, gm in zip(params, moms, grads, got_p, got_m):
        v2 = MOMENTUM * v + g + WEIGHT_DECAY * p
        np.testing.assert_allclose(gm, v2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gp, p - lr * v2, rtol=1e-5, atol=1e-6)


def test_variant_name_roundtrip():
    spec = ModelSpec(depth=4, width=16, kernel=3, image=16, batch=32)
    assert spec.name == "d4w16k3i16b32"
