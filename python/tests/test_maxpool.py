"""L1 correctness: Pallas maxpool2x2 vs the lax reduce_window oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.maxpool import maxpool2x2
from compile.kernels import ref

import pytest


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([2, 4, 6, 8, 12]),
    c=st.integers(1, 8),
)
def test_forward_matches_lax(b, hw, c):
    x = _rand(0, (b, hw, hw, c))
    got = maxpool2x2(x)
    want = ref.maxpool2x2(x)
    assert got.shape == (b, hw // 2, hw // 2, c)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=8, deadline=None)
@given(hw=st.sampled_from([2, 4, 8]), c=st.integers(1, 4))
def test_gradient_matches_lax(hw, c):
    # Continuous random inputs: ties have measure zero, so the mask-based
    # VJP must agree exactly with lax's reduce_window gradient.
    x = _rand(1, (2, hw, hw, c))
    f = lambda x: jnp.sum(jnp.sin(maxpool2x2(x)))
    g = lambda x: jnp.sum(jnp.sin(ref.maxpool2x2(x)))
    np.testing.assert_allclose(jax.grad(f)(x), jax.grad(g)(x), rtol=1e-5, atol=1e-6)


def test_rectangular_input():
    x = _rand(2, (1, 4, 8, 3))
    np.testing.assert_array_equal(maxpool2x2(x), ref.maxpool2x2(x))


def test_odd_dims_rejected():
    with pytest.raises(ValueError, match="even spatial"):
        maxpool2x2(jnp.zeros((1, 5, 4, 1)))


def test_pool_selects_window_max():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    y = maxpool2x2(x)
    np.testing.assert_array_equal(
        y[0, :, :, 0], jnp.array([[5.0, 7.0], [13.0, 15.0]])
    )
