"""L1 correctness: Pallas conv2d vs pure-jnp oracles.

This is the CORE correctness signal for the compiled artifacts: everything
the rust runtime executes flows through this kernel. hypothesis sweeps the
shape/dtype space (batch, spatial, channels, kernel size — odd AND even)
and asserts allclose against two structurally independent references.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, mxu_utilization_estimate, vmem_bytes
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.integers(3, 12),
    ci=st.integers(1, 8),
    co=st.integers(1, 16),
    k=st.integers(1, 5),
)
def test_forward_matches_lax(b, hw, ci, co, k):
    x = _rand(0, (b, hw, hw, ci))
    w = _rand(1, (k, k, ci, co))
    got = conv2d(x, w)
    want = ref.conv2d(x, w)
    assert got.shape == want.shape == (b, hw, hw, co)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    hw=st.integers(3, 10),
    ci=st.integers(1, 6),
    co=st.integers(1, 12),
    k=st.integers(1, 4),
)
def test_forward_matches_naive_im2col(hw, ci, co, k):
    x = _rand(2, (2, hw, hw, ci))
    w = _rand(3, (k, k, ci, co))
    np.testing.assert_allclose(
        conv2d(x, w), ref.conv2d_naive(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 8e-2)])
def test_forward_dtypes(dtype, tol):
    x = _rand(4, (2, 8, 8, 3), dtype)
    w = _rand(5, (3, 3, 3, 8), dtype)
    got = conv2d(x, w).astype(jnp.float32)
    want = ref.conv2d(x.astype(jnp.float32), w.astype(jnp.float32))
    assert conv2d(x, w).dtype == dtype
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_forward_rectangular_kernel():
    x = _rand(6, (1, 7, 7, 2))
    w = _rand(7, (2, 5, 2, 3))
    np.testing.assert_allclose(conv2d(x, w), ref.conv2d(x, w), rtol=1e-4, atol=1e-4)


def test_identity_kernel_is_identity():
    """A 1×1 identity filter must reproduce the input exactly."""
    x = _rand(8, (2, 6, 6, 3))
    w = jnp.eye(3, dtype=jnp.float32).reshape(1, 1, 3, 3)
    np.testing.assert_allclose(conv2d(x, w), x, rtol=0, atol=0)


def test_linearity_in_input():
    """conv is linear: conv(a·x) == a·conv(x)."""
    x = _rand(9, (1, 5, 5, 2))
    w = _rand(10, (3, 3, 2, 4))
    np.testing.assert_allclose(
        conv2d(2.5 * x, w), 2.5 * conv2d(x, w), rtol=1e-5, atol=1e-5
    )


def test_channel_mismatch_raises():
    x = _rand(11, (1, 4, 4, 3))
    w = _rand(12, (3, 3, 2, 4))
    with pytest.raises(ValueError, match="channel mismatch"):
        conv2d(x, w)


# ---------------------------------------------------------------------------
# Backward pass (Equation 2 of the paper)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    hw=st.integers(3, 9),
    ci=st.integers(1, 5),
    co=st.integers(1, 8),
    k=st.integers(1, 4),
)
def test_gradients_match_lax(hw, ci, co, k):
    x = _rand(13, (2, hw, hw, ci))
    w = _rand(14, (k, k, ci, co))
    f = lambda x, w: jnp.sum(jnp.sin(conv2d(x, w)))
    g = lambda x, w: jnp.sum(jnp.sin(ref.conv2d(x, w)))
    dx1, dw1 = jax.grad(f, (0, 1))(x, w)
    dx2, dw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(dx1, dx2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dw1, dw2, rtol=1e-3, atol=1e-3)


def test_gradient_under_jit():
    x = _rand(15, (2, 6, 6, 3))
    w = _rand(16, (3, 3, 3, 4))
    f = jax.jit(jax.grad(lambda x, w: jnp.sum(conv2d(x, w) ** 2), (0, 1)))
    dx, dw = f(x, w)
    g = jax.grad(lambda x, w: jnp.sum(ref.conv2d(x, w) ** 2), (0, 1))
    dx2, dw2 = g(x, w)
    np.testing.assert_allclose(dx, dx2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dw, dw2, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Schedule analytics (consumed by EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------


def test_vmem_estimate_positive_and_monotone():
    small = vmem_bytes(16, 16, 8, 8, 3, 3)
    big = vmem_bytes(32, 32, 16, 16, 3, 3)
    assert 0 < small < big


def test_vmem_fits_16mib_for_compiled_grid():
    """Every variant in the AOT grid must fit a 16 MiB VMEM per grid step."""
    from compile.aot import DEFAULT_GRID

    for spec in DEFAULT_GRID:
        n = vmem_bytes(spec.image, spec.image, spec.width,
                       min(spec.width, 128), spec.kernel, spec.kernel)
        assert n < 16 * 1024 * 1024, spec.name


def test_mxu_utilization_bounds():
    u = mxu_utilization_estimate(16, 16, 16, 16, 3, 3)
    assert 0.0 < u <= 1.0
    # Perfectly aligned shapes → exactly 1.
    assert mxu_utilization_estimate(16, 8, 128 // 9 * 9, 128, 1, 1) <= 1.0
    assert mxu_utilization_estimate(128, 1, 128, 128, 1, 1) == 1.0
