"""Synthetic dataset: determinism, balance, learnable structure.

The splitmix64 counter generator here must stay bit-identical to
rust/src/data/synthetic.rs — test_golden_values pins golden numbers that the
rust side pins too (rust/src/data/synthetic.rs tests use the same values).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.dataset import _splitmix64, _unit, class_template, make_batch


def test_splitmix64_golden():
    """Golden values shared with rust/src/data/synthetic.rs."""
    assert _splitmix64(0) == 0xE220A8397B1DCDAF
    assert _splitmix64(1) == 0x910A2DEC89025CC1
    assert _splitmix64(0xDEADBEEF) == 0x4ADFB90F68C9EB9B


@given(st.integers(0, 2**63))
@settings(max_examples=50, deadline=None)
def test_unit_in_range(x):
    u = _unit(_splitmix64(x))
    assert 0.0 <= u < 1.0


def test_batch_deterministic():
    a = make_batch(3, 100, 8, 8, 3, 10)
    b = make_batch(3, 100, 8, 8, 3, 10)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_batches_disjoint_indices_differ():
    a, _ = make_batch(3, 0, 8, 8, 3, 10)
    b, _ = make_batch(3, 8, 8, 8, 3, 10)
    assert not np.array_equal(a, b)


def test_labels_roughly_balanced():
    _, ys = make_batch(0, 0, 512, 4, 1, 4)
    counts = np.bincount(ys, minlength=4)
    assert counts.min() > 512 / 4 * 0.5


def test_templates_distinct_across_classes():
    t0 = class_template(0, 0, 8, 3)
    t1 = class_template(0, 1, 8, 3)
    assert np.abs(t0 - t1).max() > 0.1


def test_template_amplitude_bounded():
    t = class_template(5, 2, 16, 3)
    assert np.abs(t).max() < 2.0
