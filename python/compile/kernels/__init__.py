"""L1 — Pallas kernels for AIPerf's compute ops (conv2d + max-pool)."""

from compile.kernels.conv2d import conv2d, mxu_utilization_estimate, vmem_bytes
from compile.kernels.maxpool import maxpool2x2

__all__ = ["conv2d", "maxpool2x2", "vmem_bytes", "mxu_utilization_estimate"]
