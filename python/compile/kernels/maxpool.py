"""L1 — Pallas 2×2/2 max-pooling kernel.

The second compute op of AIPerf's model family (every stage boundary pools
— Table 2's max-pooling row). Rethought for the TPU memory hierarchy like
the conv kernel: the grid is (batch,), each step loads one feature map
block into VMEM and reduces four strided views with vectorized maxima —
no gather, no window primitive, so interpret mode lowers to plain HLO.

Autodiff: interpret-mode ``pallas_call`` has no reverse rule, so the
public op carries a ``custom_vjp``; the backward pass routes the incoming
gradient to each window's argmax via an equality mask (ties broadcast the
gradient to every maximal element — measure-zero for continuous inputs,
validated against the lax oracle by hypothesis in
python/tests/test_maxpool.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref):
    """One grid step: pool one image block.

    x_ref: (1, H, W, C) with H, W even; o_ref: (1, H/2, W/2, C).
    """
    x = x_ref[0]
    a = x[0::2, 0::2, :]
    b = x[0::2, 1::2, :]
    c = x[1::2, 0::2, :]
    d = x[1::2, 1::2, :]
    o_ref[0] = jnp.maximum(jnp.maximum(a, b), jnp.maximum(c, d))


def _maxpool_impl(x: jax.Array) -> jax.Array:
    bsz, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even spatial dims, got {h}x{w}")
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(x)


@jax.custom_vjp
def maxpool2x2(x: jax.Array) -> jax.Array:
    """2×2 stride-2 max pooling over NHWC input (even H and W)."""
    return _maxpool_impl(x)


def _fwd(x):
    y = _maxpool_impl(x)
    return y, (x, y)


def _bwd(res, g):
    x, y = res
    # Route gradient to window maxima: upsample y and g back to the input
    # grid and mask where x attains the window max.
    up = lambda t: jnp.repeat(jnp.repeat(t, 2, axis=1), 2, axis=2)
    mask = (x == up(y)).astype(g.dtype)
    return (up(g) * mask,)


maxpool2x2.defvjp(_fwd, _bwd)
