"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with stock jax/lax ops only. pytest (python/tests/test_kernel.py) asserts
allclose between kernel and oracle across hypothesis-generated shapes and
dtypes — this is the L1 correctness signal gating `make artifacts`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME, stride-1 NHWC/HWIO convolution via lax.conv_general_dilated."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def conv2d_naive(x: jax.Array, w: jax.Array) -> jax.Array:
    """Second, independent oracle: explicit im2col in plain jnp.

    Slower but structurally unrelated to both the Pallas kernel's pallas_call
    machinery and XLA's conv lowering — guards against a shared-bug false
    pass between conv2d() above and the kernel.
    """
    b, h, width, ci = x.shape
    kh, kw, _, co = w.shape
    ph0, ph1 = (kh - 1) // 2, kh // 2
    pw0, pw1 = (kw - 1) // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i : i + h, j : j + width, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(b * h * width, kh * kw * ci)
    out = patches.astype(jnp.float32) @ w.reshape(kh * kw * ci, co).astype(jnp.float32)
    return out.reshape(b, h, width, co).astype(x.dtype)


def maxpool2x2(x: jax.Array) -> jax.Array:
    """2x2/2 max pooling via lax.reduce_window (oracle for the kernel)."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ).astype(x.dtype)
