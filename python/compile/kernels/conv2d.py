"""L1 — Pallas conv2d kernel (the benchmark's compute hot-spot).

AIPerf's workload is dominated by convolutions (Table 4: 7.71e9 of 7.81e9
FP ops in ResNet-50 are conv MACCs). The paper runs them through cuDNN on
V100; here the kernel is rethought for a TPU-style memory hierarchy:

* **im2col → matmul**: instead of CUDA per-thread accumulation, each grid
  step assembles an ``(H·W, K·K·Ci)`` patch matrix in VMEM and contracts it
  against a ``(K·K·Ci, Co_tile)`` weight tile — the MXU-friendly shape.
* **BlockSpec schedule**: the grid is ``(batch, Co_tiles)``; BlockSpec
  expresses the HBM→VMEM movement the paper delegated to cuDNN's implicit
  GEMM. Each step touches one padded image block and one weight tile, so
  VMEM residency is ``(H+K-1)(W+K-1)Ci + K²Ci·Co_t + H·W·Co_t`` floats.
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; interpret mode lowers to plain HLO so the same artifact runs
  under the rust runtime. Real-TPU numbers are estimated analytically in
  EXPERIMENTS.md §Perf.

Autodiff: interpret-mode ``pallas_call`` has no reverse-mode rule, so
``conv2d`` carries a ``jax.custom_vjp`` implementing the paper's Equation 2:

    ∂L/∂X = FullConvolution(flipped F, ∂L/∂O)   — routed through the SAME
                                                  Pallas kernel (swapped
                                                  padding, transposed filter)
    ∂L/∂F = Convolution(X, ∂L/∂O)               — the im2col-transpose
                                                  matmul, in plain jnp

Only stride-1 conv is provided; the model family downsamples with pooling
(AIPerf's morphism adds conv-BN-ReLU blocks, never strided convs).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _same_padding(kh: int, kw: int) -> Tuple[int, int, int, int]:
    """(top, bottom, left, right) for SAME stride-1 conv."""
    return (kh - 1) // 2, kh // 2, (kw - 1) // 2, kw // 2


def _conv2d_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    """One grid step: one padded image × one output-channel tile.

    x_ref: (1, H+kh-1, W+kw-1, Ci) padded input block in VMEM
    w_ref: (kh*kw*Ci, Co_t)        weight tile in VMEM
    o_ref: (1, H, W, Co_t)         output block
    """
    _, hp, wp, ci = x_ref.shape
    h = hp - kh + 1
    w = wp - kw + 1
    x = x_ref[0]
    # im2col: K·K statically-sliced shifted views, concatenated on the
    # channel axis. Static slices keep the kernel free of gather ops so the
    # whole body lowers to reshapes + one dot.
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[i : i + h, j : j + w, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(h * w, kh * kw * ci)
    # MXU-shaped contraction: (H·W, K²Ci) × (K²Ci, Co_t).
    out = jnp.dot(patches, w_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = out.reshape(h, w, -1).astype(o_ref.dtype)


def _conv2d_pallas(x: jax.Array, w2: jax.Array, *, kh: int, kw: int,
                   pad: Tuple[int, int, int, int], co_tile: int) -> jax.Array:
    """Raw Pallas conv: explicit padding, pre-flattened (K²Ci, Co) filter."""
    b, h, width, ci = x.shape
    co = w2.shape[1]
    pt, pb, pl_, pr = pad
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    ho = h + pt + pb - kh + 1
    wo = width + pl_ + pr - kw + 1
    grid = (b, co // co_tile)
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ho + kh - 1, wo + kw - 1, ci), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh * kw * ci, co_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co_tile), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, co), x.dtype),
        interpret=True,
    )(xp, w2)


def _pick_co_tile(co: int) -> int:
    """Largest divisor of Co that is ≤ 128 (the MXU lane width)."""
    if co <= 128:
        return co
    for t in range(128, 0, -1):
        if co % t == 0:
            return t
    return 1


@jax.custom_vjp
def conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME, stride-1 2-D convolution via the Pallas kernel.

    Args:
      x: (B, H, W, Ci) input, NHWC.
      w: (KH, KW, Ci, Co) filter, HWIO.

    Returns:
      (B, H, W, Co) output in x.dtype. Differentiable in both arguments
      (custom VJP, see module docstring).
    """
    return _conv2d_fwd_impl(x, w)


def _conv2d_fwd_impl(x: jax.Array, w: jax.Array) -> jax.Array:
    b, h, width, ci = x.shape
    kh, kw, wci, co = w.shape
    if wci != ci:
        raise ValueError(f"channel mismatch: input Ci={ci}, filter Ci={wci}")
    return _conv2d_pallas(
        x, w.reshape(kh * kw * ci, co), kh=kh, kw=kw,
        pad=_same_padding(kh, kw), co_tile=_pick_co_tile(co),
    )


def _conv2d_fwd(x, w):
    return _conv2d_fwd_impl(x, w), (x, w)


def _conv2d_bwd(res, g):
    """Equation 2 of the paper (backpropagation through a convolution)."""
    x, w = res
    kh, kw, ci, co = w.shape
    b, h, width, _ = x.shape
    pt, pb, pl_, pr = _same_padding(kh, kw)

    # ∂L/∂X = FullConv(flipped F, g): spatially flip, swap Ci/Co, and swap
    # the padding asymmetry (for odd K this is plain SAME; even K needs the
    # mirror). Routed through the same Pallas kernel.
    w_flip = w[::-1, ::-1].transpose(0, 1, 3, 2)  # (KH, KW, Co, Ci)
    dx = _conv2d_pallas(
        g, w_flip.reshape(kh * kw * co, ci), kh=kh, kw=kw,
        pad=(kh - 1 - pt, kh - 1 - pb, kw - 1 - pl_, kw - 1 - pr),
        co_tile=_pick_co_tile(ci),
    )

    # ∂L/∂F = Conv(X, g): im2col of the padded input, contracted against the
    # incoming gradient — one (K²Ci, B·H·W) × (B·H·W, Co) matmul.
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i : i + h, j : j + width, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(b * h * width, kh * kw * ci)
    g2 = g.reshape(b * h * width, co)
    dw = (patches.astype(jnp.float32).T @ g2.astype(jnp.float32)).reshape(
        kh, kw, ci, co
    )
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


def vmem_bytes(h: int, w: int, ci: int, co_tile: int, kh: int, kw: int,
               dtype_bytes: int = 4) -> int:
    """Analytical VMEM residency of one grid step (see module docstring).

    Used by EXPERIMENTS.md §Perf to check the schedule fits a 16 MiB VMEM.
    """
    x_blk = (h + kh - 1) * (w + kw - 1) * ci
    w_blk = kh * kw * ci * co_tile
    o_blk = h * w * co_tile
    patches = h * w * kh * kw * ci  # im2col scratch
    return (x_blk + w_blk + o_blk + patches) * dtype_bytes


def mxu_utilization_estimate(h: int, w: int, ci: int, co_tile: int,
                             kh: int, kw: int) -> float:
    """Fraction of MXU 128×128×128 tiles doing useful work for the inner dot.

    The contraction is (H·W, K²Ci) × (K²Ci, Co_t): each dim is padded up to
    a multiple of the systolic array edge; utilization is the ratio of real
    to padded volume. Purely analytical — interpret mode gives no TPU clock.
    """
    m, k, n = h * w, kh * kw * ci, co_tile
    pad = lambda d: ((d + 127) // 128) * 128
    return (m * k * n) / (pad(m) * pad(k) * pad(n))
