"""L2 — JAX model family + training step for the AIPerf workload.

AIPerf's NAS (network morphism) explores ResNet-style CNNs: every morph step
adds a *block* (conv + batch-norm + activation together, §4.1). This module
defines the statically-shaped family those architectures are projected onto
for real training, and the fused train/eval steps that `aot.py` lowers to
HLO text for the rust runtime.

Conventions shared with the rust side (rust/src/runtime/artifact.rs):

* parameters are a FLAT, ORDERED list of f32 arrays (manifest.json records
  name + shape per slot);
* train_step(*params, *momenta, x, y, lr) -> (*params', *momenta', loss);
* eval_step(*params, x, y) -> (loss, accuracy);
* init is lowered with NO inputs — the PRNG seed is baked at trace time, so
  the artifact is a pure constant producer.

The optimizer is SGD + momentum with decoupled weight decay (Table 5:
mom=0.9, decay=1e-4), loss is categorical cross-entropy. Dropout is omitted
from the compiled family (it needs a runtime PRNG stream); the dropout-rate
hyperparameter is exercised by the L3 accuracy surrogate instead —
documented in DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import conv2d, maxpool2x2

Params = List[jax.Array]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A point in the compiled-architecture grid (DESIGN.md §3).

    depth:   number of residual conv-BN-ReLU blocks after the stem
    width:   channel count of every block
    kernel:  conv kernel edge (K×K)
    image:   input spatial edge (square images)
    channels: input channels
    num_classes: classifier width
    batch:   per-device batch size baked into the artifact
    """

    depth: int = 3
    width: int = 16
    kernel: int = 3
    image: int = 16
    channels: int = 3
    num_classes: int = 10
    batch: int = 32

    @property
    def name(self) -> str:
        return f"d{self.depth}w{self.width}k{self.kernel}i{self.image}b{self.batch}"


# ---------------------------------------------------------------------------
# Parameter initialization (He et al. 2015, Table 5 "Initial weight")
# ---------------------------------------------------------------------------


def param_layout(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) for every parameter slot — the ABI with rust."""
    k, w, c = spec.kernel, spec.width, spec.channels
    layout: List[Tuple[str, Tuple[int, ...]]] = [
        ("stem/conv", (k, k, c, w)),
        ("stem/bn_scale", (w,)),
        ("stem/bn_offset", (w,)),
    ]
    for i in range(spec.depth):
        layout += [
            (f"block{i}/conv", (k, k, w, w)),
            (f"block{i}/bn_scale", (w,)),
            (f"block{i}/bn_offset", (w,)),
        ]
    layout += [
        ("head/dense_w", (w, spec.num_classes)),
        ("head/dense_b", (spec.num_classes,)),
    ]
    return layout


def init_params(spec: ModelSpec, seed: int = 0) -> Params:
    """He-normal conv/dense weights, unit BN scale, zero offsets/bias."""
    key = jax.random.PRNGKey(seed)
    params: Params = []
    for name, shape in param_layout(spec):
        key, sub = jax.random.split(key)
        if name.endswith("/conv"):
            fan_in = shape[0] * shape[1] * shape[2]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        elif name.endswith("dense_w"):
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        elif name.endswith("bn_scale"):
            params.append(jnp.ones(shape, jnp.float32))
        else:  # bn_offset, dense_b
            params.append(jnp.zeros(shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _batch_norm(x: jax.Array, scale: jax.Array, offset: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    """Training-mode BN over (B, H, W) per channel (Ioffe & Szegedy 2015)."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + offset


def forward(spec: ModelSpec, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Logits for a batch of NHWC images.

    Topology mirrors the paper's morphism family: conv-BN-ReLU stem, `depth`
    residual conv-BN-ReLU blocks (identity skip — the morphism is
    function-preserving, so widths match by construction), max-pool halving
    mid-network, global average pool, dense head. Convolutions run through
    the L1 Pallas kernel so they lower into the same HLO artifact.
    """
    it = iter(params)
    nxt = lambda: next(it)

    h = conv2d(x, nxt())
    h = jax.nn.relu(_batch_norm(h, nxt(), nxt()))

    pool_at = spec.depth // 2
    for i in range(spec.depth):
        skip = h
        h = conv2d(h, nxt())
        h = _batch_norm(h, nxt(), nxt())
        h = jax.nn.relu(h + skip)  # Add layer (Table 2)
        if i == pool_at and h.shape[1] >= 2 and h.shape[1] % 2 == 0:
            h = maxpool2x2(h)  # L1 Pallas kernel (see kernels/maxpool.py)

    h = jnp.mean(h, axis=(1, 2))  # global average pool
    logits = h @ nxt() + nxt()
    # The ABI promises all slots consumed; guard against layout drift.
    try:
        next(it)
        raise ValueError("param layout longer than forward() consumes")
    except StopIteration:
        pass
    return logits


def loss_fn(spec: ModelSpec, params: Sequence[jax.Array], x: jax.Array,
            y: jax.Array) -> jax.Array:
    """Categorical cross-entropy (Table 5) over integer labels."""
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(spec: ModelSpec, params: Sequence[jax.Array], x: jax.Array,
             y: jax.Array) -> jax.Array:
    logits = forward(spec, params, x)
    return jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Train / eval steps (the units aot.py lowers)
# ---------------------------------------------------------------------------

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4


def train_step(spec: ModelSpec, params: Params, momenta: Params,
               x: jax.Array, y: jax.Array, lr: jax.Array
               ) -> Tuple[Params, Params, jax.Array]:
    """One SGD-momentum step (Qian 1999), Table 5 hyperparameters.

    v ← m·v + g + λ·θ ;  θ ← θ − lr·v
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(spec, p, x, y)
    )(list(params))
    new_params, new_momenta = [], []
    for p, v, g in zip(params, momenta, grads):
        v = MOMENTUM * v + g + WEIGHT_DECAY * p
        new_params.append(p - lr * v)
        new_momenta.append(v)
    return new_params, new_momenta, loss


def eval_step(spec: ModelSpec, params: Params, x: jax.Array, y: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """(loss, accuracy) on one validation batch."""
    return loss_fn(spec, params, x, y), accuracy(spec, params, x, y)
