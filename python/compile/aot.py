"""AOT lowering pipeline: JAX (L2, calling L1 Pallas) → artifacts/*.hlo.txt.

Runs once at build time (`make artifacts`); the rust runtime
(rust/src/runtime/) loads the HLO text via `HloModuleProto::from_text_file`
and executes it on the PJRT CPU client. Python is never on the request path.

Interchange format is **HLO text**, NOT `.serialize()` / serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/).

Per architecture variant (the compiled grid of DESIGN.md §3) we emit:

  init_<v>.hlo.txt        ()                      -> (params...,)
  train_<v>.hlo.txt       (params…, moms…, x, y, lr) -> (params…, moms…, loss)
  eval_<v>.hlo.txt        (params…, x, y)         -> (loss, accuracy)

plus a single artifacts/manifest.json describing the parameter ABI.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import (
    ModelSpec,
    eval_step,
    init_params,
    param_layout,
    train_step,
)

DEFAULT_GRID = [
    ModelSpec(depth=d, width=w)
    for d in (2, 3, 4)
    for w in (8, 16)
]
QUICK_GRID = [ModelSpec(depth=2, width=8)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_train(spec: ModelSpec, n: int):
    """train_step with a flat (params…, moms…, x, y, lr) signature."""

    def fn(*args):
        params = list(args[:n])
        moms = list(args[n : 2 * n])
        x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
        new_p, new_m, loss = train_step(spec, params, moms, x, y, lr)
        return tuple(new_p) + tuple(new_m) + (loss,)

    return fn


def _flat_eval(spec: ModelSpec, n: int):
    def fn(*args):
        params = list(args[:n])
        x, y = args[n], args[n + 1]
        return eval_step(spec, params, x, y)

    return fn


def lower_variant(spec: ModelSpec, out_dir: str, seed: int) -> dict:
    """Lower init/train/eval for one variant; return its manifest entry."""
    layout = param_layout(spec)
    n = len(layout)
    p_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in layout]
    x_spec = jax.ShapeDtypeStruct(
        (spec.batch, spec.image, spec.image, spec.channels), jnp.float32
    )
    y_spec = jax.ShapeDtypeStruct((spec.batch,), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    files = {}
    jobs = {
        "init": (lambda: tuple(init_params(spec, seed)), []),
        "train": (_flat_train(spec, n), p_specs + p_specs + [x_spec, y_spec, lr_spec]),
        "eval": (_flat_eval(spec, n), p_specs + [x_spec, y_spec]),
    }
    for kind, (fn, in_specs) in jobs.items():
        # Perf note (EXPERIMENTS.md §Perf/L2): donate_argnums on the
        # param/momentum inputs was tried and REVERTED — input-output
        # aliasing does not survive the HLO-text interchange (the 0.5.1
        # text parser drops alias metadata) and the donated lowering
        # measured 5-10 % slower through the rust runtime.
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{kind}_{spec.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
        print(f"  {fname}: {len(text)} chars")

    return {
        "name": spec.name,
        "depth": spec.depth,
        "width": spec.width,
        "kernel": spec.kernel,
        "image": spec.image,
        "channels": spec.channels,
        "num_classes": spec.num_classes,
        "batch": spec.batch,
        "seed": seed,
        "params": [
            {"name": name, "shape": list(shape)} for name, shape in layout
        ],
        "files": files,
    }


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--quick", action="store_true", help="single-variant grid")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    grid = QUICK_GRID if args.quick else DEFAULT_GRID
    entries = []
    for spec in grid:
        print(f"lowering {spec.name} …")
        entries.append(lower_variant(spec, args.out, args.seed))

    manifest = {
        "schema": 1,
        "default_variant": entries[0]["name"],
        "variants": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}/manifest.json ({len(entries)} variants)")


if __name__ == "__main__":
    main()
