"""Synthetic image corpus — the ImageNet stand-in (DESIGN.md §2).

The paper fixes ImageNet (1.28 M 224×224 RGB images) as the dataset; that is
a data gate here, so the real-training path uses a *procedurally generated*
classification corpus with a learnable class structure: each class is a
random smooth template (low-frequency Fourier mixture per channel) and every
sample is its template plus i.i.d. noise. A small CNN separates the classes
in a few hundred steps, which is exactly what `examples/train_e2e.rs` needs
to prove the three layers compose.

Determinism: the generator is a counter-based hash (splitmix64) over
(seed, class, index, pixel) — the SAME function is implemented in
rust/src/data/synthetic.rs so both sides can materialize identical batches
without shipping arrays through files.
"""

from __future__ import annotations

import numpy as np

_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def _unit(h: int) -> float:
    """Map a 64-bit hash to [0, 1)."""
    return (h >> 11) / float(1 << 53)


def class_template(seed: int, cls: int, image: int, channels: int) -> np.ndarray:
    """Smooth per-class template: sum of 4 low-frequency plane waves/channel."""
    tpl = np.zeros((image, image, channels), np.float32)
    yy, xx = np.mgrid[0:image, 0:image].astype(np.float32) / image
    for c in range(channels):
        for k in range(4):
            h = _splitmix64(seed * 1_000_003 + cls * 10_007 + c * 101 + k)
            fx = 1 + (h & 3)
            fy = 1 + ((h >> 2) & 3)
            phase = _unit(_splitmix64(h)) * 2 * np.pi
            amp = 0.5 + _unit(_splitmix64(h ^ 0xABCDEF)) * 0.5
            tpl[:, :, c] += amp * np.sin(
                2 * np.pi * (fx * xx + fy * yy) + phase
            ).astype(np.float32)
    return tpl / 4.0


def make_batch(seed: int, start_index: int, batch: int, image: int,
               channels: int, num_classes: int, noise: float = 0.35
               ) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic (x, y) batch; index space is the virtual dataset."""
    xs = np.empty((batch, image, image, channels), np.float32)
    ys = np.empty((batch,), np.int32)
    templates = [
        class_template(seed, c, image, channels) for c in range(num_classes)
    ]
    for i in range(batch):
        idx = start_index + i
        cls = _splitmix64(seed ^ (idx * 2 + 1)) % num_classes
        ys[i] = cls
        # Noise from the same counter hash, one draw per pixel.
        n = np.empty((image, image, channels), np.float32)
        flat = n.reshape(-1)
        base = _splitmix64(seed * 31 + idx)
        for j in range(flat.size):
            flat[j] = _unit(_splitmix64(base + j)) * 2.0 - 1.0
        xs[i] = templates[cls] + noise * n
    return xs, ys
