"""Build-time Python for the AIPerf reproduction.

Layers 1 (Pallas kernels) and 2 (JAX model family) live here together with
the AOT lowering pipeline. Nothing in this package is imported at runtime:
`make artifacts` runs it once, emits artifacts/*.hlo.txt + manifest.json,
and the rust binary is self-contained afterwards.
"""
