//! detlint against the actual repository: the tree must lint clean with
//! every exception pragma'd, and the acceptance drills must fail it —
//! re-introducing a HashMap in coordinator/, deleting any single
//! pragma, or adding a config key without to_text/USAGE.md coverage.

use std::path::PathBuf;

use detlint::{analyze, SourceFile};

fn tree() -> (Vec<SourceFile>, String) {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..");
    detlint::load_tree(&root).expect("load rust/src + USAGE.md")
}

#[test]
fn real_tree_is_clean() {
    let (files, usage) = tree();
    assert!(files.len() >= 30, "expected the aiperf tree, got {} files", files.len());
    let report = analyze(&files, &usage);
    let live: Vec<_> = report.unsuppressed().collect();
    assert!(
        !report.failed(),
        "tree must lint clean; unsuppressed findings: {live:#?}"
    );
    assert_eq!(
        report.advisory_count(),
        0,
        "advisories are pragma'd in-tree too: {live:#?}"
    );
    // The exception inventory is real: suppressions exist and every one
    // is justified (a justification-less pragma would be a bad_pragma
    // deny finding, caught above).
    assert!(
        report.suppressed_count() >= 10,
        "expected the in-tree pragma inventory, saw {}",
        report.suppressed_count()
    );
}

#[test]
fn reintroducing_a_hashmap_in_coordinator_fails() {
    let (mut files, usage) = tree();
    let f = files
        .iter_mut()
        .find(|f| f.rel == "coordinator/dispatcher.rs")
        .expect("dispatcher source");
    // The dispatcher's code is HashMap-free after the container swap
    // (the word may still appear in comments, which the scanner skips).
    let anchor = "BTreeMap<u64, usize>";
    assert!(f.text.contains(anchor), "dispatcher in_flight is a BTreeMap");
    f.text = f.text.replacen(anchor, "HashMap<u64, usize>", 1);
    let report = analyze(&files, &usage);
    assert!(report.failed());
    assert!(report.unsuppressed().any(|f| {
        f.rule == "unordered_collections" && f.file == "coordinator/dispatcher.rs"
    }));
}

#[test]
fn deleting_any_single_pragma_surfaces_its_findings() {
    let (files, usage) = tree();
    let mut pragma_sites = 0;
    for i in 0..files.len() {
        let lines: Vec<String> = files[i].text.lines().map(str::to_string).collect();
        for ln in 0..lines.len() {
            if !lines[ln].contains("detlint: allow") {
                continue;
            }
            pragma_sites += 1;
            let mut mutated = files.clone();
            mutated[i].text = lines
                .iter()
                .enumerate()
                .filter(|(k, _)| *k != ln)
                .map(|(_, s)| format!("{s}\n"))
                .collect();
            let report = analyze(&mutated, &usage);
            let site = format!("{}:{}", files[i].rel, ln + 1);
            if lines[ln].contains("(float_fold)") {
                // The one advisory-severity pragma: deleting it surfaces
                // the advisory (and only that) without failing the run.
                assert!(
                    !report.failed() && report.advisory_count() > 0,
                    "deleting advisory pragma at {site} must surface the advisory"
                );
            } else {
                assert!(
                    report.failed(),
                    "deleting pragma at {site} must make the lint exit non-zero"
                );
            }
        }
    }
    assert!(
        pragma_sites >= 12,
        "expected the tree's full pragma inventory, saw {pragma_sites}"
    );
}

#[test]
fn reintroducing_thread_scope_in_master_fails() {
    // The active-set refactor moved the coordinator's only thread use
    // into sim/pool.rs and widened THREAD_ALLOWED there instead of
    // leaving a pragma behind in master.rs — so any ad-hoc
    // `thread::scope` creeping back into the coordinator must be an
    // unsuppressed deny finding, not silently covered by a stale
    // exception.
    let (mut files, usage) = tree();
    // The pool is the rule-level exemption; it really does spawn.
    let pool = files
        .iter()
        .find(|f| f.rel == "sim/pool.rs")
        .expect("worker pool source");
    assert!(pool.text.contains("scope.spawn"), "pool spawns workers");
    let f = files
        .iter_mut()
        .find(|f| f.rel == "coordinator/master.rs")
        .expect("master source");
    assert!(
        !f.text.contains("detlint: allow(thread_spawn)"),
        "master.rs must not carry a thread_spawn pragma anymore"
    );
    f.text.push_str(
        "\nfn _detlint_drill() {\n    std::thread::scope(|_s| {});\n}\n",
    );
    let report = analyze(&files, &usage);
    assert!(report.failed());
    assert!(report
        .unsuppressed()
        .any(|f| f.rule == "thread_spawn" && f.file == "coordinator/master.rs"));
}

#[test]
fn adding_an_undocumented_config_key_fails() {
    let (mut files, usage) = tree();
    let f = files
        .iter_mut()
        .find(|f| f.rel == "config/mod.rs")
        .expect("config source");
    let anchor = "\"seed\" => cfg.seed = parse_u64(value)?,";
    assert!(f.text.contains(anchor), "seed key arm present");
    f.text = f.text.replacen(
        anchor,
        "\"seed\" => cfg.seed = parse_u64(value)?,\n                \
         \"zzz_new_knob\" => cfg.seed = parse_u64(value)?,",
        1,
    );
    let report = analyze(&files, &usage);
    assert!(report.failed());
    assert!(report
        .unsuppressed()
        .any(|f| f.rule == "knob_to_text" && f.message.contains("`zzz_new_knob`")));
    assert!(report
        .unsuppressed()
        .any(|f| f.rule == "knob_docs" && f.message.contains("`zzz_new_knob`")));
}

#[test]
fn real_config_knob_surface_passes_end_to_end() {
    // The knob-parity half of the acceptance criteria, isolated: with
    // only the knob inputs (config + CLI + USAGE.md), zero deny
    // findings survive — every key is emitted, documented, and either
    // CLI-named or explicitly flagless/pragma'd.
    let (files, usage) = tree();
    let subset: Vec<SourceFile> = files
        .into_iter()
        .filter(|f| f.rel == "config/mod.rs" || f.rel == "main.rs")
        .collect();
    assert_eq!(subset.len(), 2);
    let report = analyze(&subset, &usage);
    let knob_rules = ["knob_key", "knob_to_text", "knob_docs", "knob_cli"];
    let live: Vec<_> = report
        .unsuppressed()
        .filter(|f| knob_rules.contains(&f.rule))
        .collect();
    assert!(live.is_empty(), "knob parity must hold: {live:#?}");
}
