//! Scanner + rule fixtures: each rule fires exactly where expected and
//! nowhere else (strings, raw strings, nested comments, char literals
//! are opaque), pragmas suppress exactly one finding, and the
//! knob-parity cross-reference catches every drift class on a small
//! synthetic config surface.

use detlint::{analyze, Finding, Severity, SourceFile};

fn file(rel: &str, text: &str) -> SourceFile {
    SourceFile {
        rel: rel.to_string(),
        text: text.to_string(),
    }
}

fn run_one(rel: &str, text: &str) -> detlint::Report {
    analyze(&[file(rel, text)], "")
}

fn by_rule<'a>(report: &'a detlint::Report, rule: &str) -> Vec<&'a Finding> {
    report.findings.iter().filter(|f| f.rule == rule).collect()
}

// ------------------------------------------------------------- scanner

#[test]
fn strings_comments_and_chars_are_opaque() {
    let src = "\
const A: &str = \"HashMap in a cooked string\";\n\
const B: &str = r#\"HashSet \" and Instant::now() in a raw string\"#;\n\
/* block /* nested: thread::spawn */ still comment */\n\
const C: char = 'h';\n\
fn f<'a>(_x: &'a str) {}\n\
// line comment: std::env::var\n";
    let report = run_one("coordinator/x.rs", src);
    assert!(
        report.findings.is_empty(),
        "nothing should fire: {:?}",
        report.findings
    );
}

#[test]
fn raw_string_with_hashes_then_real_finding() {
    // The raw string must not desynchronize the scanner: the real
    // HashMap on line 2 is still found at line 2.
    let src = "const A: &str = r##\"quote \"# trap \"## ; \n\
               type T = std::collections::HashMap<u8, u8>;\n";
    let report = run_one("nas/x.rs", src);
    let hits = by_rule(&report, "unordered_collections");
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].line, 2);
}

// ------------------------------------------------------- rule triggers

#[test]
fn unordered_collections_only_in_deterministic_modules() {
    let src = "use std::collections::HashMap;\n";
    for module in [
        "coordinator/a.rs",
        "sim/a.rs",
        "nas/a.rs",
        "hpo/a.rs",
        "metrics/a.rs",
        "cluster/a.rs",
        "config/a.rs",
    ] {
        let report = run_one(module, src);
        assert_eq!(report.deny_count(), 1, "{module} must flag HashMap");
        assert!(report.failed());
    }
    // Outside the deterministic core the rule stays quiet.
    for module in ["runtime/client.rs", "distributed/a.rs", "util/a.rs"] {
        let report = run_one(module, src);
        assert_eq!(report.deny_count(), 0, "{module} must not flag HashMap");
    }
}

#[test]
fn wall_clock_flags_instant_now_and_system_time() {
    let src = "fn f() {\n\
               let t0 = std::time::Instant::now();\n\
               let s = std::time::SystemTime::UNIX_EPOCH;\n\
               let d: Instant = deadline;\n\
               }\n";
    let report = run_one("coordinator/a.rs", src);
    let hits = by_rule(&report, "wall_clock");
    // Instant::now on line 2, SystemTime on line 3 — a bare `Instant`
    // type annotation (line 4) is not a wall-clock *read*.
    assert_eq!(hits.len(), 2, "{hits:?}");
    assert_eq!(hits[0].line, 2);
    assert_eq!(hits[1].line, 3);
    // Runtime-facing modules are structurally exempt.
    let report = run_one("runtime/a.rs", src);
    assert!(by_rule(&report, "wall_clock").is_empty());
}

#[test]
fn thread_spawn_and_scope_flagged_outside_engine() {
    let src = "fn f() {\n\
               std::thread::spawn(|| {});\n\
               std::thread::scope(|s| { s.spawn(|| {}); });\n\
               }\n";
    let report = run_one("coordinator/a.rs", src);
    let hits = by_rule(&report, "thread_spawn");
    // spawn (line 2) and scope (line 3); `s.spawn` is a method call on
    // the scope handle, not a fresh ambient thread site.
    assert_eq!(hits.len(), 2, "{hits:?}");
    let report = run_one("sim/engine.rs", src);
    assert!(by_rule(&report, "thread_spawn").is_empty());
}

#[test]
fn env_read_flagged_outside_main() {
    let src = "fn f() { let p = std::env::temp_dir(); }\n";
    let report = run_one("util/a.rs", src);
    assert_eq!(by_rule(&report, "env_read").len(), 1);
    let report = run_one("main.rs", src);
    assert!(by_rule(&report, "env_read").is_empty());
    // `env!` (compile-time macro) is not an ambient read.
    let report = run_one("util/a.rs", "const D: &str = env!(\"CARGO_MANIFEST_DIR\");\n");
    assert!(by_rule(&report, "env_read").is_empty());
}

#[test]
fn float_fold_is_advisory_and_scoped() {
    let src = "fn f(v: &[f64]) -> f64 { v.iter().sum() }\n\
               fn g(v: &[f64]) -> f64 { v.iter().fold(0.0, |a, b| a + b) }\n";
    let report = run_one("metrics/score.rs", src);
    let hits = by_rule(&report, "float_fold");
    assert_eq!(hits.len(), 2, "{hits:?}");
    assert!(hits.iter().all(|f| f.severity == Severity::Advisory));
    // Advisory findings never fail the run.
    assert!(!report.failed());
    assert_eq!(report.advisory_count(), 2);
    // Outside the merge/score scope the pattern is not even advisory.
    let report = run_one("nas/search.rs", src);
    assert!(by_rule(&report, "float_fold").is_empty());
}

// ------------------------------------------------------------- pragmas

#[test]
fn pragma_suppresses_exactly_one_finding() {
    let src = "// detlint: allow(unordered_collections) — frozen after construction\n\
               use std::collections::HashMap;\n\
               type T = HashMap<u8, u8>;\n";
    let report = run_one("coordinator/a.rs", src);
    let hits = by_rule(&report, "unordered_collections");
    assert_eq!(hits.len(), 2, "{hits:?}"); // the `use` line + line 3
    let suppressed: Vec<_> = hits.iter().filter(|f| f.suppressed).collect();
    let live: Vec<_> = hits.iter().filter(|f| !f.suppressed).collect();
    // Line 2 (the pragma's next code line) is covered; line 3 is not.
    assert!(suppressed.iter().all(|f| f.line == 2));
    assert!(live.iter().all(|f| f.line == 3));
    assert!(!live.is_empty());
    assert!(report.failed(), "the uncovered finding still fails the run");
}

#[test]
fn same_line_pragma_and_wrapped_justification() {
    let src = "fn f() {\n\
               let t = std::time::Instant::now(); // detlint: allow(wall_clock) — UI timer\n\
               // detlint: allow(wall_clock) — a justification that wraps\n\
               // across a second comment line before the code it covers.\n\
               let u = std::time::Instant::now();\n\
               }\n";
    let report = run_one("coordinator/a.rs", src);
    assert_eq!(report.deny_count(), 0, "{:?}", report.findings);
    assert_eq!(report.suppressed_count(), 2);
    assert!(!report.failed());
}

#[test]
fn file_scope_pragma_covers_the_whole_file() {
    let src = "// detlint: allow-file(wall_clock) — live runtime path\n\
               fn a() { let t = std::time::Instant::now(); }\n\
               fn b() { let t = std::time::Instant::now(); }\n";
    let report = run_one("coordinator/live2.rs", src);
    assert_eq!(report.deny_count(), 0);
    assert_eq!(report.suppressed_count(), 2);
}

#[test]
fn pragma_without_justification_is_a_deny_finding() {
    let src = "// detlint: allow(wall_clock)\n\
               fn a() { let t = std::time::Instant::now(); }\n";
    let report = run_one("coordinator/a.rs", src);
    let bad = by_rule(&report, "bad_pragma");
    assert_eq!(bad.len(), 1, "{:?}", report.findings);
    assert!(bad[0].message.contains("justification"));
    // The malformed pragma suppresses nothing: the wall_clock finding
    // stays live too.
    assert!(report.deny_count() >= 2);
    assert!(report.failed());
}

#[test]
fn unknown_rule_is_a_bad_pragma() {
    let src = "// detlint: allow(determinisim) — typo'd rule name\nfn a() {}\n";
    let report = run_one("util/a.rs", src);
    let bad = by_rule(&report, "bad_pragma");
    assert_eq!(bad.len(), 1);
    assert!(bad[0].message.contains("unknown rule"));
    assert!(report.failed());
}

#[test]
fn unused_pragma_is_a_deny_finding() {
    let src = "// detlint: allow(wall_clock) — nothing here reads a clock\nfn a() {}\n";
    let report = run_one("util/a.rs", src);
    let unused = by_rule(&report, "unused_pragma");
    assert_eq!(unused.len(), 1, "{:?}", report.findings);
    assert_eq!(unused[0].line, 1);
    assert!(report.failed());
}

// --------------------------------------------------------- knob parity

/// A miniature `config/mod.rs`: four keys with distinct parity fates.
const CONFIG_FIXTURE: &str = "\
impl C {\n\
    pub fn from_text(s: &str) -> Result<Self, String> {\n\
        match key {\n\
            \"alpha\" => cfg.alpha = v,\n\
            \"beta\" => cfg.beta = v,\n\
            \"delta\" => cfg.delta = v,\n\
            \"gamma\" => cfg.gamma = v,\n\
            // detlint: allow(knob_key) — boolean value spelling, not a key\n\
            \"on\" | \"off\" => true,\n\
            _ => other,\n\
        }\n\
    }\n\
    pub fn to_text(&self) -> String {\n\
        format!(\"alpha = {}\\nbeta = {}\\ndelta = {}\\n\", self.alpha, self.beta, self.delta)\n\
    }\n\
}\n";

const USAGE_FIXTURE: &str = "\
# Usage\n\
| key | CLI | meaning |\n\
| --- | --- | --- |\n\
| `alpha` | `--alpha` | the alpha knob |\n\
| `beta` | \u{2014} | flagless by design |\n\
| `delta` | `--delta` | documents a flag main.rs does not have |\n";

const MAIN_FIXTURE: &str = "fn main() { let _a = \"alpha\"; }\n";

fn knob_report() -> detlint::Report {
    analyze(
        &[
            file("config/mod.rs", CONFIG_FIXTURE),
            file("main.rs", MAIN_FIXTURE),
        ],
        USAGE_FIXTURE,
    )
}

#[test]
fn knob_parity_catches_every_drift_class() {
    let report = knob_report();
    // gamma: parsed, never emitted, never documented.
    let to_text = by_rule(&report, "knob_to_text");
    assert_eq!(to_text.len(), 1, "{:?}", report.findings);
    assert!(to_text[0].message.contains("`gamma`"));
    assert_eq!(to_text[0].line, 7, "anchored at gamma's match arm");
    let docs = by_rule(&report, "knob_docs");
    assert_eq!(docs.len(), 1);
    assert!(docs[0].message.contains("`gamma`"));
    // delta: emitted + documented, but its documented flag is bogus.
    let cli = by_rule(&report, "knob_cli");
    assert_eq!(cli.len(), 1, "{:?}", report.findings);
    assert!(cli[0].message.contains("`delta`"));
    // alpha (real flag) and beta (explicit —) are clean.
    assert!(!report.findings.iter().any(|f| f.message.contains("`alpha`")));
    assert!(!report.findings.iter().any(|f| f.message.contains("`beta`")));
    // Boolean value spellings were excluded by the knob_key pragma…
    assert!(!report.findings.iter().any(|f| f.message.contains("`on`")));
    // …which therefore counts as used.
    assert!(by_rule(&report, "unused_pragma").is_empty());
    assert!(report.failed());
}

#[test]
fn clean_knob_surface_passes() {
    // Same fixture with gamma removed and delta's flag fixed: green.
    let config = CONFIG_FIXTURE.replace("            \"gamma\" => cfg.gamma = v,\n", "");
    let usage = USAGE_FIXTURE.replace("`--delta`", "\u{2014}");
    let report = analyze(
        &[file("config/mod.rs", config.as_str()), file("main.rs", MAIN_FIXTURE)],
        &usage,
    );
    assert_eq!(report.deny_count(), 0, "{:?}", report.findings);
    assert!(!report.failed());
}

#[test]
fn undocumented_new_key_fails_the_lint() {
    // The acceptance-criterion drill: adding a key to from_text without
    // to_text/USAGE.md coverage must fail.
    let config = CONFIG_FIXTURE.replace(
        "            \"alpha\" => cfg.alpha = v,\n",
        "            \"alpha\" => cfg.alpha = v,\n            \"zeta\" => cfg.zeta = v,\n",
    );
    let report = analyze(
        &[file("config/mod.rs", config.as_str()), file("main.rs", MAIN_FIXTURE)],
        USAGE_FIXTURE,
    );
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "knob_to_text" && f.message.contains("`zeta`")));
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "knob_docs" && f.message.contains("`zeta`")));
    assert!(report.failed());
}

#[test]
fn deleting_the_knob_key_pragma_fails() {
    // Without the pragma the boolean spellings become "keys" that are
    // neither emitted nor documented — deny findings, non-zero exit.
    let config = CONFIG_FIXTURE
        .replace("            // detlint: allow(knob_key) — boolean value spelling, not a key\n", "");
    let report = analyze(
        &[file("config/mod.rs", config.as_str()), file("main.rs", MAIN_FIXTURE)],
        USAGE_FIXTURE,
    );
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "knob_docs" && f.message.contains("`on`")));
    assert!(report.failed());
}
