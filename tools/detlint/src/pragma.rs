//! The `// detlint: allow(rule) — justification` pragma layer.
//!
//! Every suppression is scoped and self-documenting:
//!
//! * `// detlint: allow(RULE) — WHY` suppresses findings of `RULE` on
//!   the pragma's own line, or — when the pragma stands alone — on the
//!   next line that contains code (intervening comment-only lines, e.g.
//!   a wrapped justification, are skipped).
//! * `// detlint: allow-file(RULE) — WHY` suppresses `RULE` for the
//!   whole file.
//!
//! The justification is mandatory: a pragma without one is itself a
//! deny-severity `bad_pragma` finding, as is an unknown rule name or a
//! malformed spelling. A pragma that suppresses nothing is an
//! `unused_pragma` finding, so stale exceptions cannot rot in place.

use crate::scan::Comment;

/// Rules that may appear inside `allow(...)`.
pub const ALLOWABLE_RULES: &[&str] = &[
    "unordered_collections",
    "wall_clock",
    "thread_spawn",
    "env_read",
    "float_fold",
    "knob_key",
    "knob_to_text",
    "knob_docs",
    "knob_cli",
];

/// One parsed pragma.
#[derive(Debug, Clone)]
pub struct Pragma {
    /// Line of the comment carrying the pragma.
    pub line: usize,
    pub rule: String,
    /// `allow-file` rather than `allow`.
    pub file_scope: bool,
    pub justification: String,
    /// Set during analysis when the pragma suppresses (or, for
    /// `knob_key`, excludes) at least one thing.
    pub used: bool,
}

/// A comment that says `detlint:` but does not parse as a pragma.
#[derive(Debug, Clone)]
pub struct BadPragma {
    pub line: usize,
    pub why: String,
}

/// Extract pragmas (and malformed attempts) from a file's comments.
pub fn parse(comments: &[Comment]) -> (Vec<Pragma>, Vec<BadPragma>) {
    let mut pragmas = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        let Some(pos) = c.text.find("detlint:") else {
            continue;
        };
        let rest = c.text[pos + "detlint:".len()..].trim_start();
        match parse_one(rest) {
            Ok((rule, file_scope, justification)) => pragmas.push(Pragma {
                line: c.line,
                rule,
                file_scope,
                justification,
                used: false,
            }),
            Err(why) => bad.push(BadPragma { line: c.line, why }),
        }
    }
    (pragmas, bad)
}

/// Parse the text after `detlint:`; returns (rule, file_scope,
/// justification) or a human-readable reason it is malformed.
fn parse_one(rest: &str) -> Result<(String, bool, String), String> {
    let (file_scope, after) = if let Some(a) = rest.strip_prefix("allow-file") {
        (true, a)
    } else if let Some(a) = rest.strip_prefix("allow") {
        (false, a)
    } else {
        return Err(format!(
            "expected `allow(RULE)` or `allow-file(RULE)` after `detlint:`, got `{rest}`"
        ));
    };
    let after = after.trim_start();
    let inner = after
        .strip_prefix('(')
        .ok_or_else(|| "missing `(` after allow".to_string())?;
    let close = inner
        .find(')')
        .ok_or_else(|| "missing `)` after rule name".to_string())?;
    let rule = inner[..close].trim();
    if !ALLOWABLE_RULES.contains(&rule) {
        return Err(format!(
            "unknown rule `{rule}` (known: {})",
            ALLOWABLE_RULES.join(", ")
        ));
    }
    // Justification: everything after the `)`, minus separator dashes.
    let justification = inner[close + 1..]
        .trim_start()
        .trim_start_matches(['—', '-', ':'])
        .trim()
        .to_string();
    if justification.is_empty() {
        return Err(format!(
            "pragma for `{rule}` has no justification — write \
             `allow({rule}) — why this exception is sound`"
        ));
    }
    Ok((rule.to_string(), file_scope, justification))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan;

    fn parse_src(src: &str) -> (Vec<Pragma>, Vec<BadPragma>) {
        parse(&scan(src).comments)
    }

    #[test]
    fn well_formed_line_and_file_pragmas() {
        let (p, bad) = parse_src(
            "// detlint: allow(wall_clock) — live runtime path\n\
             // detlint: allow-file(thread_spawn) — protocol-owned ordering\n",
        );
        assert!(bad.is_empty());
        assert_eq!(p.len(), 2);
        assert!(!p[0].file_scope);
        assert_eq!(p[0].rule, "wall_clock");
        assert_eq!(p[0].justification, "live runtime path");
        assert!(p[1].file_scope);
    }

    #[test]
    fn missing_justification_is_bad() {
        let (p, bad) = parse_src("// detlint: allow(wall_clock)\n");
        assert!(p.is_empty());
        assert_eq!(bad.len(), 1);
        assert!(bad[0].why.contains("justification"), "{}", bad[0].why);
    }

    #[test]
    fn unknown_rule_is_bad() {
        let (p, bad) = parse_src("// detlint: allow(no_such_rule) — because\n");
        assert!(p.is_empty());
        assert_eq!(bad.len(), 1);
        assert!(bad[0].why.contains("unknown rule"), "{}", bad[0].why);
    }

    #[test]
    fn ascii_dash_separator_accepted() {
        let (p, bad) = parse_src("// detlint: allow(env_read) -- test scaffolding\n");
        assert!(bad.is_empty());
        assert_eq!(p[0].justification, "test scaffolding");
    }

    #[test]
    fn pragma_inside_string_literal_is_not_a_pragma() {
        let (p, bad) =
            parse_src("let s = \"// detlint: allow(wall_clock) — nope\";\n");
        assert!(p.is_empty());
        assert!(bad.is_empty());
    }
}
