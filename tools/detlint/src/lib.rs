//! detlint — in-tree determinism & knob-parity static analysis for the
//! `aiperf` sources.
//!
//! The benchmark's results are only meaningful because schedules are
//! bit-identical per seed; the dynamic gates (double-run byte diffs,
//! engine parity) catch a violation only after it has perturbed an RNG
//! stream. detlint catches the *class* statically: unordered-iteration
//! containers in deterministic modules, wall-clock reads, ad-hoc
//! threads, ambient `std::env`, float accumulation in merge/score
//! paths, and config keys that drift out of `to_text`/`USAGE.md`/CLI
//! parity. Exceptions exist, but each one must carry a scoped,
//! justified pragma (see [`pragma`]), so the exception list reads as
//! documentation.
//!
//! Run as `cargo run -p detlint --` (exit 1 on any unsuppressed
//! deny-severity finding) or with `--json FILE` for the machine-
//! readable report CI uploads.

#![forbid(unsafe_code)]

pub mod json;
pub mod knobs;
pub mod pragma;
pub mod rules;
pub mod scan;

use std::collections::BTreeSet;
use std::path::Path;

use pragma::Pragma;
use scan::Scan;

/// One input file: `rel` is the path relative to `rust/src` (always
/// forward-slashed), the unit every rule scope is written against.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub rel: String,
    pub text: String,
}

/// Finding severity: `Deny` affects the exit code; `Advisory` is
/// reported (and serialized) but never fails the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Deny,
    Advisory,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Advisory => "advisory",
        }
    }
}

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: usize,
    pub message: String,
    /// True when a pragma covers this finding.
    pub suppressed: bool,
}

impl Finding {
    pub fn new(
        rule: &'static str,
        severity: Severity,
        file: &str,
        line: usize,
        message: String,
    ) -> Self {
        Finding {
            rule,
            severity,
            file: file.to_string(),
            line,
            message,
            suppressed: false,
        }
    }
}

/// The analysis result.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    /// Findings not covered by a pragma.
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.suppressed)
    }

    /// Unsuppressed deny-severity findings — what fails the run.
    pub fn deny_count(&self) -> usize {
        self.unsuppressed()
            .filter(|f| f.severity == Severity::Deny)
            .count()
    }

    pub fn advisory_count(&self) -> usize {
        self.unsuppressed()
            .filter(|f| f.severity == Severity::Advisory)
            .count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    /// Exit policy: non-zero exactly when a deny finding survives.
    pub fn failed(&self) -> bool {
        self.deny_count() > 0
    }
}

/// One file's scan state during analysis.
pub struct FileScan {
    pub rel: String,
    pub scan: Scan,
    pub pragmas: Vec<Pragma>,
    /// Lines that contain at least one token — pragma targeting skips
    /// comment-only lines (wrapped justifications) to the next of these.
    pub code_lines: BTreeSet<usize>,
}

impl FileScan {
    fn new(rel: &str, text: &str) -> (Self, Vec<pragma::BadPragma>) {
        let scan = scan::scan(text);
        let (pragmas, bad) = pragma::parse(&scan.comments);
        let code_lines = scan.tokens.iter().map(|t| t.line).collect();
        (
            FileScan {
                rel: rel.to_string(),
                scan,
                pragmas,
                code_lines,
            },
            bad,
        )
    }

    /// The code line a line-scoped pragma applies to: its own line when
    /// that line has code, else the next line that does.
    fn pragma_target(&self, p: &Pragma) -> Option<usize> {
        if self.code_lines.contains(&p.line) {
            Some(p.line)
        } else {
            self.code_lines.range(p.line + 1..).next().copied()
        }
    }

    /// If a pragma for `rule` covers `line`, mark it used and report
    /// success. Line-scoped pragmas are tried before file-scoped ones.
    pub fn try_suppress(&mut self, rule: &str, line: usize) -> bool {
        let mut hit: Option<usize> = None;
        for (i, p) in self.pragmas.iter().enumerate() {
            if p.rule != rule {
                continue;
            }
            if !p.file_scope && self.pragma_target(p) == Some(line) {
                hit = Some(i);
                break;
            }
            if p.file_scope && hit.is_none() {
                hit = Some(i);
            }
        }
        match hit {
            Some(i) => {
                self.pragmas[i].used = true;
                true
            }
            None => false,
        }
    }
}

/// Analyze a set of sources plus the USAGE.md text.
pub fn analyze(files: &[SourceFile], usage_md: &str) -> Report {
    let mut scans: Vec<FileScan> = Vec::with_capacity(files.len());
    let mut findings: Vec<Finding> = Vec::new();

    for f in files {
        let (fs, bad) = FileScan::new(&f.rel, &f.text);
        for b in bad {
            findings.push(Finding::new(
                "bad_pragma",
                Severity::Deny,
                &f.rel,
                b.line,
                format!("malformed detlint pragma: {}", b.why),
            ));
        }
        scans.push(fs);
    }

    let mut raw: Vec<Finding> = Vec::new();
    for fs in &scans {
        raw.extend(rules::check(&fs.rel, &fs.scan));
    }

    // Knob parity runs when the config surface is part of the input set.
    if let Some(cfg_idx) = scans.iter().position(|f| f.rel == "config/mod.rs") {
        let main_literals: BTreeSet<String> = scans
            .iter()
            .find(|f| f.rel == "main.rs")
            .map(|f| {
                f.scan
                    .tokens
                    .iter()
                    .filter(|t| t.kind == scan::TokenKind::Str)
                    .map(|t| t.text.clone())
                    .collect()
            })
            .unwrap_or_default();
        raw.extend(knobs::check(&mut scans[cfg_idx], &main_literals, usage_md));
    }

    for mut f in raw {
        if let Some(fs) = scans.iter_mut().find(|s| s.rel == f.file) {
            f.suppressed = fs.try_suppress(f.rule, f.line);
        }
        findings.push(f);
    }

    for fs in &scans {
        for p in &fs.pragmas {
            if !p.used {
                findings.push(Finding::new(
                    "unused_pragma",
                    Severity::Deny,
                    &fs.rel,
                    p.line,
                    format!(
                        "pragma allow{}({}) suppresses nothing — delete it",
                        if p.file_scope { "-file" } else { "" },
                        p.rule
                    ),
                ));
            }
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Report {
        findings,
        files_scanned: files.len(),
    }
}

/// Load the real tree: every `rust/src/**/*.rs` (sorted, deterministic)
/// plus `USAGE.md`, from the repository root.
pub fn load_tree(root: &Path) -> std::io::Result<(Vec<SourceFile>, String)> {
    let base = root.join("rust").join("src");
    let mut files = Vec::new();
    walk(&base, &base, &mut files)?;
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    let usage = std::fs::read_to_string(root.join("USAGE.md"))?;
    Ok((files, usage))
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    let mut entries: Vec<std::fs::DirEntry> =
        std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            walk(base, &path, out)?;
        } else if path.extension().and_then(|s| s.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(base)
                .expect("walk stays under base")
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile {
                rel,
                text: std::fs::read_to_string(&path)?,
            });
        }
    }
    Ok(())
}
